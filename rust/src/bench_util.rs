//! Minimal micro-benchmark harness (no external criterion dependency):
//! warmup + timed iterations with mean / stddev / min reporting, and a
//! tiny table printer shared by the `benches/` targets.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.is_zero() {
            return f64::INFINITY;
        }
        1.0 / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} mean  {:>12} min  {:>10} sd  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.min),
            fmt_duration(self.stddev),
            self.iters
        )
    }
}

/// Human-friendly duration.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    summarize(name, &times)
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase takes roughly `budget`.
pub fn bench_auto<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // One probe run (also serves as warmup).
    let t = Instant::now();
    f();
    let probe = t.elapsed().max(Duration::from_nanos(50));
    let iters = (budget.as_secs_f64() / probe.as_secs_f64()).clamp(3.0, 10_000.0) as u32;
    bench(name, 1, iters, f)
}

fn summarize(name: &str, times: &[Duration]) -> BenchResult {
    let n = times.len().max(1) as f64;
    let mean_ns = times.iter().map(|t| t.as_nanos() as f64).sum::<f64>() / n;
    let var =
        times.iter().map(|t| (t.as_nanos() as f64 - mean_ns).powi(2)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: times.len() as u32,
        mean: Duration::from_nanos(mean_ns as u64),
        min: times.iter().min().copied().unwrap_or_default(),
        stddev: Duration::from_nanos(var.sqrt() as u64),
    }
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a flat JSON object of bench metrics (hand-rolled; no serde
/// dependency). String fields first, then numeric fields; non-finite
/// numbers are emitted as `null` to keep the file valid JSON.
pub fn write_metrics_json(
    path: &str,
    strings: &[(&str, &str)],
    numbers: &[(&str, f64)],
) -> std::io::Result<()> {
    let mut fields: Vec<String> = Vec::with_capacity(strings.len() + numbers.len());
    for (k, v) in strings {
        fields.push(format!("  \"{k}\": \"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")));
    }
    for (k, v) in numbers {
        if v.is_finite() {
            fields.push(format!("  \"{k}\": {v}"));
        } else {
            fields.push(format!("  \"{k}\": null"));
        }
    }
    std::fs::write(path, format!("{{\n{}\n}}\n", fields.join(",\n")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut calls = 0u32;
        let r = bench("noop", 2, 10, || calls += 1);
        assert_eq!(calls, 12);
        assert_eq!(r.iters, 10);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn fmt_durations() {
        assert!(fmt_duration(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).contains(" s"));
    }

    #[test]
    fn metrics_json_roundtrip_shape() {
        let path = std::env::temp_dir().join("dimsynth_bench_util_metrics.json");
        let path = path.to_str().unwrap();
        write_metrics_json(
            path,
            &[("design", "pend\"ulum")],
            &[("cycles_per_sec", 1.5e6), ("bad", f64::INFINITY)],
        )
        .unwrap();
        let body = std::fs::read_to_string(path).unwrap();
        std::fs::remove_file(path).ok();
        assert!(body.starts_with('{') && body.trim_end().ends_with('}'));
        assert!(body.contains("\"design\": \"pend\\\"ulum\""));
        assert!(body.contains("\"cycles_per_sec\": 1500000"));
        assert!(body.contains("\"bad\": null"));
    }

    #[test]
    fn auto_calibration_runs() {
        let r = bench_auto("fast", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }
}
