//! Offline / in-situ Φ calibration (paper Fig. 4, Steps 3–4), driven
//! entirely from Rust through the AOT train-step executables.
//!
//! The training loop lives here; the gradient computation lives in the
//! `phi_train_<system>` / `raw_train_<system>` artifacts lowered from JAX
//! (`python/compile/model.py::train_step`). Python is never invoked at
//! run time.

pub mod data;

pub use data::{build_dataset, Dataset, FeatureKind};

use crate::runtime::engine::{self, Engine};
use crate::stim::Lfsr32;

/// Hidden width of the Φ MLP — must match `python/compile/model.py`.
pub const HIDDEN: usize = 16;
/// Train-step batch size — must match `aot.py::TRAIN_BATCH`.
pub const TRAIN_BATCH: usize = 64;

/// Flat parameter count for an `in_dim -> 16 -> 16 -> 1` MLP.
pub fn param_count(in_dim: usize) -> usize {
    in_dim * HIDDEN + HIDDEN + HIDDEN * HIDDEN + HIDDEN + HIDDEN + 1
}

/// Initialize flat parameters (layout documented in model.py): scaled
/// normals for weights, zeros for biases. Uses the repo LFSR (Irwin–Hall
/// approximate normals) — initialization quality, not bit-compat, is
/// what matters here.
pub fn init_params(in_dim: usize, seed: u32) -> Vec<f32> {
    let mut rng = Lfsr32::new(seed);
    let mut normal = |scale: f32| -> f32 {
        let s: f64 = (0..4).map(|_| rng.next_f64()).sum();
        ((s - 2.0) * (3.0f64).sqrt() / 2.0) as f32 * scale
    };
    let mut p = Vec::with_capacity(param_count(in_dim));
    let s1 = (1.0 / in_dim.max(1) as f32).sqrt();
    for _ in 0..in_dim * HIDDEN {
        p.push(normal(s1));
    }
    p.extend(std::iter::repeat(0.0).take(HIDDEN));
    let s2 = (1.0 / HIDDEN as f32).sqrt();
    for _ in 0..HIDDEN * HIDDEN {
        p.push(normal(s2));
    }
    p.extend(std::iter::repeat(0.0).take(HIDDEN));
    for _ in 0..HIDDEN {
        p.push(normal(s2));
    }
    p.push(0.0);
    p
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    /// Final flat parameters.
    pub params: Vec<f32>,
    /// Loss after the final step (normalized target space).
    pub final_loss: f32,
    /// Validation RMSE in *raw* target units.
    pub val_rmse: f32,
    /// Steps executed.
    pub steps: u32,
    /// Loss after each step.
    pub loss_curve: Vec<f32>,
    /// The dataset the run used (for downstream serving).
    pub dataset: Dataset,
}

/// Artifact name for a feature kind.
pub fn train_artifact(system: &str, kind: FeatureKind) -> String {
    match kind {
        FeatureKind::Pi => format!("phi_train_{system}"),
        FeatureKind::Raw => format!("raw_train_{system}"),
    }
}

/// Inference artifact name for a feature kind (batch 64).
pub fn infer_artifact(system: &str, kind: FeatureKind) -> String {
    match kind {
        FeatureKind::Pi => format!("phi_infer_{system}_b64"),
        FeatureKind::Raw => format!("raw_infer_{system}_b64"),
    }
}

/// Draw one training batch (with replacement) from the dataset.
fn draw_batch(ds: &Dataset, rng: &mut Lfsr32) -> (Vec<f32>, Vec<f32>) {
    let rows = ds.train_rows();
    let mut x = Vec::with_capacity(TRAIN_BATCH * ds.dim);
    let mut y = Vec::with_capacity(TRAIN_BATCH);
    for _ in 0..TRAIN_BATCH {
        let i = rng.below(rows);
        x.extend_from_slice(&ds.train_x[i * ds.dim..(i + 1) * ds.dim]);
        y.push(ds.train_y[i]);
    }
    (x, y)
}

/// Run `steps` SGD steps on `params` in place, with linear lr decay from
/// `lr0` to `lr1` across the *global* schedule `[step0, total)`. Appends
/// per-step losses to `loss_curve`. This is the primitive both
/// [`train_on`] and checkpointed training loops (benches) build on.
#[allow(clippy::too_many_arguments)]
pub fn sgd_steps(
    eng: &mut Engine,
    ds: &Dataset,
    system: &str,
    params: &mut Vec<f32>,
    step0: u32,
    steps: u32,
    total: u32,
    lr0: f32,
    lr1: f32,
    rng: &mut Lfsr32,
    loss_curve: &mut Vec<f32>,
) -> anyhow::Result<f32> {
    let exe = eng.load(&train_artifact(system, ds.kind))?;
    let shift_l = engine::f32_vec(&ds.shift);
    let scale_l = engine::f32_vec(&ds.scale);
    let mut final_loss = f32::NAN;
    for s in 0..steps {
        let step = step0 + s;
        let frac = step as f32 / total.max(1) as f32;
        let lr_t = lr0 + (lr1 - lr0) * frac;
        let (bx, by) = draw_batch(ds, rng);
        let outs = exe.run(&[
            engine::f32_vec(params),
            engine::f32_matrix(TRAIN_BATCH, ds.dim, &bx)?,
            engine::f32_vec(&by),
            shift_l.clone(),
            scale_l.clone(),
            engine::f32_scalar(lr_t),
        ])?;
        *params = engine::to_f32s(&outs[0])?;
        final_loss = engine::to_f32s(&outs[1])?[0];
        loss_curve.push(final_loss);
    }
    Ok(final_loss)
}

/// Train on a pre-built dataset with an existing engine. Returns the
/// trained parameters and diagnostics.
pub fn train_on(
    eng: &mut Engine,
    ds: &Dataset,
    system: &str,
    steps: u32,
    lr: f32,
    seed: u32,
) -> anyhow::Result<TrainOutput> {
    let mut rng = Lfsr32::new(seed ^ 0x7A1E);
    let mut params = init_params(ds.dim, seed);
    let mut loss_curve = Vec::with_capacity(steps as usize);
    // Linear decay to 5% of the base rate: large early steps, a quiet
    // tail so the loss curve settles.
    let final_loss = sgd_steps(
        eng, ds, system, &mut params, 0, steps, steps, lr, 0.05 * lr, &mut rng,
        &mut loss_curve,
    )?;

    // Validation RMSE through the inference artifact (batch-padded).
    let val_rmse = validate(eng, ds, system, &params)?;
    Ok(TrainOutput {
        params,
        final_loss,
        val_rmse,
        steps,
        loss_curve,
        dataset: ds.clone(),
    })
}

/// Mean relative error of the *physical target parameter* on freshly
/// generated traces — the metric that makes Π-feature and raw-feature
/// models comparable (a Π model predicts Π₀ and inverts the monomial; a
/// raw model predicts the target directly).
pub fn eval_target_error(
    eng: &mut Engine,
    ds: &Dataset,
    system: &str,
    params: &[f32],
    n: usize,
    seed: u32,
) -> anyhow::Result<f64> {
    use crate::fixedpoint::{self, Q16_15};
    let exe = eng.load(&infer_artifact(system, ds.kind))?;
    let export = &ds.export;
    let mut rng = Lfsr32::new(seed ^ 0xE7A1);
    // Generate evaluation traces.
    let mut truths = Vec::with_capacity(n);
    let mut feats = Vec::with_capacity(n * ds.dim);
    let mut ports_q = Vec::with_capacity(n);
    for _ in 0..n {
        let s = crate::stim::sample(system, &mut rng)
            .ok_or_else(|| anyhow::anyhow!("no traces for `{system}`"))?;
        truths.push(s[export.target_index]);
        match ds.kind {
            FeatureKind::Pi => {
                let q: Vec<i64> =
                    export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
                let pis: Vec<i64> = export
                    .exponents
                    .iter()
                    .map(|e| fixedpoint::eval_monomial(Q16_15, &q, e))
                    .collect();
                if pis.len() > 1 {
                    for &p in &pis[1..] {
                        feats.push(Q16_15.to_f64(p) as f32);
                    }
                } else {
                    feats.push(1.0);
                }
                ports_q.push(q);
            }
            FeatureKind::Raw => {
                for (i, v) in s.iter().enumerate() {
                    if i != export.target_index {
                        feats.push(*v as f32);
                    }
                }
                ports_q.push(Vec::new());
            }
        }
    }
    // Batched inference.
    let mut rel_sum = 0f64;
    let mut cnt = 0usize;
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(TRAIN_BATCH);
        let mut x = vec![0f32; TRAIN_BATCH * ds.dim];
        x[..take * ds.dim].copy_from_slice(&feats[i * ds.dim..(i + take) * ds.dim]);
        let outs = exe.run(&[
            engine::f32_vec(params),
            engine::f32_matrix(TRAIN_BATCH, ds.dim, &x)?,
            engine::f32_vec(&ds.shift),
            engine::f32_vec(&ds.scale),
        ])?;
        let y_norm = engine::to_f32s(&outs[0])?;
        for j in 0..take {
            let pred_raw = (y_norm[j] * ds.y_scale + ds.y_shift) as f64;
            let est = match ds.kind {
                FeatureKind::Pi => {
                    export.recover_target(pred_raw, &ports_q[i + j], Q16_15)
                }
                FeatureKind::Raw => pred_raw,
            };
            let truth = truths[i + j];
            if est.is_finite() && truth.abs() > 1e-12 {
                rel_sum += ((est - truth) / truth).abs();
                cnt += 1;
            }
        }
        i += take;
    }
    Ok(rel_sum / cnt.max(1) as f64)
}

/// Validation RMSE in raw target units via the inference artifact.
pub fn validate(
    eng: &mut Engine,
    ds: &Dataset,
    system: &str,
    params: &[f32],
) -> anyhow::Result<f32> {
    let exe = eng.load(&infer_artifact(system, ds.kind))?;
    let shift_l = engine::f32_vec(&ds.shift);
    let scale_l = engine::f32_vec(&ds.scale);
    let rows = ds.val_rows();
    let mut se = 0f64;
    let mut i = 0usize;
    while i < rows {
        let take = (rows - i).min(TRAIN_BATCH);
        // Pad to the static batch.
        let mut x = vec![0f32; TRAIN_BATCH * ds.dim];
        x[..take * ds.dim]
            .copy_from_slice(&ds.val_x[i * ds.dim..(i + take) * ds.dim]);
        let outs = exe.run(&[
            engine::f32_vec(params),
            engine::f32_matrix(TRAIN_BATCH, ds.dim, &x)?,
            shift_l.clone(),
            scale_l.clone(),
        ])?;
        let preds = engine::to_f32s(&outs[0])?;
        for j in 0..take {
            let err = (preds[j] - ds.val_y[i + j]) as f64;
            se += err * err;
        }
        i += take;
    }
    // Denormalize: labels were standardized by y_scale.
    Ok(((se / rows as f64).sqrt() as f32) * ds.y_scale)
}

/// End-to-end convenience: build dataset, train, validate.
pub fn run_training(
    artifacts: &str,
    system: &str,
    kind: FeatureKind,
    steps: u32,
    seed: u32,
) -> anyhow::Result<TrainOutput> {
    let mut eng = Engine::new(artifacts)?;
    let ds = build_dataset(system, kind, 1024, 0.01, seed)?;
    train_on(&mut eng, &ds, system, steps, 0.2, seed)
}
