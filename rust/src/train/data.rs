//! Dataset construction for Φ calibration (paper Step 3, following
//! Wang et al. \[5\]).
//!
//! Builds supervised datasets from the physics-based synthetic traces:
//!
//! * **Π features** — signals are quantized to the hardware fixed-point
//!   format and pushed through the *same* monomial schedule the hardware
//!   executes (`fixedpoint::eval_monomial`), so training sees exactly the
//!   features the deployed sensor produces. Features are Π₁…Π_{N−1};
//!   the label is the target-isolating product Π₀.
//! * **Raw features** — the baseline: all signals except the target, in
//!   float, label = the raw target signal.

use crate::fixedpoint::{self, Q16_15};
use crate::report::export::{export_system, SystemExport};
use crate::stim::{self, Lfsr32};

/// Which feature space to train in.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FeatureKind {
    /// Dimensionless products from the synthesized hardware (the paper).
    Pi,
    /// Raw sensor signals (the baseline the paper improves on).
    Raw,
}

/// A standardized supervised dataset (train + validation split).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature dimension.
    pub dim: usize,
    /// Row-major features, training split.
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    /// Row-major features, validation split.
    pub val_x: Vec<f32>,
    pub val_y: Vec<f32>,
    /// Feature standardization (applied inside the AOT graph).
    pub shift: Vec<f32>,
    pub scale: Vec<f32>,
    /// Label standardization (applied by the trainer; labels stored
    /// normalized).
    pub y_shift: f32,
    pub y_scale: f32,
    /// System export used to build the features.
    pub export: SystemExport,
    pub kind: FeatureKind,
}

impl Dataset {
    pub fn train_rows(&self) -> usize {
        self.train_y.len()
    }

    pub fn val_rows(&self) -> usize {
        self.val_y.len()
    }
}

/// Raw (feature, label) extraction for one sample.
fn featurize(export: &SystemExport, kind: FeatureKind, sample: &[f64]) -> (Vec<f32>, f32) {
    match kind {
        FeatureKind::Pi => {
            // Quantize the participating signals in port order, run the
            // hardware-exact monomial schedules.
            let port_vals: Vec<i64> =
                export.ports.iter().map(|&si| Q16_15.from_f64(sample[si])).collect();
            let pis: Vec<i64> = export
                .exponents
                .iter()
                .map(|exps| fixedpoint::eval_monomial(Q16_15, &port_vals, exps))
                .collect();
            let y = Q16_15.to_f64(pis[0]) as f32;
            let feats: Vec<f32> = if pis.len() > 1 {
                pis[1..].iter().map(|&p| Q16_15.to_f64(p) as f32).collect()
            } else {
                vec![1.0]
            };
            (feats, y)
        }
        FeatureKind::Raw => {
            let feats: Vec<f32> = sample
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != export.target_index)
                .map(|(_, v)| *v as f32)
                .collect();
            (feats, sample[export.target_index] as f32)
        }
    }
}

/// Build a dataset of `n` samples with `noise` relative target noise and
/// an 80/20 train/val split.
pub fn build_dataset(
    system: &str,
    kind: FeatureKind,
    n: usize,
    noise: f64,
    seed: u32,
) -> anyhow::Result<Dataset> {
    let export = export_system(system, Q16_15)?;
    let mut rng = Lfsr32::new(seed);
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut ys: Vec<f32> = Vec::with_capacity(n);
    for _ in 0..n {
        let sample = stim::sample_noisy(system, &mut rng, noise)
            .ok_or_else(|| anyhow::anyhow!("no trace generator for `{system}`"))?;
        let (x, y) = featurize(&export, kind, &sample);
        xs.push(x);
        ys.push(y);
    }
    let dim = xs[0].len();

    // Standardize features and labels over the whole set.
    let mut shift = vec![0f32; dim];
    let mut scale = vec![0f32; dim];
    for d in 0..dim {
        let mean = xs.iter().map(|r| r[d]).sum::<f32>() / n as f32;
        let var = xs.iter().map(|r| (r[d] - mean).powi(2)).sum::<f32>() / n as f32;
        shift[d] = mean;
        scale[d] = var.sqrt().max(1e-6);
    }
    let y_mean = ys.iter().sum::<f32>() / n as f32;
    let y_var = ys.iter().map(|y| (y - y_mean).powi(2)).sum::<f32>() / n as f32;
    let y_shift = y_mean;
    let y_scale = y_var.sqrt().max(1e-6);

    let split = n * 4 / 5;
    let mut train_x = Vec::with_capacity(split * dim);
    let mut train_y = Vec::with_capacity(split);
    let mut val_x = Vec::with_capacity((n - split) * dim);
    let mut val_y = Vec::with_capacity(n - split);
    for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
        let yn = (y - y_shift) / y_scale;
        if i < split {
            train_x.extend_from_slice(x);
            train_y.push(yn);
        } else {
            val_x.extend_from_slice(x);
            val_y.push(yn);
        }
    }
    Ok(Dataset {
        dim,
        train_x,
        train_y,
        val_x,
        val_y,
        shift,
        scale,
        y_shift,
        y_scale,
        export,
        kind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::newton::corpus;

    #[test]
    fn pendulum_pi_dataset_labels_are_4pi2() {
        let ds = build_dataset("pendulum", FeatureKind::Pi, 200, 0.0, 7).unwrap();
        assert_eq!(ds.dim, 1); // N=1: constant feature
        // Labels normalized; the raw label mean must be ~4π² (quantized).
        let raw_mean = ds.y_shift;
        assert!(
            (raw_mean - 39.478).abs() < 0.5,
            "pendulum Π₀ mean = {raw_mean}"
        );
        // Variance of Π₀ is tiny (only quantization noise).
        assert!(ds.y_scale < 0.5, "y_scale {}", ds.y_scale);
    }

    #[test]
    fn beam_pi_dataset_is_linear() {
        // Beam: Π₀ = δ·? vs Π₁ — dimensional analysis makes the relation
        // linear (δ/L = (1/3)·FL²/EI); check correlation of feature 0
        // with label is near ±1.
        let ds = build_dataset("beam", FeatureKind::Pi, 400, 0.0, 9).unwrap();
        assert_eq!(ds.dim, 1);
        let n = ds.train_rows();
        let xs: Vec<f32> = (0..n).map(|i| ds.train_x[i * ds.dim]).collect();
        let mx = xs.iter().sum::<f32>() / n as f32;
        let my = ds.train_y.iter().sum::<f32>() / n as f32;
        let cov: f32 =
            xs.iter().zip(&ds.train_y).map(|(x, y)| (x - mx) * (y - my)).sum::<f32>();
        let vx: f32 = xs.iter().map(|x| (x - mx).powi(2)).sum::<f32>();
        let vy: f32 = ds.train_y.iter().map(|y| (y - my).powi(2)).sum::<f32>();
        let corr = cov / (vx.sqrt() * vy.sqrt()).max(1e-9);
        assert!(corr.abs() > 0.999, "correlation {corr}");
    }

    #[test]
    fn raw_dataset_dims() {
        let ds = build_dataset("pendulum", FeatureKind::Raw, 100, 0.0, 3).unwrap();
        assert_eq!(ds.dim, 3); // 4 symbols minus target
        assert_eq!(ds.train_rows(), 80);
        assert_eq!(ds.val_rows(), 20);
    }

    #[test]
    fn all_systems_build_both_kinds() {
        for e in corpus() {
            for kind in [FeatureKind::Pi, FeatureKind::Raw] {
                let ds = build_dataset(e.id, kind, 50, 0.01, 11).unwrap();
                assert!(ds.dim >= 1, "{}", e.id);
                assert!(ds.train_x.iter().all(|v| v.is_finite()));
                assert!(ds.train_y.iter().all(|v| v.is_finite()));
                assert!(ds.scale.iter().all(|s| *s > 0.0));
            }
        }
    }

    #[test]
    fn standardization_is_consistent() {
        let ds = build_dataset("beam", FeatureKind::Raw, 300, 0.0, 5).unwrap();
        // Standardized training features should have ~zero mean, ~unit std.
        for d in 0..ds.dim {
            let vals: Vec<f32> = (0..ds.train_rows())
                .map(|i| (ds.train_x[i * ds.dim + d] - ds.shift[d]) / ds.scale[d])
                .collect();
            let mean = vals.iter().sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 0.2, "dim {d} mean {mean}");
        }
    }
}
