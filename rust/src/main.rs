//! `dimsynth` — command-line driver for dimensional circuit synthesis.
//!
//! Subcommands (hand-rolled parsing; no external CLI dependency):
//!
//! ```text
//! dimsynth compile <system|file.nt> [--target <sym>] [--format Qi.f] [-o DIR]
//!     Run the compiler: Π-search report + generated Verilog + resource,
//!     timing and power reports for one system.
//! dimsynth table1 [--samples N]
//!     Regenerate the paper's Table 1 across the 7-system corpus.
//! dimsynth export-pisearch
//!     Emit the Π-search interchange JSON consumed by python/compile/aot.py.
//! dimsynth train <system> [--steps N] [--features pi|raw] [--artifacts DIR]
//!     Offline Φ calibration via the AOT train-step executable.
//! dimsynth serve <system> [--samples N] [--batch B] [--artifacts DIR]
//!     Run the in-sensor inference engine on a synthetic sensor stream.
//! dimsynth list
//!     List the corpus systems.
//! ```

use dimsynth::fixedpoint::{QFormat, Q16_15};
use dimsynth::newton::{self, corpus};
use dimsynth::pisearch;
use dimsynth::report;
use dimsynth::rtl::{self, Policy};
use dimsynth::synth;
use dimsynth::timing::{self, ICE40_LP};
use dimsynth::{coordinator, power, train};

use std::collections::HashMap;
use std::process::ExitCode;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else if let Some(name) = a.strip_prefix('-') {
            if i + 1 < args.len() {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn parse_format(s: &str) -> anyhow::Result<QFormat> {
    // "Q16.15" or "16.15"
    let s = s.trim_start_matches(['Q', 'q']);
    let (i, f) = s
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("format must look like Q16.15"))?;
    Ok(QFormat::new(i.parse()?, f.parse()?))
}

fn cmd_list() {
    println!("{:<24} {:<18} {:<40}", "id", "target", "description");
    for e in corpus() {
        println!("{:<24} {:<18} {:<40}", e.id, e.target, e.description);
    }
}

fn cmd_compile(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let what = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: dimsynth compile <system|file.nt>"))?;
    let q = flags
        .get("format")
        .map(|s| parse_format(s))
        .transpose()?
        .unwrap_or(Q16_15);

    // Resolve: corpus id or a .nt file on disk.
    let (model, target) = if let Some(e) = newton::by_id(what) {
        (newton::load_entry(&e)?, e.target.to_string())
    } else {
        let src = std::fs::read_to_string(what)?;
        let models = newton::load(&src)?;
        let model = models
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no invariant in {what}"))?;
        let target = flags
            .get("target")
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("--target required for .nt files"))?;
        (model, target)
    };

    let analysis = pisearch::analyze_optimized(&model, &target)?;
    println!("{analysis}");

    let design = rtl::build(&analysis, q);
    let verilog = rtl::verilog::emit(&design);
    let mapped = synth::map_design(&design);
    let t = timing::analyze(&mapped.netlist, &ICE40_LP);
    let act = power::measure_activity(&mapped.netlist, &design, 4, 0xACE1);

    println!("format:      {q}");
    println!("ports:       {}", design.num_inputs());
    println!("pi outputs:  {}", design.num_outputs());
    println!("latency:     {} cycles", rtl::module_latency(&design, Policy::ParallelPerPi));
    println!("LUT4 cells:  {}", mapped.lut4_cells);
    println!("gates:       {}", mapped.gate_count);
    println!("DFFs:        {}", mapped.dffs);
    println!("Fmax:        {:.2} MHz (depth {})", t.fmax_mhz, t.depth);
    println!(
        "power:       {:.2} mW @6MHz / {:.2} mW @12MHz",
        power::average_power_mw(&power::ICE40, &act, 6.0e6),
        power::average_power_mw(&power::ICE40, &act, 12.0e6)
    );

    if let Some(dir) = flags.get("o").or_else(|| flags.get("out")) {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.v", design.name);
        std::fs::write(&path, &verilog)?;
        println!("wrote {path}");
        // Self-checking testbench with golden vectors from the bit-exact
        // software model.
        let vectors = rtl::golden_vectors(&design, 16, 0x60D);
        let tb = rtl::emit_testbench(&design, &vectors);
        let tb_path = format!("{dir}/{}_tb.v", design.name);
        std::fs::write(&tb_path, tb)?;
        println!("wrote {tb_path} ({} golden vectors)", vectors.len());
        // Optional waveform of one gate-level activation.
        if flags.contains_key("vcd") {
            let mut sim = synth::GateSim::new(&mapped.netlist);
            let mut buses: Vec<String> =
                (0..design.num_outputs()).map(|u| format!("pi_{u}")).collect();
            buses.push("done".to_string());
            let bus_refs: Vec<&str> = buses.iter().map(String::as_str).collect();
            let mut rec = synth::VcdRecorder::new(&mapped.netlist, &bus_refs);
            for (p, gv) in design.ports.iter().zip(&vectors[1].inputs) {
                sim.set_bus(&format!("in_{}", p.name), *gv);
            }
            sim.set_bus("start", 1);
            sim.step();
            rec.capture(&sim);
            sim.set_bus("start", 0);
            while !sim.get_bit("done") {
                sim.step();
                rec.capture(&sim);
            }
            let vcd_path = format!("{dir}/{}.vcd", design.name);
            std::fs::write(&vcd_path, rec.render(&design.name))?;
            println!("wrote {vcd_path}");
        }
    }
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let samples: u32 = flags.get("samples").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let rows = report::generate_table(Q16_15, samples)?;
    print!("{}", report::render_markdown(&rows));
    Ok(())
}

fn cmd_export() -> anyhow::Result<()> {
    print!("{}", report::export_json(Q16_15)?);
    Ok(())
}

fn cmd_train(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let system = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: dimsynth train <system>"))?;
    let steps: u32 = flags.get("steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    let feats = match flags.get("features").map(String::as_str) {
        Some("raw") => train::FeatureKind::Raw,
        _ => train::FeatureKind::Pi,
    };
    let artifacts = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let out = train::run_training(&artifacts, system, feats, steps, 0xD1CE)?;
    println!(
        "trained {system} on {:?} features: {} steps, final loss {:.6}, val RMSE {:.5} ({} params)",
        feats,
        out.steps,
        out.final_loss,
        out.val_rmse,
        out.params.len()
    );
    Ok(())
}

fn cmd_serve(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let system = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: dimsynth serve <system>"))?;
    let samples: usize = flags.get("samples").map(|s| s.parse()).transpose()?.unwrap_or(2048);
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let artifacts = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let report = coordinator::serve_synthetic(&artifacts, system, samples, batch)?;
    println!("{report}");
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("usage: dimsynth <compile|table1|export-pisearch|train|serve|list> ...");
        return ExitCode::from(2);
    };
    let (pos, flags) = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "list" => {
            cmd_list();
            Ok(())
        }
        "compile" => cmd_compile(&pos, &flags),
        "table1" => cmd_table1(&flags),
        "export-pisearch" => cmd_export(),
        "train" => cmd_train(&pos, &flags),
        "serve" => cmd_serve(&pos, &flags),
        other => Err(anyhow::anyhow!("unknown subcommand `{other}`")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
