//! `dimsynth` — command-line driver for dimensional circuit synthesis.
//!
//! Subcommand names, positional signatures, flag allowlists, and help
//! text all live in one spec table ([`SUBCOMMANDS`]); `dimsynth help`
//! (or `--help`/`-h`) renders usage from it, and flag parsing validates
//! against it so a typo errors instead of being silently collected.
//! Run `dimsynth help` for the full generated reference; in short:
//!
//! ```text
//! dimsynth compile <system|file.nt> [--target SYM] [--format Qi.f] [--lanes N]
//!                  [-o DIR] [--vcd] [--cache-dir DIR]
//! dimsynth compile <a,b,c> --fuse [--shards K] [--cache-dir DIR]
//! dimsynth lint <system>|--all [--deny warnings] [--fuse --shards K]
//!               [--json] [--cache-dir DIR]
//! dimsynth table1 [--samples N] [--sequential] [--cache-dir DIR]
//! dimsynth cache <stats|gc|clear> --cache-dir DIR [--max-bytes N]
//! dimsynth export-pisearch
//! dimsynth train <system> [--steps N] [--features pi|raw] [--artifacts DIR]
//! dimsynth serve <system> [--samples N] [--batch B] [--artifacts DIR]
//! dimsynth serve --systems a,b,c [--cache-dir DIR] [--lanes N] [--power-flood N]
//!                [--fuse] [--shards K]
//! dimsynth serve --systems a,b,c --listen ADDR [--rate R] [--burst B]
//!                [--queue-cap N] [--deadline-ms D] [--max-conns N]
//!                [--dispatchers K] [--conn-rate R] [--scrape-addr ADDR]
//! dimsynth list
//! ```
//!
//! `serve --systems a,b,c` serves every named system from **one warm
//! `FlowSet`** behind the coordinator (`coordinator::ServeSet`): with
//! `--cache-dir` a restarted serve process boots with `recomputes=0`,
//! and power-request floods batch **across systems** through one
//! width-aware batcher.
//!
//! `serve --listen ADDR` puts the warm serve set behind a TCP front end
//! (`coordinator::net`): length-prefixed binary frames, one admission
//! tenant per served system (token bucket + bounded queue, tuned by
//! `--rate`/`--burst`/`--queue-cap`/`--deadline-ms`), typed shed and
//! deadline refusals on the wire, and a graceful drain on stdin EOF
//! that answers everything still queued before the report prints.
//! Admitted work is sharded across `--dispatchers K` parallel dispatch
//! lanes (default: half the cores, capped at the tenant count);
//! `--conn-rate R` adds a per-connection token bucket ahead of tenant
//! admission, and `--scrape-addr ADDR` exposes the live traffic report
//! as JSON over a one-shot HTTP GET endpoint.
//!
//! `--cache-dir DIR` attaches the persistent artifact store: compiled
//! stage artifacts are written to (and served from) `DIR`, so a second
//! invocation — even from another process — recomputes nothing. The
//! cache telemetry line goes to stderr (`cache: recomputes=… …`) so
//! stdout reports stay byte-identical between cold and warm runs.
//! `cache gc --max-bytes N` prunes the store oldest-first to a byte cap.
//!
//! `--lanes <64|256|512>` selects the SIMD lane width of word-parallel
//! simulation passes (see `synth::LaneWidth`; default 256); it enters
//! the flow config, and with it the power-stage cache fingerprint.
//!
//! `compile --fuse a,b,c` fuses the named corpus systems' netlists into
//! one module ([`dimsynth::shard`]) and reports the shard plan: member
//! namespaces and net ranges, per-shard gate balance, and cut-signal
//! counts. `serve --systems … --fuse` routes cross-system power floods
//! through one sharded evaluation of that fused module — bit-identical
//! to per-system dispatch, verified by the differential test suite.
//!
//! `lint <system>` (or `--all`) runs the multi-pass static verifier
//! ([`dimsynth::analyze`]) over the compiled artifacts: netlist lint,
//! Q-format interval analysis, dimensional re-check, and — with
//! `--fuse` — the shard-plan pre-flight of the fused module. Findings
//! print with stable `AN…` codes (`--json` for machine consumption);
//! the exit code is nonzero on any error-level finding, or on warnings
//! too under `--deny warnings`. The verifier is a memoized flow stage,
//! so a warm `--cache-dir` lint recomputes nothing.
//!
//! Every compilation subcommand drives the pipeline through the
//! [`dimsynth::flow`] session API; no stage-to-stage wiring lives here.

use dimsynth::fixedpoint::{QFormat, Q16_15};
use dimsynth::flow::{ensure_fused, ArtifactStore, Flow, FlowConfig, StageCounts, STORE_FORMAT_VERSION};
use dimsynth::newton::{self, corpus};
use dimsynth::report;
use dimsynth::synth::{self, LaneWidth, Netlist};
use dimsynth::{coordinator, train};

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

/// One flag a subcommand accepts.
struct FlagDef {
    name: &'static str,
    takes_value: bool,
    /// Metavariable shown in help (empty for boolean flags).
    value_name: &'static str,
    help: &'static str,
}

const fn flag(name: &'static str, value_name: &'static str, help: &'static str) -> FlagDef {
    FlagDef { name, takes_value: true, value_name, help }
}

const fn switch(name: &'static str, help: &'static str) -> FlagDef {
    FlagDef { name, takes_value: false, value_name: "", help }
}

/// One subcommand: its name, positional signature, one-line summary, and
/// flag allowlist. `--help` is generated from this table, and the parser
/// validates flags against it — one source of truth.
struct SubSpec {
    name: &'static str,
    /// Positional part of the usage line (e.g. `"<system|file.nt>"`).
    args: &'static str,
    summary: &'static str,
    flags: &'static [FlagDef],
}

const SUBCOMMANDS: &[SubSpec] = &[
    SubSpec {
        name: "compile",
        args: "<system|file.nt>",
        summary: "Π-search report + generated Verilog + resource/timing/power reports",
        flags: &[
            flag("target", "SYM", "target-symbol override (mandatory for .nt files)"),
            flag("format", "Qi.f", "fixed-point format, e.g. Q16.15"),
            flag("lanes", "N", "SIMD lane width for word-parallel simulation (64, 256, or 512; default 256)"),
            flag("o", "DIR", "write Verilog + self-checking testbench to DIR"),
            flag("out", "DIR", "alias of -o"),
            switch("vcd", "also record a gate-level waveform (needs -o)"),
            flag("cache-dir", "DIR", "attach the persistent artifact store at DIR"),
            switch("fuse", "positional is a,b,c corpus ids: fuse netlists, report the shard plan"),
            flag("shards", "K", "fuse: partition into K shards (default: cores, capped at 8)"),
        ],
    },
    SubSpec {
        name: "lint",
        args: "<system>",
        summary: "run the static verifier (dimsynth::analyze) and report its findings",
        flags: &[
            switch("all", "lint every corpus system (no positional)"),
            flag("deny", "warnings", "exit nonzero on warnings too (`--deny warnings`)"),
            switch("fuse", "also pre-flight the fused shard plan of the linted systems"),
            flag("shards", "K", "fuse: partition into K shards (default: cores, capped at 8)"),
            switch("json", "emit the report as JSON on stdout"),
            flag("format", "Qi.f", "fixed-point format, e.g. Q16.15"),
            flag("cache-dir", "DIR", "attach the persistent artifact store at DIR"),
        ],
    },
    SubSpec {
        name: "table1",
        args: "",
        summary: "regenerate the paper's Table 1 across the 7-system corpus",
        flags: &[
            flag("samples", "N", "stimulus activations per power measurement (default 4)"),
            switch("sequential", "drive the corpus on one thread (default: all cores)"),
            flag("cache-dir", "DIR", "attach the persistent artifact store at DIR"),
        ],
    },
    SubSpec {
        name: "cache",
        args: "<stats|gc|clear>",
        summary: "inspect, size-cap (gc), or clear a persistent artifact store",
        flags: &[
            flag("cache-dir", "DIR", "store root (required)"),
            flag("max-bytes", "N", "gc: prune oldest entries until the store fits N bytes"),
        ],
    },
    SubSpec {
        name: "export-pisearch",
        args: "",
        summary: "emit the Π-search interchange JSON consumed by python/compile/aot.py",
        flags: &[],
    },
    SubSpec {
        name: "train",
        args: "<system>",
        summary: "offline Φ calibration via the AOT train-step executable",
        flags: &[
            flag("steps", "N", "gradient steps (default 300)"),
            flag("features", "pi|raw", "feature kind (default pi)"),
            flag("artifacts", "DIR", "AOT artifact directory (default artifacts)"),
        ],
    },
    SubSpec {
        name: "serve",
        args: "<system>",
        summary: "run the in-sensor inference engine on a synthetic sensor stream",
        flags: &[
            flag("samples", "N", "stream length per system (default 2048; 0 skips Φ serving)"),
            flag("batch", "B", "serving batch size (default 64)"),
            flag("artifacts", "DIR", "AOT artifact directory (default artifacts)"),
            flag("systems", "a,b,c", "serve many systems from one warm FlowSet (no positional)"),
            flag("cache-dir", "DIR", "multi-system: boot the FlowSet warm from this store"),
            flag("lanes", "N", "multi-system: SIMD lane width of power batches (64, 256, or 512; default 256)"),
            flag("power-flood", "N", "multi-system: cross-system power requests (default 256)"),
            switch("fuse", "multi-system: power floods run on the fused multi-system netlist"),
            flag("shards", "K", "fuse: shard count for the fused evaluation (default: cores, capped at 8)"),
            flag("listen", "ADDR", "multi-system: serve over TCP at ADDR until stdin closes"),
            flag("rate", "R", "listen: per-tenant token-bucket rate, req/s (default unlimited)"),
            flag("burst", "B", "listen: per-tenant token-bucket burst (default 64)"),
            flag("queue-cap", "N", "listen: per-tenant bounded queue depth (default 1024)"),
            flag("deadline-ms", "D", "listen: default request deadline (default 1000)"),
            flag("max-conns", "N", "listen: cap concurrent connections; over-cap accepts get a typed shed"),
            flag("dispatchers", "K", "listen: parallel dispatch lanes (default: cores/2, capped at tenants)"),
            flag("conn-rate", "R", "listen: per-connection frame rate, req/s; over-rate frames get a typed shed"),
            flag("scrape-addr", "ADDR", "listen: serve the traffic report as JSON over HTTP GET at ADDR"),
        ],
    },
    SubSpec {
        name: "list",
        args: "",
        summary: "list the corpus systems",
        flags: &[],
    },
];

fn spec_of(cmd: &str) -> Option<&'static SubSpec> {
    SUBCOMMANDS.iter().find(|s| s.name == cmd)
}

/// Conventional rendering of a flag name: single-character names are
/// short flags (`-o`), the rest long (`--target`). The parser accepts
/// either dash count for any name.
fn flag_display(name: &str) -> String {
    if name.chars().count() == 1 {
        format!("-{name}")
    } else {
        format!("--{name}")
    }
}

/// One-line usage string of a subcommand, generated from its spec.
fn usage_line(spec: &SubSpec) -> String {
    let mut line = format!("dimsynth {}", spec.name);
    if !spec.args.is_empty() {
        line.push(' ');
        line.push_str(spec.args);
    }
    for f in spec.flags {
        if f.takes_value {
            line.push_str(&format!(" [{} {}]", flag_display(f.name), f.value_name));
        } else {
            line.push_str(&format!(" [{}]", flag_display(f.name)));
        }
    }
    line
}

/// The full `--help` text, generated from [`SUBCOMMANDS`].
fn render_help() -> String {
    let mut out = String::from(
        "dimsynth — dimensional circuit synthesis (Buckingham-Π hardware compiler)\n\nusage:\n",
    );
    for spec in SUBCOMMANDS {
        out.push_str(&format!("  {}\n      {}\n", usage_line(spec), spec.summary));
        for f in spec.flags {
            let head = if f.takes_value {
                format!("{} {}", flag_display(f.name), f.value_name)
            } else {
                flag_display(f.name)
            };
            out.push_str(&format!("      {head:<22} {}\n", f.help));
        }
    }
    out.push_str("  dimsynth help\n      print this reference\n");
    out
}

/// Open the persistent artifact store named by `--cache-dir`, if given.
fn open_store(flags: &HashMap<String, String>) -> anyhow::Result<Option<Arc<ArtifactStore>>> {
    flags.get("cache-dir").map(|dir| ArtifactStore::open(dir).map(Arc::new)).transpose()
}

/// Cache telemetry on stderr (stdout reports stay byte-identical between
/// cold and warm runs; CI greps this line for `recomputes=0`).
fn print_cache_line(counts: StageCounts) {
    eprintln!(
        "cache: recomputes={} disk_hits={} memory_hits={}",
        counts.recomputes(),
        counts.disk_hits,
        counts.memory_hits
    );
}

/// The flag name `arg` introduces, if any. Negative numerics (`-1`,
/// `-3.5`) and a bare `-` are positionals, not flags.
fn flag_name_of(arg: &str) -> Option<&str> {
    if let Some(name) = arg.strip_prefix("--") {
        return Some(name);
    }
    let name = arg.strip_prefix('-')?;
    match name.chars().next() {
        Some(c) if c.is_ascii_digit() || c == '.' => None,
        Some(_) => Some(name),
        None => None,
    }
}

/// Parse `args` into positionals and flags against the subcommand's spec.
/// Unknown flags and value-flags missing their value are errors; `--`
/// ends flag parsing. A value-taking flag consumes the next argument
/// verbatim (so `--samples -1` is an argument, later rejected by the
/// numeric parse, rather than a swallowed flag).
fn parse_args(
    args: &[String],
    spec: &SubSpec,
) -> anyhow::Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut only_positionals = false;
    let mut i = 0;
    while i < args.len() {
        let arg = &args[i];
        if !only_positionals && arg == "--" {
            only_positionals = true;
            i += 1;
            continue;
        }
        let name = if only_positionals { None } else { flag_name_of(arg) };
        let Some(name) = name else {
            pos.push(arg.clone());
            i += 1;
            continue;
        };
        let Some(def) = spec.flags.iter().find(|f| f.name == name) else {
            if spec.flags.is_empty() {
                anyhow::bail!("unknown flag `{arg}` (this subcommand takes no flags)");
            }
            let allowed: Vec<String> =
                spec.flags.iter().map(|f| flag_display(f.name)).collect();
            anyhow::bail!("unknown flag `{arg}` (allowed: {})", allowed.join(", "));
        };
        if def.takes_value {
            let Some(value) = args.get(i + 1) else {
                anyhow::bail!("flag `{arg}` requires a value");
            };
            flags.insert(def.name.to_string(), value.clone());
            i += 2;
        } else {
            flags.insert(def.name.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn parse_format(s: &str) -> anyhow::Result<QFormat> {
    // "Q16.15" or "16.15"
    let s = s.trim_start_matches(['Q', 'q']);
    let (i, f) = s
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("format must look like Q16.15"))?;
    Ok(QFormat::new(i.parse()?, f.parse()?))
}

fn cmd_list() {
    println!("{:<24} {:<18} {:<40}", "id", "target", "description");
    for e in corpus() {
        println!("{:<24} {:<18} {:<40}", e.id, e.target, e.description);
    }
}

/// Default shard count for `--fuse`: one per core, capped at 8 (the
/// per-level cut-signal exchange outgrows the parallel win beyond that
/// on corpus-sized members).
fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8)
}

/// `compile --fuse a,b,c`: compile each corpus member through its own
/// flow, fuse the mapped netlists into one module, partition it, and
/// report the shard plan (member namespaces, gate balance, cut counts).
fn cmd_compile_fused(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let what = pos.first().ok_or_else(|| {
        anyhow::anyhow!("usage: dimsynth compile <a,b,c> --fuse [--shards K] [--cache-dir DIR]")
    })?;
    // Fused mode reports the shard plan; the solo-compile emission flags
    // have no fused counterpart and would otherwise be silently ignored.
    for incompatible in ["target", "o", "out", "vcd"] {
        anyhow::ensure!(
            !flags.contains_key(incompatible),
            "--{incompatible} does not combine with --fuse (corpus defaults apply)"
        );
    }
    let systems: Vec<&str> = what.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    anyhow::ensure!(!systems.is_empty(), "--fuse needs at least one corpus system id");
    let q = flags.get("format").map(|s| parse_format(s)).transpose()?.unwrap_or(Q16_15);
    let lane_width =
        flags.get("lanes").map(|s| LaneWidth::parse(s)).transpose()?.unwrap_or_default();
    let shards: usize =
        flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or_else(default_shards);
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    let store = open_store(flags)?;

    // One flow per member; the mapped designs stay alive as Arcs so the
    // fuse step can borrow every netlist at once.
    let mut counts = StageCounts::default();
    let mut compiled = Vec::new();
    for sys in &systems {
        let e = newton::by_id(sys).ok_or_else(|| {
            anyhow::anyhow!("unknown corpus system `{sys}` (--fuse takes corpus ids; see dimsynth list)")
        })?;
        let config = FlowConfig { qformat: q, lane_width, ..FlowConfig::default() };
        let mut flow = Flow::for_entry(e, config);
        if let Some(store) = &store {
            flow.set_store(Arc::clone(store));
        }
        let design = flow.netlist_shared()?;
        counts = counts + flow.counts();
        compiled.push((flow.netlist_fingerprint(), design));
    }
    let members: Vec<(u64, &Netlist)> =
        compiled.iter().map(|(fp, m)| (*fp, &m.netlist)).collect();
    let art = ensure_fused(store.as_deref(), &members, shards);
    let plan = &art.plan;

    println!("fused {} systems into one module", art.fused.member_count());
    println!("{:<8} {:<24} {:>8} {:>16}", "prefix", "system", "gates", "nets");
    for (m, sys) in art.fused.members.iter().zip(&systems) {
        let (lo, hi) = m.net_range;
        println!("{:<8} {:<24} {:>8} {:>16}", m.prefix, sys, m.gates, format!("{lo}..{hi}"));
    }
    println!("nets:        {}", art.fused.netlist.len());
    println!("shards:      {} (gates per shard: {:?})", plan.shards, plan.shard_gates);
    println!(
        "cuts:        {} comb, {} reg, {} dff",
        plan.cuts.comb_cuts.len(),
        plan.cuts.reg_cuts.len(),
        plan.cuts.dff_cuts.len()
    );
    println!(
        "cut cost:    {} -> {} ({} cut words removed by {} refinement moves in {} sweeps)",
        plan.refinement.initial_cut_cost,
        plan.refinement.refined_cut_cost,
        plan.refinement.removed(),
        plan.refinement.cluster_moves + plan.refinement.level0_moves,
        plan.refinement.sweeps
    );
    if flags.contains_key("cache-dir") {
        print_cache_line(counts);
    }
    Ok(())
}

fn cmd_compile(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    if flags.contains_key("fuse") {
        return cmd_compile_fused(pos, flags);
    }
    anyhow::ensure!(!flags.contains_key("shards"), "--shards requires --fuse");
    let what = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: {}", usage_line(spec_of("compile").unwrap())))?;
    let q = flags
        .get("format")
        .map(|s| parse_format(s))
        .transpose()?
        .unwrap_or(Q16_15);
    let lane_width = flags
        .get("lanes")
        .map(|s| LaneWidth::parse(s))
        .transpose()?
        .unwrap_or_default();
    // `--target` overrides a corpus entry's default target and is
    // mandatory for .nt files (they carry no default).
    let config = FlowConfig {
        qformat: q,
        target: flags.get("target").cloned(),
        lane_width,
        ..FlowConfig::default()
    };

    // Resolve: corpus id or a .nt file on disk.
    let mut flow = if let Some(e) = newton::by_id(what) {
        Flow::for_entry(e, config)
    } else {
        let src = std::fs::read_to_string(what)?;
        let target = flags
            .get("target")
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("--target required for .nt files"))?;
        Flow::from_source(what, &src, &target, config)
    };
    if let Some(store) = open_store(flags)? {
        flow.set_store(store);
    }

    println!("{}", flow.pis()?);

    let (n_inputs, n_outputs, module_name) = {
        let design = flow.rtl()?;
        (design.num_inputs(), design.num_outputs(), design.name.clone())
    };
    let (lut4_cells, gate_count, dffs) = {
        let mapped = flow.netlist()?;
        (mapped.lut4_cells, mapped.gate_count, mapped.dffs)
    };
    let timing = flow.timing()?;
    let power = flow.power()?;

    println!("format:      {q}");
    println!("ports:       {n_inputs}");
    println!("pi outputs:  {n_outputs}");
    println!("latency:     {} cycles", flow.latency()?);
    println!("LUT4 cells:  {lut4_cells}");
    println!("gates:       {gate_count}");
    println!("DFFs:        {dffs}");
    println!("Fmax:        {:.2} MHz (depth {})", timing.fmax_mhz, timing.depth);
    println!(
        "power:       {:.2} mW @6MHz / {:.2} mW @12MHz",
        power.mw_6mhz, power.mw_12mhz
    );
    // Spread comes from the same cached word-parallel pass as the power
    // figures (lane 0 = the headline stimulus stream), so a warm
    // --cache-dir run prints it without simulating anything.
    let s = power.spread;
    println!(
        "power spread: {:.2}..{:.2} mW @6MHz over {} stimulus lanes (σ {:.3} mW)",
        s.min_mw(&power.model, 6.0e6),
        s.max_mw(&power.model, 6.0e6),
        s.lanes,
        s.std_mw(&power.model, 6.0e6)
    );

    if let Some(dir) = flags.get("o").or_else(|| flags.get("out")) {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{module_name}.v");
        std::fs::write(&path, flow.verilog()?)?;
        println!("wrote {path}");
        // Self-checking testbench with golden vectors from the bit-exact
        // software model.
        let design = flow.rtl()?.clone();
        let vectors = dimsynth::rtl::golden_vectors(&design, 16, 0x60D);
        let tb = dimsynth::rtl::emit_testbench(&design, &vectors);
        let tb_path = format!("{dir}/{module_name}_tb.v");
        std::fs::write(&tb_path, tb)?;
        println!("wrote {tb_path} ({} golden vectors)", vectors.len());
        // Optional waveform of one gate-level activation.
        if flags.contains_key("vcd") {
            let mapped = flow.netlist()?;
            let mut sim = synth::GateSim::new(&mapped.netlist);
            let mut buses: Vec<String> =
                (0..design.num_outputs()).map(|u| format!("pi_{u}")).collect();
            buses.push("done".to_string());
            let bus_refs: Vec<&str> = buses.iter().map(String::as_str).collect();
            let mut rec = synth::VcdRecorder::new(&mapped.netlist, &bus_refs);
            for (p, gv) in design.ports.iter().zip(&vectors[1].inputs) {
                sim.set_bus(&format!("in_{}", p.name), *gv);
            }
            sim.set_bus("start", 1);
            sim.step();
            rec.capture(&sim);
            sim.set_bus("start", 0);
            while !sim.get_bit("done") {
                sim.step();
                rec.capture(&sim);
            }
            let vcd_path = format!("{dir}/{module_name}.vcd");
            std::fs::write(&vcd_path, rec.render(&module_name))?;
            println!("wrote {vcd_path}");
        }
    }
    if flags.contains_key("cache-dir") {
        print_cache_line(flow.counts());
    }
    Ok(())
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `lint <system>` / `lint --all`: run the four-pass static verifier
/// over the compiled artifacts and report every finding. With `--fuse`
/// the shard-plan pre-flight additionally checks the fused plan the
/// serving path would run on. Exit is nonzero on any error-level
/// finding (and on warnings under `--deny warnings`), so CI can gate on
/// a clean corpus.
fn cmd_lint(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let deny_warnings = match flags.get("deny").map(String::as_str) {
        None => false,
        Some("warnings") => true,
        Some(other) => anyhow::bail!("--deny takes `warnings` (got `{other}`)"),
    };
    let q = flags.get("format").map(|s| parse_format(s)).transpose()?.unwrap_or(Q16_15);
    let entries = if flags.contains_key("all") {
        anyhow::ensure!(pos.is_empty(), "--all replaces the positional system argument");
        corpus()
    } else {
        let id = pos.first().ok_or_else(|| {
            anyhow::anyhow!("usage: {}", usage_line(spec_of("lint").unwrap()))
        })?;
        let e = newton::by_id(id)
            .ok_or_else(|| anyhow::anyhow!("unknown system `{id}` (see dimsynth list)"))?;
        vec![e]
    };
    let fuse = flags.contains_key("fuse");
    let shards: usize = if fuse {
        let k = flags
            .get("shards")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or_else(default_shards);
        anyhow::ensure!(k >= 1, "--shards must be at least 1");
        k
    } else {
        anyhow::ensure!(!flags.contains_key("shards"), "--shards requires --fuse");
        0
    };
    let store = open_store(flags)?;

    let mut counts = StageCounts::default();
    let mut reports = Vec::new();
    // Fusing borrows every member netlist at once; the Arcs keep the
    // mapped designs alive past their flows.
    let mut compiled = Vec::new();
    for e in &entries {
        let config = FlowConfig { qformat: q, ..FlowConfig::default() };
        let mut flow = Flow::for_entry(e.clone(), config);
        if let Some(store) = &store {
            flow.set_store(Arc::clone(store));
        }
        let report = flow.analysis()?;
        if fuse {
            compiled.push((flow.netlist_fingerprint(), flow.netlist_shared()?));
        }
        counts = counts + flow.counts();
        reports.push(report);
    }
    if fuse {
        let members: Vec<(u64, &Netlist)> =
            compiled.iter().map(|(fp, m)| (*fp, &m.netlist)).collect();
        let art = ensure_fused(store.as_deref(), &members, shards);
        let diagnostics =
            dimsynth::analyze::preflight_plan(&art.fused.netlist, &art.fused.members, &art.plan);
        reports.push(dimsynth::analyze::AnalysisReport {
            system: format!("fused({} members, {} shards)", entries.len(), art.plan.shards),
            diagnostics,
        });
    }

    let errors: usize = reports.iter().map(|r| r.errors()).sum();
    let warnings: usize = reports.iter().map(|r| r.warnings()).sum();

    if flags.contains_key("json") {
        let mut systems = Vec::new();
        for r in &reports {
            let diags: Vec<String> = r
                .diagnostics
                .iter()
                .map(|d| {
                    format!(
                        "{{\"code\":\"{}\",\"severity\":\"{}\",\"pass\":\"{}\",\
                         \"locus\":\"{}\",\"message\":\"{}\"}}",
                        d.code,
                        d.severity,
                        d.pass,
                        json_escape(&d.locus.to_string()),
                        json_escape(&d.message)
                    )
                })
                .collect();
            systems.push(format!(
                "{{\"system\":\"{}\",\"diagnostics\":[{}]}}",
                json_escape(&r.system),
                diags.join(",")
            ));
        }
        println!(
            "{{\"systems\":[{}],\"errors\":{errors},\"warnings\":{warnings}}}",
            systems.join(",")
        );
    } else {
        for r in &reports {
            if r.is_clean() {
                println!("{}: clean", r.system);
            } else {
                println!("{}: {} error(s), {} warning(s)", r.system, r.errors(), r.warnings());
                for d in &r.diagnostics {
                    println!("  {d}");
                }
            }
        }
        println!("lint: {} target(s), {errors} error(s), {warnings} warning(s)", reports.len());
    }
    // Memoization telemetry on stderr (CI greps `analyze stage:
    // recomputes=0` on the warm pass); the per-stage counter isolates
    // the verifier from its upstream compiles.
    eprintln!(
        "analyze stage: recomputes={} disk_hits={} memory_hits={}",
        counts.analyze, counts.disk_hits, counts.memory_hits
    );
    if errors > 0 {
        anyhow::bail!("lint found {errors} error(s)");
    }
    if deny_warnings && warnings > 0 {
        anyhow::bail!("lint found {warnings} warning(s) with --deny warnings");
    }
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let samples: u32 = flags.get("samples").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let store = open_store(flags)?;
    let (rows, counts) =
        report::generate_table_opts(Q16_15, samples, store, flags.contains_key("sequential"))?;
    print!("{}", report::render_markdown(&rows));
    if flags.contains_key("cache-dir") {
        print_cache_line(counts);
    }
    Ok(())
}

fn cmd_cache(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let action = pos.first().map(String::as_str).unwrap_or("stats");
    let dir = flags.get("cache-dir").ok_or_else(|| {
        anyhow::anyhow!("usage: {}", usage_line(spec_of("cache").unwrap()))
    })?;
    // The spec-table allowlist is shared by all cache actions; reject
    // action/flag combinations that would otherwise be silently ignored
    // (e.g. `cache clear --max-bytes N` from a user who meant `gc`).
    if action != "gc" && flags.contains_key("max-bytes") {
        anyhow::bail!("--max-bytes only applies to `cache gc` (got action `{action}`)");
    }
    let store = ArtifactStore::open(dir)?;
    match action {
        "stats" => {
            let stats = store.stats()?;
            println!("{:<10} {:>8} {:>12}", "stage", "entries", "bytes");
            for s in &stats.stages {
                println!("{:<10} {:>8} {:>12}", s.stage, s.entries, s.bytes);
            }
            println!(
                "{:<10} {:>8} {:>12}",
                "total",
                stats.total_entries(),
                stats.total_bytes()
            );
            println!(
                "format version {STORE_FORMAT_VERSION} at {}",
                store.root().display()
            );
        }
        "gc" => {
            let max_bytes: u64 = flags
                .get("max-bytes")
                .ok_or_else(|| anyhow::anyhow!("cache gc requires --max-bytes N"))?
                .parse()?;
            let report = store.gc(max_bytes)?;
            println!(
                "gc: removed {} entries ({} bytes), kept {} entries ({} bytes) under cap {max_bytes} at {}",
                report.removed_entries,
                report.removed_bytes,
                report.kept_entries,
                report.kept_bytes,
                store.root().display()
            );
        }
        "clear" => {
            let removed = store.clear()?;
            println!("cleared {removed} entries from {}", store.root().display());
        }
        other => anyhow::bail!("unknown cache action `{other}` (use stats, gc, or clear)"),
    }
    Ok(())
}

fn cmd_export() -> anyhow::Result<()> {
    print!("{}", report::export_json(Q16_15)?);
    Ok(())
}

fn cmd_train(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let system = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: {}", usage_line(spec_of("train").unwrap())))?;
    let steps: u32 = flags.get("steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    let feats = match flags.get("features").map(String::as_str) {
        Some("raw") => train::FeatureKind::Raw,
        _ => train::FeatureKind::Pi,
    };
    let artifacts = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let out = train::run_training(&artifacts, system, feats, steps, 0xD1CE)?;
    println!(
        "trained {system} on {:?} features: {} steps, final loss {:.6}, val RMSE {:.5} ({} params)",
        feats,
        out.steps,
        out.final_loss,
        out.val_rmse,
        out.params.len()
    );
    Ok(())
}

fn cmd_serve(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let samples: usize = flags.get("samples").map(|s| s.parse()).transpose()?.unwrap_or(2048);
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let artifacts = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());

    // Multi-system mode: every endpoint serves from one warm FlowSet
    // (shared artifact graph + cross-system power batching).
    if let Some(csv) = flags.get("systems") {
        let systems: Vec<&str> = csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        anyhow::ensure!(!systems.is_empty(), "--systems needs at least one system id");
        anyhow::ensure!(
            pos.is_empty(),
            "--systems replaces the positional system argument"
        );
        let lane_width = flags
            .get("lanes")
            .map(|s| LaneWidth::parse(s))
            .transpose()?
            .unwrap_or_default();
        let flood: usize =
            flags.get("power-flood").map(|s| s.parse()).transpose()?.unwrap_or(256);
        let fuse_shards: usize = if flags.contains_key("fuse") {
            let k = flags
                .get("shards")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or_else(default_shards);
            anyhow::ensure!(k >= 1, "--shards must be at least 1");
            k
        } else {
            anyhow::ensure!(!flags.contains_key("shards"), "--shards requires --fuse");
            0
        };
        let config = FlowConfig { lane_width, ..FlowConfig::default() };
        let store = open_store(flags)?;

        // Network mode: put the full serving stack (TCP frontend →
        // admission control → fair dispatch) in front of the warm set
        // and run until stdin closes (the conventional daemon idiom —
        // `dimsynth serve ... --listen ADDR < /dev/null` exits after
        // draining).
        if let Some(listen) = flags.get("listen") {
            let listen_config = coordinator::ListenConfig {
                rate_per_sec: flags
                    .get("rate")
                    .map(|s| s.parse::<f64>())
                    .transpose()?
                    .unwrap_or(f64::INFINITY),
                burst: flags.get("burst").map(|s| s.parse()).transpose()?.unwrap_or(64.0),
                queue_cap: flags.get("queue-cap").map(|s| s.parse()).transpose()?.unwrap_or(1024),
                deadline_ms: flags
                    .get("deadline-ms")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(1000),
                max_conns: flags
                    .get("max-conns")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(0),
                fuse_shards,
                dispatchers: flags
                    .get("dispatchers")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(0),
                conn_rate: flags
                    .get("conn-rate")
                    .map(|s| s.parse::<f64>())
                    .transpose()?
                    .unwrap_or(f64::INFINITY),
                scrape_addr: flags.get("scrape-addr").cloned(),
            };
            let handle =
                coordinator::serve_listen(&systems, listen, config, store, listen_config)?;
            print!("{}", handle.boot);
            if flags.contains_key("cache-dir") {
                print_cache_line(handle.counts);
            }
            // Block until the controlling stream closes, then drain.
            let mut sink = String::new();
            let _ = std::io::Read::read_to_string(&mut std::io::stdin(), &mut sink);
            // Stop answering scrapes before the drain so the endpoint
            // never serves a half-drained report.
            if let Some(scrape) = handle.scrape {
                scrape.shutdown();
            }
            let report = handle.server.shutdown();
            print!("{report}");
            anyhow::ensure!(!report.engine_panicked, "traffic engine panicked");
            return Ok(());
        }

        anyhow::ensure!(
            !flags.contains_key("max-conns"),
            "--max-conns requires --listen (it caps TCP connections)"
        );
        for listen_only in ["dispatchers", "conn-rate", "scrape-addr"] {
            anyhow::ensure!(
                !flags.contains_key(listen_only),
                "--{listen_only} requires --listen (it configures the TCP serving stack)"
            );
        }
        let (report, counts) = coordinator::serve_multi(
            &artifacts, &systems, samples, batch, flood, fuse_shards, config, store,
        )?;
        print!("{report}");
        if flags.contains_key("cache-dir") {
            print_cache_line(counts);
        }
        return Ok(());
    }

    let multi_only_flags = [
        "cache-dir",
        "lanes",
        "power-flood",
        "fuse",
        "shards",
        "listen",
        "rate",
        "burst",
        "queue-cap",
        "deadline-ms",
        "max-conns",
        "dispatchers",
        "conn-rate",
        "scrape-addr",
    ];
    for multi_only in multi_only_flags {
        anyhow::ensure!(
            !flags.contains_key(multi_only),
            "--{multi_only} requires --systems (multi-system serving)"
        );
    }
    let system = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: {}", usage_line(spec_of("serve").unwrap())))?;
    let report = coordinator::serve_synthetic(&artifacts, system, samples, batch)?;
    println!("{report}");
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        let names: Vec<&str> = SUBCOMMANDS.iter().map(|s| s.name).collect();
        eprintln!("usage: dimsynth <{}> ... (dimsynth help for details)", names.join("|"));
        return ExitCode::from(2);
    };
    if matches!(cmd.as_str(), "help" | "--help" | "-h") {
        print!("{}", render_help());
        return ExitCode::SUCCESS;
    }
    // Validate the subcommand before flag parsing, so a typo'd command
    // reports "unknown subcommand", not a misleading flag error.
    let result = match spec_of(cmd) {
        None => Err(anyhow::anyhow!("unknown subcommand `{cmd}` (dimsynth help for details)")),
        Some(spec) => parse_args(&args[1..], spec).and_then(|(pos, flags)| match spec.name {
            "list" => {
                cmd_list();
                Ok(())
            }
            "compile" => cmd_compile(&pos, &flags),
            "lint" => cmd_lint(&pos, &flags),
            "table1" => cmd_table1(&flags),
            "cache" => cmd_cache(&pos, &flags),
            "export-pisearch" => cmd_export(),
            "train" => cmd_train(&pos, &flags),
            "serve" => cmd_serve(&pos, &flags),
            _ => unreachable!("subcommand validated above"),
        }),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
