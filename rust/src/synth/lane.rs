//! The SIMD lane-word abstraction of the bit-parallel simulation engine.
//!
//! [`super::wordsim::WordSim`] packs one independent stimulus stream per
//! *bit* of a machine word; every per-net operation is a handful of
//! bitwise word ops. This module makes the engine generic over that word
//! through the [`LaneWord`] trait, with two implementations:
//!
//! * **`u64`** — the original 64-lane engine (one general-purpose
//!   register per net value);
//! * **[`W256`]** — four `u64`s evaluated as one 256-lane value. All of
//!   its operations are straight-line per-element array ops with no
//!   branches or cross-element dependencies, exactly the shape LLVM
//!   auto-vectorizes to one AVX2 op (or two SSE2/NEON ops) per logical
//!   word op, so the 4× lane count costs far less than 4× the time;
//! * **[`W512`]** — eight `u64`s as one 512-lane value, the same
//!   straight-line shape at AVX-512 width (or two AVX2 ops per logical
//!   word op on narrower machines).
//!
//! The hot mux-tree evaluation in `wordsim` is already pure
//! and/or/xor/not over whole words, so widening the engine is a type
//! substitution there; what this trait additionally pins down is the
//! *bookkeeping* surface the rest of the repo leans on — per-lane bit
//! extraction/insertion (stimulus packing, output readback), population
//! counts (toggle counting), and set-lane iteration (exact per-lane
//! differential counters).
//!
//! Lane-width selection is a runtime knob in most of the repo
//! ([`LaneWidth`], carried by `flow::FlowConfig` and the CLI `--lanes`
//! flag); monomorphized call paths dispatch on it once at the top.

use std::fmt;
use std::ops::{BitAnd, BitOr, BitXor, Not};

/// One SIMD word of independent boolean simulation lanes (bit *l* =
/// lane *l*).
///
/// Implementations must behave as a fixed-width bit vector of
/// [`LaneWord::LANES`] bits: the bitwise operators act lane-wise, and
/// the lane accessors index bits little-endian (lane 0 first). All ops
/// must be branch-free straight-line code — the simulator's inner loop
/// relies on them vectorizing.
pub trait LaneWord:
    Copy
    + PartialEq
    + Eq
    + Send
    + Sync
    + fmt::Debug
    + BitAnd<Output = Self>
    + BitOr<Output = Self>
    + BitXor<Output = Self>
    + Not<Output = Self>
    + 'static
{
    /// Number of independent simulation lanes carried per word.
    const LANES: usize;

    /// All lanes 0.
    fn zero() -> Self;

    /// All lanes 1.
    fn ones() -> Self;

    /// Broadcast one boolean to every lane.
    #[inline(always)]
    fn splat(bit: bool) -> Self {
        if bit {
            Self::ones()
        } else {
            Self::zero()
        }
    }

    /// Total set lanes (word-parallel toggle counting).
    fn count_ones(self) -> u32;

    /// Whether every lane is 0 (the "nothing toggled" fast path).
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == Self::zero()
    }

    /// Extract one lane's bit.
    fn lane(self, lane: usize) -> bool;

    /// Insert one lane's bit.
    fn set_lane(&mut self, lane: usize, v: bool);

    /// Call `f` with the index of every set lane, ascending.
    fn for_each_set_lane(self, f: impl FnMut(usize));
}

impl LaneWord for u64 {
    const LANES: usize = 64;

    #[inline(always)]
    fn zero() -> u64 {
        0
    }

    #[inline(always)]
    fn ones() -> u64 {
        !0
    }

    #[inline(always)]
    fn count_ones(self) -> u32 {
        u64::count_ones(self)
    }

    #[inline(always)]
    fn lane(self, lane: usize) -> bool {
        debug_assert!(lane < 64);
        self >> lane & 1 == 1
    }

    #[inline(always)]
    fn set_lane(&mut self, lane: usize, v: bool) {
        debug_assert!(lane < 64);
        *self = (*self & !(1u64 << lane)) | (u64::from(v) << lane);
    }

    #[inline]
    fn for_each_set_lane(self, mut f: impl FnMut(usize)) {
        let mut rest = self;
        while rest != 0 {
            f(rest.trailing_zeros() as usize);
            rest &= rest - 1;
        }
    }
}

/// A 256-lane SIMD word: four `u64`s treated as one 256-bit value
/// (element *k* holds lanes `64k..64k+63`). Every operator is a
/// straight-line four-element array op, which auto-vectorizes to AVX2 /
/// NEON on release builds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct W256(pub [u64; 4]);

impl BitAnd for W256 {
    type Output = W256;

    #[inline(always)]
    fn bitand(self, o: W256) -> W256 {
        let a = self.0;
        let b = o.0;
        W256([a[0] & b[0], a[1] & b[1], a[2] & b[2], a[3] & b[3]])
    }
}

impl BitOr for W256 {
    type Output = W256;

    #[inline(always)]
    fn bitor(self, o: W256) -> W256 {
        let a = self.0;
        let b = o.0;
        W256([a[0] | b[0], a[1] | b[1], a[2] | b[2], a[3] | b[3]])
    }
}

impl BitXor for W256 {
    type Output = W256;

    #[inline(always)]
    fn bitxor(self, o: W256) -> W256 {
        let a = self.0;
        let b = o.0;
        W256([a[0] ^ b[0], a[1] ^ b[1], a[2] ^ b[2], a[3] ^ b[3]])
    }
}

impl Not for W256 {
    type Output = W256;

    #[inline(always)]
    fn not(self) -> W256 {
        let a = self.0;
        W256([!a[0], !a[1], !a[2], !a[3]])
    }
}

impl LaneWord for W256 {
    const LANES: usize = 256;

    #[inline(always)]
    fn zero() -> W256 {
        W256([0; 4])
    }

    #[inline(always)]
    fn ones() -> W256 {
        W256([!0; 4])
    }

    #[inline(always)]
    fn count_ones(self) -> u32 {
        let a = self.0;
        a[0].count_ones() + a[1].count_ones() + a[2].count_ones() + a[3].count_ones()
    }

    #[inline(always)]
    fn is_zero(self) -> bool {
        let a = self.0;
        (a[0] | a[1] | a[2] | a[3]) == 0
    }

    #[inline(always)]
    fn lane(self, lane: usize) -> bool {
        debug_assert!(lane < 256);
        self.0[lane >> 6] >> (lane & 63) & 1 == 1
    }

    #[inline(always)]
    fn set_lane(&mut self, lane: usize, v: bool) {
        debug_assert!(lane < 256);
        let w = &mut self.0[lane >> 6];
        let bit = lane & 63;
        *w = (*w & !(1u64 << bit)) | (u64::from(v) << bit);
    }

    #[inline]
    fn for_each_set_lane(self, mut f: impl FnMut(usize)) {
        for (k, &word) in self.0.iter().enumerate() {
            let mut rest = word;
            while rest != 0 {
                f((k << 6) + rest.trailing_zeros() as usize);
                rest &= rest - 1;
            }
        }
    }
}

/// A 512-lane SIMD word: eight `u64`s treated as one 512-bit value
/// (element *k* holds lanes `64k..64k+63`). Like [`W256`], every
/// operator is a straight-line per-element array op — one AVX-512 op
/// (or two AVX2 ops) per logical word op on release builds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct W512(pub [u64; 8]);

impl BitAnd for W512 {
    type Output = W512;

    #[inline(always)]
    fn bitand(self, o: W512) -> W512 {
        let mut out = [0u64; 8];
        for k in 0..8 {
            out[k] = self.0[k] & o.0[k];
        }
        W512(out)
    }
}

impl BitOr for W512 {
    type Output = W512;

    #[inline(always)]
    fn bitor(self, o: W512) -> W512 {
        let mut out = [0u64; 8];
        for k in 0..8 {
            out[k] = self.0[k] | o.0[k];
        }
        W512(out)
    }
}

impl BitXor for W512 {
    type Output = W512;

    #[inline(always)]
    fn bitxor(self, o: W512) -> W512 {
        let mut out = [0u64; 8];
        for k in 0..8 {
            out[k] = self.0[k] ^ o.0[k];
        }
        W512(out)
    }
}

impl Not for W512 {
    type Output = W512;

    #[inline(always)]
    fn not(self) -> W512 {
        let mut out = [0u64; 8];
        for k in 0..8 {
            out[k] = !self.0[k];
        }
        W512(out)
    }
}

impl LaneWord for W512 {
    const LANES: usize = 512;

    #[inline(always)]
    fn zero() -> W512 {
        W512([0; 8])
    }

    #[inline(always)]
    fn ones() -> W512 {
        W512([!0; 8])
    }

    #[inline(always)]
    fn count_ones(self) -> u32 {
        let mut n = 0u32;
        for k in 0..8 {
            n += self.0[k].count_ones();
        }
        n
    }

    #[inline(always)]
    fn is_zero(self) -> bool {
        let a = self.0;
        (a[0] | a[1] | a[2] | a[3] | a[4] | a[5] | a[6] | a[7]) == 0
    }

    #[inline(always)]
    fn lane(self, lane: usize) -> bool {
        debug_assert!(lane < 512);
        self.0[lane >> 6] >> (lane & 63) & 1 == 1
    }

    #[inline(always)]
    fn set_lane(&mut self, lane: usize, v: bool) {
        debug_assert!(lane < 512);
        let w = &mut self.0[lane >> 6];
        let bit = lane & 63;
        *w = (*w & !(1u64 << bit)) | (u64::from(v) << bit);
    }

    #[inline]
    fn for_each_set_lane(self, mut f: impl FnMut(usize)) {
        for (k, &word) in self.0.iter().enumerate() {
            let mut rest = word;
            while rest != 0 {
                f((k << 6) + rest.trailing_zeros() as usize);
                rest &= rest - 1;
            }
        }
    }
}

/// Runtime lane-width selector for code paths that dispatch between the
/// monomorphized engines (CLI `--lanes`, `flow::FlowConfig::lane_width`,
/// the coordinator's power-request chunking).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum LaneWidth {
    /// One `u64` per net value: 64 streams per pass.
    W64,
    /// One [`W256`] per net value: 256 streams per pass. The default:
    /// on corpus-sized netlists the 4×-wider pass amortizes scheduling
    /// and cut-exchange overhead with no measurable per-stream cost,
    /// and every result is bit-identical across widths anyway.
    #[default]
    W256,
    /// One [`W512`] per net value: 512 streams per pass.
    W512,
}

impl LaneWidth {
    /// Streams simulated per pass at this width.
    pub const fn lanes(self) -> usize {
        match self {
            LaneWidth::W64 => 64,
            LaneWidth::W256 => 256,
            LaneWidth::W512 => 512,
        }
    }

    /// Parse a `--lanes` value (`"64"`, `"256"`, or `"512"`).
    pub fn parse(s: &str) -> anyhow::Result<LaneWidth> {
        match s.trim() {
            "64" => Ok(LaneWidth::W64),
            "256" => Ok(LaneWidth::W256),
            "512" => Ok(LaneWidth::W512),
            other => {
                Err(anyhow::anyhow!("unsupported lane width `{other}` (use 64, 256, or 512)"))
            }
        }
    }
}

impl fmt::Display for LaneWidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.lanes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_word_ops<W: LaneWord>() {
        assert!(W::zero().is_zero());
        assert!(!W::ones().is_zero());
        assert_eq!(W::zero().count_ones(), 0);
        assert_eq!(W::ones().count_ones(), W::LANES as u32);
        assert_eq!(W::splat(true), W::ones());
        assert_eq!(W::splat(false), W::zero());
        assert_eq!(!W::ones(), W::zero());

        // Per-lane insert/extract round-trips and stays independent.
        let mut w = W::zero();
        let lanes = [0usize, 1, W::LANES / 2, W::LANES - 1];
        for &l in &lanes {
            w.set_lane(l, true);
        }
        for &l in &lanes {
            assert!(w.lane(l), "lane {l}");
        }
        assert_eq!(w.count_ones(), lanes.len() as u32);
        w.set_lane(lanes[1], false);
        assert!(!w.lane(lanes[1]));
        assert_eq!(w.count_ones(), lanes.len() as u32 - 1);

        // Bitwise ops act lane-wise.
        let a = w;
        let b = {
            let mut b = W::zero();
            b.set_lane(lanes[0], true);
            b
        };
        assert_eq!((a & b).count_ones(), 1);
        assert_eq!(a | b, a);
        let a_again = a;
        assert!((a ^ a_again).is_zero());

        // Set-lane iteration visits exactly the set lanes, ascending.
        let mut seen = Vec::new();
        a.for_each_set_lane(|l| seen.push(l));
        let mut expect: Vec<usize> =
            lanes.iter().copied().filter(|&l| l != lanes[1]).collect();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(seen, expect);
    }

    #[test]
    fn u64_lane_word_contract() {
        check_word_ops::<u64>();
    }

    #[test]
    fn w256_lane_word_contract() {
        check_word_ops::<W256>();
    }

    #[test]
    fn w512_lane_word_contract() {
        check_word_ops::<W512>();
    }

    #[test]
    fn w512_matches_eight_u64s() {
        // W512 ops must equal the same op applied element-wise on u64.
        let mut xs = [0u64; 8];
        let mut ys = [0u64; 8];
        for k in 0..8 {
            xs[k] = 0x0123_4567_89AB_CDEFu64.rotate_left(7 * k as u32) ^ k as u64;
            ys[k] = 0xDEAD_BEEF_F00D_5EEDu64.rotate_right(11 * k as u32) | 1 << k;
        }
        let a = W512(xs);
        let b = W512(ys);
        for k in 0..8 {
            assert_eq!((a & b).0[k], xs[k] & ys[k]);
            assert_eq!((a | b).0[k], xs[k] | ys[k]);
            assert_eq!((a ^ b).0[k], xs[k] ^ ys[k]);
            assert_eq!((!a).0[k], !xs[k]);
        }
        assert_eq!(a.count_ones(), xs.iter().map(|w| w.count_ones()).sum::<u32>());
        // Lane indexing crosses every element boundary correctly.
        for lane in [0usize, 63, 64, 255, 256, 319, 448, 511] {
            assert_eq!(a.lane(lane), xs[lane >> 6] >> (lane & 63) & 1 == 1, "lane {lane}");
        }
    }

    #[test]
    fn w256_matches_four_u64s() {
        // W256 ops must equal the same op applied element-wise on u64.
        let xs = [0x0123_4567_89AB_CDEFu64, !0, 0, 0xDEAD_BEEF_F00D_5EED];
        let ys = [0xFFFF_0000_FFFF_0000u64, 0x5555_5555_5555_5555, !0, 1];
        let a = W256(xs);
        let b = W256(ys);
        for k in 0..4 {
            assert_eq!((a & b).0[k], xs[k] & ys[k]);
            assert_eq!((a | b).0[k], xs[k] | ys[k]);
            assert_eq!((a ^ b).0[k], xs[k] ^ ys[k]);
            assert_eq!((!a).0[k], !xs[k]);
        }
        assert_eq!(
            a.count_ones(),
            xs.iter().map(|w| w.count_ones()).sum::<u32>()
        );
        // Lane indexing crosses element boundaries correctly.
        for lane in [0usize, 63, 64, 127, 128, 200, 255] {
            assert_eq!(a.lane(lane), xs[lane >> 6] >> (lane & 63) & 1 == 1, "lane {lane}");
        }
    }

    #[test]
    fn lane_width_parse_and_display() {
        assert_eq!(LaneWidth::parse("64").unwrap(), LaneWidth::W64);
        assert_eq!(LaneWidth::parse(" 256 ").unwrap(), LaneWidth::W256);
        assert_eq!(LaneWidth::parse("512").unwrap(), LaneWidth::W512);
        assert!(LaneWidth::parse("128").is_err());
        assert_eq!(LaneWidth::W64.to_string(), "64");
        assert_eq!(LaneWidth::W256.to_string(), "256");
        assert_eq!(LaneWidth::W512.to_string(), "512");
        assert_eq!(LaneWidth::default(), LaneWidth::W256);
        assert_eq!(LaneWidth::W256.lanes(), 256);
        assert_eq!(LaneWidth::W512.lanes(), 512);
    }
}
