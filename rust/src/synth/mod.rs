//! Synthesis flow ("yosys/nextpnr-lite"): gate-level netlist, RTL→gate
//! lowering, optimization passes, LUT4 technology mapping, and gate-level
//! simulation. Together with [`crate::timing`] and [`crate::power`] this
//! is the substitute for the paper's iCE40 tool flow (DESIGN.md §2).

pub mod gatesim;
pub mod lane;
pub mod lower;
pub mod netlist;
pub mod opt;
pub mod techmap;
pub mod vcd;
pub mod word;
pub mod wordsim;

pub use gatesim::GateSim;
pub use lane::{LaneWidth, LaneWord, W256, W512};
pub use lower::lower;
pub use netlist::{Levelization, NetId, Netlist, Node};
pub use techmap::{map_design, MappedDesign};
pub use vcd::VcdRecorder;
pub use wordsim::{Drive, ParSession, WordSim, LANES, LEVEL_PAR_THRESHOLD};
