//! Gate-level (post-synthesis) simulation.
//!
//! Evaluates a [`Netlist`] cycle by cycle: combinational LUTs settle in
//! node-id order (construction order is topological for combinational
//! logic; flip-flop outputs are state, read from the previous cycle), then
//! all flip-flops clock simultaneously. The simulator counts toggles per
//! net, which feeds the switching-activity power model
//! ([`crate::power`]) — the substitute for the paper's physical current
//! measurement on the iCE40's core supply rail.
//!
//! This scalar engine is the **reference oracle** for the bit-parallel
//! 64-lane engine ([`super::wordsim::WordSim`]), which is the production
//! path for long stimulus runs. `tests/wordsim_differential.rs` asserts
//! lane-by-lane identity between the two on the whole corpus; keep their
//! semantics in lock-step when changing either.

use super::netlist::{NetId, Netlist, Node};
use std::collections::HashMap;

/// One LUT in the packed evaluation plan (§Perf: the netlist's enum/Vec
/// representation is flattened once at simulator construction so the
/// per-cycle loop touches only dense arrays).
#[derive(Clone, Copy)]
struct PackedLut {
    /// Output net index.
    out: u32,
    /// Input net indices (unused slots repeat input 0).
    ins: [u32; 4],
    tt: u16,
}

/// Simulation state for one netlist.
pub struct GateSim<'n> {
    nl: &'n Netlist,
    /// Current value of every net.
    vals: Vec<bool>,
    /// Per-net toggle counters (combinational + sequential transitions).
    toggles: Vec<u64>,
    /// Cycles executed.
    cycles: u64,
    /// Input bus name -> bit net ids.
    bus: HashMap<String, Vec<NetId>>,
    /// Packed combinational plan in topological order.
    luts: Vec<PackedLut>,
    /// (dff net, d net) pairs.
    dffs: Vec<(u32, u32)>,
    /// Two-phase clock-edge scratch (sampled D values).
    scratch: Vec<bool>,
}

impl<'n> GateSim<'n> {
    /// Create a simulator with flip-flops at their init values.
    pub fn new(nl: &'n Netlist) -> GateSim<'n> {
        let mut vals = vec![false; nl.len()];
        let mut luts = Vec::new();
        let mut dffs = Vec::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Const(v) => vals[id as usize] = *v,
                Node::Dff { d, init } => {
                    vals[id as usize] = *init;
                    dffs.push((id, *d));
                }
                Node::Lut { ins, tt } => {
                    let mut packed = [ins[0]; 4];
                    for (k, &i) in ins.iter().enumerate() {
                        packed[k] = i;
                    }
                    // Expand the truth table to 4 inputs so the hot loop
                    // needs no per-LUT width mask (unused index bits
                    // alias input 0 and the expansion makes them
                    // don't-cares).
                    let mask = (1usize << ins.len()) - 1;
                    let mut tt4 = 0u16;
                    for idx in 0..16usize {
                        if tt >> (idx & mask) & 1 == 1 {
                            tt4 |= 1 << idx;
                        }
                    }
                    luts.push(PackedLut { out: id, ins: packed, tt: tt4 });
                }
                Node::Input(_) => {}
            }
        }
        let bus = nl
            .input_buses
            .iter()
            .map(|(n, b)| (n.clone(), b.clone()))
            .collect();
        let scratch = vec![false; dffs.len()];
        GateSim {
            nl,
            vals,
            toggles: vec![0; nl.len()],
            cycles: 0,
            bus,
            luts,
            dffs,
            scratch,
        }
    }

    /// Bind an input bus to an integer value (LSB-first, two's complement
    /// truncation to the bus width). Values are written straight into the
    /// net state; they hold until overwritten.
    pub fn set_bus(&mut self, name: &str, value: i64) {
        // Split-borrow the fields so the bus lookup needs no clone (this
        // runs once per port per activation on the power-analysis path).
        let GateSim { bus, vals, toggles, .. } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        for (i, bit) in bits.iter().enumerate() {
            let idx = *bit as usize;
            let v = (value >> i) & 1 == 1;
            if vals[idx] != v {
                toggles[idx] += 1;
                vals[idx] = v;
            }
        }
    }

    /// Bind a 1-bit input by bus name.
    pub fn set_bit(&mut self, name: &str, value: bool) {
        self.set_bus(name, value as i64);
    }

    /// Run one clock cycle: settle combinational logic, then clock DFFs.
    pub fn step(&mut self) {
        self.cycles += 1;
        // Combinational settle (construction order is topological).
        for l in &self.luts {
            let sel = (self.vals[l.ins[0] as usize] as usize)
                | (self.vals[l.ins[1] as usize] as usize) << 1
                | (self.vals[l.ins[2] as usize] as usize) << 2
                | (self.vals[l.ins[3] as usize] as usize) << 3;
            let new = l.tt >> sel & 1 == 1;
            let idx = l.out as usize;
            if new != self.vals[idx] {
                self.toggles[idx] += 1;
                self.vals[idx] = new;
            }
        }
        // Clock edge: sample every D first (a DFF may feed another DFF
        // directly, so the capture must be two-phase), then commit.
        for (i, &(_, d)) in self.dffs.iter().enumerate() {
            self.scratch[i] = self.vals[d as usize];
        }
        for (i, &(q, _)) in self.dffs.iter().enumerate() {
            let idx = q as usize;
            let v = self.scratch[i];
            if self.vals[idx] != v {
                self.toggles[idx] += 1;
                self.vals[idx] = v;
            }
        }
    }

    /// Synchronous reset: force all DFFs back to init (models the `rst`
    /// net without burdening every fan-in cone).
    pub fn reset(&mut self) {
        for (id, node) in self.nl.nodes() {
            if let Node::Dff { init, .. } = node {
                self.vals[id as usize] = *init;
            }
        }
    }

    /// Read an output bus as a sign-extended integer. Output reads are
    /// hot in testbench-driven loops polling `done` every cycle; the
    /// lookup goes through the netlist's prebuilt name index.
    pub fn get_output(&self, name: &str) -> i64 {
        let bits = self
            .nl
            .output_bits(name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"));
        let mut v: i64 = 0;
        for (i, bit) in bits.iter().enumerate() {
            if self.vals[*bit as usize] {
                v |= 1 << i;
            }
        }
        // Sign-extend from the top bit.
        let w = bits.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read a single-bit output.
    pub fn get_bit(&self, name: &str) -> bool {
        self.get_output(name) & 1 == 1
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Total toggles across all nets.
    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean toggles per net per cycle (the switching-activity factor α).
    pub fn mean_activity(&self) -> f64 {
        if self.cycles == 0 || self.nl.len() == 0 {
            return 0.0;
        }
        self.total_toggles() as f64 / (self.cycles as f64 * self.nl.len() as f64)
    }

    /// Per-net toggle counts.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::Netlist;

    /// Build a 4-bit counter and check it counts.
    #[test]
    fn counter_counts() {
        let mut nl = Netlist::new();
        // 4 DFFs; increment: q + 1 via half-adder chain.
        let q: Vec<NetId> = (0..4).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q.clone());

        let mut sim = GateSim::new(&nl);
        for expect in 1..=20i64 {
            sim.step();
            assert_eq!(sim.get_output("q") & 0xF, expect & 0xF, "at cycle {expect}");
        }
    }

    #[test]
    fn input_bus_drives_logic() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        // Bitwise AND output.
        let y: Vec<NetId> = a.iter().zip(&b).map(|(&x, &y)| nl.and2(x, y)).collect();
        nl.add_output("y", y);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", 0b1100);
        sim.set_bus("b", 0b1010);
        sim.step();
        assert_eq!(sim.get_output("y") & 0xF, 0b1000);
    }

    #[test]
    fn sign_extension() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        nl.add_output("y", a);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", -3);
        sim.step();
        assert_eq!(sim.get_output("y"), -3);
    }

    #[test]
    fn toggles_counted() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1);
        let na = nl.not(a[0]);
        nl.add_output("y", vec![na]);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", 0);
        sim.step();
        let t0 = sim.total_toggles();
        sim.set_bus("a", 1);
        sim.step();
        assert!(sim.total_toggles() > t0);
        assert!(sim.mean_activity() > 0.0);
    }

    #[test]
    fn reset_restores_init() {
        let mut nl = Netlist::new();
        let one = nl.constant(true);
        let d = nl.dff(one, false);
        nl.add_output("q", vec![d]);
        let mut sim = GateSim::new(&nl);
        sim.step();
        assert_eq!(sim.get_output("q") & 1, 1);
        sim.reset();
        assert_eq!(sim.get_output("q") & 1, 0);
    }
}
