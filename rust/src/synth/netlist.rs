//! Gate-level netlist: the target of RTL lowering and the subject of
//! optimization, technology mapping, timing analysis, power estimation,
//! and gate-level simulation.
//!
//! The netlist is a DAG of LUT nodes (up to 4 inputs, arbitrary truth
//! table — the iCE40's native combinational primitive), D flip-flops,
//! constants and primary inputs. Structural hashing at construction time
//! deduplicates identical nodes (the same CSE yosys performs during
//! elaboration).
//!
//! **Topological invariant:** every LUT's inputs have smaller net ids than
//! the LUT itself. This holds by construction (a LUT can only reference
//! nets that already exist) and is preserved by the rebuild passes
//! ([`super::opt::dce`], [`super::techmap::pack_luts`]), which emit nodes
//! in id order. Only DFF data inputs may point forward (sequential
//! feedback). [`Netlist::levelize`] validates the invariant and derives
//! the per-level evaluation schedule the simulators iterate.

use std::collections::HashMap;

/// Index of a net (node output) in the netlist.
pub type NetId = u32;

/// A netlist node. The node's output *is* the net with the node's id.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Node {
    /// Constant 0/1.
    Const(bool),
    /// Primary input bit (name, bit index).
    Input(String),
    /// K-input LUT: output = tt bit at index formed by input values
    /// (input 0 = LSB of the index).
    Lut { ins: Vec<NetId>, tt: u16 },
    /// D flip-flop (posedge, implicit global clock), with reset-init value.
    Dff { d: NetId, init: bool },
}

/// Topological levelization of a netlist's combinational logic
/// (see [`Netlist::levelize`]).
#[derive(Clone, Debug)]
pub struct Levelization {
    /// Combinational level per net (0 for constants, inputs and DFFs).
    pub level: Vec<u32>,
    /// LUT ids sorted by level, ascending id within a level.
    pub order: Vec<NetId>,
    /// Half-open `(start, end)` ranges into `order`, one per level,
    /// starting at level 1. `bounds.len()` is the combinational depth.
    pub bounds: Vec<(u32, u32)>,
}

impl Levelization {
    /// Combinational depth (maximum LUT level).
    pub fn depth(&self) -> u32 {
        self.bounds.len() as u32
    }

    /// The LUT ids of one level (1-based, matching `level` values).
    pub fn level_luts(&self, level: u32) -> &[NetId] {
        let (s, e) = self.bounds[level as usize - 1];
        &self.order[s as usize..e as usize]
    }
}

/// A gate-level netlist.
#[derive(Clone, Default)]
pub struct Netlist {
    nodes: Vec<Node>,
    /// Named output buses: (name, bits LSB-first). Private so every
    /// declaration goes through [`Netlist::add_output`], which keeps
    /// the name index below in sync; read via [`Netlist::outputs`].
    outputs: Vec<(String, Vec<NetId>)>,
    /// Named input buses for simulation binding: (name, bits LSB-first).
    pub input_buses: Vec<(String, Vec<NetId>)>,
    /// Structural-hash cache.
    cache: HashMap<Node, NetId>,
    /// Output name → index into `outputs`, built once here so every
    /// consumer (gate/word simulators, recorders) resolves hot output
    /// reads in O(1) instead of scanning `outputs` or keeping a private
    /// copy of this map.
    out_index: HashMap<String, usize>,
}

impl Netlist {
    pub fn new() -> Netlist {
        Netlist::default()
    }

    /// Rebuild a netlist from its raw parts — the decode path of the
    /// persistent artifact store ([`crate::flow::store`]). The caller is
    /// responsible for the topological invariant (store decoding
    /// validates it); the structural-hash cache is reconstructed so
    /// further construction on the restored netlist keeps deduplicating.
    pub fn from_parts(
        nodes: Vec<Node>,
        outputs: Vec<(String, Vec<NetId>)>,
        input_buses: Vec<(String, Vec<NetId>)>,
    ) -> Netlist {
        let mut cache = HashMap::new();
        for (id, node) in nodes.iter().enumerate() {
            // Mirror `intern`: DFFs are stateful and inputs are unique by
            // construction, so neither participates in structural hashing.
            if !matches!(node, Node::Dff { .. } | Node::Input(_)) {
                cache.entry(node.clone()).or_insert(id as NetId);
            }
        }
        // Rebuild the output index (re-declarations: latest wins, like
        // `add_output`).
        let out_index =
            outputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        Netlist { nodes, outputs, input_buses, cache, out_index }
    }

    pub fn node(&self, id: NetId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> impl Iterator<Item = (NetId, &Node)> {
        self.nodes.iter().enumerate().map(|(i, n)| (i as NetId, n))
    }

    fn intern(&mut self, node: Node) -> NetId {
        // DFFs are stateful: never merged. Everything else is hashed.
        if matches!(node, Node::Dff { .. }) {
            let id = self.nodes.len() as NetId;
            self.nodes.push(node);
            return id;
        }
        if let Some(&id) = self.cache.get(&node) {
            return id;
        }
        let id = self.nodes.len() as NetId;
        self.nodes.push(node.clone());
        self.cache.insert(node, id);
        id
    }

    // ---- primitives -----------------------------------------------------

    pub fn constant(&mut self, v: bool) -> NetId {
        self.intern(Node::Const(v))
    }

    pub fn input(&mut self, name: impl Into<String>) -> NetId {
        // Inputs are unique by construction; do not hash-merge distinct
        // declarations with the same name.
        let id = self.nodes.len() as NetId;
        self.nodes.push(Node::Input(name.into()));
        id
    }

    /// Declare an input bus of `width` bits, registered for simulation.
    pub fn input_bus(&mut self, name: &str, width: u32) -> Vec<NetId> {
        let bits: Vec<NetId> = (0..width).map(|b| self.input(format!("{name}[{b}]"))).collect();
        self.input_buses.push((name.to_string(), bits.clone()));
        bits
    }

    /// Generic LUT with canonicalization of constant/duplicate inputs.
    pub fn lut(&mut self, ins: &[NetId], tt: u16) -> NetId {
        assert!(!ins.is_empty() && ins.len() <= 4, "LUT arity 1..=4");
        let n = ins.len();
        // Constant-fold if all inputs constant.
        let consts: Vec<Option<bool>> = ins
            .iter()
            .map(|&i| match self.nodes[i as usize] {
                Node::Const(v) => Some(v),
                _ => None,
            })
            .collect();
        if consts.iter().all(|c| c.is_some()) {
            let idx = consts
                .iter()
                .enumerate()
                .fold(0usize, |acc, (k, c)| acc | ((c.unwrap() as usize) << k));
            return self.constant(tt >> idx & 1 == 1);
        }
        // Partial constant propagation: cofactor the truth table.
        if consts.iter().any(|c| c.is_some()) {
            let mut new_ins = Vec::new();
            let mut new_tt = 0u16;
            let free: Vec<usize> = (0..n).filter(|&k| consts[k].is_none()).collect();
            for (fi, &k) in free.iter().enumerate() {
                let _ = (fi, k);
            }
            for idx in 0..(1usize << free.len()) {
                // Expand reduced index to full index with constants filled.
                let mut full = 0usize;
                for (fi, &k) in free.iter().enumerate() {
                    if idx >> fi & 1 == 1 {
                        full |= 1 << k;
                    }
                }
                for (k, c) in consts.iter().enumerate() {
                    if c == &Some(true) {
                        full |= 1 << k;
                    }
                }
                if tt >> full & 1 == 1 {
                    new_tt |= 1 << idx;
                }
            }
            for &k in &free {
                new_ins.push(ins[k]);
            }
            return self.lut(&new_ins, new_tt);
        }
        // Vacuous-input elimination: drop inputs the function ignores.
        for k in 0..n {
            let mut sensitive = false;
            for idx in 0..(1usize << n) {
                if idx >> k & 1 == 0 {
                    let hi = idx | (1 << k);
                    if (tt >> idx & 1) != (tt >> hi & 1) {
                        sensitive = true;
                        break;
                    }
                }
            }
            if !sensitive {
                // Cofactor with input k = 0.
                let mut new_ins = Vec::with_capacity(n - 1);
                let mut new_tt = 0u16;
                let mut out_idx = 0usize;
                for idx in 0..(1usize << n) {
                    if idx >> k & 1 == 0 {
                        if tt >> idx & 1 == 1 {
                            new_tt |= 1 << out_idx;
                        }
                        out_idx += 1;
                    }
                }
                for (j, &i) in ins.iter().enumerate() {
                    if j != k {
                        new_ins.push(i);
                    }
                }
                if new_ins.is_empty() {
                    return self.constant(new_tt & 1 == 1);
                }
                return self.lut(&new_ins, new_tt);
            }
        }
        // Duplicate-input merging.
        for k in 1..n {
            if let Some(j) = (0..k).find(|&j| ins[j] == ins[k]) {
                // Restrict tt to assignments where input k == input j.
                let mut new_ins = Vec::with_capacity(n - 1);
                let mut new_tt = 0u16;
                for idx in 0..(1usize << (n - 1)) {
                    // Expand reduced index (without position k) to full.
                    let mut full = 0usize;
                    let mut src = 0usize;
                    for pos in 0..n {
                        if pos == k {
                            continue;
                        }
                        if idx >> src & 1 == 1 {
                            full |= 1 << pos;
                        }
                        src += 1;
                    }
                    if full >> j & 1 == 1 {
                        full |= 1 << k;
                    }
                    if tt >> full & 1 == 1 {
                        new_tt |= 1 << idx;
                    }
                }
                for (pos, &i) in ins.iter().enumerate() {
                    if pos != k {
                        new_ins.push(i);
                    }
                }
                return self.lut(&new_ins, new_tt);
            }
        }
        // Identity / inverter simplification for 1-input LUTs.
        if n == 1 {
            if tt & 0b11 == 0b10 {
                return ins[0]; // buffer
            }
            if tt & 0b11 == 0b00 {
                return self.constant(false);
            }
            if tt & 0b11 == 0b11 {
                return self.constant(true);
            }
        }
        // Mask truth table to the used width for canonical hashing.
        let mask = if n == 4 { 0xFFFFu16 } else { (1u16 << (1 << n)) - 1 };
        self.intern(Node::Lut { ins: ins.to_vec(), tt: tt & mask })
    }

    pub fn not(&mut self, a: NetId) -> NetId {
        self.lut(&[a], 0b01)
    }

    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(&[a, b], 0b1000)
    }

    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(&[a, b], 0b1110)
    }

    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(&[a, b], 0b0110)
    }

    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(&[a, b], 0b0111)
    }

    /// 2:1 mux: `s ? a : b` (inputs ordered [s, a, b]).
    pub fn mux(&mut self, s: NetId, a: NetId, b: NetId) -> NetId {
        // index = s | a<<1 | b<<2 ; out = s ? a : b
        // idx: s a b -> out
        // 0: 000 -> b=0 -> 0 ; 1: s=1,a=0 -> 0
        // 2: a=1,s=0 -> b=0 -> 0 ... enumerate:
        // out(s,a,b) = s? a : b
        let mut tt = 0u16;
        for idx in 0..8u16 {
            let s_v = idx & 1 == 1;
            let a_v = idx >> 1 & 1 == 1;
            let b_v = idx >> 2 & 1 == 1;
            if (s_v && a_v) || (!s_v && b_v) {
                tt |= 1 << idx;
            }
        }
        self.lut(&[s, a, b], tt)
    }

    /// Full adder: returns (sum, carry) as two 3-input LUTs — the natural
    /// iCE40 mapping of one adder bit.
    pub fn full_adder(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        // sum = a ^ b ^ c; carry = majority(a, b, c)
        let mut sum_tt = 0u16;
        let mut carry_tt = 0u16;
        for idx in 0..8u16 {
            let bits = (idx & 1) + (idx >> 1 & 1) + (idx >> 2 & 1);
            if bits % 2 == 1 {
                sum_tt |= 1 << idx;
            }
            if bits >= 2 {
                carry_tt |= 1 << idx;
            }
        }
        (self.lut(&[a, b, c], sum_tt), self.lut(&[a, b, c], carry_tt))
    }

    pub fn dff(&mut self, d: NetId, init: bool) -> NetId {
        self.intern(Node::Dff { d, init })
    }

    /// Rewire an existing DFF's data input (used to close sequential
    /// feedback loops after the combinational logic is built).
    pub fn set_dff_input(&mut self, dff: NetId, d: NetId) {
        match &mut self.nodes[dff as usize] {
            Node::Dff { d: slot, .. } => *slot = d,
            other => panic!("set_dff_input on non-DFF node {other:?}"),
        }
    }

    pub fn add_output(&mut self, name: &str, bits: Vec<NetId>) {
        // Re-declaring a name points the index at the latest declaration.
        self.out_index.insert(name.to_string(), self.outputs.len());
        self.outputs.push((name.to_string(), bits));
    }

    /// The bit nets of a named output bus (LSB-first), or `None` when no
    /// such output was declared. O(1): backed by the prebuilt name index
    /// — this is the hot lookup of testbench-style drive loops polling
    /// `done` every cycle.
    pub fn output_bits(&self, name: &str) -> Option<&[NetId]> {
        self.out_index.get(name).map(|&i| self.outputs[i].1.as_slice())
    }

    /// The declared output buses, in declaration order.
    pub fn outputs(&self) -> &[(String, Vec<NetId>)] {
        &self.outputs
    }

    // ---- levelization ----------------------------------------------------

    /// Compute topological levels for the combinational logic and a dense
    /// per-level evaluation schedule.
    ///
    /// Constants, primary inputs and DFF outputs (state, read from the
    /// previous cycle) are level 0; a LUT's level is one more than the
    /// maximum level of its inputs. The module-level topological invariant
    /// is validated here: a LUT input with an id not smaller than the LUT
    /// itself is a construction bug and panics.
    pub fn levelize(&self) -> Levelization {
        let n = self.nodes.len();
        let mut level = vec![0u32; n];
        let mut depth = 0u32;
        for (id, node) in self.nodes.iter().enumerate() {
            if let Node::Lut { ins, .. } = node {
                let mut l = 0u32;
                for &i in ins {
                    assert!(
                        (i as usize) < id,
                        "netlist not topological: LUT {id} reads net {i}"
                    );
                    l = l.max(level[i as usize]);
                }
                level[id] = l + 1;
                depth = depth.max(l + 1);
            }
        }
        // Counting sort of LUT ids by level (stable: ascending id within a
        // level), yielding dense per-level slices for the simulators.
        let mut counts = vec![0u32; depth as usize + 1];
        for (id, node) in self.nodes.iter().enumerate() {
            if matches!(node, Node::Lut { .. }) {
                counts[level[id] as usize] += 1;
            }
        }
        let mut bounds = Vec::with_capacity(depth as usize);
        let mut start = 0u32;
        for lv in 1..=depth as usize {
            bounds.push((start, start + counts[lv]));
            start += counts[lv];
        }
        let mut next: Vec<u32> = bounds.iter().map(|&(s, _)| s).collect();
        let mut order = vec![0 as NetId; start as usize];
        for (id, node) in self.nodes.iter().enumerate() {
            if matches!(node, Node::Lut { .. }) {
                let slot = &mut next[level[id] as usize - 1];
                order[*slot as usize] = id as NetId;
                *slot += 1;
            }
        }
        Levelization { level, order, bounds }
    }

    // ---- statistics ------------------------------------------------------

    pub fn count_luts(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Lut { .. })).count()
    }

    pub fn count_dffs(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Dff { .. })).count()
    }

    pub fn count_inputs(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Input(_))).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_hashing_dedupes() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let b = nl.input("b");
        let x1 = nl.and2(a, b);
        let x2 = nl.and2(a, b);
        assert_eq!(x1, x2);
        let x3 = nl.and2(b, a); // different input order: not merged (no commutativity canon)
        let _ = x3;
        assert_eq!(nl.count_luts(), 2);
    }

    #[test]
    fn from_parts_roundtrips_and_rebuilds_cache() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let b = nl.input("b");
        let x = nl.and2(a, b);
        let d = nl.dff(x, false);
        nl.add_output("q", vec![d]);
        let nodes: Vec<Node> = nl.nodes().map(|(_, n)| n.clone()).collect();
        let mut rebuilt =
            Netlist::from_parts(nodes, nl.outputs().to_vec(), nl.input_buses.clone());
        assert_eq!(rebuilt.len(), nl.len());
        assert_eq!(rebuilt.count_luts(), nl.count_luts());
        // Structural hashing still dedupes against restored nodes.
        assert_eq!(rebuilt.and2(a, b), x);
        assert_eq!(rebuilt.len(), nl.len());
    }

    #[test]
    fn dffs_never_merge() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let d1 = nl.dff(a, false);
        let d2 = nl.dff(a, false);
        assert_ne!(d1, d2);
    }

    #[test]
    fn constant_folding() {
        let mut nl = Netlist::new();
        let t = nl.constant(true);
        let f = nl.constant(false);
        assert_eq!(nl.and2(t, f), nl.constant(false));
        assert_eq!(nl.or2(t, f), nl.constant(true));
        assert_eq!(nl.xor2(t, t), nl.constant(false));
        assert_eq!(nl.count_luts(), 0);
    }

    #[test]
    fn partial_constant_cofactor() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let t = nl.constant(true);
        let f = nl.constant(false);
        // a AND 1 = a (buffer elimination).
        assert_eq!(nl.and2(a, t), a);
        // a AND 0 = 0.
        assert_eq!(nl.and2(a, f), nl.constant(false));
        // a XOR 1 = NOT a — one LUT.
        let na = nl.xor2(a, t);
        assert_eq!(na, nl.not(a));
    }

    #[test]
    fn mux_semantics_via_fold() {
        let mut nl = Netlist::new();
        let t = nl.constant(true);
        let f = nl.constant(false);
        let a = nl.input("a");
        let b = nl.input("b");
        // s=1 -> a
        assert_eq!(nl.mux(t, a, b), a);
        // s=0 -> b
        assert_eq!(nl.mux(f, a, b), b);
    }

    #[test]
    fn full_adder_truth() {
        // Validate via constant folding across all 8 input combinations.
        for idx in 0..8u16 {
            let mut nl = Netlist::new();
            let a = nl.constant(idx & 1 == 1);
            let b = nl.constant(idx >> 1 & 1 == 1);
            let c = nl.constant(idx >> 2 & 1 == 1);
            let (s, co) = nl.full_adder(a, b, c);
            let total = (idx & 1) + (idx >> 1 & 1) + (idx >> 2 & 1);
            assert_eq!(nl.node(s), &Node::Const(total % 2 == 1));
            assert_eq!(nl.node(co), &Node::Const(total >= 2));
        }
    }

    #[test]
    fn input_bus_registers_bits() {
        let mut nl = Netlist::new();
        let bus = nl.input_bus("x", 8);
        assert_eq!(bus.len(), 8);
        assert_eq!(nl.input_buses.len(), 1);
        assert_eq!(nl.count_inputs(), 8);
    }

    #[test]
    fn levelize_orders_by_depth() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let b = nl.input("b");
        let x = nl.and2(a, b); // level 1
        let y = nl.xor2(x, a); // level 2
        let z = nl.or2(y, x); // level 3
        let lv = nl.levelize();
        assert_eq!(lv.level[a as usize], 0);
        assert_eq!(lv.level[x as usize], 1);
        assert_eq!(lv.level[y as usize], 2);
        assert_eq!(lv.level[z as usize], 3);
        assert_eq!(lv.depth(), 3);
        assert_eq!(lv.level_luts(1), &[x]);
        assert_eq!(lv.level_luts(2), &[y]);
        assert_eq!(lv.level_luts(3), &[z]);
        assert_eq!(lv.order.len(), nl.count_luts());
    }

    #[test]
    fn levelize_dff_breaks_cycles() {
        // q feeds its own next-state logic; the DFF output is level 0 so
        // the combinational logic still levelizes.
        let mut nl = Netlist::new();
        let q = nl.dff(0, false);
        let nq = nl.not(q);
        nl.set_dff_input(q, nq);
        let lv = nl.levelize();
        assert_eq!(lv.level[q as usize], 0);
        assert_eq!(lv.level[nq as usize], 1);
        assert_eq!(lv.depth(), 1);
    }

    #[test]
    fn levelize_empty_and_sequential_only() {
        let nl = Netlist::new();
        assert_eq!(nl.levelize().depth(), 0);
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let _ = nl.dff(a, false);
        let lv = nl.levelize();
        assert_eq!(lv.depth(), 0);
        assert!(lv.order.is_empty());
    }

    #[test]
    fn set_dff_input_rewires() {
        let mut nl = Netlist::new();
        let a = nl.input("a");
        let b = nl.input("b");
        let d = nl.dff(a, false);
        nl.set_dff_input(d, b);
        match nl.node(d) {
            Node::Dff { d: slot, .. } => assert_eq!(*slot, b),
            _ => panic!(),
        }
    }
}
