//! Bit-parallel (lane-word wide) gate-level simulation.
//!
//! # Lane model
//!
//! [`WordSim`] advances **one independent stimulus stream per bit of a
//! SIMD lane word** ([`LaneWord`]): every net holds a word whose bit *l*
//! is the net's boolean value in lane *l*. One [`WordSim::step`]
//! therefore simulates one clock cycle of `W::LANES` independent copies
//! of the design at once — the classic compiled-code / emulation-engine
//! trick that turns the power-analysis workload (long LFSR stimulus
//! runs, see [`crate::power`]) from one boolean per net per cycle into
//! one word op per net per cycle. Two lane words are provided:
//!
//! * `WordSim<'_, u64>` (the default) — 64 streams per pass;
//! * `WordSim<'_, W256>` — 256 streams per pass; the same straight-line
//!   word ops auto-vectorize to AVX2/NEON, so the 4× lane count costs
//!   far less than 4× the wall time.
//!
//! Lanes never interact: lane *l* of every net evolves exactly as a
//! scalar [`super::GateSim`] run would with lane *l*'s inputs. The scalar
//! simulator is kept as the reference oracle; the differential test suite
//! (`tests/wordsim_differential.rs`) asserts lane-by-lane identity of
//! outputs and per-net toggle counts on the whole corpus, at both lane
//! widths.
//!
//! # LUT evaluation
//!
//! At pack time each LUT's truth table is expanded to 4 inputs and
//! compiled into an 8-leaf Shannon mux tree over the input words: the two
//! cofactor bits of each leaf collapse into per-leaf `sel`/`inv` masks
//! (leaf = `(a & sel) ^ inv`, each mask all-ones or all-zero), and the
//! remaining three variables are resolved with the branch-free word mux
//! `x0 ^ (s & (x0 ^ x1))`. The hot loop is straight-line AND/XOR word
//! ops — no per-bit truth-table indexing, no branches, no hash lookups.
//!
//! # Levelization and intra-level parallelism
//!
//! The evaluation plan is grouped by the combinational levels computed by
//! [`Netlist::levelize`] (validated topological order). Levels are a hard
//! dependence barrier, but *within* a level every LUT reads only earlier
//! levels and writes its own output net — embarrassingly parallel. When
//! enabled ([`WordSim::with_level_parallelism`]) and driven through a
//! [`WordSim::parallel_session`], levels wider than a threshold are split
//! across persistent worker threads (spin-joined per level); narrower
//! levels and all toggle bookkeeping stay on the driving thread, so
//! parallel results are **bit-identical** to sequential ones.
//!
//! # Toggle counting
//!
//! Toggles are counted word-parallel: `count_ones` of `old ^ new` updates
//! the per-net counter for all lanes at once, and the same XOR word is
//! accumulated into per-lane totals through a 32-deep bit-plane
//! carry-save counter (amortized ~2 word ops per toggled net), so one
//! simulation pass yields `W::LANES` independent switching-activity
//! estimates.

// Every unsafe operation inside an `unsafe fn` must name its own proof
// obligation in an explicit `unsafe { .. }` block — the `unsafe fn`
// signature states the caller's contract, it does not discharge it.
#![deny(unsafe_op_in_unsafe_fn)]

use super::lane::LaneWord;
use super::netlist::{NetId, Netlist, Node};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of independent simulation lanes per `u64` machine word (the
/// default engine width; generic code should use `W::LANES`).
pub const LANES: usize = 64;

/// Bit-planes of the per-lane toggle accumulator (counts up to 2³² − 1
/// toggles per lane between flushes). Shared with [`crate::shard`]'s
/// per-member accumulators.
pub(crate) const PLANES: usize = 32;

/// Default minimum level width (packed LUTs in one combinational level)
/// for fanning a level out across worker threads; below it the
/// synchronization costs more than the evaluation.
pub const LEVEL_PAR_THRESHOLD: usize = 128;

/// One LUT in the packed word-parallel evaluation plan (also the plan
/// unit of the sharded evaluator, [`crate::shard::ShardSim`]).
#[derive(Clone, Copy)]
pub(crate) struct PackedWordLut {
    /// Output net index.
    pub(crate) out: u32,
    /// Input net indices (unused slots repeat input 0; the truth-table
    /// expansion makes them don't-cares).
    pub(crate) ins: [u32; 4],
    /// Leaf-select mask: bit j set ⇒ leaf j depends on input 0.
    pub(crate) sel: u8,
    /// Leaf-invert mask: bit j set ⇒ leaf j is complemented.
    pub(crate) inv: u8,
}

/// All-ones word if bit `i` of `byte` is set, else zero (branch-free).
#[inline(always)]
fn spread<W: LaneWord>(byte: u8, i: u32) -> W {
    W::splat((byte >> i) & 1 == 1)
}

/// Straight-line Shannon mux-tree evaluation of a packed LUT over four
/// input words. ~30 word ops for `W::LANES` lanes.
#[inline(always)]
fn eval_lut<W: LaneWord>(sel: u8, inv: u8, a: W, b: W, c: W, d: W) -> W {
    let l0 = (a & spread(sel, 0)) ^ spread(inv, 0);
    let l1 = (a & spread(sel, 1)) ^ spread(inv, 1);
    let l2 = (a & spread(sel, 2)) ^ spread(inv, 2);
    let l3 = (a & spread(sel, 3)) ^ spread(inv, 3);
    let l4 = (a & spread(sel, 4)) ^ spread(inv, 4);
    let l5 = (a & spread(sel, 5)) ^ spread(inv, 5);
    let l6 = (a & spread(sel, 6)) ^ spread(inv, 6);
    let l7 = (a & spread(sel, 7)) ^ spread(inv, 7);
    let m0 = l0 ^ (b & (l0 ^ l1));
    let m1 = l2 ^ (b & (l2 ^ l3));
    let m2 = l4 ^ (b & (l4 ^ l5));
    let m3 = l6 ^ (b & (l6 ^ l7));
    let n0 = m0 ^ (c & (m0 ^ m1));
    let n1 = m2 ^ (c & (m2 ^ m3));
    n0 ^ (d & (n0 ^ n1))
}

/// Expand a truth table of the given arity to 4 inputs (index bits beyond
/// the arity are don't-cares), then derive the 8 mux-tree leaf masks.
pub(crate) fn compile_tt(tt: u16, arity: usize) -> (u8, u8) {
    let mask = (1usize << arity) - 1;
    let mut tt4 = 0u16;
    for idx in 0..16usize {
        if tt >> (idx & mask) & 1 == 1 {
            tt4 |= 1 << idx;
        }
    }
    let mut sel = 0u8;
    let mut inv = 0u8;
    for j in 0..8 {
        let lo = tt4 >> (2 * j) & 1;
        let hi = tt4 >> (2 * j + 1) & 1;
        if lo ^ hi == 1 {
            sel |= 1 << j;
        }
        if lo == 1 {
            inv |= 1 << j;
        }
    }
    (sel, inv)
}

/// Carry-save add of toggle word `t` into the bit-plane accumulator.
/// Returns the leftover carry (must be zero below the flush threshold).
#[inline(always)]
pub(crate) fn plane_accumulate<W: LaneWord>(planes: &mut [W; PLANES], t: W) -> W {
    let mut carry = t;
    for p in planes.iter_mut() {
        if carry.is_zero() {
            break;
        }
        let sum = *p ^ carry;
        let next_carry = carry & *p;
        *p = sum;
        carry = next_carry;
    }
    carry
}

/// Move a bit-plane accumulator into flushed per-lane totals.
pub(crate) fn flush_planes_into<W: LaneWord>(
    planes: &mut [W; PLANES],
    flushed: &mut [u64],
    adds: &mut u64,
) {
    for (lane, total) in flushed.iter_mut().enumerate() {
        let mut acc = 0u64;
        for (k, plane) in planes.iter().enumerate() {
            acc |= u64::from(plane.lane(lane)) << k;
        }
        *total += acc;
    }
    *planes = [W::zero(); PLANES];
    *adds = 0;
}

/// Intra-level fan-out plan: which levels split across workers, and how.
#[derive(Clone, Debug)]
struct ParPlan {
    /// Worker-thread count (including the driving thread).
    workers: usize,
    /// Per level: index into `par_splits` when the level fans out.
    level_par: Vec<Option<u32>>,
    /// Chunk bounds into the packed plan, `workers` entries per parallel
    /// level, visited in level order every step.
    par_splits: Vec<Vec<(u32, u32)>>,
}

/// Word-parallel simulation state for one netlist, carrying `W::LANES`
/// independent stimulus streams.
pub struct WordSim<'n, W: LaneWord = u64> {
    nl: &'n Netlist,
    /// Current value word of every net (bit l = lane l).
    vals: Vec<W>,
    /// Per-net toggle counters, summed across lanes.
    toggles: Vec<u64>,
    /// Bit-plane carry-save accumulator of per-lane toggle totals.
    lane_planes: [W; PLANES],
    /// Flushed per-lane toggle totals (`W::LANES` entries).
    lane_flushed: Vec<u64>,
    /// Accumulator adds since the last flush (overflow guard).
    plane_adds: u64,
    /// Adds at which the accumulator must flush. Production value is
    /// `u32::MAX` (the plane depth); tests lower it to exercise the
    /// overflow-flush path cheaply.
    flush_threshold: u64,
    /// Optional exact per-net per-lane counters (`net * W::LANES +
    /// lane`), for differential testing; costs one pass over set toggle
    /// bits.
    lane_net_toggles: Option<Vec<u64>>,
    /// Cycles executed.
    cycles: u64,
    /// Input bus name -> bit net ids.
    bus: HashMap<String, Vec<NetId>>,
    /// Packed combinational plan, grouped by level.
    luts: Vec<PackedWordLut>,
    /// Half-open ranges into `luts`, one per combinational level.
    level_bounds: Vec<(u32, u32)>,
    /// (dff net, d net) pairs.
    dffs: Vec<(u32, u32)>,
    /// Two-phase clock-edge scratch (sampled D words).
    scratch: Vec<W>,
    /// Intra-level fan-out plan, when enabled and worthwhile.
    par: Option<ParPlan>,
}

impl<'n, W: LaneWord> WordSim<'n, W> {
    /// Create a simulator with flip-flops at their init values in every
    /// lane.
    pub fn new(nl: &'n Netlist) -> WordSim<'n, W> {
        let lv = nl.levelize();
        let mut vals = vec![W::zero(); nl.len()];
        let mut dffs = Vec::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Const(true) => vals[id as usize] = W::ones(),
                Node::Dff { d, init } => {
                    if *init {
                        vals[id as usize] = W::ones();
                    }
                    dffs.push((id, *d));
                }
                _ => {}
            }
        }
        let mut luts = Vec::with_capacity(lv.order.len());
        let mut level_bounds = Vec::with_capacity(lv.bounds.len());
        for level in 1..=lv.depth() {
            let start = luts.len() as u32;
            for &id in lv.level_luts(level) {
                let Node::Lut { ins, tt } = nl.node(id) else {
                    unreachable!("levelization order contains only LUTs")
                };
                let mut packed = [ins[0]; 4];
                for (k, &i) in ins.iter().enumerate() {
                    packed[k] = i;
                }
                let (sel, inv) = compile_tt(*tt, ins.len());
                luts.push(PackedWordLut { out: id, ins: packed, sel, inv });
            }
            level_bounds.push((start, luts.len() as u32));
        }
        let bus = nl
            .input_buses
            .iter()
            .map(|(n, b)| (n.clone(), b.clone()))
            .collect();
        let scratch = vec![W::zero(); dffs.len()];
        WordSim {
            nl,
            vals,
            toggles: vec![0; nl.len()],
            lane_planes: [W::zero(); PLANES],
            lane_flushed: vec![0; W::LANES],
            plane_adds: 0,
            flush_threshold: u64::from(u32::MAX),
            lane_net_toggles: None,
            cycles: 0,
            bus,
            luts,
            level_bounds,
            dffs,
            scratch,
            par: None,
        }
    }

    /// Enable exact per-net per-lane toggle tracking (slower; meant for
    /// differential testing against the scalar oracle).
    pub fn with_lane_net_toggles(mut self) -> WordSim<'n, W> {
        self.lane_net_toggles = Some(vec![0u64; self.nl.len() * W::LANES]);
        self
    }

    /// Lower the bit-plane flush threshold (default `u32::MAX` adds).
    /// Test hook: a small threshold forces the overflow-flush path to
    /// run constantly, proving flushes never lose counts. Values above
    /// the 32-plane accumulator capacity are clamped to it — beyond
    /// `u32::MAX` adds the carry-save planes would silently overflow.
    pub fn with_plane_flush_threshold(mut self, adds: u64) -> WordSim<'n, W> {
        self.flush_threshold = adds.min(u64::from(u32::MAX));
        self
    }

    /// Enable intra-level parallel evaluation for sessions
    /// ([`WordSim::parallel_session`]): levels with at least `threshold`
    /// packed LUTs are split evenly across one worker per core (capped).
    /// A no-op (sequential fallback) when no level is wide enough or
    /// only one core is available.
    pub fn with_level_parallelism(mut self, threshold: usize) -> WordSim<'n, W> {
        let threshold = threshold.max(2);
        let max_width = self
            .level_bounds
            .iter()
            .map(|&(s, e)| (e - s) as usize)
            .max()
            .unwrap_or(0);
        if max_width < threshold {
            self.par = None;
            return self;
        }
        // Chunks below ~half the threshold cost more in join latency
        // than they save; size the worker pool so every worker gets a
        // worthwhile slice of the widest level. (Computed from the core
        // count directly — `synth` sits below `flow` in the layer map
        // and must not reach up into `flow::worker`.)
        let chunk_min = (threshold / 2).max(1);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let workers = cores.min(max_width / chunk_min).max(1).min(8);
        if workers < 2 {
            self.par = None;
            return self;
        }
        let mut level_par = Vec::with_capacity(self.level_bounds.len());
        let mut par_splits = Vec::new();
        for &(s, e) in &self.level_bounds {
            let width = (e - s) as usize;
            if width >= threshold {
                let mut splits = Vec::with_capacity(workers);
                let per = width.div_ceil(workers);
                for w in 0..workers {
                    let cs = s as usize + (w * per).min(width);
                    let ce = s as usize + ((w + 1) * per).min(width);
                    splits.push((cs as u32, ce as u32));
                }
                level_par.push(Some(par_splits.len() as u32));
                par_splits.push(splits);
            } else {
                level_par.push(None);
            }
        }
        self.par = if par_splits.is_empty() {
            None
        } else {
            Some(ParPlan { workers, level_par, par_splits })
        };
        self
    }

    /// Whether a parallel session would actually fan levels out (false
    /// when the netlist has no sufficiently wide level or the machine
    /// has one core).
    pub fn level_parallelism_active(&self) -> bool {
        self.par.is_some()
    }

    /// Record a toggle word `t` (bit l = lane l toggled) for net `idx`.
    #[inline(always)]
    fn bump(
        toggles: &mut [u64],
        lane_planes: &mut [W; PLANES],
        plane_adds: &mut u64,
        lane_net_toggles: &mut Option<Vec<u64>>,
        idx: usize,
        t: W,
    ) {
        toggles[idx] += u64::from(t.count_ones());
        *plane_adds += 1;
        let carry = plane_accumulate(lane_planes, t);
        debug_assert!(carry.is_zero(), "lane-toggle accumulator overflow");
        if let Some(exact) = lane_net_toggles {
            t.for_each_set_lane(|lane| exact[idx * W::LANES + lane] += 1);
        }
    }

    /// Move the bit-plane accumulator into the flushed per-lane totals.
    fn flush_lanes(&mut self) {
        flush_planes_into(&mut self.lane_planes, &mut self.lane_flushed, &mut self.plane_adds);
    }

    /// Compare-bump-store one input net's word — the single copy of the
    /// input-write path (mirrors `ParSession::write_input_word`).
    /// Borrows are passed split so the callers' bus lookup stays alive.
    #[inline(always)]
    fn write_input_word(
        vals: &mut [W],
        toggles: &mut [u64],
        lane_planes: &mut [W; PLANES],
        plane_adds: &mut u64,
        lane_net_toggles: &mut Option<Vec<u64>>,
        idx: usize,
        w: W,
    ) {
        let t = vals[idx] ^ w;
        if !t.is_zero() {
            Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
            vals[idx] = w;
        }
    }

    /// Bind an input bus to `W::LANES` per-lane integer values
    /// (LSB-first, two's complement truncation to the bus width). Values
    /// hold until overwritten.
    pub fn set_bus_lanes(&mut self, name: &str, values: &[i64]) {
        assert_eq!(values.len(), W::LANES, "expected one value per lane");
        let WordSim {
            bus, vals, toggles, lane_planes, plane_adds, lane_net_toggles, ..
        } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        for (i, bit) in bits.iter().enumerate() {
            let mut w = W::zero();
            for (lane, v) in values.iter().enumerate() {
                w.set_lane(lane, (*v >> i) & 1 == 1);
            }
            Self::write_input_word(
                vals, toggles, lane_planes, plane_adds, lane_net_toggles,
                *bit as usize, w,
            );
        }
    }

    /// Bind an input bus to the same integer value in every lane.
    pub fn set_bus(&mut self, name: &str, value: i64) {
        let WordSim {
            bus, vals, toggles, lane_planes, plane_adds, lane_net_toggles, ..
        } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        for (i, bit) in bits.iter().enumerate() {
            let w = W::splat((value >> i) & 1 == 1);
            Self::write_input_word(
                vals, toggles, lane_planes, plane_adds, lane_net_toggles,
                *bit as usize, w,
            );
        }
    }

    /// Bind a 1-bit input by bus name, one bit per lane.
    pub fn set_bit_word(&mut self, name: &str, word: W) {
        let WordSim {
            bus, vals, toggles, lane_planes, plane_adds, lane_net_toggles, ..
        } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        Self::write_input_word(
            vals, toggles, lane_planes, plane_adds, lane_net_toggles,
            bits[0] as usize, word,
        );
    }

    /// Bind a 1-bit input to the same value in every lane.
    pub fn set_bit(&mut self, name: &str, value: bool) {
        self.set_bit_word(name, W::splat(value));
    }

    /// Run one clock cycle for all lanes: settle combinational logic
    /// level by level, then clock DFFs.
    pub fn step(&mut self) {
        self.cycles += 1;
        // Overflow guard: one step can add at most one count per net per
        // lane (plus input rebinds between steps, bounded by net count).
        if self.plane_adds + 2 * self.nl.len() as u64 >= self.flush_threshold {
            self.flush_lanes();
        }
        let WordSim {
            vals,
            toggles,
            lane_planes,
            plane_adds,
            lane_net_toggles,
            luts,
            level_bounds,
            dffs,
            scratch,
            ..
        } = self;
        for &(s, e) in level_bounds.iter() {
            for l in &luts[s as usize..e as usize] {
                let a = vals[l.ins[0] as usize];
                let b = vals[l.ins[1] as usize];
                let c = vals[l.ins[2] as usize];
                let d = vals[l.ins[3] as usize];
                let new = eval_lut(l.sel, l.inv, a, b, c, d);
                let idx = l.out as usize;
                let t = vals[idx] ^ new;
                if !t.is_zero() {
                    Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
                    vals[idx] = new;
                }
            }
        }
        // Clock edge: sample every D first (a DFF may feed another DFF
        // directly), then commit.
        for (i, &(_, d)) in dffs.iter().enumerate() {
            scratch[i] = vals[d as usize];
        }
        for (i, &(q, _)) in dffs.iter().enumerate() {
            let idx = q as usize;
            let t = vals[idx] ^ scratch[i];
            if !t.is_zero() {
                Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
                vals[idx] = scratch[i];
            }
        }
    }

    /// Synchronous reset: force all DFFs back to init in every lane
    /// (mirrors [`super::GateSim::reset`]; does not count toggles).
    pub fn reset(&mut self) {
        for (id, node) in self.nl.nodes() {
            if let Node::Dff { init, .. } = node {
                self.vals[id as usize] = W::splat(*init);
            }
        }
    }

    /// Read an output bus in one lane as a sign-extended integer.
    pub fn get_output_lane(&self, name: &str, lane: usize) -> i64 {
        assert!(lane < W::LANES, "lane out of range");
        let bits = self.output_bits(name);
        let mut v: i64 = 0;
        for (i, bit) in bits.iter().enumerate() {
            if self.vals[*bit as usize].lane(lane) {
                v |= 1 << i;
            }
        }
        let w = bits.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read an output bus in all lanes.
    pub fn get_output_lanes(&self, name: &str) -> Vec<i64> {
        (0..W::LANES).map(|lane| self.get_output_lane(name, lane)).collect()
    }

    /// Read a single-bit output as a lane word (bit l = lane l).
    pub fn get_bit_word(&self, name: &str) -> W {
        let bits = self.output_bits(name);
        self.vals[bits[0] as usize]
    }

    fn output_bits(&self, name: &str) -> &[NetId] {
        // Hot in done-polling drive loops; O(1) via the netlist's
        // prebuilt name index.
        self.nl
            .output_bits(name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"))
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-net toggle counts, summed across all lanes.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Total toggles across all nets and lanes.
    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Total toggles per lane (across all nets); `W::LANES` entries.
    pub fn lane_total_toggles(&mut self) -> Vec<u64> {
        self.flush_lanes();
        self.lane_flushed.clone()
    }

    /// Per-lane mean toggles per net per cycle (`W::LANES` independent
    /// switching activity factors α from one simulation pass).
    pub fn lane_mean_activity(&mut self) -> Vec<f64> {
        let totals = self.lane_total_toggles();
        let denom = self.cycles as f64 * self.nl.len() as f64;
        if denom > 0.0 {
            totals.iter().map(|&t| t as f64 / denom).collect()
        } else {
            vec![0.0; W::LANES]
        }
    }

    /// Mean toggles per net per cycle per lane, averaged over lanes
    /// (comparable to [`super::GateSim::mean_activity`]).
    pub fn mean_activity(&self) -> f64 {
        if self.cycles == 0 || self.nl.is_empty() {
            return 0.0;
        }
        self.total_toggles() as f64
            / (self.cycles as f64 * self.nl.len() as f64 * W::LANES as f64)
    }

    /// Exact per-net toggle counts for one lane (requires
    /// [`WordSim::with_lane_net_toggles`]).
    pub fn lane_net_toggles(&self, lane: usize) -> Vec<u64> {
        assert!(lane < W::LANES, "lane out of range");
        let exact = self
            .lane_net_toggles
            .as_ref()
            .expect("enable with_lane_net_toggles() first");
        (0..self.nl.len()).map(|net| exact[net * W::LANES + lane]).collect()
    }

    /// Combinational depth of the packed plan (levels iterated per step).
    pub fn depth(&self) -> u32 {
        self.level_bounds.len() as u32
    }

    /// Run `f` against a [`ParSession`] over this simulator: worker
    /// threads (when [`WordSim::with_level_parallelism`] armed a plan)
    /// are spawned once for the whole session and spin-joined at every
    /// wide level, so their cost amortizes over arbitrarily many steps.
    /// Without a plan the session degenerates to the sequential engine.
    /// All counters (cycles, toggles, lane planes) live in `self` and
    /// remain valid after the session ends; results are bit-identical to
    /// driving [`WordSim::step`] directly.
    pub fn parallel_session<R>(
        &mut self,
        f: impl FnOnce(&mut ParSession<'_, W>) -> R,
    ) -> R {
        let degenerate = ParPlan {
            workers: 1,
            level_par: vec![None; self.level_bounds.len()],
            par_splits: Vec::new(),
        };
        let plan = self.par.clone().unwrap_or(degenerate);
        let nl = self.nl;
        let nets = nl.len();
        let WordSim {
            vals,
            toggles,
            lane_planes,
            lane_flushed,
            plane_adds,
            flush_threshold,
            lane_net_toggles,
            cycles,
            bus,
            luts,
            level_bounds,
            dffs,
            scratch,
            ..
        } = self;
        let mut tword = vec![W::zero(); luts.len()];
        // Shared raw views: created once from the exclusive borrows and
        // used (by all threads, under the phase protocol) for the whole
        // session; the original borrows are not touched again until the
        // scope ends.
        let vals_raw = RawSlice::new(vals.as_mut_slice());
        let toggles_raw = RawSlice::new(toggles.as_mut_slice());
        let tword_raw = RawSlice::new(tword.as_mut_slice());
        let ctrl = ParCtrl { phase: AtomicUsize::new(0), done: AtomicUsize::new(0) };
        let luts: &[PackedWordLut] = luts;
        let plan_ref = &plan;
        let ctrl_ref = &ctrl;
        std::thread::scope(|s| {
            for w in 1..plan.workers {
                s.spawn(move || {
                    let n_par = plan_ref.par_splits.len();
                    let mut last = 0usize;
                    loop {
                        let p = wait_phase(ctrl_ref, last);
                        if p == PHASE_STOP {
                            break;
                        }
                        last = p;
                        let (cs, ce) = plan_ref.par_splits[(p - 1) % n_par][w];
                        // SAFETY: this worker's chunk owns its LUTs' out
                        // nets and tword slots exclusively for the phase
                        // (chunks are disjoint); all reads target nets
                        // of earlier levels, finished in earlier phases
                        // (Release/Acquire on phase/done orders them).
                        unsafe {
                            eval_chunk(
                                luts,
                                vals_raw,
                                toggles_raw,
                                tword_raw,
                                cs as usize,
                                ce as usize,
                            );
                        }
                        ctrl_ref.done.fetch_add(1, Ordering::Release);
                    }
                });
            }
            // Workers spin on `phase` until told to stop; a panic in `f`
            // (e.g. a failed assertion in a test drive loop) must still
            // release them or the scope would join forever.
            struct StopGuard<'c>(&'c ParCtrl);
            impl Drop for StopGuard<'_> {
                fn drop(&mut self) {
                    self.0.phase.store(PHASE_STOP, Ordering::Release);
                }
            }
            let _stop = StopGuard(ctrl_ref);
            let mut session = ParSession {
                nl,
                nets,
                vals: vals_raw,
                toggles: toggles_raw,
                tword: tword_raw,
                lane_planes,
                lane_flushed,
                plane_adds,
                flush_threshold: *flush_threshold,
                lane_net_toggles,
                cycles,
                bus,
                luts,
                level_bounds,
                dffs,
                scratch,
                plan: plan_ref,
                ctrl: ctrl_ref,
                next_phase: 1,
                expected_done: 0,
            };
            // `_stop`'s Drop releases the workers on return and unwind
            // alike.
            f(&mut session)
        })
    }
}

/// The stimulus/readback drive surface of the lane-parallel engines.
///
/// [`WordSim`] and [`ParSession`] implement it bit-identically, so one
/// drive loop — a testbench harness, the power-measurement loop in
/// [`crate::power`], a serving batcher — runs unmodified against either
/// the sequential engine or an intra-level parallel session. This trait
/// is the single public copy of the surface (it replaces the former
/// `WordSim`-method / `ParSession`-mirror / private-`power`-trait
/// triplication).
pub trait Drive<W: LaneWord> {
    /// Bind an input bus to `W::LANES` per-lane integer values
    /// (LSB-first, two's complement truncation to the bus width).
    /// Values hold until overwritten.
    fn set_bus_lanes(&mut self, name: &str, values: &[i64]);
    /// Bind an input bus to the same integer value in every lane.
    fn set_bus(&mut self, name: &str, value: i64);
    /// Bind a 1-bit input by bus name, one bit per lane.
    fn set_bit_word(&mut self, name: &str, word: W);
    /// Read a single-bit output as a lane word (bit l = lane l).
    fn get_bit_word(&self, name: &str) -> W;
    /// Run one clock cycle for all lanes.
    fn step(&mut self);

    /// Bind a 1-bit input to the same value in every lane.
    fn set_bit(&mut self, name: &str, value: bool) {
        self.set_bit_word(name, W::splat(value));
    }
}

impl<W: LaneWord> Drive<W> for WordSim<'_, W> {
    fn set_bus_lanes(&mut self, name: &str, values: &[i64]) {
        WordSim::set_bus_lanes(self, name, values);
    }
    fn set_bus(&mut self, name: &str, value: i64) {
        WordSim::set_bus(self, name, value);
    }
    fn set_bit_word(&mut self, name: &str, word: W) {
        WordSim::set_bit_word(self, name, word);
    }
    fn get_bit_word(&self, name: &str) -> W {
        WordSim::get_bit_word(self, name)
    }
    fn step(&mut self) {
        WordSim::step(self);
    }
}

// ---- intra-level parallel session ----------------------------------------

pub(crate) const PHASE_STOP: usize = usize::MAX;

/// Spin-phase control shared between the driving thread and the level
/// workers (reused shard-per-worker by [`crate::shard::ShardSim`]).
/// `phase` increments once per fanned-out level (monotonic across
/// steps); `done` counts worker completions.
pub(crate) struct ParCtrl {
    pub(crate) phase: AtomicUsize,
    pub(crate) done: AtomicUsize,
}

/// Spin until `phase` moves past `last`, with escalating backoff: pure
/// spin for the common fast path (the next fanned level is typically
/// microseconds away), then yields, then short sleeps — so workers
/// don't burn whole cores while the driving thread is in a long
/// sequential stretch (stimulus packing, narrow levels, inter-step
/// work).
pub(crate) fn wait_phase(ctrl: &ParCtrl, last: usize) -> usize {
    let mut spins = 0u32;
    loop {
        let p = ctrl.phase.load(Ordering::Acquire);
        if p != last {
            return p;
        }
        spins = spins.saturating_add(1);
        if spins < 1 << 12 {
            std::hint::spin_loop();
        } else if spins < 1 << 16 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }
}

/// A raw shared view of a slice, for the phase-protocol fork-join. All
/// accesses are `unsafe`; callers uphold disjointness + ordering (see
/// [`WordSim::parallel_session`]).
pub(crate) struct RawSlice<T> {
    ptr: *mut T,
    #[cfg(debug_assertions)]
    len: usize,
}

impl<T: Copy> RawSlice<T> {
    pub(crate) fn new(s: &mut [T]) -> RawSlice<T> {
        RawSlice {
            ptr: s.as_mut_ptr(),
            #[cfg(debug_assertions)]
            len: s.len(),
        }
    }

    #[inline(always)]
    pub(crate) unsafe fn get(&self, i: usize) -> T {
        #[cfg(debug_assertions)]
        assert!(i < self.len, "RawSlice read out of bounds: {i} >= {}", self.len);
        // SAFETY: `i` is in bounds of the slice this view was created
        // from (debug-asserted above), and the caller guarantees no
        // thread concurrently writes element `i` (phase protocol).
        unsafe { *self.ptr.add(i) }
    }

    #[inline(always)]
    pub(crate) unsafe fn set(&self, i: usize, v: T) {
        #[cfg(debug_assertions)]
        assert!(i < self.len, "RawSlice write out of bounds: {i} >= {}", self.len);
        // SAFETY: `i` is in bounds (debug-asserted above), and the
        // caller guarantees exclusive ownership of element `i` for the
        // duration of the phase (no concurrent read or write).
        unsafe { *self.ptr.add(i) = v }
    }
}

impl<T> Clone for RawSlice<T> {
    fn clone(&self) -> RawSlice<T> {
        *self
    }
}

impl<T> Copy for RawSlice<T> {}

// SAFETY: the phase protocol serializes all conflicting accesses; the
// wrapper itself only carries the pointer.
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

/// Evaluate packed LUTs `[s, e)`: write new value words, per-net toggle
/// counts, and the per-slot toggle word (consumed by the driving
/// thread's plane accounting).
///
/// SAFETY: the caller guarantees (a) exclusive ownership of the out nets
/// and `tword` slots in the range for the duration of the call, and (b)
/// that every input net read is not concurrently written (levelization:
/// inputs live in strictly earlier levels).
pub(crate) unsafe fn eval_chunk<W: LaneWord>(
    luts: &[PackedWordLut],
    vals: RawSlice<W>,
    toggles: RawSlice<u64>,
    tword: RawSlice<W>,
    s: usize,
    e: usize,
) {
    for (i, l) in luts[s..e].iter().enumerate() {
        // SAFETY: input nets live in strictly earlier levels, finished
        // in earlier phases (caller contract (b)); the out net and
        // tword slot `s + i` belong to this chunk exclusively (caller
        // contract (a)) — chunks partition `[s, e)` slots and out nets.
        unsafe {
            let a = vals.get(l.ins[0] as usize);
            let b = vals.get(l.ins[1] as usize);
            let c = vals.get(l.ins[2] as usize);
            let d = vals.get(l.ins[3] as usize);
            let new = eval_lut(l.sel, l.inv, a, b, c, d);
            let idx = l.out as usize;
            let t = vals.get(idx) ^ new;
            tword.set(s + i, t);
            if !t.is_zero() {
                vals.set(idx, new);
                toggles.set(idx, toggles.get(idx) + u64::from(t.count_ones()));
            }
        }
    }
}

/// A driving handle over a [`WordSim`] whose wide levels fan out across
/// the session's worker threads. Its whole stimulus/readback surface is
/// the shared [`Drive`] trait; stepping through it produces results
/// bit-identical to [`WordSim::step`].
pub struct ParSession<'a, W: LaneWord> {
    nl: &'a Netlist,
    nets: usize,
    vals: RawSlice<W>,
    toggles: RawSlice<u64>,
    tword: RawSlice<W>,
    lane_planes: &'a mut [W; PLANES],
    lane_flushed: &'a mut Vec<u64>,
    plane_adds: &'a mut u64,
    flush_threshold: u64,
    lane_net_toggles: &'a mut Option<Vec<u64>>,
    cycles: &'a mut u64,
    bus: &'a HashMap<String, Vec<NetId>>,
    luts: &'a [PackedWordLut],
    level_bounds: &'a [(u32, u32)],
    dffs: &'a [(u32, u32)],
    scratch: &'a mut Vec<W>,
    plan: &'a ParPlan,
    ctrl: &'a ParCtrl,
    next_phase: usize,
    expected_done: usize,
}

impl<'a, W: LaneWord> ParSession<'a, W> {
    /// Compare-bump-store one input word (main thread; workers idle).
    #[inline]
    fn write_input_word(&mut self, idx: usize, w: W) {
        // SAFETY: outside a phase the driving thread has exclusive
        // access to every shared buffer.
        unsafe {
            let t = self.vals.get(idx) ^ w;
            if !t.is_zero() {
                self.bump(idx, t);
                self.vals.set(idx, w);
            }
        }
    }

    /// Full toggle accounting for one net (counter + planes + exact).
    #[inline]
    unsafe fn bump(&mut self, idx: usize, t: W) {
        // SAFETY: the caller guarantees exclusive access to the shared
        // buffers (drive surface, outside any phase).
        unsafe {
            self.toggles.set(idx, self.toggles.get(idx) + u64::from(t.count_ones()));
        }
        self.bump_planes(idx, t);
    }

    /// Plane + exact-counter half of toggle accounting (the per-net
    /// counter was already updated by [`eval_chunk`]).
    #[inline]
    fn bump_planes(&mut self, idx: usize, t: W) {
        *self.plane_adds += 1;
        let carry = plane_accumulate(self.lane_planes, t);
        debug_assert!(carry.is_zero(), "lane-toggle accumulator overflow");
        if let Some(exact) = self.lane_net_toggles {
            t.for_each_set_lane(|lane| exact[idx * W::LANES + lane] += 1);
        }
    }

    fn input_bits(&self, name: &str) -> &'a [NetId] {
        self.bus
            .get(name)
            .unwrap_or_else(|| panic!("no input bus `{name}`"))
    }
}

impl<W: LaneWord> Drive<W> for ParSession<'_, W> {
    fn set_bus_lanes(&mut self, name: &str, values: &[i64]) {
        assert_eq!(values.len(), W::LANES, "expected one value per lane");
        let bits = self.input_bits(name);
        for (i, bit) in bits.iter().enumerate() {
            let mut w = W::zero();
            for (lane, v) in values.iter().enumerate() {
                w.set_lane(lane, (*v >> i) & 1 == 1);
            }
            self.write_input_word(*bit as usize, w);
        }
    }

    fn set_bus(&mut self, name: &str, value: i64) {
        let bits = self.input_bits(name);
        for (i, bit) in bits.iter().enumerate() {
            let w = W::splat((value >> i) & 1 == 1);
            self.write_input_word(*bit as usize, w);
        }
    }

    fn set_bit_word(&mut self, name: &str, word: W) {
        let bits = self.input_bits(name);
        self.write_input_word(bits[0] as usize, word);
    }

    fn get_bit_word(&self, name: &str) -> W {
        let bits = self
            .nl
            .output_bits(name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"));
        // SAFETY: read outside any phase; main thread exclusive.
        unsafe { self.vals.get(bits[0] as usize) }
    }

    /// One clock cycle for all lanes, wide levels fanned out across the
    /// session workers.
    fn step(&mut self) {
        *self.cycles += 1;
        if *self.plane_adds + 2 * self.nets as u64 >= self.flush_threshold {
            flush_planes_into(self.lane_planes, self.lane_flushed, self.plane_adds);
        }
        for (lvl, &(s, e)) in self.level_bounds.iter().enumerate() {
            let (s, e) = (s as usize, e as usize);
            if s == e {
                continue;
            }
            match self.plan.level_par[lvl] {
                Some(pi) => {
                    let splits = &self.plan.par_splits[pi as usize];
                    self.ctrl.phase.store(self.next_phase, Ordering::Release);
                    self.next_phase += 1;
                    let (cs, ce) = splits[0];
                    // SAFETY: chunk 0 is the driving thread's; see the
                    // worker-side comment for the disjointness argument.
                    unsafe {
                        eval_chunk(
                            self.luts,
                            self.vals,
                            self.toggles,
                            self.tword,
                            cs as usize,
                            ce as usize,
                        );
                    }
                    self.expected_done += self.plan.workers - 1;
                    let mut spins = 0u32;
                    while self.ctrl.done.load(Ordering::Acquire) < self.expected_done {
                        spins = spins.wrapping_add(1);
                        if spins % 4096 == 0 {
                            std::thread::yield_now();
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                }
                None => unsafe {
                    eval_chunk(self.luts, self.vals, self.toggles, self.tword, s, e);
                },
            }
            // Plane accounting for the level, on the driving thread, in
            // plan order — bit-identical to the sequential engine.
            for i in s..e {
                // SAFETY: workers are joined (or never ran); exclusive.
                let t = unsafe { self.tword.get(i) };
                if !t.is_zero() {
                    let idx = self.luts[i].out as usize;
                    self.bump_planes(idx, t);
                }
            }
        }
        // Clock edge: sample every D first, then commit (main thread).
        for (i, &(_, d)) in self.dffs.iter().enumerate() {
            // SAFETY: exclusive outside phases.
            self.scratch[i] = unsafe { self.vals.get(d as usize) };
        }
        for (i, &(q, _)) in self.dffs.iter().enumerate() {
            let idx = q as usize;
            let sampled = self.scratch[i];
            unsafe {
                let t = self.vals.get(idx) ^ sampled;
                if !t.is_zero() {
                    self.bump(idx, t);
                    self.vals.set(idx, sampled);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::gatesim::GateSim;
    use crate::synth::lane::W256;
    use crate::synth::netlist::Netlist;

    /// 4-bit counter netlist (same as the scalar GateSim test).
    fn counter() -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..4).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    fn counter_counts_in_every_lane_impl<W: LaneWord>() {
        let nl = counter();
        let mut sim = WordSim::<W>::new(&nl);
        for expect in 1..=20i64 {
            sim.step();
            let lanes = sim.get_output_lanes("q");
            assert_eq!(lanes.len(), W::LANES);
            for (lane, v) in lanes.iter().enumerate() {
                assert_eq!(v & 0xF, expect & 0xF, "lane {lane} cycle {expect}");
            }
        }
    }

    #[test]
    fn counter_counts_in_every_lane() {
        counter_counts_in_every_lane_impl::<u64>();
        counter_counts_in_every_lane_impl::<W256>();
    }

    fn lanes_are_independent_impl<W: LaneWord>() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let y: Vec<NetId> = a.iter().zip(&b).map(|(&x, &y)| nl.and2(x, y)).collect();
        nl.add_output("y", y);
        let mut sim = WordSim::<W>::new(&nl);
        let mut av = vec![0i64; W::LANES];
        let mut bv = vec![0i64; W::LANES];
        for lane in 0..W::LANES {
            av[lane] = (lane as i64) & 0xF;
            bv[lane] = ((lane as i64) >> 2) & 0xF;
        }
        sim.set_bus_lanes("a", &av);
        sim.set_bus_lanes("b", &bv);
        sim.step();
        let got = sim.get_output_lanes("y");
        for lane in 0..W::LANES {
            assert_eq!(got[lane] & 0xF, av[lane] & bv[lane], "lane {lane}");
        }
    }

    #[test]
    fn lanes_are_independent() {
        lanes_are_independent_impl::<u64>();
        lanes_are_independent_impl::<W256>();
    }

    fn broadcast_matches_scalar_oracle_impl<W: LaneWord>() {
        let nl = counter();
        let mut word = WordSim::<W>::new(&nl);
        let mut scalar = GateSim::new(&nl);
        for _ in 0..50 {
            word.step();
            scalar.step();
            assert_eq!(word.get_output_lane("q", 0), scalar.get_output("q"));
            assert_eq!(
                word.get_output_lane("q", W::LANES - 1),
                scalar.get_output("q")
            );
        }
        // Broadcast lanes toggle identically, so per-net totals are
        // LANES×.
        for (net, &t) in scalar.toggles().iter().enumerate() {
            assert_eq!(word.toggles()[net], t * W::LANES as u64, "net {net}");
        }
        let lanes = word.lane_total_toggles();
        assert_eq!(lanes.len(), W::LANES);
        for (lane, &t) in lanes.iter().enumerate() {
            assert_eq!(t, scalar.total_toggles(), "lane {lane}");
        }
    }

    #[test]
    fn broadcast_matches_scalar_oracle() {
        broadcast_matches_scalar_oracle_impl::<u64>();
        broadcast_matches_scalar_oracle_impl::<W256>();
    }

    #[test]
    fn sign_extension_per_lane() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        nl.add_output("y", a);
        let mut sim = WordSim::<u64>::new(&nl);
        let mut av = [0i64; LANES];
        av[3] = -3;
        av[17] = 5;
        sim.set_bus_lanes("a", &av);
        sim.step();
        assert_eq!(sim.get_output_lane("y", 3), -3);
        assert_eq!(sim.get_output_lane("y", 17), 5);
        assert_eq!(sim.get_output_lane("y", 0), 0);
    }

    fn exact_lane_net_toggles_impl<W: LaneWord>() {
        let nl = counter();
        let mut sim = WordSim::<W>::new(&nl).with_lane_net_toggles();
        for _ in 0..37 {
            sim.step();
        }
        // Sum of exact per-lane counts equals the word-parallel per-net
        // counters, for every net.
        for net in 0..nl.len() {
            let sum: u64 = (0..W::LANES).map(|l| sim.lane_net_toggles(l)[net]).sum();
            assert_eq!(sum, sim.toggles()[net], "net {net}");
        }
        // And per-lane totals agree with the bit-plane accumulator.
        let plane_totals = sim.lane_total_toggles();
        for lane in 0..W::LANES {
            let exact: u64 = sim.lane_net_toggles(lane).iter().sum();
            assert_eq!(exact, plane_totals[lane], "lane {lane}");
        }
    }

    #[test]
    fn exact_lane_net_toggles_match_aggregates() {
        exact_lane_net_toggles_impl::<u64>();
        exact_lane_net_toggles_impl::<W256>();
    }

    #[test]
    fn reset_restores_init_all_lanes() {
        let mut nl = Netlist::new();
        let one = nl.constant(true);
        let d = nl.dff(one, false);
        nl.add_output("q", vec![d]);
        let mut sim = WordSim::<u64>::new(&nl);
        sim.step();
        assert_eq!(sim.get_bit_word("q"), !0);
        sim.reset();
        assert_eq!(sim.get_bit_word("q"), 0);
    }

    fn mux_tree_impl<W: LaneWord>() {
        // Exhaustive over arities and random truth tables: the compiled
        // sel/inv plan equals per-bit truth-table lookup.
        let mut rng = crate::stim::Lfsr32::new(0x7AB1E);
        for _ in 0..200 {
            let arity = 1 + rng.below(4);
            let tt = (rng.next_u32() & 0xFFFF) as u16;
            let (sel, inv) = compile_tt(tt, arity);
            let words: Vec<W> = (0..4)
                .map(|_| {
                    let mut w = W::zero();
                    for lane in 0..W::LANES {
                        w.set_lane(lane, rng.next_u32() & 1 == 1);
                    }
                    w
                })
                .collect();
            let mut ins = [words[0]; 4];
            for (k, slot) in ins.iter_mut().enumerate().take(arity) {
                *slot = words[k];
            }
            let got = eval_lut(sel, inv, ins[0], ins[1], ins[2], ins[3]);
            let mask = (1usize << arity) - 1;
            for lane in 0..W::LANES {
                let mut idx = 0usize;
                for (k, w) in words.iter().enumerate().take(arity) {
                    idx |= usize::from(w.lane(lane)) << k;
                }
                let want = tt >> (idx & mask) & 1 == 1;
                assert_eq!(got.lane(lane), want, "arity {arity} tt {tt:#x} lane {lane}");
            }
        }
    }

    #[test]
    fn mux_tree_matches_truth_table_indexing() {
        mux_tree_impl::<u64>();
        mux_tree_impl::<W256>();
    }

    fn tiny_flush_threshold_impl<W: LaneWord>() {
        // A minuscule flush threshold forces the overflow-flush path on
        // virtually every step; totals must be identical to a run that
        // never flushes before the final read.
        let nl = counter();
        let mut tiny = WordSim::<W>::new(&nl)
            .with_lane_net_toggles()
            .with_plane_flush_threshold(2 * nl.len() as u64 + 1);
        let mut big = WordSim::<W>::new(&nl).with_lane_net_toggles();
        for _ in 0..123 {
            tiny.step();
            big.step();
        }
        assert_eq!(tiny.lane_total_toggles(), big.lane_total_toggles());
        assert_eq!(tiny.toggles(), big.toggles());
        for lane in [0usize, 1, W::LANES - 1] {
            assert_eq!(tiny.lane_net_toggles(lane), big.lane_net_toggles(lane));
        }
    }

    #[test]
    fn tiny_flush_threshold_loses_no_counts() {
        tiny_flush_threshold_impl::<u64>();
        tiny_flush_threshold_impl::<W256>();
    }

    /// A netlist with one very wide combinational level: `n` independent
    /// AND gates off two input buses, all at level 1, plus a register
    /// layer to exercise the clock edge.
    fn wide_level_netlist(n: usize) -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let b = nl.input_bus("b", 8);
        let mut outs = Vec::new();
        for i in 0..n {
            let x = a[i % a.len()];
            let y = b[(i / a.len()) % b.len()];
            let g = match i % 3 {
                0 => nl.and2(x, y),
                1 => nl.xor2(x, y),
                _ => nl.or2(x, y),
            };
            outs.push(nl.dff(g, false));
        }
        // Observe a slice of the register outputs.
        nl.add_output("y", outs[..8.min(outs.len())].to_vec());
        nl
    }

    fn parallel_session_matches_sequential_impl<W: LaneWord>() {
        let nl = wide_level_netlist(512);
        let mut rng = crate::stim::Lfsr32::new(0x9A11);
        let stim: Vec<(i64, i64)> = (0..40)
            .map(|_| (rng.next_u32() as i64 & 0xFF, rng.next_u32() as i64 & 0xFF))
            .collect();

        let mut seq = WordSim::<W>::new(&nl).with_lane_net_toggles();
        for &(a, b) in &stim {
            seq.set_bus("a", a);
            seq.set_bus("b", b);
            seq.step();
        }

        let mut par = WordSim::<W>::new(&nl)
            .with_lane_net_toggles()
            .with_level_parallelism(64);
        par.parallel_session(|s| {
            for &(a, b) in &stim {
                s.set_bus("a", a);
                s.set_bus("b", b);
                s.step();
            }
        });

        assert_eq!(par.cycles(), seq.cycles());
        assert_eq!(par.toggles(), seq.toggles());
        assert_eq!(par.get_output_lanes("y"), seq.get_output_lanes("y"));
        assert_eq!(par.lane_total_toggles(), seq.lane_total_toggles());
        for lane in [0usize, W::LANES / 2, W::LANES - 1] {
            assert_eq!(par.lane_net_toggles(lane), seq.lane_net_toggles(lane), "lane {lane}");
        }
    }

    #[test]
    fn parallel_session_matches_sequential() {
        parallel_session_matches_sequential_impl::<u64>();
        parallel_session_matches_sequential_impl::<W256>();
    }

    #[test]
    fn parallel_session_without_plan_is_sequential() {
        // No with_level_parallelism: the session must degenerate cleanly
        // (no workers) and still be exact.
        let nl = counter();
        let mut a = WordSim::<u64>::new(&nl);
        a.parallel_session(|s| {
            for _ in 0..10 {
                s.step();
            }
        });
        let mut b = WordSim::<u64>::new(&nl);
        for _ in 0..10 {
            b.step();
        }
        assert!(!a.level_parallelism_active());
        assert_eq!(a.toggles(), b.toggles());
        assert_eq!(a.get_output_lanes("q"), b.get_output_lanes("q"));
    }

    #[test]
    fn narrow_levels_do_not_arm_parallelism() {
        let nl = counter();
        let sim = WordSim::<u64>::new(&nl).with_level_parallelism(LEVEL_PAR_THRESHOLD);
        assert!(!sim.level_parallelism_active());
    }
}
