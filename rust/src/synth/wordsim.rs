//! Bit-parallel (64-wide) gate-level simulation.
//!
//! # Lane model
//!
//! [`WordSim`] advances **64 independent stimulus streams per machine
//! word**: every net holds a `u64` whose bit *l* is the net's boolean
//! value in lane *l*. One [`WordSim::step`] therefore simulates one clock
//! cycle of 64 independent copies of the design at once — the classic
//! compiled-code / emulation-engine trick that turns the power-analysis
//! workload (long LFSR stimulus runs, see [`crate::power`]) from one
//! boolean per net per cycle into one word op per net per cycle.
//!
//! Lanes never interact: lane *l* of every net evolves exactly as a
//! scalar [`super::GateSim`] run would with lane *l*'s inputs. The scalar
//! simulator is kept as the reference oracle; the differential test suite
//! (`tests/wordsim_differential.rs`) asserts lane-by-lane identity of
//! outputs and per-net toggle counts on the whole corpus.
//!
//! # LUT evaluation
//!
//! At pack time each LUT's truth table is expanded to 4 inputs and
//! compiled into an 8-leaf Shannon mux tree over the input words: the two
//! cofactor bits of each leaf collapse into per-leaf `sel`/`inv` masks
//! (leaf = `(a & sel) ^ inv`, each mask all-ones or all-zero), and the
//! remaining three variables are resolved with the branch-free word mux
//! `x0 ^ (s & (x0 ^ x1))`. The hot loop is straight-line AND/XOR word
//! ops — no per-bit truth-table indexing, no branches, no hash lookups.
//!
//! # Levelization
//!
//! The evaluation plan is grouped by the combinational levels computed by
//! [`Netlist::levelize`] (validated topological order). Iterating dense
//! per-level slices keeps the schedule correct under any future
//! within-level reordering or parallel evaluation, and documents the
//! data-dependence structure explicitly.
//!
//! # Toggle counting
//!
//! Toggles are counted word-parallel: `count_ones` of `old ^ new` updates
//! the per-net counter for all 64 lanes at once, and the same XOR word is
//! accumulated into per-lane totals through a 32-deep bit-plane
//! carry-save counter (amortized ~2 word ops per toggled net), so one
//! simulation pass yields 64 independent switching-activity estimates.

use super::netlist::{NetId, Netlist, Node};
use std::collections::HashMap;

/// Number of independent simulation lanes per machine word.
pub const LANES: usize = 64;

/// Bit-planes of the per-lane toggle accumulator (counts up to 2³² − 1
/// toggles per lane between flushes).
const PLANES: usize = 32;

/// One LUT in the packed word-parallel evaluation plan.
#[derive(Clone, Copy)]
struct PackedWordLut {
    /// Output net index.
    out: u32,
    /// Input net indices (unused slots repeat input 0; the truth-table
    /// expansion makes them don't-cares).
    ins: [u32; 4],
    /// Leaf-select mask: bit j set ⇒ leaf j depends on input 0.
    sel: u8,
    /// Leaf-invert mask: bit j set ⇒ leaf j is complemented.
    inv: u8,
}

/// All-ones word if bit `i` of `byte` is set, else zero (branch-free).
#[inline(always)]
fn spread(byte: u8, i: u32) -> u64 {
    0u64.wrapping_sub(u64::from((byte >> i) & 1))
}

/// Straight-line Shannon mux-tree evaluation of a packed LUT over four
/// input words. ~30 word ops for 64 lanes.
#[inline(always)]
fn eval_lut(sel: u8, inv: u8, a: u64, b: u64, c: u64, d: u64) -> u64 {
    let l0 = (a & spread(sel, 0)) ^ spread(inv, 0);
    let l1 = (a & spread(sel, 1)) ^ spread(inv, 1);
    let l2 = (a & spread(sel, 2)) ^ spread(inv, 2);
    let l3 = (a & spread(sel, 3)) ^ spread(inv, 3);
    let l4 = (a & spread(sel, 4)) ^ spread(inv, 4);
    let l5 = (a & spread(sel, 5)) ^ spread(inv, 5);
    let l6 = (a & spread(sel, 6)) ^ spread(inv, 6);
    let l7 = (a & spread(sel, 7)) ^ spread(inv, 7);
    let m0 = l0 ^ (b & (l0 ^ l1));
    let m1 = l2 ^ (b & (l2 ^ l3));
    let m2 = l4 ^ (b & (l4 ^ l5));
    let m3 = l6 ^ (b & (l6 ^ l7));
    let n0 = m0 ^ (c & (m0 ^ m1));
    let n1 = m2 ^ (c & (m2 ^ m3));
    n0 ^ (d & (n0 ^ n1))
}

/// Expand a truth table of the given arity to 4 inputs (index bits beyond
/// the arity are don't-cares), then derive the 8 mux-tree leaf masks.
fn compile_tt(tt: u16, arity: usize) -> (u8, u8) {
    let mask = (1usize << arity) - 1;
    let mut tt4 = 0u16;
    for idx in 0..16usize {
        if tt >> (idx & mask) & 1 == 1 {
            tt4 |= 1 << idx;
        }
    }
    let mut sel = 0u8;
    let mut inv = 0u8;
    for j in 0..8 {
        let lo = tt4 >> (2 * j) & 1;
        let hi = tt4 >> (2 * j + 1) & 1;
        if lo ^ hi == 1 {
            sel |= 1 << j;
        }
        if lo == 1 {
            inv |= 1 << j;
        }
    }
    (sel, inv)
}

/// 64-lane word-parallel simulation state for one netlist.
pub struct WordSim<'n> {
    nl: &'n Netlist,
    /// Current value word of every net (bit l = lane l).
    vals: Vec<u64>,
    /// Per-net toggle counters, summed across lanes.
    toggles: Vec<u64>,
    /// Bit-plane carry-save accumulator of per-lane toggle totals.
    lane_planes: [u64; PLANES],
    /// Flushed per-lane toggle totals.
    lane_flushed: [u64; LANES],
    /// Accumulator adds since the last flush (overflow guard).
    plane_adds: u64,
    /// Optional exact per-net per-lane counters (`net * LANES + lane`),
    /// for differential testing; costs one pass over set toggle bits.
    lane_net_toggles: Option<Vec<u64>>,
    /// Cycles executed.
    cycles: u64,
    /// Input bus name -> bit net ids.
    bus: HashMap<String, Vec<NetId>>,
    /// Packed combinational plan, grouped by level.
    luts: Vec<PackedWordLut>,
    /// Half-open ranges into `luts`, one per combinational level.
    level_bounds: Vec<(u32, u32)>,
    /// (dff net, d net) pairs.
    dffs: Vec<(u32, u32)>,
    /// Two-phase clock-edge scratch (sampled D words).
    scratch: Vec<u64>,
}

impl<'n> WordSim<'n> {
    /// Create a simulator with flip-flops at their init values in every
    /// lane.
    pub fn new(nl: &'n Netlist) -> WordSim<'n> {
        let lv = nl.levelize();
        let mut vals = vec![0u64; nl.len()];
        let mut dffs = Vec::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Const(true) => vals[id as usize] = !0,
                Node::Dff { d, init } => {
                    if *init {
                        vals[id as usize] = !0;
                    }
                    dffs.push((id, *d));
                }
                _ => {}
            }
        }
        let mut luts = Vec::with_capacity(lv.order.len());
        let mut level_bounds = Vec::with_capacity(lv.bounds.len());
        for level in 1..=lv.depth() {
            let start = luts.len() as u32;
            for &id in lv.level_luts(level) {
                let Node::Lut { ins, tt } = nl.node(id) else {
                    unreachable!("levelization order contains only LUTs")
                };
                let mut packed = [ins[0]; 4];
                for (k, &i) in ins.iter().enumerate() {
                    packed[k] = i;
                }
                let (sel, inv) = compile_tt(*tt, ins.len());
                luts.push(PackedWordLut { out: id, ins: packed, sel, inv });
            }
            level_bounds.push((start, luts.len() as u32));
        }
        let bus = nl
            .input_buses
            .iter()
            .map(|(n, b)| (n.clone(), b.clone()))
            .collect();
        let scratch = vec![0u64; dffs.len()];
        WordSim {
            nl,
            vals,
            toggles: vec![0; nl.len()],
            lane_planes: [0; PLANES],
            lane_flushed: [0; LANES],
            plane_adds: 0,
            lane_net_toggles: None,
            cycles: 0,
            bus,
            luts,
            level_bounds,
            dffs,
            scratch,
        }
    }

    /// Enable exact per-net per-lane toggle tracking (slower; meant for
    /// differential testing against the scalar oracle).
    pub fn with_lane_net_toggles(mut self) -> WordSim<'n> {
        self.lane_net_toggles = Some(vec![0u64; self.nl.len() * LANES]);
        self
    }

    /// Record a toggle word `t` (bit l = lane l toggled) for net `idx`.
    #[inline(always)]
    fn bump(
        toggles: &mut [u64],
        lane_planes: &mut [u64; PLANES],
        plane_adds: &mut u64,
        lane_net_toggles: &mut Option<Vec<u64>>,
        idx: usize,
        t: u64,
    ) {
        toggles[idx] += u64::from(t.count_ones());
        *plane_adds += 1;
        let mut carry = t;
        for p in lane_planes.iter_mut() {
            if carry == 0 {
                break;
            }
            let s = *p ^ carry;
            carry &= *p;
            *p = s;
        }
        debug_assert_eq!(carry, 0, "lane-toggle accumulator overflow");
        if let Some(exact) = lane_net_toggles {
            let mut rest = t;
            while rest != 0 {
                let lane = rest.trailing_zeros() as usize;
                exact[idx * LANES + lane] += 1;
                rest &= rest - 1;
            }
        }
    }

    /// Move the bit-plane accumulator into the flushed per-lane totals.
    fn flush_lanes(&mut self) {
        for (lane, total) in self.lane_flushed.iter_mut().enumerate() {
            let mut acc = 0u64;
            for (k, plane) in self.lane_planes.iter().enumerate() {
                acc |= (plane >> lane & 1) << k;
            }
            *total += acc;
        }
        self.lane_planes = [0; PLANES];
        self.plane_adds = 0;
    }

    /// Bind an input bus to 64 per-lane integer values (LSB-first, two's
    /// complement truncation to the bus width). Values hold until
    /// overwritten.
    pub fn set_bus_lanes(&mut self, name: &str, values: &[i64; LANES]) {
        let WordSim {
            bus, vals, toggles, lane_planes, plane_adds, lane_net_toggles, ..
        } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        for (i, bit) in bits.iter().enumerate() {
            let mut w = 0u64;
            for (lane, v) in values.iter().enumerate() {
                w |= ((*v >> i) as u64 & 1) << lane;
            }
            let idx = *bit as usize;
            let t = vals[idx] ^ w;
            if t != 0 {
                Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
                vals[idx] = w;
            }
        }
    }

    /// Bind an input bus to the same integer value in every lane.
    pub fn set_bus(&mut self, name: &str, value: i64) {
        self.set_bus_lanes(name, &[value; LANES]);
    }

    /// Bind a 1-bit input by bus name, one bit per lane.
    pub fn set_bit_word(&mut self, name: &str, word: u64) {
        let WordSim {
            bus, vals, toggles, lane_planes, plane_adds, lane_net_toggles, ..
        } = self;
        let bits = bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"));
        let idx = bits[0] as usize;
        let t = vals[idx] ^ word;
        if t != 0 {
            Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
            vals[idx] = word;
        }
    }

    /// Bind a 1-bit input to the same value in every lane.
    pub fn set_bit(&mut self, name: &str, value: bool) {
        self.set_bit_word(name, if value { !0 } else { 0 });
    }

    /// Run one clock cycle for all 64 lanes: settle combinational logic
    /// level by level, then clock DFFs.
    pub fn step(&mut self) {
        self.cycles += 1;
        // Overflow guard: one step can add at most one count per net per
        // lane (plus input rebinds between steps, bounded by net count).
        if self.plane_adds + 2 * self.nl.len() as u64 >= u32::MAX as u64 {
            self.flush_lanes();
        }
        let WordSim {
            vals,
            toggles,
            lane_planes,
            plane_adds,
            lane_net_toggles,
            luts,
            level_bounds,
            dffs,
            scratch,
            ..
        } = self;
        for &(s, e) in level_bounds.iter() {
            for l in &luts[s as usize..e as usize] {
                let a = vals[l.ins[0] as usize];
                let b = vals[l.ins[1] as usize];
                let c = vals[l.ins[2] as usize];
                let d = vals[l.ins[3] as usize];
                let new = eval_lut(l.sel, l.inv, a, b, c, d);
                let idx = l.out as usize;
                let t = vals[idx] ^ new;
                if t != 0 {
                    Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
                    vals[idx] = new;
                }
            }
        }
        // Clock edge: sample every D first (a DFF may feed another DFF
        // directly), then commit.
        for (i, &(_, d)) in dffs.iter().enumerate() {
            scratch[i] = vals[d as usize];
        }
        for (i, &(q, _)) in dffs.iter().enumerate() {
            let idx = q as usize;
            let t = vals[idx] ^ scratch[i];
            if t != 0 {
                Self::bump(toggles, lane_planes, plane_adds, lane_net_toggles, idx, t);
                vals[idx] = scratch[i];
            }
        }
    }

    /// Synchronous reset: force all DFFs back to init in every lane
    /// (mirrors [`super::GateSim::reset`]; does not count toggles).
    pub fn reset(&mut self) {
        for (id, node) in self.nl.nodes() {
            if let Node::Dff { init, .. } = node {
                self.vals[id as usize] = if *init { !0 } else { 0 };
            }
        }
    }

    /// Read an output bus in one lane as a sign-extended integer.
    pub fn get_output_lane(&self, name: &str, lane: usize) -> i64 {
        assert!(lane < LANES, "lane out of range");
        let bits = self.output_bits(name);
        let mut v: i64 = 0;
        for (i, bit) in bits.iter().enumerate() {
            if self.vals[*bit as usize] >> lane & 1 == 1 {
                v |= 1 << i;
            }
        }
        let w = bits.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read an output bus in all lanes.
    pub fn get_output_lanes(&self, name: &str) -> [i64; LANES] {
        let mut out = [0i64; LANES];
        for (lane, slot) in out.iter_mut().enumerate() {
            *slot = self.get_output_lane(name, lane);
        }
        out
    }

    /// Read a single-bit output as a lane word (bit l = lane l).
    pub fn get_bit_word(&self, name: &str) -> u64 {
        let bits = self.output_bits(name);
        self.vals[bits[0] as usize]
    }

    fn output_bits(&self, name: &str) -> &[NetId] {
        let (_, bits) = self
            .nl
            .outputs
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"));
        bits
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-net toggle counts, summed across all lanes.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Total toggles across all nets and lanes.
    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Total toggles per lane (across all nets).
    pub fn lane_total_toggles(&mut self) -> [u64; LANES] {
        self.flush_lanes();
        self.lane_flushed
    }

    /// Per-lane mean toggles per net per cycle (64 independent switching
    /// activity factors α from one simulation pass).
    pub fn lane_mean_activity(&mut self) -> [f64; LANES] {
        let totals = self.lane_total_toggles();
        let denom = self.cycles as f64 * self.nl.len() as f64;
        let mut out = [0f64; LANES];
        if denom > 0.0 {
            for (o, t) in out.iter_mut().zip(totals.iter()) {
                *o = *t as f64 / denom;
            }
        }
        out
    }

    /// Mean toggles per net per cycle per lane, averaged over lanes
    /// (comparable to [`super::GateSim::mean_activity`]).
    pub fn mean_activity(&self) -> f64 {
        if self.cycles == 0 || self.nl.is_empty() {
            return 0.0;
        }
        self.total_toggles() as f64
            / (self.cycles as f64 * self.nl.len() as f64 * LANES as f64)
    }

    /// Exact per-net toggle counts for one lane (requires
    /// [`WordSim::with_lane_net_toggles`]).
    pub fn lane_net_toggles(&self, lane: usize) -> Vec<u64> {
        assert!(lane < LANES, "lane out of range");
        let exact = self
            .lane_net_toggles
            .as_ref()
            .expect("enable with_lane_net_toggles() first");
        (0..self.nl.len()).map(|net| exact[net * LANES + lane]).collect()
    }

    /// Combinational depth of the packed plan (levels iterated per step).
    pub fn depth(&self) -> u32 {
        self.level_bounds.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::gatesim::GateSim;
    use crate::synth::netlist::Netlist;

    /// 4-bit counter netlist (same as the scalar GateSim test).
    fn counter() -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..4).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    #[test]
    fn counter_counts_in_every_lane() {
        let nl = counter();
        let mut sim = WordSim::new(&nl);
        for expect in 1..=20i64 {
            sim.step();
            let lanes = sim.get_output_lanes("q");
            for (lane, v) in lanes.iter().enumerate() {
                assert_eq!(v & 0xF, expect & 0xF, "lane {lane} cycle {expect}");
            }
        }
    }

    #[test]
    fn lanes_are_independent() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let y: Vec<NetId> = a.iter().zip(&b).map(|(&x, &y)| nl.and2(x, y)).collect();
        nl.add_output("y", y);
        let mut sim = WordSim::new(&nl);
        let mut av = [0i64; LANES];
        let mut bv = [0i64; LANES];
        for lane in 0..LANES {
            av[lane] = (lane as i64) & 0xF;
            bv[lane] = ((lane as i64) >> 2) & 0xF;
        }
        sim.set_bus_lanes("a", &av);
        sim.set_bus_lanes("b", &bv);
        sim.step();
        let got = sim.get_output_lanes("y");
        for lane in 0..LANES {
            assert_eq!(got[lane] & 0xF, av[lane] & bv[lane], "lane {lane}");
        }
    }

    #[test]
    fn broadcast_matches_scalar_oracle() {
        let nl = counter();
        let mut word = WordSim::new(&nl);
        let mut scalar = GateSim::new(&nl);
        for _ in 0..50 {
            word.step();
            scalar.step();
            assert_eq!(word.get_output_lane("q", 0), scalar.get_output("q"));
            assert_eq!(word.get_output_lane("q", 63), scalar.get_output("q"));
        }
        // Broadcast lanes toggle identically, so per-net totals are 64×.
        for (net, &t) in scalar.toggles().iter().enumerate() {
            assert_eq!(word.toggles()[net], t * LANES as u64, "net {net}");
        }
        let lanes = word.lane_total_toggles();
        for (lane, &t) in lanes.iter().enumerate() {
            assert_eq!(t, scalar.total_toggles(), "lane {lane}");
        }
    }

    #[test]
    fn sign_extension_per_lane() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        nl.add_output("y", a);
        let mut sim = WordSim::new(&nl);
        let mut av = [0i64; LANES];
        av[3] = -3;
        av[17] = 5;
        sim.set_bus_lanes("a", &av);
        sim.step();
        assert_eq!(sim.get_output_lane("y", 3), -3);
        assert_eq!(sim.get_output_lane("y", 17), 5);
        assert_eq!(sim.get_output_lane("y", 0), 0);
    }

    #[test]
    fn exact_lane_net_toggles_match_aggregates() {
        let nl = counter();
        let mut sim = WordSim::new(&nl).with_lane_net_toggles();
        for _ in 0..37 {
            sim.step();
        }
        // Sum of exact per-lane counts equals the word-parallel per-net
        // counters, for every net.
        for net in 0..nl.len() {
            let sum: u64 = (0..LANES).map(|l| sim.lane_net_toggles(l)[net]).sum();
            assert_eq!(sum, sim.toggles()[net], "net {net}");
        }
        // And per-lane totals agree with the bit-plane accumulator.
        let plane_totals = sim.lane_total_toggles();
        for lane in 0..LANES {
            let exact: u64 = sim.lane_net_toggles(lane).iter().sum();
            assert_eq!(exact, plane_totals[lane], "lane {lane}");
        }
    }

    #[test]
    fn reset_restores_init_all_lanes() {
        let mut nl = Netlist::new();
        let one = nl.constant(true);
        let d = nl.dff(one, false);
        nl.add_output("q", vec![d]);
        let mut sim = WordSim::new(&nl);
        sim.step();
        assert_eq!(sim.get_bit_word("q"), !0);
        sim.reset();
        assert_eq!(sim.get_bit_word("q"), 0);
    }

    #[test]
    fn mux_tree_matches_truth_table_indexing() {
        // Exhaustive over arities and random truth tables: the compiled
        // sel/inv plan equals per-bit truth-table lookup.
        let mut rng = crate::stim::Lfsr32::new(0x7AB1E);
        for _ in 0..500 {
            let arity = 1 + rng.below(4);
            let tt = (rng.next_u32() & 0xFFFF) as u16;
            let (sel, inv) = compile_tt(tt, arity);
            let words: Vec<u64> = (0..4)
                .map(|_| (rng.next_u32() as u64) << 32 | rng.next_u32() as u64)
                .collect();
            let mut ins = [words[0]; 4];
            for (k, slot) in ins.iter_mut().enumerate().take(arity) {
                *slot = words[k];
            }
            let got = eval_lut(sel, inv, ins[0], ins[1], ins[2], ins[3]);
            let mask = (1usize << arity) - 1;
            for lane in 0..LANES {
                let mut idx = 0usize;
                for (k, w) in words.iter().enumerate().take(arity) {
                    idx |= ((w >> lane & 1) as usize) << k;
                }
                let want = tt >> (idx & mask) & 1 == 1;
                assert_eq!(got >> lane & 1 == 1, want, "arity {arity} tt {tt:#x} lane {lane}");
            }
        }
    }
}
