//! RTL → gate-level lowering (the "yosys" stage of the flow).
//!
//! Elaborates a [`PiModuleDesign`] into a [`Netlist`] of LUTs and DFFs
//! with the *same cycle-level behaviour* as the RTL simulator: per-Π
//! microprogrammed FSMs, a sequential shift-add multiplier and a restoring
//! divider per unit, operand muxes, and the done handshake. Gate-level
//! simulation of the lowered netlist must agree with
//! [`crate::rtl::sim`] bit-for-bit and cycle-for-cycle — this is the
//! repo's substitute for trusting an external synthesis tool's
//! equivalence.
//!
//! Timing contract (mirrors [`crate::rtl::sched::OpLatency`]):
//! * the cycle where `start` is sampled high captures control (cycle 0);
//! * op `k` occupies `lat(op_k)` cycles; its result commits on its last
//!   cycle; multiplier iterations run on the first `width` cycles of a
//!   mul op, divider iterations on all `width + frac` cycles of a div op
//!   (the final iteration is folded combinationally into the commit);
//! * `done` rises one cycle after the slowest unit's final commit.

use super::netlist::{NetId, Netlist};
use super::word::*;
use crate::fixedpoint::MonOp;
use crate::rtl::ir::PiModuleDesign;
use crate::rtl::sched::OpLatency;

/// Lower a design to gates.
pub fn lower(design: &PiModuleDesign) -> Netlist {
    let mut nl = Netlist::new();
    let w = design.q.width();
    let start = nl.input_bus("start", 1)[0];
    let ports: Vec<Word> = design
        .ports
        .iter()
        .map(|p| nl.input_bus(&format!("in_{}", p.name), w))
        .collect();

    let mut unit_dones = Vec::new();
    for (ui, _) in design.units.iter().enumerate() {
        let (pi, udone) = elaborate_unit(&mut nl, design, ui, start, &ports);
        nl.add_output(&format!("pi_{ui}"), pi);
        unit_dones.push(udone);
    }
    // Registered done: the epilogue flip-flop of the latency model. A new
    // `start` clears it on the capture cycle itself (via the !start term)
    // so back-to-back activations behave.
    let all_done = and_reduce(&mut nl, &unit_dones);
    let nstart = nl.not(start);
    let done_d = nl.and2(all_done, nstart);
    let done_ff = nl.dff(done_d, false);
    nl.add_output("done", vec![done_ff]);
    nl
}

/// Encoded microprogram entry.
struct RomEntry {
    kind: u64, // 0=load 1=mul 2=div 3=load-one
    sel: u64,  // operand port index
    lat: u64,  // op latency in cycles
}

fn encode_ops(design: &PiModuleDesign, ui: usize) -> Vec<RomEntry> {
    let lat = OpLatency::for_format(design.q);
    design.units[ui]
        .ops
        .iter()
        .map(|op| match op {
            MonOp::Load(i) => RomEntry { kind: 0, sel: *i as u64, lat: lat.load },
            MonOp::Mul(i) => RomEntry { kind: 1, sel: *i as u64, lat: lat.mul },
            MonOp::Div(i) => RomEntry { kind: 2, sel: *i as u64, lat: lat.div },
            MonOp::LoadOne => RomEntry { kind: 3, sel: 0, lat: lat.load },
        })
        .collect()
}

/// Build a ROM field: `values[k]` selected by the one-hot pc decode.
fn rom_field(nl: &mut Netlist, onehot: &[NetId], values: &[u64], width: u32) -> Word {
    (0..width)
        .map(|b| {
            let sels: Vec<NetId> = onehot
                .iter()
                .zip(values)
                .filter(|(_, v)| (**v >> b) & 1 == 1)
                .map(|(s, _)| *s)
                .collect();
            or_reduce(nl, &sels)
        })
        .collect()
}

fn elaborate_unit(
    nl: &mut Netlist,
    design: &PiModuleDesign,
    ui: usize,
    start: NetId,
    ports: &[Word],
) -> (Word, NetId) {
    let q = design.q;
    let w = q.width();
    let f = q.frac_bits;
    let qw = w + f; // divider quotient width
    let rom = encode_ops(design, ui);
    let nops = rom.len();
    let lat = OpLatency::for_format(q);
    let max_lat = lat.mul.max(lat.div).max(lat.load);
    let pcw = bits_for((nops - 1) as u64).max(1);
    let cw = bits_for(max_lat).max(1);

    // ---- state ----------------------------------------------------------
    let busy = register(nl, 1);
    let udone = register(nl, 1);
    let first = register(nl, 1); // next cycle is an op's first cycle
    let pc = register(nl, pcw);
    let cnt = register(nl, cw);
    let acc = register(nl, w);
    let psign = register(nl, 1);
    let asign = register(nl, 1);
    let dbz = register(nl, 1);
    let p = register(nl, 2 * w); // multiplier accumulator (magnitude)
    let mcand = register(nl, w);
    let mplier = register(nl, w);
    let rem = register(nl, w + 1);
    let quot = register(nl, qw);
    let den = register(nl, w);

    // ---- microprogram ROM -------------------------------------------------
    let onehot: Vec<NetId> = (0..nops).map(|k| eq_const(nl, &pc, k as i64)).collect();
    let kinds: Vec<u64> = rom.iter().map(|e| e.kind).collect();
    let sels: Vec<u64> = rom.iter().map(|e| e.sel).collect();
    let next_lats: Vec<u64> = (0..nops).map(|k| rom.get(k + 1).map(|e| e.lat).unwrap_or(0)).collect();
    let kind = rom_field(nl, &onehot, &kinds, 2);
    let kind_load = {
        let n1 = nl.not(kind[1]);
        let n0 = nl.not(kind[0]);
        nl.and2(n1, n0)
    };
    let kind_mul = {
        let n1 = nl.not(kind[1]);
        nl.and2(n1, kind[0])
    };
    let kind_div = {
        let n0 = nl.not(kind[0]);
        nl.and2(kind[1], n0)
    };
    let kind_one = nl.and2(kind[1], kind[0]);
    let next_lat = rom_field(nl, &onehot, &next_lats, cw);
    let is_last = eq_const(nl, &pc, (nops - 1) as i64);

    // Operand mux: sel -> port. One-hot per port id.
    let nports = ports.len().max(1);
    let port_onehot: Vec<NetId> = (0..nports)
        .map(|pid| {
            let hits: Vec<NetId> = onehot
                .iter()
                .zip(&sels)
                .filter(|(_, s)| **s == pid as u64)
                .map(|(h, _)| *h)
                .collect();
            or_reduce(nl, &hits)
        })
        .collect();
    let operand: Word = (0..w as usize)
        .map(|b| {
            let terms: Vec<NetId> = ports
                .iter()
                .zip(&port_onehot)
                .map(|(pw, &sel)| nl.and2(sel, pw[b]))
                .collect();
            or_reduce(nl, &terms)
        })
        .collect();

    // ---- control ---------------------------------------------------------
    let not_busy = nl.not(busy[0]);
    let do_start = nl.and2(start, not_busy);
    let is_commit = {
        let c1 = eq_const(nl, &cnt, 1);
        nl.and2(busy[0], c1)
    };
    let commit_last = nl.and2(is_commit, is_last);
    let commit_more = {
        let nl_ = nl.not(is_last);
        nl.and2(is_commit, nl_)
    };

    // ---- shared operand preprocessing -------------------------------------
    let abs_acc = abs(nl, &acc);
    let abs_op = abs(nl, &operand);
    let acc_s = acc[w as usize - 1];
    let op_s = operand[w as usize - 1];
    let psign_new = nl.xor2(acc_s, op_s);
    let op_is_zero = is_zero(nl, &operand);

    // ---- multiplier datapath ----------------------------------------------
    // Effective inputs on the first cycle of a mul op.
    let mcand_eff = mux_word(nl, first[0], &abs_acc, &mcand);
    let mplier_eff = mux_word(nl, first[0], &abs_op, &mplier);
    let zero_2w = word_const(nl, 2 * w, 0);
    let p_eff = mux_word(nl, first[0], &zero_2w, &p);
    // High-half add: p_hi + (mplier[0] ? mcand : 0), W+1 bits.
    let p_hi = slice(&p_eff, w, 2 * w);
    let zero_w = word_const(nl, w, 0);
    let addend = mux_word(nl, mplier_eff[0], &mcand_eff, &zero_w);
    let zero_c = nl.constant(false);
    let (hi_sum, hi_carry) = add(nl, &p_hi, &addend, zero_c);
    // p_next = {carry, hi_sum, p_eff[W-1:0]} >> 1 (2W bits kept).
    let full = {
        let mut v = slice(&p_eff, 0, w);
        v.extend_from_slice(&hi_sum);
        v.push(hi_carry);
        v
    };
    let p_iter: Word = full[1..=(2 * w) as usize].to_vec();
    let mplier_shift: Word = {
        let mut v = slice(&mplier_eff, 1, w);
        v.push(nl.constant(false));
        v
    };

    // Mul finalize (commit cycle): signed product, round, shift, saturate.
    // Negation is folded into the rounding adder via the two's-complement
    // identity −p + r = (p ⊕ 1…1) + r + 1: conditional XOR plus carry-in,
    // halving the finalize ripple depth (one 2W adder instead of two).
    let p_x: Word = p.iter().map(|&b| nl.xor2(b, psign[0])).collect();
    let round_c = word_const(nl, 2 * w, 1i64 << (f - 1));
    let rounded = add(nl, &p_x, &round_c, psign[0]).0;
    // Arithmetic >> f within 2W bits.
    let shifted = slice(&rounded, f, 2 * w);
    let sh_sign = *shifted.last().unwrap();
    // Overflow iff any of shifted[W-1 ..] differs from the sign bit.
    let ovf_bits: Vec<NetId> = shifted[(w - 1) as usize..]
        .iter()
        .map(|&b| nl.xor2(b, sh_sign))
        .collect();
    let mul_ovf = or_reduce(nl, &ovf_bits);
    let max_w = word_const(nl, w, q.max_raw());
    let min_w = word_const(nl, w, q.min_raw());
    let sat_val = mux_word(nl, sh_sign, &min_w, &max_w);
    let sh_low = slice(&shifted, 0, w);
    let mul_result = mux_word(nl, mul_ovf, &sat_val, &sh_low);

    // ---- divider datapath ---------------------------------------------------
    // Effective inputs on the first cycle of a div op.
    let zero_w1 = word_const(nl, w + 1, 0);
    let rem_eff = mux_word(nl, first[0], &zero_w1, &rem);
    let dividend: Word = {
        let mut v = word_const(nl, f, 0);
        v.extend_from_slice(&abs_acc);
        v
    };
    let quot_eff = mux_word(nl, first[0], &dividend, &quot);
    let den_eff = mux_word(nl, first[0], &abs_op, &den);
    // sh = {rem[W-1:0], quot[QW-1]}  (W+1 bits, LSB = incoming quotient bit)
    let sh: Word = {
        let mut v = vec![quot_eff[qw as usize - 1]];
        v.extend_from_slice(&rem_eff[..w as usize]);
        v
    };
    let den_ext = zext(nl, &den_eff, w + 1);
    let (diff, geq) = sub(nl, &sh, &den_ext);
    let rem_iter = mux_word(nl, geq, &diff, &sh);
    let quot_iter: Word = {
        let mut v = vec![geq];
        v.extend_from_slice(&quot_eff[..qw as usize - 1]);
        v
    };

    // Div finalize (commit cycle): the final iteration is quot_iter itself.
    let q_mag = &quot_iter;
    // Positive overflow: any bit at or above W-1.
    let div_ovf_pos = or_reduce(nl, &q_mag[(w - 1) as usize..]);
    // Negative overflow: magnitude > 2^(W-1).
    let hi_any = or_reduce(nl, &q_mag[w as usize..]);
    let low_any = or_reduce(nl, &q_mag[..(w - 1) as usize]);
    let edge = nl.and2(q_mag[(w - 1) as usize], low_any);
    let div_ovf_neg = nl.or2(hi_any, edge);
    let q_low = q_mag[..w as usize].to_vec();
    let q_neg = neg(nl, &q_low);
    let pos_val = mux_word(nl, div_ovf_pos, &max_w, &q_low);
    let neg_val = mux_word(nl, div_ovf_neg, &min_w, &q_neg);
    let signed_q = mux_word(nl, psign[0], &neg_val, &pos_val);
    let dbz_val = mux_word(nl, asign[0], &min_w, &max_w);
    let div_result = mux_word(nl, dbz[0], &dbz_val, &signed_q);

    // ---- register updates ----------------------------------------------------
    // acc: at commit, by op kind.
    let one_w = word_const(nl, w, q.one());
    let loadish = mux_word(nl, kind_one, &one_w, &operand);
    let muldiv = mux_word(nl, kind_mul, &mul_result, &div_result);
    let is_loadish = nl.or2(kind_load, kind_one);
    let commit_val = mux_word(nl, is_loadish, &loadish, &muldiv);
    let acc_next = mux_word(nl, is_commit, &commit_val, &acc);
    connect(nl, &acc, &acc_next);

    // Multiplier registers: iterate while mul op active and not committing.
    let not_commit = nl.not(is_commit);
    let mul_busy = nl.and2(busy[0], kind_mul);
    let mul_iter_en = nl.and2(mul_busy, not_commit);
    let p_next = mux_word(nl, mul_iter_en, &p_iter, &p);
    connect(nl, &p, &p_next);
    let mcand_next = mux_word(nl, mul_iter_en, &mcand_eff, &mcand);
    connect(nl, &mcand, &mcand_next);
    let mplier_next = mux_word(nl, mul_iter_en, &mplier_shift, &mplier);
    connect(nl, &mplier, &mplier_next);

    // Divider registers: iterate on every div cycle except the commit
    // (whose iteration is folded combinationally).
    let div_busy = nl.and2(busy[0], kind_div);
    let div_iter_en = nl.and2(div_busy, not_commit);
    let rem_next = mux_word(nl, div_iter_en, &rem_iter, &rem);
    connect(nl, &rem, &rem_next);
    let quot_next = mux_word(nl, div_iter_en, &quot_iter, &quot);
    connect(nl, &quot, &quot_next);
    let den_upd = nl.and2(div_busy, first[0]);
    let den_next = mux_word(nl, den_upd, &abs_op, &den);
    connect(nl, &den, &den_next);

    // Sign/zero captures on the first cycle of mul/div ops.
    let muldiv_busy = nl.or2(mul_busy, div_busy);
    let sign_upd = nl.and2(muldiv_busy, first[0]);
    let psign_next = vec![nl.mux(sign_upd, psign_new, psign[0])];
    connect(nl, &psign, &psign_next);
    let asign_next = vec![nl.mux(sign_upd, acc_s, asign[0])];
    connect(nl, &asign, &asign_next);
    let dbz_upd = nl.and2(div_busy, first[0]);
    let dbz_next = vec![nl.mux(dbz_upd, op_is_zero, dbz[0])];
    connect(nl, &dbz, &dbz_next);

    // pc: advance at commit (unless last); reset at start.
    let pc_inc = inc(nl, &pc);
    let pc_zero = word_const(nl, pcw, 0);
    let pc_adv = mux_word(nl, commit_more, &pc_inc, &pc);
    let pc_next = mux_word(nl, do_start, &pc_zero, &pc_adv);
    connect(nl, &pc, &pc_next);

    // cnt: load lat(op0) at start; next_lat at commit; else decrement.
    let lat0 = word_const(nl, cw, rom[0].lat as i64);
    let cnt_dec = dec(nl, &cnt);
    let cnt_run = mux_word(nl, is_commit, &next_lat, &cnt_dec);
    let cnt_hold = mux_word(nl, busy[0], &cnt_run, &cnt);
    let cnt_next = mux_word(nl, do_start, &lat0, &cnt_hold);
    connect(nl, &cnt, &cnt_next);

    // busy / done / first flags.
    let busy_clr = nl.not(commit_last);
    let busy_run = nl.and2(busy[0], busy_clr);
    let busy_next = vec![nl.or2(do_start, busy_run)];
    connect(nl, &busy, &busy_next);

    let udone_keep = {
        let ns = nl.not(do_start);
        nl.and2(udone[0], ns)
    };
    let udone_next = vec![nl.or2(commit_last, udone_keep)];
    connect(nl, &udone, &udone_next);

    let first_next = vec![nl.or2(do_start, commit_more)];
    connect(nl, &first, &first_next);

    (acc, udone[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::{Q16_15, QFormat};
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::rtl::sched::{module_latency, Policy};
    use crate::rtl::sim as rtlsim;
    use crate::stim::Lfsr32;
    use crate::synth::gatesim::GateSim;

    fn design_for(id: &str, q: QFormat) -> PiModuleDesign {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        ir::build(&a, q)
    }

    /// Run the gate-level module once: assert start for one cycle, then
    /// clock until done; return (pi outputs, cycles after start).
    fn run_gates(nl: &Netlist, design: &PiModuleDesign, inputs: &[i64]) -> (Vec<i64>, u64) {
        let mut sim = GateSim::new(nl);
        for (p, v) in design.ports.iter().zip(inputs) {
            sim.set_bus(&format!("in_{}", p.name), *v);
        }
        sim.set_bus("start", 1);
        sim.step(); // capture cycle
        sim.set_bus("start", 0);
        let mut cycles = 0u64;
        while !sim.get_bit("done") {
            sim.step();
            cycles += 1;
            assert!(cycles < 5_000, "gate sim did not finish");
        }
        let outs = (0..design.units.len())
            .map(|u| sim.get_output(&format!("pi_{u}")))
            .collect();
        (outs, cycles)
    }

    #[test]
    fn gate_sim_matches_rtl_sim_pendulum() {
        let d = design_for("pendulum", Q16_15);
        let nl = lower(&d);
        let mut lfsr = Lfsr32::new(0xBEEF);
        for _ in 0..10 {
            let inputs: Vec<i64> = (0..d.num_inputs())
                .map(|_| Q16_15.from_f64(lfsr.range(0.25, 8.0)))
                .collect();
            let rtl = rtlsim::run_once(&d, &inputs);
            let (gates, cycles) = run_gates(&nl, &d, &inputs);
            assert_eq!(gates, rtl.outputs, "outputs for {inputs:?}");
            assert_eq!(cycles, rtl.cycles, "cycles for {inputs:?}");
        }
    }

    #[test]
    fn gate_sim_matches_rtl_sim_all_systems() {
        let mut lfsr = Lfsr32::new(0x5EED);
        for e in corpus::corpus() {
            let d = design_for(e.id, Q16_15);
            let nl = lower(&d);
            for _ in 0..3 {
                let inputs: Vec<i64> = (0..d.num_inputs())
                    .map(|_| Q16_15.from_f64(lfsr.range(0.25, 8.0)))
                    .collect();
                let rtl = rtlsim::run_once(&d, &inputs);
                let (gates, cycles) = run_gates(&nl, &d, &inputs);
                assert_eq!(gates, rtl.outputs, "{}: outputs for {inputs:?}", e.id);
                assert_eq!(cycles, rtl.cycles, "{}: cycle count", e.id);
            }
        }
    }

    #[test]
    fn gate_latency_equals_schedule() {
        let d = design_for("beam", Q16_15);
        let nl = lower(&d);
        let inputs = vec![Q16_15.one(); d.num_inputs()];
        let (_, cycles) = run_gates(&nl, &d, &inputs);
        assert_eq!(cycles, module_latency(&d, Policy::ParallelPerPi));
    }

    #[test]
    fn saturation_and_dbz_match_software() {
        let d = design_for("pendulum", Q16_15);
        let nl = lower(&d);
        // Zero inputs: exercises divide-by-zero saturation.
        let inputs = vec![0i64; d.num_inputs()];
        let rtl = rtlsim::run_once(&d, &inputs);
        let (gates, _) = run_gates(&nl, &d, &inputs);
        assert_eq!(gates, rtl.outputs);
        // Huge inputs: exercises multiplier saturation.
        let inputs = vec![Q16_15.max_raw(); d.num_inputs()];
        let rtl = rtlsim::run_once(&d, &inputs);
        let (gates, _) = run_gates(&nl, &d, &inputs);
        assert_eq!(gates, rtl.outputs);
    }

    #[test]
    fn negative_operands_match() {
        let d = design_for("pendulum", Q16_15);
        let nl = lower(&d);
        let mut lfsr = Lfsr32::new(77);
        for _ in 0..10 {
            let inputs: Vec<i64> = (0..d.num_inputs())
                .map(|_| {
                    let v = lfsr.range(0.25, 8.0);
                    Q16_15.from_f64(if lfsr.next_f64() < 0.5 { -v } else { v })
                })
                .collect();
            let rtl = rtlsim::run_once(&d, &inputs);
            let (gates, _) = run_gates(&nl, &d, &inputs);
            assert_eq!(gates, rtl.outputs, "inputs {inputs:?}");
        }
    }

    #[test]
    fn narrow_format_matches() {
        let q = QFormat::new(8, 7);
        let d = design_for("pendulum", q);
        let nl = lower(&d);
        let mut lfsr = Lfsr32::new(3);
        for _ in 0..10 {
            let inputs: Vec<i64> =
                (0..d.num_inputs()).map(|_| q.from_f64(lfsr.range(0.5, 3.0))).collect();
            let rtl = rtlsim::run_once(&d, &inputs);
            let (gates, cycles) = run_gates(&nl, &d, &inputs);
            assert_eq!(gates, rtl.outputs);
            assert_eq!(cycles, rtl.cycles);
        }
    }

    #[test]
    fn module_reusable_across_activations() {
        let d = design_for("pendulum", Q16_15);
        let nl = lower(&d);
        let mut sim = GateSim::new(&nl);
        let q = Q16_15;
        for round in 1..=3i64 {
            let vals: Vec<i64> = (0..d.num_inputs() as i64)
                .map(|i| q.from_f64(1.0 + (round + i) as f64 * 0.5))
                .collect();
            for (p, v) in d.ports.iter().zip(&vals) {
                sim.set_bus(&format!("in_{}", p.name), *v);
            }
            sim.set_bus("start", 1);
            sim.step();
            sim.set_bus("start", 0);
            let mut n = 0;
            while !sim.get_bit("done") {
                sim.step();
                n += 1;
                assert!(n < 1000);
            }
            let expect = rtlsim::run_once(&d, &vals);
            let got: Vec<i64> =
                (0..d.units.len()).map(|u| sim.get_output(&format!("pi_{u}"))).collect();
            assert_eq!(got, expect.outputs, "round {round}");
        }
    }
}
