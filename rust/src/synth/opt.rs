//! Netlist optimization passes.
//!
//! Construction-time structural hashing and constant folding live in
//! [`super::netlist`]; this module adds the global passes that need the
//! whole graph: dead-code elimination (sweep from outputs and flip-flop
//! feedback) and netlist statistics used by reports.

use super::netlist::{NetId, Netlist, Node};

/// Dead-code elimination: keep nodes reachable from any output, tracing
//  through LUT inputs and DFF data inputs. Primary inputs are always kept
/// (they are the module interface). Returns the pruned netlist and the
/// number of nodes removed.
pub fn dce(nl: &Netlist) -> (Netlist, usize) {
    let n = nl.len();
    let mut live = vec![false; n];
    let mut stack: Vec<NetId> = Vec::new();
    for (_, bits) in nl.outputs() {
        for &b in bits {
            if !live[b as usize] {
                live[b as usize] = true;
                stack.push(b);
            }
        }
    }
    while let Some(id) = stack.pop() {
        match nl.node(id) {
            Node::Lut { ins, .. } => {
                for &i in ins {
                    if !live[i as usize] {
                        live[i as usize] = true;
                        stack.push(i);
                    }
                }
            }
            Node::Dff { d, .. } => {
                if !live[*d as usize] {
                    live[*d as usize] = true;
                    stack.push(*d);
                }
            }
            _ => {}
        }
    }
    // Inputs always survive (interface stability for simulation binding).
    for (id, node) in nl.nodes() {
        if matches!(node, Node::Input(_)) {
            live[id as usize] = true;
        }
    }

    // Rebuild with compacted ids.
    let mut remap = vec![u32::MAX; n];
    let mut out = Netlist::new();
    let mut removed = 0usize;
    for (id, node) in nl.nodes() {
        if !live[id as usize] {
            removed += 1;
            continue;
        }
        let new_id = match node {
            Node::Const(v) => out.constant(*v),
            Node::Input(name) => out.input(name.clone()),
            Node::Lut { ins, tt } => {
                let new_ins: Vec<NetId> = ins.iter().map(|&i| remap[i as usize]).collect();
                debug_assert!(new_ins.iter().all(|&i| i != u32::MAX));
                out.lut(&new_ins, *tt)
            }
            Node::Dff { init, .. } => {
                // D input may be a forward reference; patch after.
                out.dff(0, *init)
            }
        };
        remap[id as usize] = new_id;
    }
    // Patch DFF data inputs.
    for (id, node) in nl.nodes() {
        if let Node::Dff { d, .. } = node {
            if live[id as usize] {
                out.set_dff_input(remap[id as usize], remap[*d as usize]);
            }
        }
    }
    // Remap interface lists.
    for (name, bits) in nl.outputs() {
        out.add_output(name, bits.iter().map(|&b| remap[b as usize]).collect());
    }
    out.input_buses = nl
        .input_buses
        .iter()
        .map(|(name, bits)| {
            (name.clone(), bits.iter().map(|&b| remap[b as usize]).collect())
        })
        .collect();
    (out, removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::gatesim::GateSim;

    #[test]
    fn dce_removes_unused_logic() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 2);
        let used = nl.and2(a[0], a[1]);
        let _dead1 = nl.xor2(a[0], a[1]);
        let dead2 = nl.or2(a[0], a[1]);
        let _dead3 = nl.not(dead2);
        nl.add_output("y", vec![used]);
        let before = nl.count_luts();
        let (pruned, removed) = dce(&nl);
        assert_eq!(pruned.count_luts(), 1);
        assert_eq!(before - pruned.count_luts(), removed - 0);
        assert!(removed >= 3);
    }

    #[test]
    fn dce_keeps_dff_feedback() {
        // Toggle FF: q <= not q. The NOT is only reachable via the DFF.
        let mut nl = Netlist::new();
        let q = nl.dff(0, false);
        let nq = nl.not(q);
        nl.set_dff_input(q, nq);
        nl.add_output("q", vec![q]);
        let (pruned, _) = dce(&nl);
        assert_eq!(pruned.count_dffs(), 1);
        assert_eq!(pruned.count_luts(), 1);
        // Still toggles after pruning.
        let mut sim = GateSim::new(&pruned);
        sim.step();
        assert_eq!(sim.get_output("q") & 1, 1);
        sim.step();
        assert_eq!(sim.get_output("q") & 1, 0);
    }

    #[test]
    fn dce_preserves_behaviour() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let y: Vec<_> = a.iter().zip(&b).map(|(&x, &y)| nl.xor2(x, y)).collect();
        let _dead: Vec<_> = a.iter().map(|&x| nl.not(x)).collect();
        nl.add_output("y", y);
        let (pruned, _) = dce(&nl);
        let mut s1 = GateSim::new(&nl);
        let mut s2 = GateSim::new(&pruned);
        for (av, bv) in [(3, 5), (0xF, 0xF), (0, 9)] {
            s1.set_bus("a", av);
            s1.set_bus("b", bv);
            s1.step();
            s2.set_bus("a", av);
            s2.set_bus("b", bv);
            s2.step();
            assert_eq!(s1.get_output("y"), s2.get_output("y"));
        }
    }

    #[test]
    fn inputs_survive_dce() {
        let mut nl = Netlist::new();
        let _a = nl.input_bus("a", 3);
        let one = nl.constant(true);
        nl.add_output("y", vec![one]);
        let (pruned, _) = dce(&nl);
        assert_eq!(pruned.count_inputs(), 3);
        assert_eq!(pruned.input_buses.len(), 1);
    }
}
