//! LUT4 technology mapping and resource accounting (the "nextpnr packing"
//! stage).
//!
//! The lowering stage emits fine-grained LUTs (mostly 2–3 inputs). This
//! pass greedily collapses single-fanout LUT chains into larger LUTs while
//! the combined support fits in 4 inputs — the classic cut-based packing
//! that fills iCE40 LUT4s — then accounts resources the way the paper's
//! Table 1 does:
//!
//! * **LUT4 cells** — logic cells consumed: packed LUTs plus flip-flops
//!   that cannot share a cell with the LUT driving their D input (an
//!   iCE40 PLB pairs one LUT4 with one DFF).
//! * **Gate count** — 2-input-gate equivalents of the mapped logic
//!   (`arity − 1` per LUT, minimum 1). The paper's exact gate metric is
//!   not specified; ours is consistent across designs so cross-design
//!   ordering is meaningful (EXPERIMENTS.md discusses the scale).

use super::netlist::{NetId, Netlist, Node};
use super::opt::dce;
use crate::rtl::ir::PiModuleDesign;

/// Result of technology mapping.
pub struct MappedDesign {
    /// The packed netlist (valid for simulation, timing and power).
    pub netlist: Netlist,
    /// Logic cells (packed LUT4s + unshared DFFs).
    pub lut4_cells: usize,
    /// Packed LUT count only.
    pub luts: usize,
    /// Flip-flop count.
    pub dffs: usize,
    /// 2-input-gate equivalents.
    pub gate_count: usize,
}

/// Pack LUT chains into LUT4s. Returns a new netlist.
pub fn pack_luts(nl: &Netlist) -> Netlist {
    // Work on mutable copies of the LUT nodes.
    let n = nl.len();
    let mut ins: Vec<Vec<NetId>> = vec![Vec::new(); n];
    let mut tts: Vec<u64> = vec![0; n];
    let mut is_lut = vec![false; n];
    for (id, node) in nl.nodes() {
        if let Node::Lut { ins: i, tt } = node {
            ins[id as usize] = i.clone();
            tts[id as usize] = *tt as u64;
            is_lut[id as usize] = true;
        }
    }
    // Fanout counts (LUT ins + DFF d + outputs).
    let mut fanout = vec![0u32; n];
    for (_, node) in nl.nodes() {
        match node {
            Node::Lut { ins, .. } => {
                for &i in ins {
                    fanout[i as usize] += 1;
                }
            }
            Node::Dff { d, .. } => fanout[*d as usize] += 1,
            _ => {}
        }
    }
    for (_, bits) in nl.outputs() {
        for &b in bits {
            fanout[b as usize] += 1;
        }
    }

    // Greedy collapse, processing nodes in order (inputs of a node have
    // smaller ids, so by the time we process a node its children are
    // final).
    let mut absorbed = vec![false; n];
    for id in 0..n {
        if !is_lut[id] {
            continue;
        }
        loop {
            // Find a single-fanout LUT input worth absorbing.
            let mut cand: Option<usize> = None;
            for &i in &ins[id] {
                let ii = i as usize;
                if is_lut[ii] && !absorbed[ii] && fanout[ii] == 1 {
                    // Combined support if we absorb `i`.
                    let mut support: Vec<NetId> =
                        ins[id].iter().copied().filter(|&x| x != i).collect();
                    for &ci in &ins[ii] {
                        if !support.contains(&ci) {
                            support.push(ci);
                        }
                    }
                    if support.len() <= 4 {
                        cand = Some(ii);
                        break;
                    }
                }
            }
            let Some(child) = cand else { break };
            // Merge truth tables: new support = parent ins minus child,
            // plus child ins (deduped, order: remaining parent ins then
            // new child ins).
            let child_id = child as NetId;
            // Take both input lists instead of cloning: the parent's is
            // replaced by `support` below, and the child is absorbed
            // (never read again).
            let parent_ins = std::mem::take(&mut ins[id]);
            let child_ins = std::mem::take(&mut ins[child]);
            let mut support: Vec<NetId> =
                parent_ins.iter().copied().filter(|&x| x != child_id).collect();
            for &ci in &child_ins {
                if !support.contains(&ci) {
                    support.push(ci);
                }
            }
            let mut new_tt: u64 = 0;
            for idx in 0..(1usize << support.len()) {
                let val_of = |net: NetId| -> bool {
                    let pos = support.iter().position(|&s| s == net).unwrap();
                    idx >> pos & 1 == 1
                };
                // Child output under this assignment.
                let mut cidx = 0usize;
                for (k, &ci) in child_ins.iter().enumerate() {
                    if val_of(ci) {
                        cidx |= 1 << k;
                    }
                }
                let cval = tts[child] >> cidx & 1 == 1;
                // Parent output with child substituted.
                let mut pidx = 0usize;
                for (k, &pi) in parent_ins.iter().enumerate() {
                    let v = if pi == child_id { cval } else { val_of(pi) };
                    if v {
                        pidx |= 1 << k;
                    }
                }
                if tts[id] >> pidx & 1 == 1 {
                    new_tt |= 1 << idx;
                }
            }
            // Update fanouts: child's inputs gain a use, child loses one.
            for &ci in &child_ins {
                fanout[ci as usize] += 1;
            }
            // (child had fanout 1, now absorbed)
            absorbed[child] = true;
            is_lut[child] = false;
            ins[id] = support;
            tts[id] = new_tt;
        }
    }

    // Rebuild the netlist with absorbed nodes dropped.
    let mut out = Netlist::new();
    let mut remap = vec![u32::MAX; n];
    for (id, node) in nl.nodes() {
        let idu = id as usize;
        if absorbed[idu] {
            continue;
        }
        let new_id = match node {
            Node::Const(v) => out.constant(*v),
            Node::Input(name) => out.input(name.clone()),
            Node::Lut { .. } => {
                let new_ins: Vec<NetId> =
                    ins[idu].iter().map(|&i| remap[i as usize]).collect();
                out.lut(&new_ins, tts[idu] as u16)
            }
            Node::Dff { init, .. } => out.dff(0, *init),
        };
        remap[idu] = new_id;
    }
    for (id, node) in nl.nodes() {
        if let Node::Dff { d, .. } = node {
            if !absorbed[id as usize] {
                out.set_dff_input(remap[id as usize], remap[*d as usize]);
            }
        }
    }
    for (name, bits) in nl.outputs() {
        out.add_output(name, bits.iter().map(|&b| remap[b as usize]).collect());
    }
    out.input_buses = nl
        .input_buses
        .iter()
        .map(|(name, bits)| (name.clone(), bits.iter().map(|&b| remap[b as usize]).collect()))
        .collect();
    // Absorption can orphan nodes (e.g. constants); sweep.
    dce(&out).0
}

/// Standard-cell estimate for one LUT function: how many cells of a
/// typical CMOS library (INV/NAND/NOR/XOR/MUX/AOI) the function maps to.
/// MUX-like functions (both cofactors w.r.t. some input are literals or
/// constants) map to a single MUX cell; parity functions need `n−1` XOR
/// cells; the general case is estimated at `n−1` two-input cells.
fn gate_equiv(ins: usize, tt: u16) -> usize {
    let n = ins;
    if n <= 2 {
        return 1;
    }
    let size = 1usize << n;
    // Parity check.
    let mut is_parity = true;
    let mut is_nparity = true;
    for idx in 0..size {
        let ones = (idx as u32).count_ones() % 2 == 1;
        let bit = tt >> idx & 1 == 1;
        if bit != ones {
            is_parity = false;
        }
        if bit == ones {
            is_nparity = false;
        }
    }
    if is_parity || is_nparity {
        return n - 1;
    }
    // MUX-like: some select input whose two cofactors each depend on at
    // most one remaining variable.
    for s in 0..n {
        let mut dep0 = 0usize; // variables the s=0 cofactor depends on
        let mut dep1 = 0usize;
        for v in 0..n {
            if v == s {
                continue;
            }
            for idx in 0..size {
                if idx >> v & 1 == 1 {
                    continue;
                }
                let j = idx | (1 << v);
                if (tt >> idx & 1) != (tt >> j & 1) {
                    if idx >> s & 1 == 0 {
                        dep0 |= 1 << v;
                    } else {
                        dep1 |= 1 << v;
                    }
                }
            }
        }
        if dep0.count_ones() <= 1 && dep1.count_ones() <= 1 {
            return if n == 3 { 1 } else { 2 };
        }
    }
    n - 1
}

/// Map a design end to end: lower → DCE → pack → count.
pub fn map_design(design: &PiModuleDesign) -> MappedDesign {
    let raw = super::lower::lower(design);
    let (clean, _) = dce(&raw);
    let packed = pack_luts(&clean);
    stats(packed)
}

/// Compute resource statistics for an already-packed netlist.
pub fn stats(netlist: Netlist) -> MappedDesign {
    let luts = netlist.count_luts();
    let dffs = netlist.count_dffs();
    // DFF/LUT cell sharing: a DFF packs into the cell of the LUT driving
    // its D input when that LUT has no other fanout.
    let mut fanout = vec![0u32; netlist.len()];
    for (_, node) in netlist.nodes() {
        match node {
            Node::Lut { ins, .. } => {
                for &i in ins {
                    fanout[i as usize] += 1;
                }
            }
            Node::Dff { d, .. } => fanout[*d as usize] += 1,
            _ => {}
        }
    }
    for (_, bits) in netlist.outputs() {
        for &b in bits {
            fanout[b as usize] += 1;
        }
    }
    let mut shared = 0usize;
    for (_, node) in netlist.nodes() {
        if let Node::Dff { d, .. } = node {
            if matches!(netlist.node(*d), Node::Lut { .. }) && fanout[*d as usize] == 1 {
                shared += 1;
            }
        }
    }
    let gate_count: usize = netlist
        .nodes()
        .filter_map(|(_, n)| match n {
            Node::Lut { ins, tt } => Some(gate_equiv(ins.len(), *tt)),
            _ => None,
        })
        .sum();
    MappedDesign {
        lut4_cells: luts + dffs.saturating_sub(shared),
        luts,
        dffs,
        gate_count,
        netlist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::synth::gatesim::GateSim;
    use crate::synth::netlist::Netlist;

    #[test]
    fn packing_collapses_chains() {
        // a^b^c^d as a chain of three XOR2s: packs into fewer LUTs.
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let x1 = nl.xor2(a[0], a[1]);
        let x2 = nl.xor2(x1, a[2]);
        let x3 = nl.xor2(x2, a[3]);
        nl.add_output("y", vec![x3]);
        let packed = pack_luts(&nl);
        assert_eq!(packed.count_luts(), 1, "should pack into one LUT4");
    }

    #[test]
    fn packing_preserves_function() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let x1 = nl.xor2(a[0], a[1]);
        let a2 = nl.and2(x1, a[2]);
        let o1 = nl.or2(a2, a[3]);
        nl.add_output("y", vec![o1]);
        let packed = pack_luts(&nl);
        for v in 0..16i64 {
            let mut s1 = GateSim::new(&nl);
            let mut s2 = GateSim::new(&packed);
            s1.set_bus("a", v);
            s2.set_bus("a", v);
            s1.step();
            s2.step();
            assert_eq!(s1.get_output("y"), s2.get_output("y"), "input {v}");
        }
    }

    #[test]
    fn packing_respects_fanout() {
        // Shared node must not be absorbed twice.
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 3);
        let shared = nl.xor2(a[0], a[1]);
        let y0 = nl.and2(shared, a[2]);
        let y1 = nl.or2(shared, a[2]);
        nl.add_output("y0", vec![y0]);
        nl.add_output("y1", vec![y1]);
        let packed = pack_luts(&nl);
        // shared has fanout 2: stays; 3 LUTs total.
        assert_eq!(packed.count_luts(), 3);
        for v in 0..8i64 {
            let mut s1 = GateSim::new(&nl);
            let mut s2 = GateSim::new(&packed);
            s1.set_bus("a", v);
            s2.set_bus("a", v);
            s1.step();
            s2.step();
            assert_eq!(s1.get_output("y0"), s2.get_output("y0"));
            assert_eq!(s1.get_output("y1"), s2.get_output("y1"));
        }
    }

    #[test]
    fn mapped_designs_have_plausible_counts() {
        for e in corpus::corpus() {
            let entry = corpus::by_id(e.id).unwrap();
            let m = corpus::load_entry(&entry).unwrap();
            let a = analyze_optimized(&m, entry.target).unwrap();
            let d = ir::build(&a, Q16_15);
            let mapped = map_design(&d);
            // Order-of-magnitude window around the paper's Table 1.
            assert!(
                mapped.lut4_cells > 300 && mapped.lut4_cells < 20_000,
                "{}: {} cells",
                e.id,
                mapped.lut4_cells
            );
            assert!(mapped.gate_count > 100, "{}: gates", e.id);
            assert!(mapped.dffs > 100, "{}: dffs", e.id);
        }
    }

    #[test]
    fn packed_pendulum_still_computes() {
        use crate::rtl::sim as rtlsim;
        let entry = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&entry).unwrap();
        let a = analyze_optimized(&m, entry.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let inputs: Vec<i64> = vec![
            Q16_15.from_f64(2.0),
            Q16_15.from_f64(1.5),
            Q16_15.from_f64(9.81),
        ];
        let mut sim = GateSim::new(&mapped.netlist);
        for (p, v) in d.ports.iter().zip(&inputs) {
            sim.set_bus(&format!("in_{}", p.name), *v);
        }
        sim.set_bus("start", 1);
        sim.step();
        sim.set_bus("start", 0);
        let mut n = 0;
        while !sim.get_bit("done") {
            sim.step();
            n += 1;
            assert!(n < 1000);
        }
        let expect = rtlsim::run_once(&d, &inputs);
        assert_eq!(sim.get_output("pi_0"), expect.outputs[0]);
        assert_eq!(n, expect.cycles);
    }

    #[test]
    fn more_signals_more_cells() {
        // Fluid-in-pipe (6 signals, 3 units) must use more cells than the
        // pendulum (3 signals, 1 unit) — the paper's Table-1 ordering.
        let cells = |id: &str| {
            let e = corpus::by_id(id).unwrap();
            let m = corpus::load_entry(&e).unwrap();
            let a = analyze_optimized(&m, e.target).unwrap();
            map_design(&ir::build(&a, Q16_15)).lut4_cells
        };
        assert!(cells("fluid_pipe") > cells("pendulum"));
    }
}
