//! VCD (Value Change Dump) waveform capture from the gate-level
//! simulator — lets generated designs be inspected in GTKWave and other
//! standard waveform viewers, like any real hardware flow.

use super::gatesim::GateSim;
use super::netlist::Netlist;
use std::fmt::Write as _;

/// Records selected buses each cycle and renders an IEEE-1364 VCD.
pub struct VcdRecorder {
    /// (bus name, width, samples per cycle).
    traces: Vec<(String, usize, Vec<i64>)>,
    cycles: u64,
}

impl VcdRecorder {
    /// Record the named output buses (must exist on the netlist).
    pub fn new(nl: &Netlist, buses: &[&str]) -> VcdRecorder {
        let traces = buses
            .iter()
            .map(|b| {
                let width = nl
                    .output_bits(b)
                    .map(<[_]>::len)
                    .unwrap_or_else(|| panic!("no output bus `{b}`"));
                (b.to_string(), width, Vec::new())
            })
            .collect();
        VcdRecorder { traces, cycles: 0 }
    }

    /// Capture the current value of every traced bus (call once per
    /// simulated cycle, after `GateSim::step`).
    pub fn capture(&mut self, sim: &GateSim<'_>) {
        for (name, _, samples) in self.traces.iter_mut() {
            samples.push(sim.get_output(name));
        }
        self.cycles += 1;
    }

    /// Render the VCD text (one timescale unit per clock cycle).
    pub fn render(&self, module: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "$date dimsynth $end");
        let _ = writeln!(out, "$timescale 1ns $end");
        let _ = writeln!(out, "$scope module {module} $end");
        // VCD identifier codes: printable ASCII starting at '!'.
        let ids: Vec<char> = (0..self.traces.len()).map(|i| (33 + i as u8) as char).collect();
        for ((name, width, _), id) in self.traces.iter().zip(&ids) {
            let _ = writeln!(out, "$var wire {width} {id} {name} $end");
        }
        let _ = writeln!(out, "$upscope $end");
        let _ = writeln!(out, "$enddefinitions $end");
        let mut last: Vec<Option<i64>> = vec![None; self.traces.len()];
        for t in 0..self.cycles as usize {
            let mut emitted_time = false;
            for (ti, (_, width, samples)) in self.traces.iter().enumerate() {
                let v = samples[t];
                if last[ti] != Some(v) {
                    if !emitted_time {
                        let _ = writeln!(out, "#{t}");
                        emitted_time = true;
                    }
                    let mut bits = String::with_capacity(*width);
                    for b in (0..*width).rev() {
                        bits.push(if (v >> b) & 1 == 1 { '1' } else { '0' });
                    }
                    let _ = writeln!(out, "b{bits} {}", ids[ti]);
                    last[ti] = Some(v);
                }
            }
        }
        let _ = writeln!(out, "#{}", self.cycles);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::{by_id, load_entry};
    use crate::pisearch::analyze_optimized;
    use crate::rtl;
    use crate::synth::{map_design, GateSim};

    #[test]
    fn vcd_captures_pendulum_activation() {
        let e = by_id("pendulum").unwrap();
        let m = load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = rtl::build(&a, Q16_15);
        let mapped = map_design(&d);
        let mut sim = GateSim::new(&mapped.netlist);
        let mut rec = VcdRecorder::new(&mapped.netlist, &["pi_0", "done"]);
        for (p, v) in d.ports.iter().zip([2.0, 1.5, 9.81]) {
            sim.set_bus(&format!("in_{}", p.name), Q16_15.from_f64(v));
        }
        sim.set_bus("start", 1);
        sim.step();
        rec.capture(&sim);
        sim.set_bus("start", 0);
        while !sim.get_bit("done") {
            sim.step();
            rec.capture(&sim);
        }
        let vcd = rec.render("pi_compute_pendulum");
        assert!(vcd.contains("$var wire 32 ! pi_0 $end"));
        assert!(vcd.contains("$var wire 1 \" done $end"));
        assert!(vcd.contains("$enddefinitions"));
        // done must transition exactly once (0 → 1): two value records.
        let done_changes = vcd.lines().filter(|l| l.ends_with(" \"")).count();
        assert_eq!(done_changes, 2, "vcd:\n{vcd}");
        // Timestamps are monotonically increasing.
        let times: Vec<u64> = vcd
            .lines()
            .filter_map(|l| l.strip_prefix('#').and_then(|t| t.parse().ok()))
            .collect();
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    #[should_panic]
    fn unknown_bus_panics() {
        let e = by_id("pendulum").unwrap();
        let m = load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = rtl::build(&a, Q16_15);
        let mapped = map_design(&d);
        let _ = VcdRecorder::new(&mapped.netlist, &["bogus"]);
    }
}
