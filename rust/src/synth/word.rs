//! Word-level construction helpers over the bit-level netlist: buses,
//! registers, adders, negation, absolute value, muxes, comparators and
//! reductions. These are the building blocks [`mod@super::lower`] uses to
//! elaborate the RTL datapaths into gates.

use super::netlist::{NetId, Netlist};

/// A bus of nets, LSB first.
pub type Word = Vec<NetId>;

/// Constant word of `width` bits (sign-extended past bit 63 for wide
/// buses, e.g. the 2W-bit product registers of wide formats).
pub fn word_const(nl: &mut Netlist, width: u32, value: i64) -> Word {
    (0..width).map(|b| nl.constant((value >> b.min(63)) & 1 == 1)).collect()
}

/// A register bank: `width` DFFs with init 0. Returns the Q outputs; data
/// inputs are closed later with [`connect`].
pub fn register(nl: &mut Netlist, width: u32) -> Word {
    (0..width)
        .map(|_| {
            // Temporarily self-looped; rewired by `connect`.
            let placeholder = nl.constant(false);
            nl.dff(placeholder, false)
        })
        .collect()
}

/// Close register feedback: drive register `q`'s D inputs from `d`.
pub fn connect(nl: &mut Netlist, q: &Word, d: &Word) {
    assert_eq!(q.len(), d.len(), "register width mismatch");
    for (&ff, &din) in q.iter().zip(d.iter()) {
        nl.set_dff_input(ff, din);
    }
}

/// Ripple-carry adder; returns (sum, carry_out).
pub fn add(nl: &mut Netlist, a: &Word, b: &Word, cin: NetId) -> (Word, NetId) {
    assert_eq!(a.len(), b.len());
    let mut carry = cin;
    let mut sum = Vec::with_capacity(a.len());
    for (&x, &y) in a.iter().zip(b.iter()) {
        let (s, c) = nl.full_adder(x, y, carry);
        sum.push(s);
        carry = c;
    }
    (sum, carry)
}

/// Subtractor `a - b`; returns (difference, borrow-free flag: 1 if a >= b
/// treating operands as unsigned).
pub fn sub(nl: &mut Netlist, a: &Word, b: &Word) -> (Word, NetId) {
    let nb: Word = b.iter().map(|&x| nl.not(x)).collect();
    let one = nl.constant(true);
    add(nl, a, &nb, one)
}

/// Two's-complement negation.
pub fn neg(nl: &mut Netlist, a: &Word) -> Word {
    let na: Word = a.iter().map(|&x| nl.not(x)).collect();
    let zero = word_const(nl, a.len() as u32, 0);
    let one = nl.constant(true);
    add(nl, &na, &zero, one).0
}

/// Absolute value of a two's-complement word (the extremum negates to
/// itself, as in real hardware).
pub fn abs(nl: &mut Netlist, a: &Word) -> Word {
    let sign = *a.last().unwrap();
    let n = neg(nl, a);
    mux_word(nl, sign, &n, a)
}

/// Word-wide 2:1 mux: `s ? a : b`.
pub fn mux_word(nl: &mut Netlist, s: NetId, a: &Word, b: &Word) -> Word {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| nl.mux(s, x, y)).collect()
}

/// OR-reduction (balanced tree).
pub fn or_reduce(nl: &mut Netlist, w: &[NetId]) -> NetId {
    match w.len() {
        0 => nl.constant(false),
        1 => w[0],
        n => {
            let (lo, hi) = w.split_at(n / 2);
            let l = or_reduce(nl, lo);
            let r = or_reduce(nl, hi);
            nl.or2(l, r)
        }
    }
}

/// AND-reduction (balanced tree).
pub fn and_reduce(nl: &mut Netlist, w: &[NetId]) -> NetId {
    match w.len() {
        0 => nl.constant(true),
        1 => w[0],
        n => {
            let (lo, hi) = w.split_at(n / 2);
            let l = and_reduce(nl, lo);
            let r = and_reduce(nl, hi);
            nl.and2(l, r)
        }
    }
}

/// Equality with a constant.
pub fn eq_const(nl: &mut Netlist, w: &Word, k: i64) -> NetId {
    let bits: Vec<NetId> = w
        .iter()
        .enumerate()
        .map(|(i, &b)| if (k >> i) & 1 == 1 { b } else { nl.not(b) })
        .collect();
    and_reduce(nl, &bits)
}

/// Zero test.
pub fn is_zero(nl: &mut Netlist, w: &Word) -> NetId {
    let any = or_reduce(nl, w);
    nl.not(any)
}

/// Static left shift (wiring): `w << n` within `width` bits.
pub fn shl_const(nl: &mut Netlist, w: &Word, n: u32) -> Word {
    let zero = nl.constant(false);
    let mut out = vec![zero; n as usize];
    out.extend_from_slice(w);
    out.truncate(w.len());
    out
}

/// Take a bit range `[lo, hi)` (wiring).
pub fn slice(w: &Word, lo: u32, hi: u32) -> Word {
    w[lo as usize..hi as usize].to_vec()
}

/// Zero-extend to `width`.
pub fn zext(nl: &mut Netlist, w: &Word, width: u32) -> Word {
    let zero = nl.constant(false);
    let mut out = w.clone();
    while (out.len() as u32) < width {
        out.push(zero);
    }
    out
}

/// Concatenate (lo word first).
pub fn concat(lo: &Word, hi: &Word) -> Word {
    let mut out = lo.clone();
    out.extend_from_slice(hi);
    out
}

/// Incrementer: `w + 1`.
pub fn inc(nl: &mut Netlist, w: &Word) -> Word {
    let zero = word_const(nl, w.len() as u32, 0);
    let one = nl.constant(true);
    add(nl, w, &zero, one).0
}

/// Decrementer: `w - 1`.
pub fn dec(nl: &mut Netlist, w: &Word) -> Word {
    let ones = word_const(nl, w.len() as u32, -1);
    let zero_c = nl.constant(false);
    add(nl, w, &ones, zero_c).0
}

/// Number of bits needed to hold values `0..=max`.
pub fn bits_for(max: u64) -> u32 {
    64 - max.leading_zeros().max(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::gatesim::GateSim;

    /// Helper: build a combinational function of two input buses and
    /// evaluate it.
    fn eval2(
        width: u32,
        a_val: i64,
        b_val: i64,
        f: impl Fn(&mut Netlist, &Word, &Word) -> Word,
    ) -> i64 {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", width);
        let b = nl.input_bus("b", width);
        let y = f(&mut nl, &a, &b);
        nl.add_output("y", y);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", a_val);
        sim.set_bus("b", b_val);
        sim.step();
        sim.get_output("y")
    }

    #[test]
    fn adder_exhaustive_4bit() {
        for a in -8..8i64 {
            for b in -8..8i64 {
                let got = eval2(4, a, b, |nl, x, y| {
                    let z = nl.constant(false);
                    add(nl, x, y, z).0
                });
                let expect = ((a + b) << 60) >> 60; // wrap to 4 bits signed
                assert_eq!(got, expect, "{a}+{b}");
            }
        }
    }

    #[test]
    fn subtractor_exhaustive_4bit() {
        for a in -8..8i64 {
            for b in -8..8i64 {
                let got = eval2(4, a, b, |nl, x, y| sub(nl, x, y).0);
                let expect = ((a - b) << 60) >> 60;
                assert_eq!(got, expect, "{a}-{b}");
            }
        }
    }

    #[test]
    fn sub_borrow_flag_unsigned() {
        // flag = 1 iff a >= b (unsigned).
        for a in 0..16i64 {
            for b in 0..16i64 {
                let mut nl = Netlist::new();
                let aw = nl.input_bus("a", 4);
                let bw = nl.input_bus("b", 4);
                let (_, ok) = sub(&mut nl, &aw, &bw);
                nl.add_output("ok", vec![ok]);
                let mut sim = GateSim::new(&nl);
                sim.set_bus("a", a);
                sim.set_bus("b", b);
                sim.step();
                assert_eq!(sim.get_bit("ok"), a >= b, "{a} >= {b}");
            }
        }
    }

    #[test]
    fn neg_abs_8bit() {
        for v in -128..128i64 {
            let got_neg = eval2(8, v, 0, |nl, x, _| neg(nl, x));
            assert_eq!(got_neg, ((-v) << 56) >> 56, "neg {v}");
            let got_abs = eval2(8, v, 0, |nl, x, _| abs(nl, x));
            let expect = if v == -128 { -128 } else { v.abs() };
            assert_eq!(got_abs, expect, "abs {v}");
        }
    }

    #[test]
    fn mux_and_reductions() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let s = nl.input_bus("s", 1);
        let y = mux_word(&mut nl, s[0], &a, &b);
        let z = is_zero(&mut nl, &a);
        let e = eq_const(&mut nl, &a, 5);
        nl.add_output("y", y);
        nl.add_output("z", vec![z]);
        nl.add_output("e", vec![e]);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", 5);
        sim.set_bus("b", 2);
        sim.set_bus("s", 1);
        sim.step();
        assert_eq!(sim.get_output("y") & 0xF, 5);
        assert!(!sim.get_bit("z"));
        assert!(sim.get_bit("e"));
        sim.set_bus("s", 0);
        sim.set_bus("a", 0);
        sim.step();
        assert_eq!(sim.get_output("y") & 0xF, 2);
        assert!(sim.get_bit("z"));
        assert!(!sim.get_bit("e"));
    }

    #[test]
    fn inc_dec_roundtrip() {
        for v in 0..15i64 {
            let got = eval2(4, v, 0, |nl, x, _| {
                let i = inc(nl, x);
                dec(nl, &i)
            });
            assert_eq!(got & 0xF, v, "inc/dec {v}");
        }
    }

    #[test]
    fn shifts_and_slices() {
        let got = eval2(8, 0b0000_0101, 0, |nl, x, _| shl_const(nl, x, 2));
        assert_eq!(got & 0xFF, 0b0001_0100);
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let s = slice(&a, 4, 8);
        nl.add_output("y", s);
        let mut sim = GateSim::new(&nl);
        sim.set_bus("a", 0xA5);
        sim.step();
        assert_eq!(sim.get_output("y") & 0xF, 0xA);
    }

    #[test]
    fn bits_for_widths() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(47), 6);
        assert_eq!(bits_for(48), 6);
        assert_eq!(bits_for(63), 6);
        assert_eq!(bits_for(64), 7);
    }

    #[test]
    fn register_connect_cycle() {
        // Register that doubles each cycle: q <= q + q (i.e. shifts left).
        let mut nl = Netlist::new();
        let q = register(&mut nl, 8);
        // Initialize via mux with a start input.
        let start = nl.input_bus("start", 1);
        let one = word_const(&mut nl, 8, 1);
        let z = nl.constant(false);
        let doubled = add(&mut nl, &q, &q, z).0;
        let d = mux_word(&mut nl, start[0], &one, &doubled);
        connect(&mut nl, &q, &d);
        nl.add_output("q", q.clone());
        let mut sim = GateSim::new(&nl);
        sim.set_bus("start", 1);
        sim.step();
        sim.set_bus("start", 0);
        for expect in [2i64, 4, 8, 16, 32, 64] {
            sim.step();
            assert_eq!(sim.get_output("q") & 0xFF, expect);
        }
    }
}
