//! The in-sensor inference engine (Layer 3): request routing, dynamic
//! batching, the Π→Φ pipeline, and serving metrics.
//!
//! Architecture (paper Figs. 3–4): sensor observations are quantized to
//! the hardware fixed-point format, preprocessed into dimensionless
//! products (by the synthesized hardware in a real deployment; here by
//! one of three bit-identical Π paths), and fed to the Φ model executed
//! as an AOT-compiled XLA artifact. Python never runs at serve time.
//!
//! Multi-system deployments serve from **one warm [`ServeSet`]**: a
//! shared [`flow::FlowSet`](crate::flow::FlowSet) (optionally backed by
//! a persistent artifact store, so restarts boot with zero recomputes)
//! hands each per-system [`InferenceServer`] a [`SystemHandle`] view of
//! its compiled state, and [`PowerRequest`] floods from every system
//! run through one global width-aware [`PowerBatcher`] that packs
//! word-parallel lanes across systems — or, with fusion enabled
//! ([`ServeSet::enable_fusion`]), through one sharded evaluation of the
//! fused multi-system netlist ([`crate::shard`]), bit-identical either
//! way.

//! Network deployments add three layers in front of the engine:
//! [`net`] (TCP framing, per-connection threads, per-connection rate
//! limits, and the HTTP metrics scrape endpoint) → [`admission`]
//! (per-tenant token buckets, bounded queues, deadlines, sharded into
//! per-lane queue groups) → [`engine`] (K parallel dispatch lanes, each
//! running fair round-robin collection over its own tenants, with typed
//! [`error::ServeError`] outcomes and per-lane panic containment), with
//! [`faults`] providing deterministic sabotage — including lane kills —
//! for the e2e/soak harnesses.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod error;
pub mod faults;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod server;
pub mod serveset;

pub use admission::{AdmissionConfig, Deadline, TenantSpec};
pub use engine::{EngineConfig, RequestPayload, TrafficEngine, TrafficReply, TrafficResponse};
pub use error::ServeError;
pub use faults::{FaultAction, FaultPlan};
pub use metrics::{LaneTraffic, LatencyHistogram, ServeStats, TrafficCounters, TrafficReport};
pub use net::{DriverConfig, DriverReport, NetClient, NetConfig, NetServer, ScrapeServer, StatsProbe};
pub use pipeline::{
    estimate_power_requests, estimate_power_requests_fused, estimate_power_requests_fused_stats,
    estimate_power_requests_grouped, DatasetStats, Pipeline, PiPath, PowerEstimate, PowerRequest,
    Prediction, SensorInput, SystemPowerRequest,
};
pub use server::{InferenceServer, Request, ServerConfig};
pub use serveset::{FloodStats, FusedPlan, PowerBatcher, ServeSet, SystemHandle};

use crate::fixedpoint::Q16_15;
use crate::flow::{ArtifactStore, FlowConfig, StageCounts};
use crate::report::export::SystemExport;
use crate::stim::{self, Lfsr32};
use crate::train::{self, FeatureKind, TrainOutput};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stream `n` synthetic observations through a running server and
/// return (mean relative target error over valid samples, valid-sample
/// count). Shared by the single- and multi-system synthetic drivers.
fn stream_synthetic(
    server: &InferenceServer,
    export: &SystemExport,
    system: &str,
    n: usize,
    stream_seed: u32,
) -> anyhow::Result<(f64, usize)> {
    let mut rng = Lfsr32::new(stream_seed);
    let mut pending = Vec::with_capacity(n);
    let mut truths = Vec::with_capacity(n);
    for _ in 0..n {
        let sample = stim::sample_noisy(system, &mut rng, 0.0)
            .ok_or_else(|| anyhow::anyhow!("no trace generator for `{system}`"))?;
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(sample[si])).collect();
        truths.push(sample[export.target_index]);
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut err_sum = 0f64;
    let mut err_n = 0usize;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let pred = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped a response"))??;
        if pred.target_estimate.is_finite() && truth.abs() > 1e-9 {
            err_sum += ((pred.target_estimate - truth) / truth).abs();
            err_n += 1;
        }
    }
    Ok((err_sum / err_n.max(1) as f64, err_n))
}

/// End-to-end synthetic serve: train Φ, start the server, stream `n`
/// synthetic sensor observations through it, and return a report.
///
/// This is what `dimsynth serve <system>` runs, and the core of the
/// quickstart example.
pub fn serve_synthetic(
    artifacts: &str,
    system: &str,
    n: usize,
    max_batch: usize,
) -> anyhow::Result<String> {
    // Offline calibration (Step 3).
    let trained = train::run_training(artifacts, system, FeatureKind::Pi, 800, 0xD1CE)?;
    let export = trained.dataset.export.clone();

    // Deployment (Step 4).
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: artifacts.to_string(),
            system: system.to_string(),
            max_batch,
            linger: Duration::from_micros(500),
            pi_path: PiPath::Native,
        },
        trained.clone(),
    )?;

    // Stream observations and check target recovery online.
    let (mean_rel, _) = stream_synthetic(&server, &export, system, n, 0xFEED)?;
    let stats = server.shutdown();

    let mut out = String::new();
    out.push_str(&format!("system:      {system}\n"));
    out.push_str(&format!(
        "train loss:  {:.6} ({} steps)\n",
        trained.final_loss, trained.steps
    ));
    out.push_str(&format!("val RMSE:    {:.5} (raw target units)\n", trained.val_rmse));
    out.push_str(&format!("mean |rel. target error| online: {:.3}%\n", 100.0 * mean_rel));
    out.push_str(&stats.to_string());
    Ok(out)
}

/// Admission-policy knobs of a [`serve_listen`] deployment, applied to
/// every tenant (the default roster is one tenant per served system,
/// named after it).
#[derive(Clone, Debug)]
pub struct ListenConfig {
    /// Token-bucket sustained rate per tenant (requests/second;
    /// `f64::INFINITY` disables rate limiting).
    pub rate_per_sec: f64,
    /// Token-bucket burst per tenant.
    pub burst: f64,
    /// Bounded queue depth per tenant.
    pub queue_cap: usize,
    /// Default request deadline (requests may carry their own).
    pub deadline_ms: u64,
    /// Cap on concurrent TCP connections (0 = unlimited); accepts over
    /// the cap get a typed shed handshake and a clean close.
    pub max_conns: usize,
    /// Fuse every served system's netlist into one module partitioned
    /// into this many shards and route power floods through the sharded
    /// evaluation (0 = per-netlist grouped dispatch).
    pub fuse_shards: usize,
    /// Parallel dispatch lanes (dispatcher threads); 0 = auto:
    /// `min(cores/2, tenants)`, at least 1. Tenants are hash-sharded
    /// across lanes by name.
    pub dispatchers: usize,
    /// Per-connection token-bucket rate (requests/second ahead of
    /// tenant admission; `f64::INFINITY` disables). Over-rate frames
    /// are answered with a typed shed carrying a retry hint.
    pub conn_rate: f64,
    /// Optional HTTP metrics scrape address (`GET` returns the traffic
    /// report as JSON, Prometheus-collector friendly).
    pub scrape_addr: Option<String>,
}

impl Default for ListenConfig {
    fn default() -> Self {
        ListenConfig {
            rate_per_sec: f64::INFINITY,
            burst: 64.0,
            queue_cap: 1024,
            deadline_ms: 1000,
            max_conns: 0,
            fuse_shards: 0,
            dispatchers: 0,
            conn_rate: f64::INFINITY,
            scrape_addr: None,
        }
    }
}

/// A live network deployment from [`serve_listen`]: shut it down with
/// `handle.server.shutdown()` once the caller decides to stop (e.g. on
/// stdin EOF).
pub struct ListenHandle {
    pub server: NetServer,
    /// The HTTP metrics endpoint, when `scrape_addr` was configured.
    pub scrape: Option<net::ScrapeServer>,
    /// Human-readable boot summary (systems, cache telemetry, address).
    pub boot: String,
    pub counts: StageCounts,
}

/// Boot a multi-system [`ServeSet`] and put the full serving stack —
/// TCP frontend, per-tenant admission control, fair dispatch — in front
/// of it: what `dimsynth serve --systems a,b --listen ADDR` runs. One
/// tenant per system is registered, named after the system, with
/// `listen_config`'s admission policy.
pub fn serve_listen(
    systems: &[&str],
    listen: &str,
    config: FlowConfig,
    store: Option<Arc<ArtifactStore>>,
    listen_config: ListenConfig,
) -> anyhow::Result<ListenHandle> {
    let activations = config.power_samples;
    let t0 = Instant::now();
    let mut set = ServeSet::boot(systems, config, store)?;
    if listen_config.fuse_shards > 0 {
        // Before the engine starts: it snapshots the fusion state.
        set.enable_fusion(listen_config.fuse_shards)?;
    }
    let boot_time = t0.elapsed();
    let counts = set.total_counts();
    let mut admission = AdmissionConfig::one_tenant_per_system(&set.systems());
    admission.default_deadline = Duration::from_millis(listen_config.deadline_ms);
    for tenant in &mut admission.tenants {
        tenant.rate_per_sec = listen_config.rate_per_sec;
        tenant.burst = listen_config.burst;
        tenant.queue_cap = listen_config.queue_cap;
    }
    // Auto lane count: half the cores (the other half serves the Π/power
    // compute itself), never more lanes than tenants, never zero.
    let dispatchers = if listen_config.dispatchers == 0 {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        (cores / 2).clamp(1, set.len())
    } else {
        listen_config.dispatchers
    };
    let engine = Arc::new(TrafficEngine::start(
        &set,
        admission,
        EngineConfig { activations, max_batch: 0, dispatchers },
        FaultPlan::none(),
    )?);
    let lanes = engine.lane_count();
    let server = NetServer::start_with(
        engine.clone(),
        listen,
        net::NetConfig {
            max_conns: listen_config.max_conns,
            conn_rate: listen_config.conn_rate,
        },
    )?;
    let scrape = match &listen_config.scrape_addr {
        Some(addr) => Some(net::ScrapeServer::start(engine, addr)?),
        None => None,
    };
    let mut boot = String::new();
    boot.push_str(&format!(
        "serve set:   {} systems ({}) on one warm FlowSet\n",
        set.len(),
        set.systems().join(", ")
    ));
    boot.push_str(&format!(
        "boot:        {:.1} ms ({} recomputes, {} disk hits, {} lanes/pass)\n",
        boot_time.as_secs_f64() * 1e3,
        counts.recomputes(),
        counts.disk_hits,
        set.lane_width().lanes()
    ));
    if let Some(f) = set.fusion() {
        boot.push_str(&format!(
            "fused:       {} nets over {} members, {} shards ({} comb cuts, {} reg cuts; cut cost {}, refinement -{})\n",
            f.artifact.fused.netlist.len(),
            f.artifact.fused.member_count(),
            f.plan.shards,
            f.plan.cuts.comb_cuts.len(),
            f.plan.cuts.reg_cuts.len(),
            f.plan.cut_cost(),
            f.plan.refinement.removed()
        ));
    }
    boot.push_str(&format!(
        "listening:   {} (net → admission → {} dispatch lane{})\n",
        server.local_addr(),
        lanes,
        if lanes == 1 { "" } else { "s" }
    ));
    if let Some(s) = &scrape {
        boot.push_str(&format!("scrape:      http://{} (GET → traffic report JSON)\n", s.local_addr()));
    }
    Ok(ListenHandle { server, scrape, boot, counts })
}

/// Multi-system synthetic serve on one warm [`ServeSet`] — what
/// `dimsynth serve --systems a,b,c [--cache-dir DIR]` runs.
///
/// Boots the shared flow graph (warm from `store` when given), floods
/// the cross-system [`PowerBatcher`] with `flood` requests spread
/// round-robin over the systems, and — when the AOT artifacts exist and
/// `samples > 0` — trains and serves a synthetic stream per system
/// through [`InferenceServer::start_shared`]. With `fuse_shards > 0`
/// the set's netlists are fused into one module partitioned that many
/// ways and the flood runs through the sharded evaluation
/// ([`ServeSet::enable_fusion`]) — bit-identical estimates, one fused
/// pass per lane round. Returns the report text and the set's
/// stage-cache telemetry (`recomputes() == 0` on a warm reboot — the
/// acceptance gate CI greps for).
#[allow(clippy::too_many_arguments)]
pub fn serve_multi(
    artifacts: &str,
    systems: &[&str],
    samples: usize,
    max_batch: usize,
    flood: usize,
    fuse_shards: usize,
    config: FlowConfig,
    store: Option<Arc<ArtifactStore>>,
) -> anyhow::Result<(String, StageCounts)> {
    let activations = config.power_samples;
    let t0 = Instant::now();
    let mut set = ServeSet::boot(systems, config, store)?;
    if fuse_shards > 0 {
        // Before the batcher spawns: it snapshots the fusion state.
        set.enable_fusion(fuse_shards)?;
    }
    let boot = t0.elapsed();
    let counts = set.total_counts();

    let mut out = String::new();
    out.push_str(&format!(
        "serve set:   {} systems ({}) on one warm FlowSet\n",
        set.len(),
        set.systems().join(", ")
    ));
    out.push_str(&format!(
        "boot:        {:.1} ms ({} recomputes, {} disk hits, {} lanes/pass)\n",
        boot.as_secs_f64() * 1e3,
        counts.recomputes(),
        counts.disk_hits,
        set.lane_width().lanes()
    ));
    if let Some(f) = set.fusion() {
        out.push_str(&format!(
            "fused:       {} nets over {} members, {} shards ({} comb cuts, {} reg cuts; cut cost {}, refinement -{})\n",
            f.artifact.fused.netlist.len(),
            f.artifact.fused.member_count(),
            f.plan.shards,
            f.plan.cuts.comb_cuts.len(),
            f.plan.cuts.reg_cuts.len(),
            f.plan.cut_cost(),
            f.plan.refinement.removed()
        ));
    }

    if flood > 0 {
        // Mixed-system power-request flood through the global batcher:
        // zero linger — the flood is already queued, so batches fill
        // without waiting.
        let batcher = set.power_batcher(Duration::ZERO, activations);
        let t = Instant::now();
        let pending: Vec<_> = (0..flood)
            .map(|i| {
                let request = PowerRequest {
                    seed: 0xF10_0D ^ i as u32,
                    f_hz: if i % 2 == 0 { 6.0e6 } else { 12.0e6 },
                };
                batcher.submit(i % set.len(), request)
            })
            .collect();
        let mut mw_sum = 0f64;
        for rx in pending {
            mw_sum += rx
                .recv()
                .map_err(|_| anyhow::anyhow!("power batcher dropped a response"))??
                .mw;
        }
        let dt = t.elapsed().max(Duration::from_nanos(1));
        let stats = batcher.shutdown();
        anyhow::ensure!(!stats.worker_panicked, "power batcher worker panicked");
        out.push_str(&format!(
            "power flood: {} requests over {} systems in {:.1} ms ({:.0} req/s, {} batches, mean fill {:.1}, {} cross-system)\n",
            stats.requests,
            set.len(),
            dt.as_secs_f64() * 1e3,
            stats.requests as f64 / dt.as_secs_f64(),
            stats.batches,
            stats.mean_batch_fill(),
            stats.mixed_batches,
        ));
        out.push_str(&format!(
            "             mean estimate {:.2} mW over the flood\n",
            mw_sum / flood as f64
        ));
    }

    if samples > 0 {
        if !std::path::Path::new(artifacts).join("manifest.txt").exists() {
            out.push_str(&format!(
                "Φ serving:   skipped — no AOT artifacts at `{artifacts}` (run `make artifacts`)\n"
            ));
        } else {
            for system in set.systems() {
                let trained: TrainOutput =
                    train::run_training(artifacts, system, FeatureKind::Pi, 800, 0xD1CE)?;
                let export = trained.dataset.export.clone();
                let server = InferenceServer::start_shared(
                    ServerConfig {
                        artifacts: artifacts.to_string(),
                        system: system.to_string(),
                        max_batch,
                        linger: Duration::from_micros(500),
                        pi_path: PiPath::Native,
                    },
                    trained,
                    set.handle(system).expect("system is in the set"),
                )?;
                let (mean_rel, _) = stream_synthetic(&server, &export, system, samples, 0xFEED)?;
                let stats = server.shutdown();
                anyhow::ensure!(!stats.worker_panicked, "serving worker for `{system}` panicked");
                out.push_str(&format!(
                    "{system:<24} {samples} samples, {:.0}/s, mean |rel err| {:.3}%, p99 {} µs\n",
                    stats.throughput(),
                    100.0 * mean_rel,
                    stats.latency.percentile_us(0.99),
                ));
            }
        }
    }

    Ok((out, counts))
}
