//! The in-sensor inference engine (Layer 3): request routing, dynamic
//! batching, the Π→Φ pipeline, and serving metrics.
//!
//! Architecture (paper Figs. 3–4): sensor observations are quantized to
//! the hardware fixed-point format, preprocessed into dimensionless
//! products (by the synthesized hardware in a real deployment; here by
//! one of three bit-identical Π paths), and fed to the Φ model executed
//! as an AOT-compiled XLA artifact. Python never runs at serve time.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod server;

pub use metrics::{LatencyHistogram, ServeStats};
pub use pipeline::{
    estimate_power_requests, DatasetStats, Pipeline, PiPath, PowerEstimate, PowerRequest,
    Prediction, SensorInput,
};
pub use server::{InferenceServer, Request, ServerConfig};

use crate::fixedpoint::Q16_15;
use crate::stim::{self, Lfsr32};
use crate::train::{self, FeatureKind};
use std::time::Duration;

/// End-to-end synthetic serve: train Φ, start the server, stream `n`
/// synthetic sensor observations through it, and return a report.
///
/// This is what `dimsynth serve <system>` runs, and the core of the
/// quickstart example.
pub fn serve_synthetic(
    artifacts: &str,
    system: &str,
    n: usize,
    max_batch: usize,
) -> anyhow::Result<String> {
    // Offline calibration (Step 3).
    let trained = train::run_training(artifacts, system, FeatureKind::Pi, 800, 0xD1CE)?;
    let export = trained.dataset.export.clone();

    // Deployment (Step 4).
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: artifacts.to_string(),
            system: system.to_string(),
            max_batch,
            linger: Duration::from_micros(500),
            pi_path: PiPath::Native,
        },
        trained.clone(),
    )?;

    // Stream observations and check target recovery online.
    let mut rng = Lfsr32::new(0xFEED);
    let mut pending = Vec::with_capacity(n);
    let mut truths = Vec::with_capacity(n);
    for _ in 0..n {
        let sample = stim::sample_noisy(system, &mut rng, 0.0)
            .ok_or_else(|| anyhow::anyhow!("no trace generator for `{system}`"))?;
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(sample[si])).collect();
        truths.push(sample[export.target_index]);
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut err_sum = 0f64;
    let mut err_n = 0usize;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let pred = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped a response"))??;
        if pred.target_estimate.is_finite() && truth.abs() > 1e-9 {
            err_sum += ((pred.target_estimate - truth) / truth).abs();
            err_n += 1;
        }
    }
    let stats = server.shutdown();

    let mut out = String::new();
    out.push_str(&format!("system:      {system}\n"));
    out.push_str(&format!(
        "train loss:  {:.6} ({} steps)\n",
        trained.final_loss, trained.steps
    ));
    out.push_str(&format!("val RMSE:    {:.5} (raw target units)\n", trained.val_rmse));
    out.push_str(&format!(
        "mean |rel. target error| online: {:.3}%\n",
        100.0 * err_sum / err_n.max(1) as f64
    ));
    out.push_str(&stats.to_string());
    Ok(out)
}
