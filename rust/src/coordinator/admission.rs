//! Per-tenant admission control: token-bucket rate limits, bounded
//! per-tenant queues, request deadlines, and fair (round-robin) batch
//! collection — the discipline that keeps one flooding tenant from
//! starving everyone else behind the shared serving substrate.
//!
//! The shape follows production serving frontends: every tenant owns a
//! private bounded queue and a private token bucket, so overload
//! backpressures the tenant that caused it. A request is either
//! *admitted* (it will receive exactly one response, served or typed
//! error) or *rejected at the door* with a [`Rejection`] carrying a
//! retry-after hint computed from real queue pressure — never silently
//! dropped. Dispatch pulls batches round-robin across tenants
//! ([`TenantQueues::collect_fair`]): one item per non-empty tenant per
//! sweep, so a tenant with 10 000 queued requests and a tenant with 1
//! both make progress every round.
//!
//! # Lane topology
//!
//! The queue registry is sharded into `K` **dispatch lanes** so `K`
//! dispatcher threads can collect concurrently without contending on
//! one lock. Every tenant lives in exactly one lane — by default the
//! stable FNV-1a hash of its name modulo `K` ([`TenantQueues::lane_for`]),
//! or pinned explicitly via [`TenantSpec::with_lane`]. Each lane group
//! owns a private mutex + condvar and its own round-robin cursor, so
//! fairness is arbitrated *within* a lane and lanes never block each
//! other. Token buckets and queue caps stay attached to the tenant
//! (which is in exactly one lane), so rate limits remain tenant-scoped
//! — sharding never splits or multiplies a tenant's budget.
//!
//! Deadlines ride on every queued item ([`Deadline`]); expired work is
//! dropped *at dequeue* by the dispatcher (answered with
//! `DeadlineExceeded`, not computed) — queue time counts against the
//! budget, which is what bounds tail latency under overload.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::flow::config::StableHasher;

/// An absolute expiry instant carried by every enqueued request.
///
/// Constructed from a relative budget at admission
/// ([`Deadline::after`]); checked at dequeue so queueing time counts
/// against the budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadline {
    expires_at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline { expires_at: Instant::now() + budget }
    }

    /// A deadline at an explicit instant (tests, replay harnesses).
    pub fn at(expires_at: Instant) -> Deadline {
        Deadline { expires_at }
    }

    /// Has the deadline passed?
    pub fn expired(&self) -> bool {
        Instant::now() >= self.expires_at
    }

    /// Time left before expiry (zero when already expired).
    pub fn remaining(&self) -> Duration {
        self.expires_at.saturating_duration_since(Instant::now())
    }
}

/// One tenant's admission policy: identity, which system of the serve
/// set it targets, its token-bucket rate limit, its queue bound, and an
/// optional explicit dispatch-lane pin.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant identity presented on the wire.
    pub name: String,
    /// System (by serve-set id) this tenant's requests run against.
    pub system: String,
    /// Sustained admission rate (requests/second). `f64::INFINITY`
    /// disables rate limiting for this tenant.
    pub rate_per_sec: f64,
    /// Token-bucket burst capacity (requests admitted back-to-back from
    /// a full bucket).
    pub burst: f64,
    /// Bounded queue depth; an arrival beyond this is shed.
    pub queue_cap: usize,
    /// Explicit dispatch-lane pin (`Some(l)` places the tenant in lane
    /// `l % K`); `None` hash-shards by tenant name.
    pub lane: Option<usize>,
}

impl TenantSpec {
    /// A tenant with permissive defaults: no rate limit, burst 64, a
    /// 1024-deep queue, hash-sharded lane placement.
    pub fn new(name: &str, system: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            system: system.to_string(),
            rate_per_sec: f64::INFINITY,
            burst: 64.0,
            queue_cap: 1024,
            lane: None,
        }
    }

    /// Set the token-bucket rate and burst.
    pub fn with_rate(mut self, rate_per_sec: f64, burst: f64) -> TenantSpec {
        self.rate_per_sec = rate_per_sec;
        self.burst = burst;
        self
    }

    /// Set the bounded queue depth.
    pub fn with_queue_cap(mut self, cap: usize) -> TenantSpec {
        self.queue_cap = cap;
        self
    }

    /// Pin the tenant to dispatch lane `lane % K`, overriding the
    /// default hash placement (fault drills, fairness tests, manual
    /// load balancing).
    pub fn with_lane(mut self, lane: usize) -> TenantSpec {
        self.lane = Some(lane);
        self
    }
}

/// The admission policy of a whole deployment: the registered tenants
/// plus the deadline applied to requests that do not carry their own.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    pub tenants: Vec<TenantSpec>,
    /// Deadline budget for requests that carry none (wire `deadline_us
    /// == 0`).
    pub default_deadline: Duration,
}

impl AdmissionConfig {
    /// One permissive tenant per system, named after it — the shape
    /// `serve --listen` boots with by default.
    pub fn one_tenant_per_system(systems: &[&str]) -> AdmissionConfig {
        AdmissionConfig {
            tenants: systems.iter().map(|s| TenantSpec::new(s, s)).collect(),
            default_deadline: Duration::from_secs(1),
        }
    }
}

/// Why admission refused a request. Maps onto
/// [`ServeError::Shed`](super::error::ServeError::Shed) at the serving
/// boundary; kept separate so the queue layer stays transport-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejection {
    /// Token bucket empty; retry once it has refilled one token.
    RateLimited { retry_after: Duration },
    /// Bounded queue full; retry-after is the oldest entry's age (a
    /// live estimate of drain time — real queue pressure, not a
    /// constant).
    QueueFull { retry_after: Duration },
    /// The server is draining; nothing new is admitted.
    Draining,
}

impl Rejection {
    /// The retry-after hint in milliseconds, clamped to [1, 60000].
    /// Draining reports 0: "do not retry here".
    pub fn retry_after_ms(&self) -> u32 {
        match self {
            Rejection::RateLimited { retry_after } | Rejection::QueueFull { retry_after } => {
                (retry_after.as_millis() as u64).clamp(1, 60_000) as u32
            }
            Rejection::Draining => 0,
        }
    }
}

/// A deterministic token bucket: `burst` capacity, `rate` tokens/second
/// refill, explicitly clocked (callers pass `now`) so tests drive it
/// with synthetic time.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    tokens: f64,
    rate: f64,
    burst: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_per_sec: f64, burst: f64, now: Instant) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { tokens: burst, rate: rate_per_sec.max(0.0), burst, last: now }
    }

    /// Take one token at `now`, or report how long until one refills.
    /// An infinite rate always succeeds.
    pub fn try_take_at(&mut self, now: Instant) -> Result<(), Duration> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        if self.rate <= 0.0 {
            // A zero-rate tenant can never refill; report a long hold.
            return Err(Duration::from_secs(60));
        }
        Err(Duration::from_secs_f64((1.0 - self.tokens) / self.rate))
    }
}

/// One tenant's private slot inside its lane group: bounded FIFO (items
/// timestamped at enqueue, so oldest-entry age is observable) plus its
/// token bucket and a monotone per-tenant admission sequence number
/// (deterministic fault-injection keys on it). The bucket lives here —
/// with the tenant, not the lane — so rate limits stay tenant-scoped no
/// matter how tenants are sharded.
struct Slot<T> {
    queue: VecDeque<(Instant, T)>,
    bucket: TokenBucket,
    cap: usize,
    admitted: u64,
}

struct GroupState<T> {
    slots: Vec<Slot<T>>,
    /// Round-robin position of the next collection sweep (per lane —
    /// fairness is arbitrated among the lane's own tenants).
    cursor: usize,
    closing: bool,
}

/// One dispatch lane's queue group: its tenants' slots behind a private
/// lock, with a private condvar so its dispatcher blocks independently.
struct LaneGroup<T> {
    state: Mutex<GroupState<T>>,
    ready: Condvar,
}

/// Outcome of one fair collection.
pub enum FairBatch<T> {
    /// A non-empty batch; the server keeps running.
    Batch(Vec<T>),
    /// The queues are draining: these are queued leftovers (process
    /// them, then call again). An **empty** `Closing` batch means fully
    /// drained — exit.
    Closing(Vec<T>),
}

/// Per-tenant bounded queues sharded across `K` dispatch lanes, each
/// lane a private lock + condvar with fair round-robin collection over
/// its own tenants (see module docs). Generic over the queued item so
/// the dispatch engine owns its request type.
pub struct TenantQueues<T> {
    groups: Vec<LaneGroup<T>>,
    /// Global tenant index → (lane, slot-within-lane).
    route: Vec<(usize, usize)>,
    /// Lane → global tenant indices resident in it (spec order).
    members: Vec<Vec<usize>>,
}

/// Lock, surviving poisoning: a panicking peer must not take the whole
/// serving path down with it (panics are contained per-request by the
/// dispatcher; the queue state itself is never left mid-mutation).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl<T> TenantQueues<T> {
    /// The lane a spec lands in among `lanes` total: the explicit pin
    /// modulo `lanes` when set, else stable FNV-1a of the tenant name
    /// modulo `lanes` — deterministic across processes and restarts.
    pub fn lane_for(spec: &TenantSpec, lanes: usize) -> usize {
        let lanes = lanes.max(1);
        match spec.lane {
            Some(l) => l % lanes,
            None => (StableHasher::new().str(&spec.name).finish() % lanes as u64) as usize,
        }
    }

    /// Queues for `specs.len()` tenants (index space = spec order),
    /// sharded across `lanes.max(1)` dispatch lanes.
    pub fn new(specs: &[TenantSpec], lanes: usize) -> TenantQueues<T> {
        let now = Instant::now();
        let k = lanes.max(1);
        let mut groups: Vec<Vec<Slot<T>>> = (0..k).map(|_| Vec::new()).collect();
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut route = Vec::with_capacity(specs.len());
        for (tenant, s) in specs.iter().enumerate() {
            let lane = Self::lane_for(s, k);
            route.push((lane, groups[lane].len()));
            members[lane].push(tenant);
            groups[lane].push(Slot {
                queue: VecDeque::new(),
                bucket: TokenBucket::new(s.rate_per_sec, s.burst, now),
                cap: s.queue_cap.max(1),
                admitted: 0,
            });
        }
        TenantQueues {
            groups: groups
                .into_iter()
                .map(|slots| LaneGroup {
                    state: Mutex::new(GroupState { slots, cursor: 0, closing: false }),
                    ready: Condvar::new(),
                })
                .collect(),
            route,
            members,
        }
    }

    /// Number of dispatch lanes.
    pub fn lane_count(&self) -> usize {
        self.groups.len()
    }

    /// The lane tenant `tenant` (spec-order index) is resident in.
    pub fn lane_of(&self, tenant: usize) -> usize {
        self.route[tenant].0
    }

    /// Global tenant indices resident in `lane`, in spec order.
    pub fn lane_members(&self, lane: usize) -> &[usize] {
        &self.members[lane]
    }

    /// Admit one item for `tenant` (an index into the spec order), or
    /// reject with a retry hint. `build` receives the tenant's
    /// admission sequence number (0-based, assigned atomically with the
    /// enqueue) and constructs the queued item. Bucket take, cap check,
    /// sequence assignment, and enqueue are one atomic step — under the
    /// tenant's lane lock only, so admissions to different lanes never
    /// contend.
    pub fn try_admit_with(
        &self,
        tenant: usize,
        build: impl FnOnce(u64) -> T,
    ) -> Result<u64, Rejection> {
        let now = Instant::now();
        let (lane, slot) = self.route[tenant];
        let group = &self.groups[lane];
        let mut st = lock(&group.state);
        if st.closing {
            return Err(Rejection::Draining);
        }
        let slot = &mut st.slots[slot];
        if slot.queue.len() >= slot.cap {
            let oldest = slot
                .queue
                .front()
                .map(|(t, _)| now.saturating_duration_since(*t))
                .unwrap_or_default();
            return Err(Rejection::QueueFull {
                retry_after: oldest.max(Duration::from_millis(1)),
            });
        }
        slot.bucket
            .try_take_at(now)
            .map_err(|retry_after| Rejection::RateLimited { retry_after })?;
        let seq = slot.admitted;
        slot.admitted += 1;
        slot.queue.push_back((now, build(seq)));
        drop(st);
        group.ready.notify_one();
        Ok(seq)
    }

    /// Collect up to `max` items from one lane, round-robin across the
    /// lane's tenants: each sweep takes at most one item per tenant, so
    /// no tenant can occupy more than its share of a contended batch.
    /// Blocks while every queue in the lane is empty (idle dispatch
    /// burns no CPU); once the queues are closing it never blocks —
    /// leftovers come back as [`FairBatch::Closing`] until an empty one
    /// signals full drain.
    pub fn collect_fair(&self, lane: usize, max: usize) -> FairBatch<T> {
        let group = &self.groups[lane];
        let mut st = lock(&group.state);
        loop {
            if st.slots.iter().any(|l| !l.queue.is_empty()) {
                break;
            }
            if st.closing {
                return FairBatch::Closing(Vec::new());
            }
            st = group.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let n = st.slots.len();
        let mut out = Vec::new();
        'fill: loop {
            let mut took_any = false;
            for k in 0..n {
                let i = (st.cursor + k) % n;
                if let Some((_, item)) = st.slots[i].queue.pop_front() {
                    out.push(item);
                    took_any = true;
                    if out.len() >= max {
                        st.cursor = (i + 1) % n;
                        break 'fill;
                    }
                }
            }
            if !took_any {
                break;
            }
        }
        if st.closing {
            FairBatch::Closing(out)
        } else {
            FairBatch::Batch(out)
        }
    }

    /// Stop admitting on every lane; wake all dispatchers so they drain
    /// and exit.
    pub fn close(&self) {
        for group in &self.groups {
            lock(&group.state).closing = true;
            group.ready.notify_all();
        }
    }

    /// Live pressure of one tenant's queue: depth and oldest-entry age
    /// (None when empty).
    pub fn pressure(&self, tenant: usize) -> (usize, Option<Duration>) {
        let (lane, slot) = self.route[tenant];
        let st = lock(&self.groups[lane].state);
        let slot = &st.slots[slot];
        let now = Instant::now();
        (
            slot.queue.len(),
            slot.queue.front().map(|(t, _)| now.saturating_duration_since(*t)),
        )
    }

    /// Total queued items across all tenants and lanes.
    pub fn total_depth(&self) -> usize {
        self.groups
            .iter()
            .map(|g| lock(&g.state).slots.iter().map(|l| l.queue.len()).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(n: usize) -> Vec<TenantSpec> {
        (0..n).map(|i| TenantSpec::new(&format!("t{i}"), "pendulum")).collect()
    }

    #[test]
    fn token_bucket_is_deterministic_under_synthetic_time() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0, t0);
        // Burst of 2 from a full bucket, then rate-limited.
        assert!(b.try_take_at(t0).is_ok());
        assert!(b.try_take_at(t0).is_ok());
        let wait = b.try_take_at(t0).unwrap_err();
        // Refill at 10/s: one token in 100 ms.
        assert!((wait.as_secs_f64() - 0.1).abs() < 1e-9, "{wait:?}");
        // 150 ms later one token has refilled (capped below burst).
        assert!(b.try_take_at(t0 + Duration::from_millis(150)).is_ok());
        assert!(b.try_take_at(t0 + Duration::from_millis(150)).is_err());
        // A long idle period refills only to burst, never beyond.
        let later = t0 + Duration::from_secs(3600);
        assert!(b.try_take_at(later).is_ok());
        assert!(b.try_take_at(later).is_ok());
        assert!(b.try_take_at(later).is_err());
    }

    #[test]
    fn infinite_rate_never_limits_and_zero_rate_never_refills() {
        let t0 = Instant::now();
        let mut inf = TokenBucket::new(f64::INFINITY, 1.0, t0);
        for _ in 0..10_000 {
            assert!(inf.try_take_at(t0).is_ok());
        }
        let mut zero = TokenBucket::new(0.0, 1.0, t0);
        assert!(zero.try_take_at(t0).is_ok());
        let wait = zero.try_take_at(t0 + Duration::from_secs(100)).unwrap_err();
        assert!(wait >= Duration::from_secs(60));
    }

    #[test]
    fn queue_cap_sheds_with_pressure_derived_hint() {
        let q: TenantQueues<u32> = TenantQueues::new(
            &[TenantSpec::new("a", "s").with_queue_cap(2).with_rate(f64::INFINITY, 1.0)],
            1,
        );
        assert_eq!(q.try_admit_with(0, |_| 1).unwrap(), 0);
        assert_eq!(q.try_admit_with(0, |_| 2).unwrap(), 1);
        match q.try_admit_with(0, |_| 3) {
            Err(Rejection::QueueFull { retry_after }) => {
                assert!(retry_after >= Duration::from_millis(1));
            }
            other => panic!("expected QueueFull, got {:?}", other.map(|_| ())),
        }
        let (depth, oldest) = q.pressure(0);
        assert_eq!(depth, 2);
        assert!(oldest.is_some());
    }

    #[test]
    fn collect_fair_interleaves_tenants_round_robin() {
        let q: TenantQueues<(usize, u64)> = TenantQueues::new(&specs(3), 1);
        // Tenant 0 floods; tenants 1 and 2 each queue a couple.
        for _ in 0..100 {
            q.try_admit_with(0, |seq| (0, seq)).unwrap();
        }
        for t in [1usize, 2] {
            for _ in 0..2 {
                q.try_admit_with(t, |seq| (t, seq)).unwrap();
            }
        }
        let batch = match q.collect_fair(0, 6) {
            FairBatch::Batch(b) => b,
            FairBatch::Closing(_) => panic!("not closing"),
        };
        // Two full sweeps: every tenant appears twice, in rotation — the
        // flooder cannot occupy the whole batch.
        let owners: Vec<usize> = batch.iter().map(|&(t, _)| t).collect();
        assert_eq!(owners, vec![0, 1, 2, 0, 1, 2]);
        // Within a tenant, FIFO order (sequence numbers ascend).
        assert_eq!(batch[0].1, 0);
        assert_eq!(batch[3].1, 1);
        // The flooder's backlog is intact minus its fair share.
        assert_eq!(q.total_depth(), 100 - 2);
    }

    #[test]
    fn cursor_rotates_between_batches() {
        let q: TenantQueues<usize> = TenantQueues::new(&specs(2), 1);
        for _ in 0..4 {
            q.try_admit_with(0, |_| 0).unwrap();
            q.try_admit_with(1, |_| 1).unwrap();
        }
        // A max-1 batch takes from one tenant and advances the cursor,
        // so the next batch starts at the other tenant.
        let first = match q.collect_fair(0, 1) {
            FairBatch::Batch(b) => b[0],
            _ => panic!(),
        };
        let second = match q.collect_fair(0, 1) {
            FairBatch::Batch(b) => b[0],
            _ => panic!(),
        };
        assert_ne!(first, second, "consecutive 1-item batches must rotate tenants");
    }

    #[test]
    fn closing_drains_then_signals_done_and_rejects_new_work() {
        let q: TenantQueues<u64> = TenantQueues::new(&specs(1), 1);
        q.try_admit_with(0, |seq| seq).unwrap();
        q.try_admit_with(0, |seq| seq).unwrap();
        q.close();
        assert!(matches!(q.try_admit_with(0, |seq| seq), Err(Rejection::Draining)));
        match q.collect_fair(0, 16) {
            FairBatch::Closing(v) => assert_eq!(v, vec![0, 1]),
            FairBatch::Batch(_) => panic!("closing queues must report Closing"),
        }
        match q.collect_fair(0, 16) {
            FairBatch::Closing(v) => assert!(v.is_empty(), "fully drained"),
            FairBatch::Batch(_) => panic!("closing queues must report Closing"),
        }
    }

    #[test]
    fn rejection_hints_clamp_to_sane_milliseconds() {
        assert_eq!(
            Rejection::RateLimited { retry_after: Duration::from_micros(10) }.retry_after_ms(),
            1
        );
        assert_eq!(
            Rejection::QueueFull { retry_after: Duration::from_secs(3600) }.retry_after_ms(),
            60_000
        );
        assert_eq!(Rejection::Draining.retry_after_ms(), 0);
    }

    #[test]
    fn deadline_expiry_and_remaining() {
        let d = Deadline::after(Duration::from_secs(3600));
        assert!(!d.expired());
        assert!(d.remaining() > Duration::from_secs(3599));
        let past = Deadline::at(Instant::now() - Duration::from_secs(1));
        assert!(past.expired());
        assert_eq!(past.remaining(), Duration::ZERO);
    }

    #[test]
    fn lane_assignment_is_deterministic_and_pins_override_hash() {
        // Hash placement is a pure function of the name: two queue sets
        // built from the same specs agree, and every lane index is in
        // range.
        let s = specs(8);
        let a: TenantQueues<u8> = TenantQueues::new(&s, 3);
        let b: TenantQueues<u8> = TenantQueues::new(&s, 3);
        for t in 0..s.len() {
            assert_eq!(a.lane_of(t), b.lane_of(t));
            assert!(a.lane_of(t) < 3);
        }
        // Explicit pins win over the hash, modulo the lane count.
        let pinned = vec![
            TenantSpec::new("x", "s").with_lane(1),
            TenantSpec::new("y", "s").with_lane(5), // 5 % 3 == 2
        ];
        let q: TenantQueues<u8> = TenantQueues::new(&pinned, 3);
        assert_eq!(q.lane_of(0), 1);
        assert_eq!(q.lane_of(1), 2);
        assert_eq!(q.lane_count(), 3);
        assert_eq!(q.lane_members(1), &[0]);
        assert_eq!(q.lane_members(2), &[1]);
        assert!(q.lane_members(0).is_empty());
    }

    #[test]
    fn lanes_collect_independently_with_per_lane_fairness() {
        // Four tenants pinned two per lane. Each lane's collection sees
        // only its own tenants, round-robin among them; the other
        // lane's backlog is untouched.
        let s = vec![
            TenantSpec::new("a0", "s").with_lane(0),
            TenantSpec::new("a1", "s").with_lane(0),
            TenantSpec::new("b0", "s").with_lane(1),
            TenantSpec::new("b1", "s").with_lane(1),
        ];
        let q: TenantQueues<usize> = TenantQueues::new(&s, 2);
        for t in 0..4 {
            for _ in 0..3 {
                q.try_admit_with(t, |_| t).unwrap();
            }
        }
        let lane0 = match q.collect_fair(0, 4) {
            FairBatch::Batch(b) => b,
            _ => panic!("not closing"),
        };
        assert_eq!(lane0, vec![0, 1, 0, 1], "lane 0 interleaves only its tenants");
        assert_eq!(q.total_depth(), 8, "lane 1 backlog untouched");
        let lane1 = match q.collect_fair(1, usize::MAX) {
            FairBatch::Batch(b) => b,
            _ => panic!("not closing"),
        };
        assert_eq!(lane1, vec![2, 3, 2, 3, 2, 3]);
        // Draining: close() wakes every lane; both report Closing.
        q.close();
        match q.collect_fair(0, 16) {
            FairBatch::Closing(v) => assert_eq!(v, vec![0, 1]),
            FairBatch::Batch(_) => panic!("closing queues must report Closing"),
        }
        match q.collect_fair(1, 16) {
            FairBatch::Closing(v) => assert!(v.is_empty()),
            FairBatch::Batch(_) => panic!("closing queues must report Closing"),
        }
    }

    #[test]
    fn rate_limits_stay_tenant_scoped_across_lanes() {
        // One rate-limited tenant sharded among unlimited neighbors in
        // other lanes: its bucket is private to it, so its budget is
        // neither split by sharding nor shared with lane peers.
        let s = vec![
            TenantSpec::new("limited", "s").with_lane(0).with_rate(1.0, 2.0),
            TenantSpec::new("free-same-lane", "s").with_lane(0),
            TenantSpec::new("free-other-lane", "s").with_lane(1),
        ];
        let q: TenantQueues<u8> = TenantQueues::new(&s, 2);
        assert!(q.try_admit_with(0, |_| 0).is_ok());
        assert!(q.try_admit_with(0, |_| 0).is_ok());
        assert!(matches!(
            q.try_admit_with(0, |_| 0),
            Err(Rejection::RateLimited { .. })
        ));
        // Neighbors (same lane and different lane) are unaffected.
        for _ in 0..100 {
            assert!(q.try_admit_with(1, |_| 0).is_ok());
            assert!(q.try_admit_with(2, |_| 0).is_ok());
        }
    }
}
