//! The per-batch inference pipeline (paper Fig. 3): quantized sensor
//! signals → Π products → Φ model → target-parameter estimate.
//!
//! The Π stage has three interchangeable implementations, all bit-exact
//! with one another (tested):
//!
//! * [`PiPath::Native`] — the Rust fixed-point software model (fastest;
//!   the production path when no hardware is attached).
//! * [`PiPath::Hlo`] — the AOT-compiled Pallas kernel through PJRT (the
//!   same artifact a TPU-class deployment would execute).
//! * [`PiPath::RtlSim`] — the cycle-accurate simulation of the generated
//!   hardware (what the physical sensor IC would compute, used for
//!   hardware-in-the-loop validation and cycle accounting).

use crate::fixedpoint::{self, Q16_15};
use crate::flow::{worker, Flow, FlowConfig};
use crate::power;
use crate::report::export::SystemExport;
use crate::rtl::{self, PiModuleDesign};
use crate::runtime::engine::{self, Engine};
use crate::synth;
use crate::train::{Dataset, TrainOutput, TRAIN_BATCH};

/// Π computation implementation choice.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PiPath {
    Native,
    Hlo,
    RtlSim,
}

/// One sensor observation, already quantized to port order.
#[derive(Clone, Debug)]
pub struct SensorInput {
    /// Q16.15 raw values, one per hardware port.
    pub values_q: Vec<i64>,
}

/// The engine's answer for one observation.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Π products (Q16.15 raw), unit order (target group first).
    pub pis: Vec<i64>,
    /// Predicted target-group product Π₀ (raw target units after
    /// denormalization).
    pub pi0_pred: f32,
    /// Recovered physical target estimate (e.g. period in seconds).
    pub target_estimate: f64,
    /// Cycles the synthesized hardware would spend (RTL-sim path only).
    pub hw_cycles: Option<u64>,
}

/// A power-estimation request: predict the synthesized hardware's power
/// under one pseudorandom stimulus stream at one clock frequency.
#[derive(Clone, Copy, Debug)]
pub struct PowerRequest {
    /// LFSR seed of the request's stimulus stream.
    pub seed: u32,
    /// Clock frequency to evaluate at (Hz).
    pub f_hz: f64,
}

/// The engine's answer to one [`PowerRequest`].
#[derive(Clone, Copy, Debug)]
pub struct PowerEstimate {
    /// Predicted average power (milliwatts).
    pub mw: f64,
    /// Measured mean net toggles per cycle under the request's stimulus.
    pub toggles_per_cycle: f64,
    /// Gate-level cycles simulated for the estimate.
    pub cycles: u64,
}

/// The stateful pipeline owned by the serving worker.
pub struct Pipeline {
    pub export: SystemExport,
    pub design: PiModuleDesign,
    pub params: Vec<f32>,
    pub dataset_stats: DatasetStats,
    pub pi_path: PiPath,
    system: String,
    engine: Engine,
    /// The compilation session the design came from; keeps the lazily
    /// technology-mapped netlist memoized for power estimation.
    flow: Flow,
}

/// The standardization constants serving needs from training.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub shift: Vec<f32>,
    pub scale: Vec<f32>,
    pub y_shift: f32,
    pub y_scale: f32,
    pub dim: usize,
}

impl From<&Dataset> for DatasetStats {
    fn from(ds: &Dataset) -> Self {
        DatasetStats {
            shift: ds.shift.clone(),
            scale: ds.scale.clone(),
            y_shift: ds.y_shift,
            y_scale: ds.y_scale,
            dim: ds.dim,
        }
    }
}

impl Pipeline {
    /// Build a pipeline from a completed training run.
    pub fn new(
        artifacts: &str,
        system: &str,
        trained: &TrainOutput,
        pi_path: PiPath,
    ) -> anyhow::Result<Pipeline> {
        let engine = Engine::new(artifacts)?;
        let export = trained.dataset.export.clone();
        let mut flow = Flow::for_system(system, FlowConfig::default())?;
        let design = flow.rtl()?.clone();
        // Validate the target participates (its port is needed for
        // monomial inversion).
        let _ = export.target_port();
        let mut engine = engine;
        // Warm the executable cache: artifact compilation must not land
        // on the first request's latency.
        engine.load(&format!("phi_infer_{system}_b64"))?;
        if pi_path == PiPath::Hlo {
            engine.load(&format!("pi_{system}_b64"))?;
        }
        Ok(Pipeline {
            export,
            design,
            params: trained.params.clone(),
            dataset_stats: DatasetStats::from(&trained.dataset),
            pi_path,
            system: system.to_string(),
            engine,
            flow,
        })
    }

    /// Serve power-estimation requests in lane-width-wide batches:
    /// requests are packed into the lanes of one word-parallel
    /// gate-level simulation pass
    /// ([`power::measure_activity_batch_wide`]), so 64 or 256
    /// independent stimulus streams (the flow config's
    /// [`LaneWidth`](crate::synth::LaneWidth)) cost one netlist
    /// traversal per cycle.
    pub fn estimate_power_batch(
        &mut self,
        requests: &[PowerRequest],
        activations: u32,
    ) -> Vec<PowerEstimate> {
        let width = self.flow.config().lane_width;
        // Design and netlist come from the same session generation, so
        // they can never diverge even if the flow's config were edited.
        let (design, mapped) = self
            .flow
            .rtl_and_netlist()
            .expect("netlist derivation cannot fail once the design is built");
        estimate_power_requests(&mapped.netlist, design, requests, activations, width)
    }

    /// Compute Π products for a batch via the configured path. Returns
    /// per-sample Π vectors and (for RtlSim) hardware cycles.
    pub fn compute_pis(
        &mut self,
        inputs: &[SensorInput],
    ) -> anyhow::Result<(Vec<Vec<i64>>, Option<u64>)> {
        let n = self.export.exponents.len();
        match self.pi_path {
            PiPath::Native => {
                let out = inputs
                    .iter()
                    .map(|s| {
                        self.export
                            .exponents
                            .iter()
                            .map(|e| fixedpoint::eval_monomial(Q16_15, &s.values_q, e))
                            .collect()
                    })
                    .collect();
                Ok((out, None))
            }
            PiPath::RtlSim => {
                let samples: Vec<&[i64]> =
                    inputs.iter().map(|s| s.values_q.as_slice()).collect();
                let batch = rtl::run_batch(&self.design, &samples);
                Ok((batch.outputs, Some(batch.total_cycles)))
            }
            PiPath::Hlo => {
                let kp = self.export.ports.len();
                let exe = self.engine.load(&format!("pi_{}_b64", self.system))?;
                let samples: Vec<&[i64]> =
                    inputs.iter().map(|s| s.values_q.as_slice()).collect();
                let out = exe.run_batched_i32(64, kp, n, &samples)?;
                Ok((out, None))
            }
        }
    }

    /// Run Φ inference over the batch's Π features and recover targets.
    pub fn infer(&mut self, inputs: &[SensorInput]) -> anyhow::Result<Vec<Prediction>> {
        let (pis, hw_cycles) = self.compute_pis(inputs)?;
        let n = self.export.exponents.len();
        let dim = self.dataset_stats.dim;
        let exe = self.engine.load(&format!("phi_infer_{}_b64", self.system))?;

        let mut preds = Vec::with_capacity(inputs.len());
        let mut i = 0usize;
        while i < inputs.len() {
            let take = (inputs.len() - i).min(TRAIN_BATCH);
            let mut x = vec![0f32; TRAIN_BATCH * dim];
            for (j, p) in pis[i..i + take].iter().enumerate() {
                if n > 1 {
                    for d in 0..dim {
                        x[j * dim + d] = Q16_15.to_f64(p[d + 1]) as f32;
                    }
                } else {
                    x[j * dim] = 1.0;
                }
            }
            let outs = exe.run(&[
                engine::f32_vec(&self.params),
                engine::f32_matrix(TRAIN_BATCH, dim, &x)?,
                engine::f32_vec(&self.dataset_stats.shift),
                engine::f32_vec(&self.dataset_stats.scale),
            ])?;
            let y_norm = engine::to_f32s(&outs[0])?;
            for j in 0..take {
                let pi0_pred =
                    y_norm[j] * self.dataset_stats.y_scale + self.dataset_stats.y_shift;
                let sample = &inputs[i + j];
                let target = self.recover_target(pi0_pred as f64, &sample.values_q);
                preds.push(Prediction {
                    pis: pis[i + j].clone(),
                    pi0_pred,
                    target_estimate: target,
                    hw_cycles: hw_cycles.map(|c| c / inputs.len() as u64),
                });
            }
            i += take;
        }
        Ok(preds)
    }

    /// Invert the target-isolating monomial (delegates to the export).
    pub fn recover_target(&self, pi0: f64, values_q: &[i64]) -> f64 {
        self.export.recover_target(pi0, values_q, Q16_15)
    }

    pub fn system(&self) -> &str {
        &self.system
    }
}

/// Dispatch power-estimation requests against a mapped netlist in
/// lane-width-wide batches (the engine-independent core of
/// [`Pipeline::estimate_power_batch`], unit-testable without artifacts).
/// Unfilled lanes of the last batch simulate padding streams whose
/// results are dropped.
///
/// Each chunk of `width.lanes()` requests is one independent
/// word-parallel simulation pass, so chunks fan out across all cores on
/// scoped worker threads ([`worker::parallel_map_chunks`]); request
/// floods use every core on top of the 64×/256× lane win. Results are
/// returned in request order, bit-identical to a sequential dispatch —
/// and to either lane width, since each lane's stimulus stream depends
/// only on its own seed.
pub fn estimate_power_requests(
    netlist: &crate::synth::Netlist,
    design: &PiModuleDesign,
    requests: &[PowerRequest],
    activations: u32,
    width: synth::LaneWidth,
) -> Vec<PowerEstimate> {
    match width {
        synth::LaneWidth::W64 => {
            estimate_power_requests_w::<u64>(netlist, design, requests, activations)
        }
        synth::LaneWidth::W256 => {
            estimate_power_requests_w::<synth::W256>(netlist, design, requests, activations)
        }
    }
}

/// Monomorphized core of [`estimate_power_requests`].
fn estimate_power_requests_w<W: synth::LaneWord>(
    netlist: &crate::synth::Netlist,
    design: &PiModuleDesign,
    requests: &[PowerRequest],
    activations: u32,
) -> Vec<PowerEstimate> {
    worker::parallel_map_chunks(requests, W::LANES, |_, chunk| {
        let mut seeds = vec![0u32; W::LANES];
        for (lane, slot) in seeds.iter_mut().enumerate() {
            *slot = match chunk.get(lane) {
                Some(r) => r.seed,
                // Padding lanes: any seed works, results are dropped.
                None => 0x9E37_79B9 ^ lane as u32,
            };
        }
        let act =
            power::measure_activity_batch_wide::<W>(netlist, design, activations, &seeds, None);
        chunk
            .iter()
            .enumerate()
            .map(|(lane, req)| {
                let lane_act = act.lane(lane);
                PowerEstimate {
                    mw: power::average_power_mw(&power::ICE40, &lane_act, req.f_hz),
                    toggles_per_cycle: lane_act.toggles_per_cycle,
                    cycles: act.cycles,
                }
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pendulum_flow() -> Flow {
        Flow::for_system("pendulum", FlowConfig::default()).unwrap()
    }

    /// A 65-request batch (two 64-lane chunks, the second padded,
    /// dispatched across worker threads) must agree with scalar
    /// measure_activity + average_power_mw per request.
    #[test]
    fn power_requests_match_scalar_path_across_chunks() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        let requests: Vec<PowerRequest> = (0..65)
            .map(|i| PowerRequest { seed: 0x1000 + i as u32, f_hz: 6.0e6 })
            .collect();
        let got = estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W64);
        assert_eq!(got.len(), 65);
        // Spot-check both chunks, including the chunk boundary and the
        // padded tail chunk's only real lane.
        for &i in &[0usize, 17, 63, 64] {
            let act = power::measure_activity(netlist, &design, 2, requests[i].seed);
            let want = power::average_power_mw(&power::ICE40, &act, requests[i].f_hz);
            assert_eq!(got[i].toggles_per_cycle, act.toggles_per_cycle, "request {i}");
            assert_eq!(got[i].cycles, act.cycles, "request {i}");
            assert!((got[i].mw - want).abs() < 1e-12, "request {i}");
        }
    }

    /// Each lane's stimulus depends only on its own seed, so the same
    /// request batch dispatched at 64 and 256 lanes must produce
    /// bit-identical estimates (256 just packs more requests per pass).
    #[test]
    fn power_requests_identical_across_lane_widths() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        let requests: Vec<PowerRequest> = (0..70)
            .map(|i| PowerRequest { seed: 0x2000 + i as u32, f_hz: 12.0e6 })
            .collect();
        let narrow =
            estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W64);
        let wide =
            estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W256);
        assert_eq!(narrow.len(), wide.len());
        for (i, (n, w)) in narrow.iter().zip(&wide).enumerate() {
            assert_eq!(n.toggles_per_cycle, w.toggles_per_cycle, "request {i}");
            assert_eq!(n.cycles, w.cycles, "request {i}");
            assert_eq!(n.mw, w.mw, "request {i}");
        }
    }

    #[test]
    fn empty_request_batch_is_empty() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        assert!(
            estimate_power_requests(netlist, &design, &[], 1, synth::LaneWidth::W64).is_empty()
        );
    }
}
