//! The per-batch inference pipeline (paper Fig. 3): quantized sensor
//! signals → Π products → Φ model → target-parameter estimate.
//!
//! The Π stage has three interchangeable implementations, all bit-exact
//! with one another (tested):
//!
//! * [`PiPath::Native`] — the Rust fixed-point software model (fastest;
//!   the production path when no hardware is attached).
//! * [`PiPath::Hlo`] — the AOT-compiled Pallas kernel through PJRT (the
//!   same artifact a TPU-class deployment would execute).
//! * [`PiPath::RtlSim`] — the cycle-accurate simulation of the generated
//!   hardware (what the physical sensor IC would compute, used for
//!   hardware-in-the-loop validation and cycle accounting).

use super::serveset::SystemHandle;
use crate::fixedpoint::{self, Q16_15};
use crate::flow::{worker, Flow, FlowConfig};
use crate::power;
use crate::report::export::SystemExport;
use crate::rtl::{self, PiModuleDesign};
use crate::runtime::engine::{self, Engine};
use crate::synth;
use crate::train::{Dataset, TrainOutput, TRAIN_BATCH};

/// Π computation implementation choice.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PiPath {
    Native,
    Hlo,
    RtlSim,
}

/// One sensor observation, already quantized to port order.
#[derive(Clone, Debug)]
pub struct SensorInput {
    /// Q16.15 raw values, one per hardware port.
    pub values_q: Vec<i64>,
}

/// The engine's answer for one observation.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Π products (Q16.15 raw), unit order (target group first).
    pub pis: Vec<i64>,
    /// Predicted target-group product Π₀ (raw target units after
    /// denormalization).
    pub pi0_pred: f32,
    /// Recovered physical target estimate (e.g. period in seconds).
    pub target_estimate: f64,
    /// Cycles the synthesized hardware would spend (RTL-sim path only).
    pub hw_cycles: Option<u64>,
}

/// A power-estimation request: predict the synthesized hardware's power
/// under one pseudorandom stimulus stream at one clock frequency.
#[derive(Clone, Copy, Debug)]
pub struct PowerRequest {
    /// LFSR seed of the request's stimulus stream.
    pub seed: u32,
    /// Clock frequency to evaluate at (Hz).
    pub f_hz: f64,
}

/// The engine's answer to one [`PowerRequest`].
#[derive(Clone, Copy, Debug)]
pub struct PowerEstimate {
    /// Predicted average power (milliwatts).
    pub mw: f64,
    /// Measured mean net toggles per cycle under the request's stimulus.
    pub toggles_per_cycle: f64,
    /// Gate-level cycles simulated for the estimate.
    pub cycles: u64,
}

/// A [`PowerRequest`] aimed at one system of a multi-system serve set
/// (`system` indexes the set's boot-order system list).
#[derive(Clone, Copy, Debug)]
pub struct SystemPowerRequest {
    pub system: usize,
    pub request: PowerRequest,
}

/// The stateful pipeline owned by the serving worker.
pub struct Pipeline {
    pub export: SystemExport,
    pub params: Vec<f32>,
    pub dataset_stats: DatasetStats,
    pub pi_path: PiPath,
    system: String,
    engine: Engine,
    /// Warm compiled hardware state — the design and its mapped netlist
    /// from one consistent flow generation. Shared (`Arc`) with the
    /// owning [`super::ServeSet`] when the pipeline was built through
    /// [`Pipeline::from_handle`]; private otherwise.
    handle: SystemHandle,
}

/// The standardization constants serving needs from training.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub shift: Vec<f32>,
    pub scale: Vec<f32>,
    pub y_shift: f32,
    pub y_scale: f32,
    pub dim: usize,
}

impl From<&Dataset> for DatasetStats {
    fn from(ds: &Dataset) -> Self {
        DatasetStats {
            shift: ds.shift.clone(),
            scale: ds.scale.clone(),
            y_shift: ds.y_shift,
            y_scale: ds.y_scale,
            dim: ds.dim,
        }
    }
}

impl Pipeline {
    /// Build a standalone pipeline from a completed training run,
    /// compiling a private flow session for its hardware state. Serving
    /// deployments with more than one system should boot a
    /// [`super::ServeSet`] and use [`Pipeline::from_handle`] so all
    /// endpoints share one warm artifact graph.
    pub fn new(
        artifacts: &str,
        system: &str,
        trained: &TrainOutput,
        pi_path: PiPath,
    ) -> anyhow::Result<Pipeline> {
        let mut flow = Flow::for_system(system, FlowConfig::default())?;
        Pipeline::from_handle(artifacts, trained, pi_path, SystemHandle::from_flow(&mut flow)?)
    }

    /// Build a pipeline on shared warm compiled state (no compilation
    /// happens here — the handle already carries the design + netlist).
    pub fn from_handle(
        artifacts: &str,
        trained: &TrainOutput,
        pi_path: PiPath,
        handle: SystemHandle,
    ) -> anyhow::Result<Pipeline> {
        let mut engine = Engine::new(artifacts)?;
        let export = trained.dataset.export.clone();
        // Validate the target participates (its port is needed for
        // monomial inversion).
        let _ = export.target_port();
        let system = handle.system().to_string();
        // Warm the executable cache: artifact compilation must not land
        // on the first request's latency.
        engine.load(&format!("phi_infer_{system}_b64"))?;
        if pi_path == PiPath::Hlo {
            engine.load(&format!("pi_{system}_b64"))?;
        }
        Ok(Pipeline {
            export,
            params: trained.params.clone(),
            dataset_stats: DatasetStats::from(&trained.dataset),
            pi_path,
            system,
            engine,
            handle,
        })
    }

    /// The generated RTL design this pipeline serves.
    pub fn design(&self) -> &PiModuleDesign {
        self.handle.design()
    }

    /// Serve power-estimation requests in lane-width-wide batches:
    /// requests are packed into the lanes of one word-parallel
    /// gate-level simulation pass
    /// ([`power::measure_activity_batch_wide`]), so 64 or 256
    /// independent stimulus streams (the flow config's
    /// [`LaneWidth`](crate::synth::LaneWidth)) cost one netlist
    /// traversal per cycle.
    pub fn estimate_power_batch(
        &self,
        requests: &[PowerRequest],
        activations: u32,
    ) -> Vec<PowerEstimate> {
        estimate_power_requests(
            self.handle.netlist(),
            self.handle.design(),
            requests,
            activations,
            self.handle.lane_width(),
        )
    }

    /// Compute Π products for a batch via the configured path. Returns
    /// per-sample Π vectors and (for RtlSim) hardware cycles.
    pub fn compute_pis(
        &mut self,
        inputs: &[SensorInput],
    ) -> anyhow::Result<(Vec<Vec<i64>>, Option<u64>)> {
        let n = self.export.exponents.len();
        match self.pi_path {
            PiPath::Native => {
                let out = inputs
                    .iter()
                    .map(|s| {
                        self.export
                            .exponents
                            .iter()
                            .map(|e| fixedpoint::eval_monomial(Q16_15, &s.values_q, e))
                            .collect()
                    })
                    .collect();
                Ok((out, None))
            }
            PiPath::RtlSim => {
                let samples: Vec<&[i64]> =
                    inputs.iter().map(|s| s.values_q.as_slice()).collect();
                let batch = rtl::run_batch(self.handle.design(), &samples);
                Ok((batch.outputs, Some(batch.total_cycles)))
            }
            PiPath::Hlo => {
                let kp = self.export.ports.len();
                let exe = self.engine.load(&format!("pi_{}_b64", self.system))?;
                let samples: Vec<&[i64]> =
                    inputs.iter().map(|s| s.values_q.as_slice()).collect();
                let out = exe.run_batched_i32(64, kp, n, &samples)?;
                Ok((out, None))
            }
        }
    }

    /// Run Φ inference over the batch's Π features and recover targets.
    pub fn infer(&mut self, inputs: &[SensorInput]) -> anyhow::Result<Vec<Prediction>> {
        let (pis, hw_cycles) = self.compute_pis(inputs)?;
        let n = self.export.exponents.len();
        let dim = self.dataset_stats.dim;
        let exe = self.engine.load(&format!("phi_infer_{}_b64", self.system))?;

        let mut preds = Vec::with_capacity(inputs.len());
        let mut i = 0usize;
        while i < inputs.len() {
            let take = (inputs.len() - i).min(TRAIN_BATCH);
            let mut x = vec![0f32; TRAIN_BATCH * dim];
            for (j, p) in pis[i..i + take].iter().enumerate() {
                if n > 1 {
                    for d in 0..dim {
                        x[j * dim + d] = Q16_15.to_f64(p[d + 1]) as f32;
                    }
                } else {
                    x[j * dim] = 1.0;
                }
            }
            let outs = exe.run(&[
                engine::f32_vec(&self.params),
                engine::f32_matrix(TRAIN_BATCH, dim, &x)?,
                engine::f32_vec(&self.dataset_stats.shift),
                engine::f32_vec(&self.dataset_stats.scale),
            ])?;
            let y_norm = engine::to_f32s(&outs[0])?;
            for j in 0..take {
                let pi0_pred =
                    y_norm[j] * self.dataset_stats.y_scale + self.dataset_stats.y_shift;
                let sample = &inputs[i + j];
                let target = self.recover_target(pi0_pred as f64, &sample.values_q);
                preds.push(Prediction {
                    pis: pis[i + j].clone(),
                    pi0_pred,
                    target_estimate: target,
                    hw_cycles: hw_cycles.map(|c| c / inputs.len() as u64),
                });
            }
            i += take;
        }
        Ok(preds)
    }

    /// Invert the target-isolating monomial (delegates to the export).
    pub fn recover_target(&self, pi0: f64, values_q: &[i64]) -> f64 {
        self.export.recover_target(pi0, values_q, Q16_15)
    }

    pub fn system(&self) -> &str {
        &self.system
    }
}

/// Dispatch power-estimation requests against one mapped netlist in
/// lane-width-wide batches (the engine-independent core of
/// [`Pipeline::estimate_power_batch`], unit-testable without artifacts).
///
/// This is the single-system view of
/// [`estimate_power_requests_grouped`]: results are returned in request
/// order, bit-identical to a sequential dispatch — and to either lane
/// width, since each lane's stimulus stream depends only on its own
/// seed.
pub fn estimate_power_requests(
    netlist: &crate::synth::Netlist,
    design: &PiModuleDesign,
    requests: &[PowerRequest],
    activations: u32,
    width: synth::LaneWidth,
) -> Vec<PowerEstimate> {
    let tagged: Vec<SystemPowerRequest> = requests
        .iter()
        .map(|&request| SystemPowerRequest { system: 0, request })
        .collect();
    estimate_power_requests_grouped(&[(netlist, design)], &tagged, activations, width)
}

/// Dispatch a mixed-system flood of power requests: requests are
/// **grouped by netlist** (each request's `system` indexes `targets`),
/// each group is cut into `width.lanes()`-wide chunks — one independent
/// word-parallel simulation pass per chunk, unfilled tail lanes
/// simulate padding streams whose results are dropped — and the chunks
/// of *all* systems fan out over one scoped worker pool
/// ([`worker::parallel_map_chunks`]). A flood skewed across any number
/// of systems therefore saturates every core on top of the 64×/256×
/// lane win.
///
/// Results come back in request order. Because a lane's stimulus
/// depends only on its own seed, every estimate is bit-identical to
/// per-system (or fully sequential, or other-width) dispatch of the
/// same requests.
///
/// Panics if a request's `system` index is out of range of `targets`
/// (serving frontends validate indices at the submission boundary).
pub fn estimate_power_requests_grouped(
    targets: &[(&crate::synth::Netlist, &PiModuleDesign)],
    requests: &[SystemPowerRequest],
    activations: u32,
    width: synth::LaneWidth,
) -> Vec<PowerEstimate> {
    match width {
        synth::LaneWidth::W64 => {
            estimate_power_requests_grouped_w::<u64>(targets, requests, activations)
        }
        synth::LaneWidth::W256 => {
            estimate_power_requests_grouped_w::<synth::W256>(targets, requests, activations)
        }
        synth::LaneWidth::W512 => {
            estimate_power_requests_grouped_w::<synth::W512>(targets, requests, activations)
        }
    }
}

/// Monomorphized core of [`estimate_power_requests_grouped`].
fn estimate_power_requests_grouped_w<W: synth::LaneWord>(
    targets: &[(&crate::synth::Netlist, &PiModuleDesign)],
    requests: &[SystemPowerRequest],
    activations: u32,
) -> Vec<PowerEstimate> {
    // Group request positions by target, preserving arrival order
    // within each group (order inside a group decides lane packing, so
    // it must be deterministic for bit-identical re-dispatch).
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); targets.len()];
    for (pos, r) in requests.iter().enumerate() {
        assert!(
            r.system < targets.len(),
            "request {pos} targets system {} of {}",
            r.system,
            targets.len()
        );
        groups[r.system].push(pos as u32);
    }
    // One task per lane-width chunk of one group; tasks from every
    // system share the worker fan-out below.
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
    for (target, group) in groups.iter().enumerate() {
        for start in (0..group.len()).step_by(W::LANES) {
            tasks.push((target, start, group.len().min(start + W::LANES)));
        }
    }
    let answers: Vec<(u32, PowerEstimate)> = worker::parallel_map_chunks(&tasks, 1, |_, task| {
        let &(target, start, end) = &task[0];
        let (netlist, design) = targets[target];
        let positions = &groups[target][start..end];
        let mut seeds = vec![0u32; W::LANES];
        for (lane, slot) in seeds.iter_mut().enumerate() {
            *slot = match positions.get(lane) {
                Some(&p) => requests[p as usize].request.seed,
                // Padding lanes: any seed works, results are dropped.
                None => 0x9E37_79B9 ^ lane as u32,
            };
        }
        let act =
            power::measure_activity_batch_wide::<W>(netlist, design, activations, &seeds, None);
        positions
            .iter()
            .enumerate()
            .map(|(lane, &p)| {
                let lane_act = act.lane(lane);
                let f_hz = requests[p as usize].request.f_hz;
                let estimate = PowerEstimate {
                    mw: power::average_power_mw(&power::ICE40, &lane_act, f_hz),
                    toggles_per_cycle: lane_act.toggles_per_cycle,
                    cycles: act.cycles,
                };
                (p, estimate)
            })
            .collect()
    });
    // Scatter back to request order.
    let mut out =
        vec![PowerEstimate { mw: 0.0, toggles_per_cycle: 0.0, cycles: 0 }; requests.len()];
    for (pos, estimate) in answers {
        out[pos as usize] = estimate;
    }
    out
}

/// Dispatch a mixed-system flood through **one fused sharded
/// evaluation** per round instead of one simulation pass per system per
/// chunk: requests are grouped and chunked exactly like
/// [`estimate_power_requests_grouped`], but round `j` — the `j`-th
/// lane-width chunk of *every* system — runs as a single
/// [`ShardSim`](crate::shard::ShardSim) pass over the fused netlist,
/// its K persistent shard workers sweeping all member systems at once.
///
/// Chunking, lane packing, and padding seeds are identical to the
/// grouped dispatch, and fusion keeps member state disjoint, so every
/// estimate is **bit-identical** to grouped (and per-system, and
/// sequential) dispatch of the same requests — tested below.
///
/// `designs` is the per-member design list in fuse (= boot) order;
/// `plan` must partition `fused`. Panics on a request with an
/// out-of-range system index (like the grouped dispatch; serving
/// frontends validate at the submission boundary).
pub fn estimate_power_requests_fused(
    fused: &crate::shard::FusedNetlist,
    plan: &crate::shard::ShardPlan,
    designs: &[&PiModuleDesign],
    requests: &[SystemPowerRequest],
    activations: u32,
    width: synth::LaneWidth,
) -> Vec<PowerEstimate> {
    estimate_power_requests_fused_stats(fused, plan, designs, requests, activations, width).0
}

/// [`estimate_power_requests_fused`] plus the cut-word exchange
/// counters merged across every dispatch round — the benchmark and
/// boot reports read words-published-per-cycle from these.
pub fn estimate_power_requests_fused_stats(
    fused: &crate::shard::FusedNetlist,
    plan: &crate::shard::ShardPlan,
    designs: &[&PiModuleDesign],
    requests: &[SystemPowerRequest],
    activations: u32,
    width: synth::LaneWidth,
) -> (Vec<PowerEstimate>, crate::shard::ExchangeStats) {
    match width {
        synth::LaneWidth::W64 => {
            estimate_power_requests_fused_w::<u64>(fused, plan, designs, requests, activations)
        }
        synth::LaneWidth::W256 => estimate_power_requests_fused_w::<synth::W256>(
            fused, plan, designs, requests, activations,
        ),
        synth::LaneWidth::W512 => estimate_power_requests_fused_w::<synth::W512>(
            fused, plan, designs, requests, activations,
        ),
    }
}

/// Monomorphized core of [`estimate_power_requests_fused`].
fn estimate_power_requests_fused_w<W: synth::LaneWord>(
    fused: &crate::shard::FusedNetlist,
    plan: &crate::shard::ShardPlan,
    designs: &[&PiModuleDesign],
    requests: &[SystemPowerRequest],
    activations: u32,
) -> (Vec<PowerEstimate>, crate::shard::ExchangeStats) {
    use crate::shard::{measure_fused_activity, ExchangeStats, MemberStim, ShardSim};

    assert_eq!(
        designs.len(),
        fused.member_count(),
        "one design per fused member, in fuse order"
    );
    // Same grouping and chunk geometry as the grouped dispatch: group
    // request positions by system in arrival order, cut each group into
    // lane-width chunks.
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); designs.len()];
    for (pos, r) in requests.iter().enumerate() {
        assert!(
            r.system < designs.len(),
            "request {pos} targets system {} of {}",
            r.system,
            designs.len()
        );
        groups[r.system].push(pos as u32);
    }
    let rounds = groups
        .iter()
        .map(|g| g.len().div_ceil(W::LANES))
        .max()
        .unwrap_or(0);
    let mut out =
        vec![PowerEstimate { mw: 0.0, toggles_per_cycle: 0.0, cycles: 0 }; requests.len()];
    let mut exchange = ExchangeStats::default();
    // Round j packs the j-th chunk of every system into one fused pass:
    // a fresh sharded simulator (member state must start from reset,
    // exactly like a fresh solo pass) drives all members' schedules at
    // once, and each member's per-lane report scatters to its chunk.
    for round in 0..rounds {
        let mut sim: ShardSim<'_, W> = ShardSim::new(fused, plan);
        let stims: Vec<MemberStim<'_>> = designs
            .iter()
            .enumerate()
            .map(|(m, &design)| {
                let group = &groups[m];
                let start = round * W::LANES;
                let chunk = &group[group.len().min(start)..group.len().min(start + W::LANES)];
                let mut seeds = vec![0u32; W::LANES];
                for (lane, slot) in seeds.iter_mut().enumerate() {
                    *slot = match chunk.get(lane) {
                        Some(&p) => requests[p as usize].request.seed,
                        // Padding lanes: same seeds as the grouped
                        // dispatch; results are dropped.
                        None => 0x9E37_79B9 ^ lane as u32,
                    };
                }
                MemberStim {
                    design,
                    activations: if chunk.is_empty() { 0 } else { activations },
                    seeds,
                }
            })
            .collect();
        let reports = measure_fused_activity(&mut sim, &stims);
        exchange.merge(&sim.exchange_stats());
        for (m, report) in reports.iter().enumerate() {
            let group = &groups[m];
            let start = round * W::LANES;
            let chunk = &group[group.len().min(start)..group.len().min(start + W::LANES)];
            for (lane, &p) in chunk.iter().enumerate() {
                let lane_act = report.lane(lane);
                let f_hz = requests[p as usize].request.f_hz;
                out[p as usize] = PowerEstimate {
                    mw: power::average_power_mw(&power::ICE40, &lane_act, f_hz),
                    toggles_per_cycle: lane_act.toggles_per_cycle,
                    cycles: report.cycles,
                };
            }
        }
    }
    (out, exchange)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pendulum_flow() -> Flow {
        Flow::for_system("pendulum", FlowConfig::default()).unwrap()
    }

    /// A 65-request batch (two 64-lane chunks, the second padded,
    /// dispatched across worker threads) must agree with scalar
    /// measure_activity + average_power_mw per request.
    #[test]
    fn power_requests_match_scalar_path_across_chunks() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        let requests: Vec<PowerRequest> = (0..65)
            .map(|i| PowerRequest { seed: 0x1000 + i as u32, f_hz: 6.0e6 })
            .collect();
        let got = estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W64);
        assert_eq!(got.len(), 65);
        // Spot-check both chunks, including the chunk boundary and the
        // padded tail chunk's only real lane.
        for &i in &[0usize, 17, 63, 64] {
            let act = power::measure_activity(netlist, &design, 2, requests[i].seed);
            let want = power::average_power_mw(&power::ICE40, &act, requests[i].f_hz);
            assert_eq!(got[i].toggles_per_cycle, act.toggles_per_cycle, "request {i}");
            assert_eq!(got[i].cycles, act.cycles, "request {i}");
            assert!((got[i].mw - want).abs() < 1e-12, "request {i}");
        }
    }

    /// Each lane's stimulus depends only on its own seed, so the same
    /// request batch dispatched at 64 and 256 lanes must produce
    /// bit-identical estimates (256 just packs more requests per pass).
    #[test]
    fn power_requests_identical_across_lane_widths() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        let requests: Vec<PowerRequest> = (0..70)
            .map(|i| PowerRequest { seed: 0x2000 + i as u32, f_hz: 12.0e6 })
            .collect();
        let narrow =
            estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W64);
        let wide =
            estimate_power_requests(netlist, &design, &requests, 2, synth::LaneWidth::W256);
        assert_eq!(narrow.len(), wide.len());
        for (i, (n, w)) in narrow.iter().zip(&wide).enumerate() {
            assert_eq!(n.toggles_per_cycle, w.toggles_per_cycle, "request {i}");
            assert_eq!(n.cycles, w.cycles, "request {i}");
            assert_eq!(n.mw, w.mw, "request {i}");
        }
    }

    #[test]
    fn empty_request_batch_is_empty() {
        let mut flow = pendulum_flow();
        let design = flow.rtl().unwrap().clone();
        let netlist = &flow.netlist().unwrap().netlist;
        assert!(
            estimate_power_requests(netlist, &design, &[], 1, synth::LaneWidth::W64).is_empty()
        );
    }

    /// A mixed-system flood grouped by netlist must answer every
    /// request bit-identically to dispatching each system's requests on
    /// its own — packing order across systems cannot leak between
    /// lanes.
    #[test]
    fn grouped_dispatch_matches_per_system_dispatch() {
        let mut pendulum = pendulum_flow();
        let mut spring = Flow::for_system("spring_mass", FlowConfig::default()).unwrap();
        let p_design = pendulum.rtl().unwrap().clone();
        let s_design = spring.rtl().unwrap().clone();
        let p_netlist = pendulum.netlist().unwrap().netlist.clone();
        let s_netlist = &spring.netlist().unwrap().netlist;
        let targets: Vec<(&crate::synth::Netlist, &PiModuleDesign)> =
            vec![(&p_netlist, &p_design), (s_netlist, &s_design)];

        // Unevenly interleaved: system 0 gets 2 of every 3 requests.
        let requests: Vec<SystemPowerRequest> = (0..75u32)
            .map(|i| SystemPowerRequest {
                system: (i % 3 == 2) as usize,
                request: PowerRequest { seed: 0x4000 + i, f_hz: 6.0e6 + 1.0e6 * (i % 2) as f64 },
            })
            .collect();
        let grouped =
            estimate_power_requests_grouped(&targets, &requests, 2, synth::LaneWidth::W64);
        assert_eq!(grouped.len(), requests.len());

        for sys in 0..targets.len() {
            let own: Vec<PowerRequest> = requests
                .iter()
                .filter(|r| r.system == sys)
                .map(|r| r.request)
                .collect();
            let solo = estimate_power_requests(
                targets[sys].0,
                targets[sys].1,
                &own,
                2,
                synth::LaneWidth::W64,
            );
            let mixed: Vec<&PowerEstimate> = requests
                .iter()
                .zip(&grouped)
                .filter(|(r, _)| r.system == sys)
                .map(|(_, e)| e)
                .collect();
            assert_eq!(solo.len(), mixed.len());
            for (i, (a, b)) in solo.iter().zip(mixed).enumerate() {
                assert_eq!(a.mw, b.mw, "system {sys} request {i}");
                assert_eq!(a.toggles_per_cycle, b.toggles_per_cycle, "system {sys} request {i}");
                assert_eq!(a.cycles, b.cycles, "system {sys} request {i}");
            }
        }
    }

    /// The fused sharded dispatch must answer a skewed mixed-system
    /// flood bit-identically to the grouped per-system dispatch, at
    /// every shard count — including K=1 (fusion alone) and K large
    /// enough to force member splits with per-level sync.
    #[test]
    fn fused_dispatch_matches_grouped_dispatch() {
        use crate::shard::{FusedNetlist, ShardPlan};

        let mut pendulum = pendulum_flow();
        let mut spring = Flow::for_system("spring_mass", FlowConfig::default()).unwrap();
        let p_design = pendulum.rtl().unwrap().clone();
        let s_design = spring.rtl().unwrap().clone();
        let p_netlist = pendulum.netlist().unwrap().netlist.clone();
        let s_netlist = spring.netlist().unwrap().netlist.clone();
        let targets: Vec<(&crate::synth::Netlist, &PiModuleDesign)> =
            vec![(&p_netlist, &p_design), (&s_netlist, &s_design)];

        // Skewed 2:1 across systems, spilling into a second padded
        // round for system 0.
        let requests: Vec<SystemPowerRequest> = (0..70u32)
            .map(|i| SystemPowerRequest {
                system: (i % 3 == 2) as usize,
                request: PowerRequest { seed: 0x7000 + i, f_hz: 6.0e6 + 2.0e6 * (i % 2) as f64 },
            })
            .collect();
        let grouped =
            estimate_power_requests_grouped(&targets, &requests, 2, synth::LaneWidth::W64);

        let fused = FusedNetlist::fuse_refs(&[&p_netlist, &s_netlist]);
        for k in [1usize, 2, 4] {
            let plan = ShardPlan::partition(&fused, k);
            let got = estimate_power_requests_fused(
                &fused,
                &plan,
                &[&p_design, &s_design],
                &requests,
                2,
                synth::LaneWidth::W64,
            );
            assert_eq!(got.len(), grouped.len());
            for (i, (f, g)) in got.iter().zip(&grouped).enumerate() {
                assert_eq!(f.mw, g.mw, "K={k} request {i}");
                assert_eq!(f.toggles_per_cycle, g.toggles_per_cycle, "K={k} request {i}");
                assert_eq!(f.cycles, g.cycles, "K={k} request {i}");
            }
        }
    }

    /// The stats-reporting dispatch variant merges exchange counters
    /// across rounds without disturbing the estimates, and the merged
    /// counters keep the per-shard opportunity accounting: every owned
    /// cut word gets exactly one publication opportunity per simulated
    /// cycle, summed over all rounds.
    #[test]
    fn fused_dispatch_reports_merged_exchange_stats() {
        use crate::shard::{FusedNetlist, ShardPlan};

        let mut pendulum = pendulum_flow();
        let mut spring = Flow::for_system("spring_mass", FlowConfig::default()).unwrap();
        let p_design = pendulum.rtl().unwrap().clone();
        let s_design = spring.rtl().unwrap().clone();
        let p_netlist = pendulum.netlist().unwrap().netlist.clone();
        let s_netlist = spring.netlist().unwrap().netlist.clone();

        // 70 requests over two members: two rounds at 64 lanes, so the
        // merge path (fresh simulator per round) actually folds.
        let requests: Vec<SystemPowerRequest> = (0..70u32)
            .map(|i| SystemPowerRequest {
                system: (i % 3 == 2) as usize,
                request: PowerRequest { seed: 0x7100 + i, f_hz: 6.0e6 },
            })
            .collect();
        let fused = FusedNetlist::fuse_refs(&[&p_netlist, &s_netlist]);
        // K=4 over 2 members forces member splits, so cut words exist.
        let plan = ShardPlan::partition(&fused, 4);
        let plain = estimate_power_requests_fused(
            &fused, &plan, &[&p_design, &s_design], &requests, 2, synth::LaneWidth::W64,
        );
        let (got, stats) = estimate_power_requests_fused_stats(
            &fused, &plan, &[&p_design, &s_design], &requests, 2, synth::LaneWidth::W64,
        );
        assert_eq!(got.len(), plain.len());
        for (i, (a, b)) in got.iter().zip(&plain).enumerate() {
            assert_eq!(a.mw, b.mw, "request {i}: stats variant changed the estimate");
            assert_eq!(a.toggles_per_cycle, b.toggles_per_cycle, "request {i}");
            assert_eq!(a.cycles, b.cycles, "request {i}");
        }

        assert!(stats.cut_words > 0, "K=4 over 2 members must cut");
        assert_eq!(stats.owner_cut_words.iter().sum::<u64>(), stats.cut_words as u64);
        assert!(stats.total_published() > 0, "live stimulus exchanges words");
        // Opportunity accounting survives the merge: the same total
        // cycle count C applies to every shard's owned words.
        let total = stats.total_published() + stats.total_skipped();
        assert_eq!(total % stats.cut_words as u64, 0);
        let cycles = total / stats.cut_words as u64;
        assert!(cycles > 0);
        for s in 0..plan.shards {
            assert_eq!(
                stats.published[s] + stats.skipped[s],
                stats.owner_cut_words[s] * cycles,
                "shard {s} opportunity accounting"
            );
        }
        assert!(stats.total_published() <= stats.cut_words as u64 * stats.phases);
    }
}
