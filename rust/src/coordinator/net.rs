//! The std-only TCP front end: a small length-prefixed binary protocol
//! over blocking sockets, feeding [`super::engine::TrafficEngine`].
//!
//! # Wire protocol
//!
//! Every message — request or response, both directions — is one frame:
//!
//! ```text
//! ┌──────────┬─────────┬──────────────────────┐
//! │ len: u32 │ kind:u8 │ payload (len-1 bytes)│   all integers little-endian
//! └──────────┴─────────┴──────────────────────┘
//! ```
//!
//! `len` counts the kind byte plus the payload and must be in
//! `1..=MAX_FRAME`. Request kinds: `0x01` Π inference, `0x02` power
//! estimate, `0x03` stats, `0x04` health. A response echoes its
//! request's kind with the high bit set (`kind | 0x80`).
//!
//! Request payloads:
//!
//! ```text
//! pi:     req_id:u32  deadline_us:u32  tlen:u8 tenant[tlen]  nvals:u16  vals[nvals]:i64
//! power:  req_id:u32  deadline_us:u32  tlen:u8 tenant[tlen]  seed:u32   f_hz:f64
//! stats:  req_id:u32  [format:u8]
//! health: req_id:u32
//! ```
//!
//! `deadline_us == 0` means "use the server's default deadline". A
//! `stats` request may carry a trailing format byte: `0` (or absent —
//! the pre-flag wire form) renders the report as text, `1` as the
//! machine-readable JSON of [`TrafficReport::to_json`]; any other value
//! is a protocol error.
//!
//! Response payloads start with `req_id:u32 status:u8`, where `status`
//! is [`CODE_OK`](super::error::CODE_OK) or a
//! [`ServeError`](super::error::ServeError) wire code, then:
//!
//! ```text
//! ok pi:            hw_cycles:u64  n:u16  pis[n]:i64
//! ok power:         mw:f64  toggles_per_cycle:f64  cycles:u64
//! ok stats/health:  len:u32  utf8[len]
//! shed:             retry_after_ms:u32
//! deadline:         (empty)
//! unknown/panic/protocol: len:u32  utf8-detail[len]
//! analysis:         len:u32  utf8-system[len]  errors:u32
//! ```
//!
//! One frame originates server-side without a request: a connection
//! accepted over the [`NetServer::start_capped`] concurrency cap is
//! answered with a `kind 0x05 | 0x80` handshake frame carrying
//! `req_id 0, status shed, retry_after_ms:u32`, then closed (FIN) —
//! a typed refusal, never a silent hang.
//!
//! # Threading
//!
//! One blocking accept loop; per connection, one reader thread (decodes
//! frames, submits to the engine — admission rejections are answered
//! immediately with the typed error) and one writer thread (drains a
//! reply channel onto the socket; responses may arrive out of request
//! order, correlated by `req_id`). Graceful shutdown half-closes each
//! connection's read side, drains the engine so every admitted request
//! is answered, then joins everything.
//!
//! # Per-connection rate limits
//!
//! [`NetConfig::conn_rate`] puts a token bucket on each connection
//! *ahead of* tenant admission: an over-rate Π/power frame is answered
//! with a typed `Shed { retry_after_ms }` for its own `req_id` and
//! never reaches the engine, so one hot socket cannot spend a whole
//! tenant's admission budget. Stats/health frames are control plane and
//! exempt. The bucket is private to the connection — it neither splits
//! a tenant's budget nor shares state across sockets.
//!
//! # Metrics scrape endpoint
//!
//! [`ScrapeServer`] is a deliberately minimal HTTP/1.1 responder for
//! Prometheus-style collectors: any `GET` returns `200` with the live
//! [`TrafficReport::to_json`] body; anything else is `405`. One request
//! per connection (`Connection: close`), std-only, no TLS, no routing —
//! point it at loopback or a scrape-only interface.

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::TokenBucket;
use super::engine::{RequestPayload, TrafficEngine, TrafficReply, TrafficResponse};
use super::error::{
    ServeError, CODE_ANALYSIS, CODE_DEADLINE, CODE_OK, CODE_PROTOCOL, CODE_SHED,
    CODE_TENANT_UNKNOWN, CODE_WORKER_PANICKED,
};
use super::metrics::{LatencyHistogram, TrafficReport};
use super::pipeline::{PowerEstimate, PowerRequest};
use crate::fixedpoint::Q16_15;
use crate::stim::Lfsr32;

/// Largest accepted frame (kind + payload), either direction.
pub const MAX_FRAME: usize = 1 << 20;

/// Request kind: Π inference.
pub const KIND_PI: u8 = 0x01;
/// Request kind: power estimate.
pub const KIND_POWER: u8 = 0x02;
/// Request kind: serving statistics (rendered [`TrafficReport`]).
pub const KIND_STATS: u8 = 0x03;
/// Request kind: one-line liveness check.
pub const KIND_HEALTH: u8 = 0x04;
/// Connection-level control: the server's over-capacity refusal
/// handshake (response direction only — clients never send it).
pub const KIND_CONN: u8 = 0x05;
/// A response's kind is its request's kind with this bit set.
pub const RESPONSE_BIT: u8 = 0x80;

/// Retry hint carried by the over-capacity connection handshake.
const CONN_SHED_RETRY_MS: u32 = 50;

/// Correlate a reply back to its response kind + request id: the engine
/// echoes the 64-bit id verbatim, so the writer thread recovers both.
fn pack_id(kind: u8, req_id: u32) -> u64 {
    (u64::from(kind) << 32) | u64::from(req_id)
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// Read one frame. `Ok(None)` is a clean EOF *between* frames (the
/// peer finished); EOF inside a frame is an error (mid-request
/// disconnect).
fn read_frame(r: &mut impl Read) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut len4 = [0u8; 4];
    // First byte read manually so a between-frames EOF is clean.
    match r.read(&mut len4[..1])? {
        0 => return Ok(None),
        _ => r.read_exact(&mut len4[1..])?,
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} outside 1..={MAX_FRAME}"),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let payload = buf.split_off(1);
    Ok(Some((buf[0], payload)))
}

fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = 1 + payload.len();
    assert!(len <= MAX_FRAME, "oversized outbound frame");
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)
}

/// Bounds-checked little-endian reader over a request/response payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn utf8(&mut self, n: usize) -> Result<String, String> {
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| e.to_string())
    }

    fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn done(&self) -> Result<(), String> {
        if self.at_end() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after payload", self.buf.len() - self.pos))
        }
    }
}

// ---------------------------------------------------------------------
// Request codec
// ---------------------------------------------------------------------

/// A decoded inbound request.
enum DecodedRequest {
    Traffic {
        req_id: u32,
        tenant: String,
        deadline: Option<Duration>,
        payload: RequestPayload,
    },
    Stats {
        req_id: u32,
        /// Render the report as JSON instead of text.
        json: bool,
    },
    Health { req_id: u32 },
}

/// Decode one request frame; on failure, the best-known `req_id` (0 if
/// the header itself was bad) rides with the `Protocol` error so the
/// client can still correlate the refusal.
fn decode_request(kind: u8, payload: &[u8]) -> Result<DecodedRequest, (u32, ServeError)> {
    let mut c = Cursor::new(payload);
    let req_id = c
        .u32()
        .map_err(|detail| (0, ServeError::Protocol { detail }))?;
    decode_request_body(kind, req_id, &mut c)
        .map_err(|detail| (req_id, ServeError::Protocol { detail }))
}

fn decode_request_body(
    kind: u8,
    req_id: u32,
    c: &mut Cursor<'_>,
) -> Result<DecodedRequest, String> {
    match kind {
        KIND_STATS => {
            // Optional trailing format byte; its absence is the
            // pre-flag wire form and means text.
            let json = if c.at_end() {
                false
            } else {
                match c.u8()? {
                    0 => false,
                    1 => true,
                    other => return Err(format!("unknown stats format {other} (0=text, 1=json)")),
                }
            };
            c.done()?;
            Ok(DecodedRequest::Stats { req_id, json })
        }
        KIND_HEALTH => {
            c.done()?;
            Ok(DecodedRequest::Health { req_id })
        }
        KIND_PI | KIND_POWER => {
            let deadline_us = c.u32()?;
            let tlen = c.u8()? as usize;
            let tenant = c.utf8(tlen)?;
            let payload = if kind == KIND_PI {
                let nvals = c.u16()? as usize;
                let mut values_q = Vec::with_capacity(nvals);
                for _ in 0..nvals {
                    values_q.push(c.i64()?);
                }
                RequestPayload::Pi { values_q }
            } else {
                let seed = c.u32()?;
                let f_hz = c.f64()?;
                RequestPayload::Power(PowerRequest { seed, f_hz })
            };
            c.done()?;
            let deadline = if deadline_us == 0 {
                None
            } else {
                Some(Duration::from_micros(u64::from(deadline_us)))
            };
            Ok(DecodedRequest::Traffic { req_id, tenant, deadline, payload })
        }
        other => Err(format!("unknown request kind 0x{other:02x}")),
    }
}

fn encode_request_header(out: &mut Vec<u8>, req_id: u32, deadline_us: u32, tenant: &str) {
    out.extend_from_slice(&req_id.to_le_bytes());
    out.extend_from_slice(&deadline_us.to_le_bytes());
    assert!(tenant.len() <= u8::MAX as usize, "tenant name too long for the wire");
    out.push(tenant.len() as u8);
    out.extend_from_slice(tenant.as_bytes());
}

// ---------------------------------------------------------------------
// Response codec
// ---------------------------------------------------------------------

fn encode_response(reply: &TrafficReply) -> (u8, Vec<u8>) {
    let kind = ((reply.id >> 32) as u8) | RESPONSE_BIT;
    let mut out = Vec::new();
    out.extend_from_slice(&(reply.id as u32).to_le_bytes());
    match &reply.result {
        Ok(TrafficResponse::Pi { pis, hw_cycles }) => {
            out.push(CODE_OK);
            out.extend_from_slice(&hw_cycles.to_le_bytes());
            out.extend_from_slice(&(pis.len() as u16).to_le_bytes());
            for v in pis {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(TrafficResponse::Power(est)) => {
            out.push(CODE_OK);
            out.extend_from_slice(&est.mw.to_le_bytes());
            out.extend_from_slice(&est.toggles_per_cycle.to_le_bytes());
            out.extend_from_slice(&est.cycles.to_le_bytes());
        }
        Ok(TrafficResponse::Text(s)) => {
            out.push(CODE_OK);
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        Err(e) => {
            out.push(e.code());
            match e {
                ServeError::Shed { retry_after_ms } => {
                    out.extend_from_slice(&retry_after_ms.to_le_bytes());
                }
                ServeError::DeadlineExceeded => {}
                ServeError::TenantUnknown { tenant } => {
                    out.extend_from_slice(&(tenant.len() as u32).to_le_bytes());
                    out.extend_from_slice(tenant.as_bytes());
                }
                ServeError::WorkerPanicked { reason } => {
                    out.extend_from_slice(&(reason.len() as u32).to_le_bytes());
                    out.extend_from_slice(reason.as_bytes());
                }
                ServeError::Protocol { detail } => {
                    out.extend_from_slice(&(detail.len() as u32).to_le_bytes());
                    out.extend_from_slice(detail.as_bytes());
                }
                ServeError::AnalysisRejected { system, errors } => {
                    out.extend_from_slice(&(system.len() as u32).to_le_bytes());
                    out.extend_from_slice(system.as_bytes());
                    out.extend_from_slice(&(*errors as u32).to_le_bytes());
                }
            }
        }
    }
    (kind, out)
}

/// A decoded response, as the client sees it.
pub struct NetResponse {
    /// The *request* kind this answers (high bit stripped).
    pub kind: u8,
    pub req_id: u32,
    pub result: Result<TrafficResponse, ServeError>,
}

fn decode_response(wire_kind: u8, payload: &[u8]) -> anyhow::Result<NetResponse> {
    anyhow::ensure!(
        wire_kind & RESPONSE_BIT != 0,
        "expected a response frame, got request kind 0x{wire_kind:02x}"
    );
    let kind = wire_kind & !RESPONSE_BIT;
    let mut c = Cursor::new(payload);
    let mut parse = || -> Result<NetResponse, String> {
        let req_id = c.u32()?;
        let status = c.u8()?;
        let result = match status {
            CODE_OK => Ok(match kind {
                KIND_PI => {
                    let hw_cycles = c.u64()?;
                    let n = c.u16()? as usize;
                    let mut pis = Vec::with_capacity(n);
                    for _ in 0..n {
                        pis.push(c.i64()?);
                    }
                    TrafficResponse::Pi { pis, hw_cycles }
                }
                KIND_POWER => TrafficResponse::Power(PowerEstimate {
                    mw: c.f64()?,
                    toggles_per_cycle: c.f64()?,
                    cycles: c.u64()?,
                }),
                KIND_STATS | KIND_HEALTH => {
                    let n = c.u32()? as usize;
                    TrafficResponse::Text(c.utf8(n)?)
                }
                other => return Err(format!("unknown response kind 0x{other:02x}")),
            }),
            CODE_SHED => Err(ServeError::Shed { retry_after_ms: c.u32()? }),
            CODE_DEADLINE => Err(ServeError::DeadlineExceeded),
            CODE_TENANT_UNKNOWN => {
                let n = c.u32()? as usize;
                Err(ServeError::TenantUnknown { tenant: c.utf8(n)? })
            }
            CODE_WORKER_PANICKED => {
                let n = c.u32()? as usize;
                Err(ServeError::WorkerPanicked { reason: c.utf8(n)? })
            }
            CODE_PROTOCOL => {
                let n = c.u32()? as usize;
                Err(ServeError::Protocol { detail: c.utf8(n)? })
            }
            CODE_ANALYSIS => {
                let n = c.u32()? as usize;
                let system = c.utf8(n)?;
                Err(ServeError::AnalysisRejected { system, errors: c.u32()? as usize })
            }
            other => return Err(format!("unknown status code {other}")),
        };
        c.done()?;
        Ok(NetResponse { kind, req_id, result })
    };
    parse().map_err(|e| anyhow::anyhow!("malformed response: {e}"))
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// Frontend policy knobs of a [`NetServer`], applied per connection.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Cap on concurrent connections (`0` = unlimited); accepts over
    /// the cap get the typed over-capacity handshake and a clean close.
    pub max_conns: usize,
    /// Per-connection token-bucket rate for Π/power frames
    /// (requests/second; `f64::INFINITY` disables). Burst is one
    /// second's worth of tokens, at least 1. Over-rate frames are
    /// answered `Shed` with a refill-derived retry hint, ahead of
    /// tenant admission.
    pub conn_rate: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { max_conns: 0, conn_rate: f64::INFINITY }
    }
}

/// The running TCP front end: accept loop + per-connection threads,
/// all feeding one [`TrafficEngine`].
pub struct NetServer {
    engine: Arc<TrafficEngine>,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>>,
    /// Connections currently inside `conn_loop` (the `max_conns` gauge).
    live: Arc<AtomicUsize>,
    /// Connections refused with the over-capacity handshake.
    conn_shed: Arc<AtomicU64>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:0`) and start accepting, with no
    /// concurrency cap and no per-connection rate limit.
    pub fn start(engine: Arc<TrafficEngine>, listen: &str) -> anyhow::Result<NetServer> {
        NetServer::start_with(engine, listen, NetConfig::default())
    }

    /// Bind `listen` and start accepting at most `max_conns` concurrent
    /// connections (`0` = unlimited). A connection accepted over the
    /// cap is answered with one typed handshake frame — `kind`
    /// [`KIND_CONN`]` | `[`RESPONSE_BIT`], `req_id 0`, status shed with
    /// a retry hint — and closed cleanly (FIN), never silently hung or
    /// dropped. The slot frees when a live connection's reader exits.
    pub fn start_capped(
        engine: Arc<TrafficEngine>,
        listen: &str,
        max_conns: usize,
    ) -> anyhow::Result<NetServer> {
        NetServer::start_with(engine, listen, NetConfig { max_conns, ..NetConfig::default() })
    }

    /// Bind `listen` and start accepting under the full frontend policy
    /// ([`NetConfig`]): connection cap plus per-connection rate limit.
    pub fn start_with(
        engine: Arc<TrafficEngine>,
        listen: &str,
        config: NetConfig,
    ) -> anyhow::Result<NetServer> {
        let NetConfig { max_conns, conn_rate } = config;
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("cannot bind `{listen}`: {e}"))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));
        let conn_shed = Arc::new(AtomicU64::new(0));
        let accept = {
            let engine = engine.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            let live = live.clone();
            let conn_shed = conn_shed.clone();
            std::thread::Builder::new()
                .name("dimsynth-net-accept".to_string())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let _ = stream.set_nodelay(true);
                        if max_conns > 0 && live.load(Ordering::SeqCst) >= max_conns {
                            conn_shed.fetch_add(1, Ordering::SeqCst);
                            shed_connection(&stream);
                            continue;
                        }
                        let Ok(reader_stream) = stream.try_clone() else { continue };
                        live.fetch_add(1, Ordering::SeqCst);
                        let engine = engine.clone();
                        let conn_live = live.clone();
                        let handle = std::thread::Builder::new()
                            .name("dimsynth-net-conn".to_string())
                            .spawn(move || {
                                // Frees the slot however the loop exits.
                                struct Slot(Arc<AtomicUsize>);
                                impl Drop for Slot {
                                    fn drop(&mut self) {
                                        self.0.fetch_sub(1, Ordering::SeqCst);
                                    }
                                }
                                let _slot = Slot(conn_live);
                                conn_loop(reader_stream, &engine, conn_rate);
                            })
                            .expect("spawn connection thread");
                        conns
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .push((stream, handle));
                    }
                })?
        };
        Ok(NetServer {
            engine,
            local_addr,
            stop,
            accept: Some(accept),
            conns,
            live,
            conn_shed,
        })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connections currently being served.
    pub fn live_connections(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Connections refused with the over-capacity handshake so far.
    pub fn connections_shed(&self) -> u64 {
        self.conn_shed.load(Ordering::SeqCst)
    }

    /// Graceful drain: stop accepting, half-close every connection's
    /// read side (in-flight answers still flow out), drain the engine
    /// so every admitted request is answered, join all threads, and
    /// return the final report.
    pub fn shutdown(mut self) -> TrafficReport {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap_or_else(|e| e.into_inner()));
        for (stream, _) in &conns {
            let _ = stream.shutdown(Shutdown::Read);
        }
        // Engine drain answers everything still queued; the per-conn
        // writers deliver those answers before their channels close.
        let drained = self.engine.shutdown();
        for (_, handle) in conns {
            let _ = handle.join();
        }
        // Re-snapshot so late writer-side counters (undelivered,
        // disconnects) are included; the drain verdict is authoritative.
        let mut report = self.engine.report();
        report.engine_panicked = drained.engine_panicked;
        report
    }
}

/// Refuse one over-capacity connection: write the typed shed handshake
/// and half-close the write side (FIN). Best-effort — a peer that
/// vanished mid-handshake is already gone.
fn shed_connection(stream: &TcpStream) {
    let reply = TrafficReply {
        id: pack_id(KIND_CONN, 0),
        result: Err(ServeError::Shed { retry_after_ms: CONN_SHED_RETRY_MS }),
    };
    let (kind, payload) = encode_response(&reply);
    let _ = write_frame(&mut &*stream, kind, &payload);
    let _ = stream.shutdown(Shutdown::Write);
}

// ---------------------------------------------------------------------
// Metrics scrape endpoint
// ---------------------------------------------------------------------

/// Minimal HTTP metrics endpoint (see module docs): `GET` → `200` with
/// the live traffic report JSON; anything else → `405`. One thread, one
/// request per connection, std-only.
pub struct ScrapeServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ScrapeServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and start answering scrapes
    /// from the engine's live [`TrafficReport`].
    pub fn start(engine: Arc<TrafficEngine>, addr: &str) -> anyhow::Result<ScrapeServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind scrape address `{addr}`: {e}"))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("dimsynth-scrape".to_string())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        // A stalled collector must not wedge the
                        // endpoint; scrapes are tiny.
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                        serve_scrape(&stream, &engine);
                    }
                })?
        };
        Ok(ScrapeServer { local_addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    fn stop_now(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.local_addr);
            let _ = h.join();
        }
    }

    /// Stop accepting and join the endpoint thread.
    pub fn shutdown(mut self) {
        self.stop_now();
    }
}

impl Drop for ScrapeServer {
    fn drop(&mut self) {
        // A dropped handle must not leak a thread blocked in accept.
        self.stop_now();
    }
}

/// Answer one HTTP exchange: read the request head, write the report.
fn serve_scrape(stream: &TcpStream, engine: &TrafficEngine) {
    let mut r = BufReader::new(stream);
    let mut request_line = String::new();
    if r.read_line(&mut request_line).is_err() || request_line.is_empty() {
        return;
    }
    // Drain the header block; the body (none expected) is ignored.
    loop {
        let mut header = String::new();
        match r.read_line(&mut header) {
            Ok(0) => break,
            Ok(_) if header == "\r\n" || header == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let (status, body) = if request_line.starts_with("GET ") {
        ("200 OK", engine.stats_json())
    } else {
        ("405 Method Not Allowed", "{\"error\":\"GET only\"}".to_string())
    };
    let mut w = BufWriter::new(stream);
    let _ = write!(
        w,
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = w.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

fn conn_loop(stream: TcpStream, engine: &Arc<TrafficEngine>, conn_rate: f64) {
    let (tx, rx) = mpsc::channel::<TrafficReply>();
    let Ok(writer_stream) = stream.try_clone() else { return };
    let writer = {
        let engine = engine.clone();
        std::thread::Builder::new()
            .name("dimsynth-net-write".to_string())
            .spawn(move || writer_loop(writer_stream, &rx, &engine))
            .expect("spawn writer thread")
    };
    // Per-connection admission throttle: burst = one second of tokens
    // (at least 1), so a compliant client never notices it.
    let mut bucket = conn_rate
        .is_finite()
        .then(|| TokenBucket::new(conn_rate, conn_rate.max(1.0), Instant::now()));
    let mut r = BufReader::new(stream);
    let mut clean = false;
    loop {
        match read_frame(&mut r) {
            Ok(None) => {
                clean = true;
                break;
            }
            Ok(Some((kind, payload))) => {
                if !handle_frame(kind, &payload, engine, &tx, bucket.as_mut()) {
                    // Unrecoverable protocol error: the refusal is on
                    // its way out; stop trusting this byte stream.
                    break;
                }
            }
            Err(_) => break,
        }
    }
    if !clean {
        engine.note_disconnect();
    }
    drop(tx);
    let _ = writer.join();
}

/// Dispatch one decoded frame. Returns `false` when the connection
/// should close (undecodable input). `bucket`, when present, is the
/// connection's private rate limiter for traffic (Π/power) frames.
fn handle_frame(
    kind: u8,
    payload: &[u8],
    engine: &Arc<TrafficEngine>,
    tx: &Sender<TrafficReply>,
    bucket: Option<&mut TokenBucket>,
) -> bool {
    match decode_request(kind, payload) {
        Ok(DecodedRequest::Stats { req_id, json }) => {
            let body = if json { engine.stats_json() } else { engine.stats_text() };
            let _ = tx.send(TrafficReply {
                id: pack_id(KIND_STATS, req_id),
                result: Ok(TrafficResponse::Text(body)),
            });
            true
        }
        Ok(DecodedRequest::Health { req_id }) => {
            let _ = tx.send(TrafficReply {
                id: pack_id(KIND_HEALTH, req_id),
                result: Ok(TrafficResponse::Text(engine.health_text())),
            });
            true
        }
        Ok(DecodedRequest::Traffic { req_id, tenant, deadline, payload }) => {
            let id = pack_id(kind, req_id);
            if let Some(b) = bucket {
                if let Err(refill) = b.try_take_at(Instant::now()) {
                    // Over the connection's rate, ahead of tenant
                    // admission: typed shed with the refill hint.
                    let retry_after_ms = (refill.as_millis() as u64).clamp(1, 60_000) as u32;
                    let _ = tx.send(TrafficReply {
                        id,
                        result: Err(ServeError::Shed { retry_after_ms }),
                    });
                    return true;
                }
            }
            if let Err(e) = engine.submit(&tenant, payload, deadline, id, tx.clone()) {
                // Refused at the door: the engine sends nothing, so the
                // frontend answers with the typed error itself.
                let _ = tx.send(TrafficReply { id, result: Err(e) });
            }
            true
        }
        Err((req_id, e)) => {
            let _ = tx.send(TrafficReply {
                id: pack_id(kind & !RESPONSE_BIT, req_id),
                result: Err(e),
            });
            false
        }
    }
}

fn writer_loop(stream: TcpStream, rx: &Receiver<TrafficReply>, engine: &Arc<TrafficEngine>) {
    let mut w = BufWriter::new(stream);
    let mut broken = false;
    while let Ok(first) = rx.recv() {
        // Batch everything already queued behind one flush.
        let mut pending = vec![first];
        while let Ok(more) = rx.try_recv() {
            pending.push(more);
        }
        for reply in pending {
            if broken {
                engine.note_undelivered(1);
                continue;
            }
            let (kind, payload) = encode_response(&reply);
            if write_frame(&mut w, kind, &payload).is_err() {
                // Peer went away mid-request; absorb the rest.
                engine.note_disconnect();
                engine.note_undelivered(1);
                broken = true;
            }
        }
        if !broken && w.flush().is_err() {
            engine.note_disconnect();
            broken = true;
        }
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A blocking client for the wire protocol. Send and receive are
/// decoupled: responses arrive in completion order, correlated by
/// `req_id`, so callers can pipeline a window of requests.
pub struct NetClient {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl NetClient {
    pub fn connect(addr: &str) -> anyhow::Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect `{addr}`: {e}"))?;
        let _ = stream.set_nodelay(true);
        // A hung server must fail a test, not wedge it.
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let r = BufReader::new(stream.try_clone()?);
        Ok(NetClient { w: stream, r })
    }

    fn send(&mut self, kind: u8, payload: &[u8]) -> anyhow::Result<()> {
        write_frame(&mut self.w, kind, payload)?;
        Ok(())
    }

    /// Submit a Π inference request (`deadline_us == 0` = server default).
    pub fn send_pi(
        &mut self,
        req_id: u32,
        tenant: &str,
        deadline_us: u32,
        values_q: &[i64],
    ) -> anyhow::Result<()> {
        let mut p = Vec::new();
        encode_request_header(&mut p, req_id, deadline_us, tenant);
        p.extend_from_slice(&(values_q.len() as u16).to_le_bytes());
        for v in values_q {
            p.extend_from_slice(&v.to_le_bytes());
        }
        self.send(KIND_PI, &p)
    }

    /// Submit a power-estimation request.
    pub fn send_power(
        &mut self,
        req_id: u32,
        tenant: &str,
        deadline_us: u32,
        seed: u32,
        f_hz: f64,
    ) -> anyhow::Result<()> {
        let mut p = Vec::new();
        encode_request_header(&mut p, req_id, deadline_us, tenant);
        p.extend_from_slice(&seed.to_le_bytes());
        p.extend_from_slice(&f_hz.to_le_bytes());
        self.send(KIND_POWER, &p)
    }

    pub fn send_stats(&mut self, req_id: u32) -> anyhow::Result<()> {
        self.send(KIND_STATS, &req_id.to_le_bytes())
    }

    /// Submit a stats request with the machine-readable format flag:
    /// the response text is the JSON of
    /// [`TrafficReport::to_json`](super::metrics::TrafficReport::to_json).
    pub fn send_stats_json(&mut self, req_id: u32) -> anyhow::Result<()> {
        let mut p = req_id.to_le_bytes().to_vec();
        p.push(1);
        self.send(KIND_STATS, &p)
    }

    pub fn send_health(&mut self, req_id: u32) -> anyhow::Result<()> {
        self.send(KIND_HEALTH, &req_id.to_le_bytes())
    }

    /// Block for the next response frame.
    pub fn recv(&mut self) -> anyhow::Result<NetResponse> {
        match read_frame(&mut self.r)? {
            Some((kind, payload)) => decode_response(kind, &payload),
            None => anyhow::bail!("server closed the connection"),
        }
    }
}

// ---------------------------------------------------------------------
// Traffic drivers (e2e harness + soak bench)
// ---------------------------------------------------------------------

/// One synthetic tenant's client behavior: a seeded mixed Π/power
/// request stream with windowed pipelining.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    pub tenant: String,
    /// Port count of the tenant's system (Π request width).
    pub ports: usize,
    pub requests: usize,
    /// Max in-flight requests before the driver reads a response.
    pub window: usize,
    pub seed: u32,
    /// Fraction of requests that are power estimates (rest are Π).
    pub power_ratio: f64,
    /// Per-request deadline on the wire; 0 = server default.
    pub deadline_us: u32,
    /// Pause between sends (shapes offered load).
    pub gap: Duration,
    /// Drop the connection after reading this many responses, leaving
    /// the rest in flight (the mid-request-disconnect injection).
    pub disconnect_after_reads: Option<usize>,
    /// After the stream drains, fetch the server's stats in the JSON
    /// wire format and parse the global counters into
    /// [`DriverReport::server_stats`].
    pub probe_stats_json: bool,
}

impl DriverConfig {
    pub fn new(tenant: &str, ports: usize) -> DriverConfig {
        DriverConfig {
            tenant: tenant.to_string(),
            ports,
            requests: 100,
            window: 16,
            seed: 1,
            power_ratio: 0.25,
            deadline_us: 0,
            gap: Duration::ZERO,
            disconnect_after_reads: None,
            probe_stats_json: false,
        }
    }
}

/// Global counters parsed by the driver from the JSON `stats` wire
/// variant — the machine-readable view of what the server recorded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsProbe {
    pub admitted: u64,
    pub served: u64,
    pub shed: u64,
}

/// Scan `json` for `"key":<digits>` and parse the first match — enough
/// for the global counters, because [`TrafficReport::to_json`] emits
/// `totals` before any per-tenant object.
///
/// [`TrafficReport::to_json`]: super::metrics::TrafficReport::to_json
fn json_counter(json: &str, key: &str) -> Option<u64> {
    let needle = format!("\"{key}\":");
    let at = json.find(&needle)? + needle.len();
    let rest = &json[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

impl StatsProbe {
    /// Parse the global counters out of a JSON stats response.
    pub fn parse(json: &str) -> Option<StatsProbe> {
        Some(StatsProbe {
            admitted: json_counter(json, "admitted")?,
            served: json_counter(json, "served")?,
            shed: json_counter(json, "shed")?,
        })
    }
}

/// What one driver observed, by typed outcome. When the driver was not
/// configured to disconnect, `sent` equals the sum of the outcome
/// counters — exactly one response per request.
#[derive(Clone, Debug, Default)]
pub struct DriverReport {
    pub sent: u64,
    pub ok: u64,
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub panicked: u64,
    pub protocol: u64,
    pub tenant_unknown: u64,
    /// Client-observed round-trip latency of `ok` responses.
    pub latency: LatencyHistogram,
    /// The driver dropped the connection on purpose.
    pub disconnected: bool,
    /// Parsed JSON stats, when [`DriverConfig::probe_stats_json`] ran.
    pub server_stats: Option<StatsProbe>,
}

impl DriverReport {
    /// Responses received, by any outcome.
    pub fn answered(&self) -> u64 {
        self.ok + self.shed + self.deadline_exceeded + self.panicked + self.protocol
            + self.tenant_unknown
    }

    fn count(&mut self, resp: &NetResponse, inflight: &mut HashMap<u32, Instant>) {
        let t0 = inflight.remove(&resp.req_id);
        match &resp.result {
            Ok(_) => {
                self.ok += 1;
                if let Some(t0) = t0 {
                    self.latency.record(t0.elapsed());
                }
            }
            Err(ServeError::Shed { .. }) => self.shed += 1,
            Err(ServeError::DeadlineExceeded) => self.deadline_exceeded += 1,
            Err(ServeError::WorkerPanicked { .. }) => self.panicked += 1,
            Err(ServeError::Protocol { .. }) => self.protocol += 1,
            Err(ServeError::TenantUnknown { .. }) => self.tenant_unknown += 1,
            // Boot-time refusal: a booted server never answers traffic
            // with it, so a driver seeing one indicates a protocol-level
            // disagreement.
            Err(ServeError::AnalysisRejected { .. }) => self.protocol += 1,
        }
    }
}

/// Run one tenant's traffic against a serving address and tally every
/// typed outcome. Deterministic for a fixed config: the request mix,
/// values, and seeds all derive from `cfg.seed`.
pub fn run_driver(addr: &str, cfg: &DriverConfig) -> anyhow::Result<DriverReport> {
    let mut client = NetClient::connect(addr)?;
    let mut rng = Lfsr32::new(cfg.seed);
    let mut report = DriverReport::default();
    let mut inflight: HashMap<u32, Instant> = HashMap::new();
    let mut reads = 0usize;
    let window = cfg.window.max(1);
    let disconnect_now =
        |reads: usize| cfg.disconnect_after_reads.is_some_and(|limit| reads >= limit);
    for i in 0..cfg.requests {
        while inflight.len() >= window {
            let resp = client.recv()?;
            report.count(&resp, &mut inflight);
            reads += 1;
            if disconnect_now(reads) {
                report.disconnected = true;
                return Ok(report);
            }
        }
        let req_id = i as u32;
        if rng.next_f64() < cfg.power_ratio {
            let f_hz = if rng.next_u32() & 1 == 0 { 6.0e6 } else { 12.0e6 };
            client.send_power(req_id, &cfg.tenant, cfg.deadline_us, rng.next_u32(), f_hz)?;
        } else {
            // Physical-range stimulus, like the synthetic serve driver.
            let values_q: Vec<i64> = (0..cfg.ports)
                .map(|_| Q16_15.from_f64(0.5 + 3.0 * rng.next_f64()))
                .collect();
            client.send_pi(req_id, &cfg.tenant, cfg.deadline_us, &values_q)?;
        }
        inflight.insert(req_id, Instant::now());
        report.sent += 1;
        if !cfg.gap.is_zero() {
            std::thread::sleep(cfg.gap);
        }
    }
    while !inflight.is_empty() {
        let resp = client.recv()?;
        report.count(&resp, &mut inflight);
        reads += 1;
        if disconnect_now(reads) {
            report.disconnected = true;
            return Ok(report);
        }
    }
    if cfg.probe_stats_json {
        // The stream is drained, so the next frame is this answer.
        client.send_stats_json(u32::MAX)?;
        let resp = client.recv()?;
        anyhow::ensure!(resp.kind == KIND_STATS, "expected a stats response");
        match resp.result {
            Ok(TrafficResponse::Text(json)) => {
                report.server_stats = Some(
                    StatsProbe::parse(&json)
                        .ok_or_else(|| anyhow::anyhow!("unparseable stats JSON: {json}"))?,
                );
            }
            other => anyhow::bail!("stats probe failed: {other:?}"),
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::{AdmissionConfig, TenantSpec};
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::faults::FaultPlan;
    use crate::coordinator::serveset::ServeSet;
    use crate::flow::FlowConfig;

    #[test]
    fn frame_roundtrip_and_limits() {
        let mut buf = Vec::new();
        write_frame(&mut buf, KIND_PI, &[1, 2, 3]).unwrap();
        let (kind, payload) = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!((kind, payload.as_slice()), (KIND_PI, &[1u8, 2, 3][..]));
        // Clean EOF between frames.
        assert!(read_frame(&mut [].as_slice()).unwrap().is_none());
        // EOF inside a frame is an error.
        assert!(read_frame(&mut &buf[..3]).is_err());
        // Zero-length and oversized frames are rejected.
        assert!(read_frame(&mut 0u32.to_le_bytes().as_slice()).is_err());
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_frame(&mut huge.as_slice()).is_err());
    }

    #[test]
    fn request_codec_roundtrip() {
        let mut p = Vec::new();
        encode_request_header(&mut p, 42, 1500, "tenant-a");
        p.extend_from_slice(&2u16.to_le_bytes());
        p.extend_from_slice(&123i64.to_le_bytes());
        p.extend_from_slice(&(-7i64).to_le_bytes());
        match decode_request(KIND_PI, &p).unwrap() {
            DecodedRequest::Traffic { req_id, tenant, deadline, payload } => {
                assert_eq!(req_id, 42);
                assert_eq!(tenant, "tenant-a");
                assert_eq!(deadline, Some(Duration::from_micros(1500)));
                match payload {
                    RequestPayload::Pi { values_q } => assert_eq!(values_q, vec![123, -7]),
                    other => panic!("expected Pi, got {other:?}"),
                }
            }
            _ => panic!("expected Traffic"),
        }

        let mut p = Vec::new();
        encode_request_header(&mut p, 7, 0, "t");
        p.extend_from_slice(&0xBEEFu32.to_le_bytes());
        p.extend_from_slice(&6.0e6f64.to_le_bytes());
        match decode_request(KIND_POWER, &p).unwrap() {
            DecodedRequest::Traffic { deadline, payload, .. } => {
                assert_eq!(deadline, None, "0 µs = server default");
                match payload {
                    RequestPayload::Power(r) => {
                        assert_eq!(r.seed, 0xBEEF);
                        assert_eq!(r.f_hz, 6.0e6);
                    }
                    other => panic!("expected Power, got {other:?}"),
                }
            }
            _ => panic!("expected Traffic"),
        }

        match decode_request(KIND_HEALTH, &9u32.to_le_bytes()).unwrap() {
            DecodedRequest::Health { req_id } => assert_eq!(req_id, 9),
            _ => panic!("expected Health"),
        }
    }

    #[test]
    fn malformed_requests_fail_typed_with_best_known_req_id() {
        // Truncated header: no req_id recovered.
        let (req_id, e) = decode_request(KIND_PI, &[1, 2]).unwrap_err();
        assert_eq!(req_id, 0);
        assert!(matches!(e, ServeError::Protocol { .. }));
        // Bad body after a good header: req_id recovered.
        let mut p = Vec::new();
        encode_request_header(&mut p, 31, 0, "t");
        p.push(0xFF); // garbage instead of nvals:u16
        let (req_id, e) = decode_request(KIND_PI, &p).unwrap_err();
        assert_eq!(req_id, 31);
        assert!(matches!(e, ServeError::Protocol { .. }));
        // Unknown kind.
        let (_, e) = decode_request(0x77, &5u32.to_le_bytes()).unwrap_err();
        assert!(e.to_string().contains("0x77"), "{e}");
        // Trailing bytes are rejected, not ignored.
        let mut p = 9u32.to_le_bytes().to_vec();
        p.push(0);
        assert!(decode_request(KIND_HEALTH, &p).is_err());
        // Stats tolerates exactly one trailing byte (the format flag);
        // anything beyond is still trailing garbage.
        let mut p = 9u32.to_le_bytes().to_vec();
        p.extend_from_slice(&[1, 0]);
        assert!(decode_request(KIND_STATS, &p).is_err());
    }

    #[test]
    fn stats_format_flag_selects_rendering() {
        // Bare request (pre-flag wire form): text.
        match decode_request(KIND_STATS, &9u32.to_le_bytes()).unwrap() {
            DecodedRequest::Stats { req_id, json } => {
                assert_eq!(req_id, 9);
                assert!(!json);
            }
            _ => panic!("expected Stats"),
        }
        for (flag, want) in [(0u8, false), (1, true)] {
            let mut p = 9u32.to_le_bytes().to_vec();
            p.push(flag);
            match decode_request(KIND_STATS, &p).unwrap() {
                DecodedRequest::Stats { json, .. } => assert_eq!(json, want),
                _ => panic!("expected Stats"),
            }
        }
        // Unknown flag values refuse typed, with the req_id recovered.
        let mut p = 9u32.to_le_bytes().to_vec();
        p.push(7);
        let (req_id, e) = decode_request(KIND_STATS, &p).unwrap_err();
        assert_eq!(req_id, 9);
        assert!(e.to_string().contains("stats format"), "{e}");
    }

    #[test]
    fn stats_probe_parses_the_json_wire_variant() {
        assert_eq!(
            StatsProbe::parse("{\"totals\":{\"admitted\":8,\"served\":7,\"shed\":1}}"),
            Some(StatsProbe { admitted: 8, served: 7, shed: 1 })
        );
        assert_eq!(StatsProbe::parse("not json"), None);
        assert_eq!(json_counter("{\"served\":12,", "served"), Some(12));
        assert_eq!(json_counter("{\"served\":}", "served"), None);
    }

    #[test]
    fn response_codec_roundtrip_every_status() {
        let cases: Vec<(u8, Result<TrafficResponse, ServeError>)> = vec![
            (KIND_PI, Ok(TrafficResponse::Pi { pis: vec![1, -2, 3], hw_cycles: 99 })),
            (
                KIND_POWER,
                Ok(TrafficResponse::Power(PowerEstimate {
                    mw: 1.25,
                    toggles_per_cycle: 0.5,
                    cycles: 1024,
                })),
            ),
            (KIND_STATS, Ok(TrafficResponse::Text("report".to_string()))),
            (KIND_PI, Err(ServeError::Shed { retry_after_ms: 17 })),
            (KIND_POWER, Err(ServeError::DeadlineExceeded)),
            (KIND_PI, Err(ServeError::TenantUnknown { tenant: "ghost".into() })),
            (KIND_PI, Err(ServeError::WorkerPanicked { reason: "injected".into() })),
            (KIND_POWER, Err(ServeError::Protocol { detail: "bad frame".into() })),
            (
                KIND_PI,
                Err(ServeError::AnalysisRejected { system: "pendulum".into(), errors: 3 }),
            ),
        ];
        for (i, (kind, result)) in cases.into_iter().enumerate() {
            let reply = TrafficReply { id: pack_id(kind, 1000 + i as u32), result };
            let (wire_kind, payload) = encode_response(&reply);
            assert_eq!(wire_kind, kind | RESPONSE_BIT);
            let back = decode_response(wire_kind, &payload).unwrap();
            assert_eq!(back.kind, kind);
            assert_eq!(back.req_id, 1000 + i as u32);
            match (&reply.result, &back.result) {
                (Ok(TrafficResponse::Pi { pis: a, hw_cycles: ca }),
                    Ok(TrafficResponse::Pi { pis: b, hw_cycles: cb })) => {
                    assert_eq!(a, b);
                    assert_eq!(ca, cb);
                }
                (Ok(TrafficResponse::Power(a)), Ok(TrafficResponse::Power(b))) => {
                    assert_eq!(a.mw, b.mw);
                    assert_eq!(a.toggles_per_cycle, b.toggles_per_cycle);
                    assert_eq!(a.cycles, b.cycles);
                }
                (Ok(TrafficResponse::Text(a)), Ok(TrafficResponse::Text(b))) => {
                    assert_eq!(a, b);
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                (a, b) => panic!("variant mismatch: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn loopback_serves_pi_power_stats_health() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let ports = set.handle_at(0).design().num_inputs();
        let engine = Arc::new(
            TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&["pendulum"]),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        let server = NetServer::start(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let report = run_driver(&addr, &DriverConfig {
            requests: 24,
            window: 8,
            seed: 0xA11CE,
            ..DriverConfig::new("pendulum", ports)
        })
        .unwrap();
        assert_eq!(report.sent, 24);
        assert_eq!(report.ok, 24, "{report:?}");
        assert_eq!(report.answered(), report.sent);
        assert!(report.latency.count() > 0);

        let mut client = NetClient::connect(&addr).unwrap();
        client.send_health(1).unwrap();
        client.send_stats(2).unwrap();
        let mut saw_health = false;
        let mut saw_stats = false;
        for _ in 0..2 {
            let resp = client.recv().unwrap();
            match (resp.kind, resp.result.unwrap()) {
                (KIND_HEALTH, TrafficResponse::Text(s)) => {
                    assert!(s.starts_with("ok:"), "{s}");
                    saw_health = true;
                }
                (KIND_STATS, TrafficResponse::Text(s)) => {
                    assert!(s.contains("admitted"), "{s}");
                    saw_stats = true;
                }
                other => panic!("unexpected {:?}", other.0),
            }
        }
        assert!(saw_health && saw_stats);

        // Unknown tenant over the wire comes back typed.
        client.send_pi(3, "ghost", 0, &vec![0i64; ports]).unwrap();
        match client.recv().unwrap().result.unwrap_err() {
            ServeError::TenantUnknown { tenant } => assert_eq!(tenant, "ghost"),
            other => panic!("expected TenantUnknown, got {other}"),
        }
        drop(client);

        let final_report = server.shutdown();
        assert!(!final_report.engine_panicked);
        let t = final_report.tenant("pendulum").unwrap();
        assert_eq!(t.counters.served, 24);
        assert_eq!(t.counters.terminal(), t.counters.admitted);
        assert_eq!(final_report.tenant_unknown, 1);
    }

    fn boot_pendulum_server(max_conns: usize) -> (NetServer, String, usize) {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let ports = set.handle_at(0).design().num_inputs();
        let engine = Arc::new(
            TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&["pendulum"]),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        let server = NetServer::start_capped(engine, "127.0.0.1:0", max_conns).unwrap();
        let addr = server.local_addr().to_string();
        (server, addr, ports)
    }

    #[test]
    fn stats_json_wire_variant_and_driver_probe() {
        let (server, addr, ports) = boot_pendulum_server(0);

        // The traffic driver fetches and parses the JSON variant.
        let report = run_driver(&addr, &DriverConfig {
            requests: 8,
            window: 4,
            seed: 0xBEE,
            probe_stats_json: true,
            ..DriverConfig::new("pendulum", ports)
        })
        .unwrap();
        assert_eq!(report.ok, 8, "{report:?}");
        let probe = report.server_stats.expect("probe parsed");
        assert!(probe.served >= 8, "{probe:?}");
        assert!(probe.admitted >= probe.served, "{probe:?}");

        // Raw client: both renderings from the same connection.
        let mut client = NetClient::connect(&addr).unwrap();
        client.send_stats(1).unwrap();
        match client.recv().unwrap().result.unwrap() {
            TrafficResponse::Text(s) => {
                assert!(s.contains("admitted") && !s.starts_with('{'), "{s}")
            }
            other => panic!("expected Text, got {other:?}"),
        }
        client.send_stats_json(2).unwrap();
        match client.recv().unwrap().result.unwrap() {
            TrafficResponse::Text(s) => {
                assert!(s.starts_with('{') && s.contains("\"totals\""), "{s}");
                assert!(StatsProbe::parse(&s).is_some(), "{s}");
            }
            other => panic!("expected Text, got {other:?}"),
        }
        // A bad format flag refuses typed, then the server stops
        // trusting the byte stream.
        let mut p = 3u32.to_le_bytes().to_vec();
        p.push(9);
        client.send(KIND_STATS, &p).unwrap();
        match client.recv().unwrap().result.unwrap_err() {
            ServeError::Protocol { detail } => assert!(detail.contains("stats format"), "{detail}"),
            other => panic!("expected Protocol, got {other}"),
        }
        drop(client);
        server.shutdown();
    }

    #[test]
    fn conn_cap_sheds_typed_handshake_and_frees_slots() {
        let (server, addr, _ports) = boot_pendulum_server(1);

        // First connection owns the only slot (a served round trip
        // proves the accept loop registered it).
        let mut c1 = NetClient::connect(&addr).unwrap();
        c1.send_health(1).unwrap();
        assert!(c1.recv().unwrap().result.is_ok());
        assert_eq!(server.live_connections(), 1);

        // Over the cap: one typed handshake frame, then a clean close.
        let mut c2 = NetClient::connect(&addr).unwrap();
        let resp = c2.recv().unwrap();
        assert_eq!((resp.kind, resp.req_id), (KIND_CONN, 0));
        match resp.result.unwrap_err() {
            ServeError::Shed { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected Shed, got {other}"),
        }
        let closed = c2.recv().unwrap_err().to_string();
        assert!(closed.contains("closed"), "{closed}");
        assert_eq!(server.connections_shed(), 1);
        drop(c2);

        // Freeing the slot re-admits: once c1's reader notices the EOF,
        // a fresh connection serves again.
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let mut c3 = NetClient::connect(&addr).unwrap();
            c3.send_health(9).unwrap();
            match c3.recv().unwrap().result {
                Ok(_) => break,
                Err(ServeError::Shed { .. }) => {
                    assert!(Instant::now() < deadline, "cap slot never freed");
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(other) => panic!("unexpected {other}"),
            }
        }
        server.shutdown();
    }

    #[test]
    fn per_connection_rate_limit_sheds_typed_ahead_of_admission() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let ports = set.handle_at(0).design().num_inputs();
        let engine = Arc::new(
            TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&["pendulum"]),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        // Burst 1 and a refill that takes ~11 days: the second traffic
        // frame on a connection is over-rate deterministically.
        let server = NetServer::start_with(
            engine,
            "127.0.0.1:0",
            NetConfig { max_conns: 0, conn_rate: 1e-6 },
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        let values: Vec<i64> = vec![Q16_15.from_f64(1.0); ports];

        let mut client = NetClient::connect(&addr).unwrap();
        client.send_pi(1, "pendulum", 0, &values).unwrap();
        assert!(client.recv().unwrap().result.is_ok(), "burst token serves");
        client.send_pi(2, "pendulum", 0, &values).unwrap();
        let resp = client.recv().unwrap();
        assert_eq!(resp.req_id, 2);
        match resp.result.unwrap_err() {
            ServeError::Shed { retry_after_ms } => {
                assert!(retry_after_ms >= 1, "refill-derived hint");
            }
            other => panic!("expected Shed, got {other}"),
        }
        // Control plane is exempt from the connection bucket.
        client.send_health(3).unwrap();
        assert!(client.recv().unwrap().result.is_ok());
        // Buckets are per connection, not shared across sockets.
        let mut c2 = NetClient::connect(&addr).unwrap();
        c2.send_pi(9, "pendulum", 0, &values).unwrap();
        assert!(c2.recv().unwrap().result.is_ok());
        drop(client);
        drop(c2);

        let report = server.shutdown();
        let t = report.tenant("pendulum").unwrap();
        assert_eq!(t.counters.admitted, 2, "the over-rate frame never reached admission");
        assert_eq!(t.counters.shed, 0, "the shed happened at the net layer, not the tenant");
    }

    #[test]
    fn scrape_endpoint_serves_report_json_over_http() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let engine = Arc::new(
            TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&["pendulum"]),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        let scrape = ScrapeServer::start(engine.clone(), "127.0.0.1:0").unwrap();
        let addr = scrape.local_addr();

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(stream, "GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
        assert!(response.contains("Content-Type: application/json"), "{response}");
        let body = response.split("\r\n\r\n").nth(1).expect("header/body split");
        assert!(body.starts_with('{') && body.ends_with('}'), "{body}");
        assert!(body.contains("\"totals\"") && body.contains("\"lanes\""), "{body}");
        assert!(StatsProbe::parse(body).is_some(), "{body}");

        // Anything but GET is a 405, still a well-formed HTTP answer.
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(stream, "POST /metrics HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.1 405"), "{response}");

        scrape.shutdown();
        engine.shutdown();
    }
}
