//! Dynamic batching: collect requests from a channel up to a maximum
//! batch size or a deadline, whichever comes first — the standard
//! latency/throughput knob of serving systems, applied to sensor samples.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Outcome of one batch collection.
pub enum BatchOutcome<T> {
    /// A (possibly partial) batch.
    Batch(Vec<T>),
    /// Channel closed and drained: shut down.
    Closed(Vec<T>),
}

/// Collect up to `max_batch` items. The first item is awaited without a
/// deadline (idle server consumes no CPU); once the batch is "open", more
/// items are accepted until `linger` elapses or the batch fills.
pub fn collect<T>(rx: &Receiver<T>, max_batch: usize, linger: Duration) -> BatchOutcome<T> {
    let mut batch = Vec::with_capacity(max_batch);
    // Blocking wait for the first item.
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return BatchOutcome::Closed(batch),
    }
    let deadline = Instant::now() + linger;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return BatchOutcome::Closed(batch),
        }
    }
    BatchOutcome::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn fills_batch_when_items_ready() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect(&rx, 4, Duration::from_millis(50)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn partial_batch_on_linger() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let got = collect(&rx, 8, Duration::from_millis(10));
        match got {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![1]),
            _ => panic!("expected partial batch"),
        }
    }

    #[test]
    fn closed_channel_reports_shutdown() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(collect(&rx, 4, Duration::from_millis(5)), BatchOutcome::Closed(_)));
    }

    #[test]
    fn items_arriving_during_linger_are_included() {
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
        });
        // Depending on timing the sender may have hung up by the time the
        // batch closes; both outcomes must carry the two items.
        match collect(&rx, 4, Duration::from_millis(100)) {
            BatchOutcome::Batch(b) | BatchOutcome::Closed(b) => assert_eq!(b.len(), 2),
        }
        t.join().unwrap();
    }
}
