//! Dynamic batching: collect requests from a channel up to a maximum
//! batch size or a deadline, whichever comes first — the standard
//! latency/throughput knob of serving systems, applied to sensor samples.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Observable queue pressure for a channel-fed batcher: depth and
/// oldest-entry age, maintained by the enqueue/dequeue sites around the
/// opaque `mpsc` channel (which exposes neither). Admission control and
/// metrics read *real* pressure from this instead of guessing.
///
/// The gauge tracks enqueue timestamps in FIFO order; `on_dequeue(n)`
/// retires the `n` oldest. Both sides are O(1) amortized behind one
/// short-lived lock, so the gauge adds no contention to the hot path.
#[derive(Debug, Default)]
pub struct QueueGauge {
    inner: Mutex<std::collections::VecDeque<Instant>>,
}

impl QueueGauge {
    pub fn new() -> QueueGauge {
        QueueGauge::default()
    }

    /// Record one item entering the queue (call at the send site).
    pub fn on_enqueue(&self) {
        self.lock().push_back(Instant::now());
    }

    /// Record `n` items leaving the queue (call at the collect site).
    pub fn on_dequeue(&self, n: usize) {
        let mut q = self.lock();
        for _ in 0..n.min(q.len()) {
            q.pop_front();
        }
    }

    /// Items currently queued.
    pub fn depth(&self) -> usize {
        self.lock().len()
    }

    /// Age of the oldest queued item; `None` when the queue is empty.
    pub fn oldest_age(&self) -> Option<Duration> {
        self.lock().front().map(Instant::elapsed)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, std::collections::VecDeque<Instant>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Outcome of one batch collection.
pub enum BatchOutcome<T> {
    /// A (possibly partial) batch.
    Batch(Vec<T>),
    /// Channel closed and drained: shut down.
    Closed(Vec<T>),
}

/// Collect up to `max_batch` items. The first item is awaited without a
/// deadline (idle server consumes no CPU); once the batch is "open",
/// items already sitting in the channel are drained for free, and more
/// are accepted until `linger` elapses or the batch fills.
///
/// `linger` bounds *waiting*, not batching: with `linger == 0` (or an
/// already-expired deadline) a flood that queued `max_batch` items still
/// comes back as one full batch — zero linger means "don't wait", never
/// "don't batch".
pub fn collect<T>(rx: &Receiver<T>, max_batch: usize, linger: Duration) -> BatchOutcome<T> {
    let mut batch = Vec::with_capacity(max_batch);
    // Blocking wait for the first item.
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return BatchOutcome::Closed(batch),
    }
    // Free drain of items that are already queued — before looking at
    // the clock, so an expired deadline cannot degrade ready work into
    // batches of one.
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(item) => batch.push(item),
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => return BatchOutcome::Closed(batch),
        }
    }
    let deadline = Instant::now() + linger;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return BatchOutcome::Closed(batch),
        }
    }
    BatchOutcome::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn fills_batch_when_items_ready() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect(&rx, 4, Duration::from_millis(50)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn partial_batch_on_linger() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let got = collect(&rx, 8, Duration::from_millis(10));
        match got {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![1]),
            _ => panic!("expected partial batch"),
        }
    }

    /// Regression: zero linger (an already-expired deadline) must still
    /// drain everything the channel already holds — "no waiting" must
    /// not mean "no batching".
    #[test]
    fn zero_linger_still_fills_from_ready_items() {
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        match collect(&rx, 4, Duration::ZERO) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            _ => panic!("expected a full batch"),
        }
        // And a partially-filled channel comes back whole, not 1-by-1.
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        match collect(&rx, 4, Duration::ZERO) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![7, 8]),
            _ => panic!("expected the ready pair"),
        }
    }

    #[test]
    fn closed_channel_reports_shutdown() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(collect(&rx, 4, Duration::from_millis(5)), BatchOutcome::Closed(_)));
    }

    #[test]
    fn gauge_tracks_depth_and_oldest_age_fifo() {
        let g = QueueGauge::new();
        assert_eq!(g.depth(), 0);
        assert_eq!(g.oldest_age(), None);
        g.on_enqueue();
        std::thread::sleep(Duration::from_millis(2));
        g.on_enqueue();
        assert_eq!(g.depth(), 2);
        let oldest = g.oldest_age().unwrap();
        assert!(oldest >= Duration::from_millis(2), "{oldest:?}");
        // FIFO retire: after one dequeue the younger entry remains.
        g.on_dequeue(1);
        assert_eq!(g.depth(), 1);
        assert!(g.oldest_age().unwrap() < oldest);
        // Over-dequeue is clamped, not a panic.
        g.on_dequeue(10);
        assert_eq!(g.depth(), 0);
        assert_eq!(g.oldest_age(), None);
    }

    /// Regression (PR 5 semantics): wiring a gauge around `collect` must
    /// not change zero-linger drain behavior — ready items still come
    /// back as one whole batch, and the gauge sees them retire together.
    #[test]
    fn gauged_zero_linger_drain_is_unchanged() {
        let g = QueueGauge::new();
        let (tx, rx) = mpsc::channel();
        for i in 0..6 {
            tx.send(i).unwrap();
            g.on_enqueue();
        }
        assert_eq!(g.depth(), 6);
        match collect(&rx, 8, Duration::ZERO) {
            BatchOutcome::Batch(b) => {
                g.on_dequeue(b.len());
                assert_eq!(b, vec![0, 1, 2, 3, 4, 5], "zero linger must still drain whole");
            }
            _ => panic!("expected a batch"),
        }
        assert_eq!(g.depth(), 0);
    }

    #[test]
    fn items_arriving_during_linger_are_included() {
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
        });
        // Depending on timing the sender may have hung up by the time the
        // batch closes; both outcomes must carry the two items.
        match collect(&rx, 4, Duration::from_millis(100)) {
            BatchOutcome::Batch(b) | BatchOutcome::Closed(b) => assert_eq!(b.len(), 2),
        }
        t.join().unwrap();
    }
}
