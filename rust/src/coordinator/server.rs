//! The in-sensor inference server: a worker thread owning the pipeline
//! (the PJRT client is not `Send`-safe, so it is created *inside* the
//! worker), fed through a request channel with dynamic batching.
//!
//! A server can stand alone ([`InferenceServer::start`], which compiles
//! its own hardware state) or serve as one tenant of a multi-system
//! [`super::ServeSet`] ([`InferenceServer::start_shared`], which reuses
//! the set's warm compiled artifacts instead of building a cold session
//! per endpoint).

use super::batcher::{self, BatchOutcome};
use super::metrics::ServeStats;
use super::pipeline::{Pipeline, PiPath, Prediction, SensorInput};
use super::serveset::SystemHandle;
use crate::train::TrainOutput;

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request in flight: input + response channel + submit timestamp.
pub struct Request {
    pub input: SensorInput,
    pub resp: Sender<anyhow::Result<Prediction>>,
    pub t_submit: Instant,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts: String,
    pub system: String,
    pub max_batch: usize,
    pub linger: Duration,
    pub pi_path: PiPath,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<ServeStats>>,
}

impl InferenceServer {
    /// Start a standalone worker that compiles its own hardware state.
    /// `trained` supplies Φ parameters and feature statistics (see
    /// [`crate::train`]). Blocks until the pipeline is initialized
    /// (artifact compilation) or fails.
    pub fn start(config: ServerConfig, trained: TrainOutput) -> anyhow::Result<InferenceServer> {
        InferenceServer::launch(config, trained, None)
    }

    /// Start a worker serving from a [`super::ServeSet`]'s shared warm
    /// compiled state: the handle's design/netlist are reused, so no
    /// per-endpoint compilation happens at all.
    pub fn start_shared(
        config: ServerConfig,
        trained: TrainOutput,
        handle: SystemHandle,
    ) -> anyhow::Result<InferenceServer> {
        anyhow::ensure!(
            handle.system() == config.system,
            "handle compiled for `{}` cannot serve system `{}`",
            handle.system(),
            config.system
        );
        InferenceServer::launch(config, trained, Some(handle))
    }

    fn launch(
        config: ServerConfig,
        trained: TrainOutput,
        handle: Option<SystemHandle>,
    ) -> anyhow::Result<InferenceServer> {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let worker = std::thread::Builder::new()
            .name(format!("dimsynth-serve-{}", config.system))
            .spawn(move || worker_loop(config, trained, handle, rx, ready_tx))
            .expect("spawn worker");
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(InferenceServer { tx: Some(tx), worker: Some(worker) }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow::anyhow!("server worker died during init"))
            }
        }
    }

    /// Submit one observation; returns the response channel.
    pub fn submit(&self, input: SensorInput) -> Receiver<anyhow::Result<Prediction>> {
        let (tx, rx) = mpsc::channel();
        let req = Request { input, resp: tx, t_submit: Instant::now() };
        if let Some(q) = &self.tx {
            // A send failure means the worker is gone; the caller sees a
            // closed response channel.
            let _ = q.send(req);
        }
        rx
    }

    /// Close the queue and collect final statistics. A worker that died
    /// by panic is reported as such ([`ServeStats::worker_panicked`]) —
    /// it must not masquerade as a clean zero-traffic run.
    pub fn shutdown(mut self) -> ServeStats {
        self.tx.take(); // close channel
        match self.worker.take().map(JoinHandle::join) {
            Some(Ok(stats)) => stats,
            Some(Err(_)) => ServeStats { worker_panicked: true, ..ServeStats::default() },
            None => ServeStats::default(),
        }
    }
}

fn worker_loop(
    config: ServerConfig,
    trained: TrainOutput,
    handle: Option<SystemHandle>,
    rx: Receiver<Request>,
    ready: Sender<anyhow::Result<()>>,
) -> ServeStats {
    let built = match handle {
        Some(h) => Pipeline::from_handle(&config.artifacts, &trained, config.pi_path, h),
        None => Pipeline::new(&config.artifacts, &config.system, &trained, config.pi_path),
    };
    let mut pipeline = match built {
        Ok(p) => {
            let _ = ready.send(Ok(()));
            p
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return ServeStats::default();
        }
    };

    let mut stats = ServeStats::default();
    let t0 = Instant::now();
    loop {
        let (batch, closing) = match batcher::collect(&rx, config.max_batch, config.linger) {
            BatchOutcome::Batch(b) => (b, false),
            BatchOutcome::Closed(b) => (b, true),
        };
        if !batch.is_empty() {
            stats.batches += 1;
            stats.samples += batch.len() as u64;
            let inputs: Vec<SensorInput> =
                batch.iter().map(|r| r.input.clone()).collect();
            match pipeline.infer(&inputs) {
                Ok(preds) => {
                    for (req, pred) in batch.into_iter().zip(preds) {
                        stats.latency.record(req.t_submit.elapsed());
                        let _ = req.resp.send(Ok(pred));
                    }
                }
                Err(e) => {
                    let msg = format!("{e}");
                    for req in batch {
                        let _ = req.resp.send(Err(anyhow::anyhow!("{msg}")));
                    }
                }
            }
        }
        if closing {
            break;
        }
    }
    stats.wall = t0.elapsed();
    stats
}
