//! Typed serving-path errors: every way the traffic layer can refuse or
//! fail a request, as an enum that maps 1:1 onto wire response codes.
//!
//! The serving path deliberately does **not** funnel these through
//! `anyhow` — a network frontend needs to tell a shed apart from a
//! deadline miss apart from a crashed worker *in machine-readable form*,
//! because clients react differently to each (back off and retry,
//! tighten the budget, page an operator). [`ServeError::code`] is the
//! wire status byte ([`crate::coordinator::net`] encodes/decodes the
//! per-variant payload around it); `0` on the wire means success and is
//! never a `ServeError`.

use std::fmt;

/// Wire status code of a successful response (never a `ServeError`).
pub const CODE_OK: u8 = 0;
/// Wire status code of [`ServeError::Shed`].
pub const CODE_SHED: u8 = 1;
/// Wire status code of [`ServeError::DeadlineExceeded`].
pub const CODE_DEADLINE: u8 = 2;
/// Wire status code of [`ServeError::TenantUnknown`].
pub const CODE_TENANT_UNKNOWN: u8 = 3;
/// Wire status code of [`ServeError::WorkerPanicked`].
pub const CODE_WORKER_PANICKED: u8 = 4;
/// Wire status code of [`ServeError::Protocol`].
pub const CODE_PROTOCOL: u8 = 5;
/// Wire status code of [`ServeError::AnalysisRejected`].
pub const CODE_ANALYSIS: u8 = 6;

/// A typed refusal or failure on the serving path. Every submitted
/// request is answered with exactly one `Ok` response or exactly one of
/// these — never a hang, never a silent drop.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Overload: admission control refused the request (token bucket
    /// empty, per-tenant queue full, or the server is draining). The
    /// hint tells a well-behaved client how long to back off before
    /// retrying; it is derived from real queue pressure (oldest-entry
    /// age / refill time), not a constant.
    Shed { retry_after_ms: u32 },
    /// The request's deadline expired before a worker got to it; the
    /// work was dropped at dequeue, not computed.
    DeadlineExceeded,
    /// The named tenant is not registered with this serve set.
    TenantUnknown { tenant: String },
    /// The worker computing this request panicked; the panic was
    /// contained and the request answered with the panic message.
    WorkerPanicked { reason: String },
    /// The request could not be decoded or failed validation (bad
    /// frame, wrong port count, non-finite frequency, ...).
    Protocol { detail: String },
    /// The static verifier ([`crate::analyze`]) found error-level
    /// defects in this system's compiled artifacts, so the serve set
    /// refused to boot it — serving a netlist with a combinational loop
    /// or a non-dimensionless Π unit would answer requests with garbage.
    AnalysisRejected { system: String, errors: usize },
}

impl ServeError {
    /// The wire status byte this variant encodes to (1:1, stable).
    pub fn code(&self) -> u8 {
        match self {
            ServeError::Shed { .. } => CODE_SHED,
            ServeError::DeadlineExceeded => CODE_DEADLINE,
            ServeError::TenantUnknown { .. } => CODE_TENANT_UNKNOWN,
            ServeError::WorkerPanicked { .. } => CODE_WORKER_PANICKED,
            ServeError::Protocol { .. } => CODE_PROTOCOL,
            ServeError::AnalysisRejected { .. } => CODE_ANALYSIS,
        }
    }

    /// Short stable name of the variant, for logs and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Shed { .. } => "shed",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::TenantUnknown { .. } => "tenant_unknown",
            ServeError::WorkerPanicked { .. } => "worker_panicked",
            ServeError::Protocol { .. } => "protocol",
            ServeError::AnalysisRejected { .. } => "analysis_rejected",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Shed { retry_after_ms } => {
                write!(f, "shed by admission control (retry after {retry_after_ms} ms)")
            }
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before dispatch"),
            ServeError::TenantUnknown { tenant } => write!(f, "unknown tenant `{tenant}`"),
            ServeError::WorkerPanicked { reason } => write!(f, "worker panicked: {reason}"),
            ServeError::Protocol { detail } => write!(f, "protocol error: {detail}"),
            ServeError::AnalysisRejected { system, errors } => write!(
                f,
                "system `{system}` rejected by static analysis ({errors} error-level \
                 finding(s); run `dimsynth lint {system}` for the report)"
            ),
        }
    }
}

impl From<ServeError> for anyhow::Error {
    fn from(e: ServeError) -> anyhow::Error {
        anyhow::anyhow!("{e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_distinct_and_stable() {
        let all = [
            ServeError::Shed { retry_after_ms: 5 },
            ServeError::DeadlineExceeded,
            ServeError::TenantUnknown { tenant: "x".into() },
            ServeError::WorkerPanicked { reason: "r".into() },
            ServeError::Protocol { detail: "d".into() },
            ServeError::AnalysisRejected { system: "s".into(), errors: 2 },
        ];
        let codes: Vec<u8> = all.iter().map(ServeError::code).collect();
        assert_eq!(codes, vec![1, 2, 3, 4, 5, 6]);
        for e in &all {
            assert_ne!(e.code(), CODE_OK, "{e}");
            assert!(!e.kind().is_empty());
            assert!(!e.to_string().is_empty());
        }
    }
}
