//! Deterministic fault injection for the traffic layer.
//!
//! A serving stack's robustness claims are only as good as the worst
//! traffic it has demonstrably survived, so the e2e harness and the
//! soak bench drive the engine with *planned* hostility: a
//! [`FaultPlan`] names exactly which requests are sabotaged and how,
//! keyed on `(tenant, admission sequence)` — the per-tenant sequence
//! number assigned atomically at admission
//! ([`super::admission::TenantQueues::try_admit_with`]). Because a
//! tenant's requests are admitted in submission order on one
//! connection, the same plan + the same driver seed reproduces the same
//! fault on the same request, run after run — no wall-clock races in
//! the trigger.
//!
//! Faults fire *inside* the dispatch engine, at the point the request
//! would compute: [`FaultAction::Panic`] panics on the worker (the
//! containment path under test answers `WorkerPanicked`),
//! [`FaultAction::Delay`] sleeps first (a slow tenant, for deadline and
//! fairness tests). Mid-request disconnects are driven from the client
//! side (drop the socket after reading a prefix of the responses) —
//! the server-side behavior under test is counting the disconnect and
//! absorbing the undeliverable answers.
//!
//! Lane faults ([`FaultPlan::kill_lane_at`]) target a whole dispatch
//! lane instead of one request: the lane's dispatcher thread panics
//! *outside* per-request containment on its `nth` collected batch,
//! after the batch is in flight — the exact shape of the
//! lost-answer hazard the per-lane janitor exists for. Keyed on the
//! lane's own batch counter, so the trigger is deterministic under any
//! interleaving of the other lanes.

use std::collections::HashMap;
use std::time::Duration;

/// What to do to a sabotaged request at compute time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic on the dispatch worker while computing this request.
    Panic,
    /// Sleep this long before computing (a slow tenant / slow backend).
    Delay(Duration),
}

/// A deterministic sabotage schedule for one engine run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Faults firing on one specific request: keyed by tenant name and
    /// per-tenant admission sequence (0-based).
    per_request: HashMap<(String, u64), FaultAction>,
    /// Faults firing on *every* request of a tenant.
    per_tenant: HashMap<String, FaultAction>,
    /// Lane kills: dispatch lane → the (0-based) batch number on which
    /// its dispatcher panics uncontained.
    lane_kills: HashMap<usize, u64>,
}

impl FaultPlan {
    /// The empty plan: no faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Panic while computing `tenant`'s request number `seq`.
    pub fn panic_at(mut self, tenant: &str, seq: u64) -> FaultPlan {
        self.per_request.insert((tenant.to_string(), seq), FaultAction::Panic);
        self
    }

    /// Delay `tenant`'s request number `seq` by `d`.
    pub fn delay_at(mut self, tenant: &str, seq: u64, d: Duration) -> FaultPlan {
        self.per_request.insert((tenant.to_string(), seq), FaultAction::Delay(d));
        self
    }

    /// Delay every request of `tenant` by `d` (a persistently slow
    /// tenant).
    pub fn delay_all(mut self, tenant: &str, d: Duration) -> FaultPlan {
        self.per_tenant.insert(tenant.to_string(), FaultAction::Delay(d));
        self
    }

    /// Kill dispatch lane `lane`'s dispatcher (uncontained panic) on
    /// its batch number `batch` (0-based, counted per lane).
    pub fn kill_lane_at(mut self, lane: usize, batch: u64) -> FaultPlan {
        self.lane_kills.insert(lane, batch);
        self
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.per_request.is_empty() && self.per_tenant.is_empty() && self.lane_kills.is_empty()
    }

    /// Should `lane`'s dispatcher die on its batch number `batch`?
    pub fn lane_kill(&self, lane: usize, batch: u64) -> bool {
        self.lane_kills.get(&lane) == Some(&batch)
    }

    /// The fault (if any) for `tenant`'s request `seq`. Request-specific
    /// faults shadow tenant-wide ones.
    pub fn action(&self, tenant: &str, seq: u64) -> Option<FaultAction> {
        self.per_request
            .get(&(tenant.to_string(), seq))
            .or_else(|| self.per_tenant.get(tenant))
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_injects_nothing() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.action("anyone", 0), None);
    }

    #[test]
    fn per_request_faults_key_on_tenant_and_sequence() {
        let p = FaultPlan::none()
            .panic_at("good", 17)
            .delay_at("good", 3, Duration::from_millis(5));
        assert!(!p.is_empty());
        assert_eq!(p.action("good", 17), Some(FaultAction::Panic));
        assert_eq!(p.action("good", 3), Some(FaultAction::Delay(Duration::from_millis(5))));
        assert_eq!(p.action("good", 16), None);
        assert_eq!(p.action("other", 17), None);
    }

    #[test]
    fn tenant_wide_faults_apply_everywhere_but_yield_to_specific() {
        let d = Duration::from_millis(2);
        let p = FaultPlan::none().delay_all("slow", d).panic_at("slow", 9);
        assert_eq!(p.action("slow", 0), Some(FaultAction::Delay(d)));
        assert_eq!(p.action("slow", 1_000_000), Some(FaultAction::Delay(d)));
        assert_eq!(p.action("slow", 9), Some(FaultAction::Panic), "specific shadows tenant-wide");
    }

    #[test]
    fn lane_kills_key_on_lane_and_batch_number() {
        let p = FaultPlan::none().kill_lane_at(1, 3);
        assert!(!p.is_empty());
        assert!(p.lane_kill(1, 3));
        assert!(!p.lane_kill(1, 2), "only the named batch triggers");
        assert!(!p.lane_kill(0, 3), "other lanes unaffected");
        assert_eq!(p.action("anyone", 3), None, "lane kills are not request faults");
    }
}
