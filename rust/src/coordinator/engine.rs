//! The traffic dispatch engine: admission-controlled, deadline-aware,
//! panic-containing request dispatch over one warm [`ServeSet`].
//!
//! This is the layer between the network frontend
//! ([`super::net`]) and the compute substrate: requests from any
//! transport are [`TrafficEngine::submit`]ted with a tenant identity, a
//! payload, and a deadline; they pass per-tenant admission control
//! ([`super::admission`]) and land in bounded per-tenant queues; one
//! dispatcher thread collects fair round-robin batches, drops expired
//! work *at dequeue* (answered `DeadlineExceeded`, never computed),
//! executes Π inference batches per system through the cycle-accurate
//! RTL simulator and power requests through the cross-system grouped
//! dispatch, and answers every admitted request with exactly one
//! [`TrafficReply`] — including when the computation panics
//! (`catch_unwind` → [`ServeError::WorkerPanicked`], the engine keeps
//! serving other tenants).
//!
//! Fault injection ([`super::faults::FaultPlan`]) hooks in at compute
//! time, so the e2e harness and soak bench exercise exactly these
//! containment paths deterministically.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{AdmissionConfig, Deadline, FairBatch, TenantQueues, TenantSpec};
use super::error::ServeError;
use super::faults::{FaultAction, FaultPlan};
use super::metrics::{LatencyHistogram, TenantTraffic, TrafficCounters, TrafficReport};
use super::pipeline::{
    estimate_power_requests_grouped, PowerEstimate, PowerRequest, SystemPowerRequest,
};
use super::serveset::{dispatch_flood, FusedPlan, ServeSet, SystemHandle};
use crate::rtl;
use crate::synth::LaneWidth;

/// What a traffic request asks the engine to compute.
#[derive(Clone, Debug)]
pub enum RequestPayload {
    /// Π inference on one quantized observation (port-order Q16.15 raw
    /// values), computed by the cycle-accurate RTL simulation of the
    /// tenant's synthesized hardware.
    Pi { values_q: Vec<i64> },
    /// Power estimation under one stimulus seed + clock frequency.
    Power(PowerRequest),
}

/// The engine's answer to one [`RequestPayload`].
#[derive(Clone, Debug)]
pub enum TrafficResponse {
    /// Π products plus the hardware cycles one activation costs.
    Pi { pis: Vec<i64>, hw_cycles: u64 },
    Power(PowerEstimate),
    /// Free-form text (stats/health introspection).
    Text(String),
}

/// Exactly one of these answers every submitted request.
#[derive(Clone, Debug)]
pub struct TrafficReply {
    /// Caller-chosen correlation id, echoed verbatim.
    pub id: u64,
    pub result: Result<TrafficResponse, ServeError>,
}

/// Engine tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Activations per power estimate (gate-sim stimulus length).
    pub activations: u32,
    /// Max requests per fair dispatch batch; 0 = `lanes × systems`.
    pub max_batch: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { activations: 4, max_batch: 0 }
    }
}

/// One admitted request waiting in its tenant's queue.
struct Item {
    tenant: usize,
    seq: u64,
    deadline: Deadline,
    payload: RequestPayload,
    id: u64,
    reply: Sender<TrafficReply>,
    /// Admission instant — served latency is queue-to-answer.
    t0: Instant,
}

struct MetricsState {
    tenants: Vec<(TrafficCounters, LatencyHistogram)>,
    tenant_unknown: u64,
    disconnects: u64,
    undelivered: u64,
}

/// Everything the submit path and the dispatcher share.
struct Inner {
    specs: Vec<TenantSpec>,
    /// tenant name → index into `specs` (= queue lane index).
    tenant_idx: HashMap<String, usize>,
    /// tenant index → serve-set system index.
    tenant_system: Vec<usize>,
    handles: Vec<SystemHandle>,
    /// The serve set's fused evaluation state at engine start: when
    /// present, power batches run as one sharded fused evaluation
    /// instead of per-netlist grouping (bit-identical results).
    fused: Option<Arc<FusedPlan>>,
    width: LaneWidth,
    queues: TenantQueues<Item>,
    metrics: Mutex<MetricsState>,
    faults: FaultPlan,
    default_deadline: Duration,
    activations: u32,
}

/// The running engine: admission + queues + one dispatcher thread.
pub struct TrafficEngine {
    inner: Arc<Inner>,
    worker: Mutex<Option<JoinHandle<()>>>,
    started: Instant,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn panic_reason(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

impl TrafficEngine {
    /// Validate the tenant roster against the serve set and start the
    /// dispatcher. Tenant names must be unique; every tenant's `system`
    /// must be served by `set`.
    pub fn start(
        set: &ServeSet,
        admission: AdmissionConfig,
        config: EngineConfig,
        faults: FaultPlan,
    ) -> anyhow::Result<TrafficEngine> {
        anyhow::ensure!(!admission.tenants.is_empty(), "traffic engine needs at least one tenant");
        let mut tenant_idx = HashMap::new();
        let mut tenant_system = Vec::with_capacity(admission.tenants.len());
        for (i, spec) in admission.tenants.iter().enumerate() {
            anyhow::ensure!(
                tenant_idx.insert(spec.name.clone(), i).is_none(),
                "duplicate tenant `{}`",
                spec.name
            );
            let sys = set.system_index(&spec.system).ok_or_else(|| {
                anyhow::anyhow!(
                    "tenant `{}` targets system `{}`, which this serve set does not serve",
                    spec.name,
                    spec.system
                )
            })?;
            tenant_system.push(sys);
        }
        let handles: Vec<SystemHandle> =
            (0..set.len()).map(|i| set.handle_at(i).clone()).collect();
        let max_batch = if config.max_batch == 0 {
            set.lane_width().lanes() * handles.len()
        } else {
            config.max_batch
        };
        let inner = Arc::new(Inner {
            queues: TenantQueues::new(&admission.tenants),
            metrics: Mutex::new(MetricsState {
                tenants: admission
                    .tenants
                    .iter()
                    .map(|_| (TrafficCounters::default(), LatencyHistogram::new()))
                    .collect(),
                tenant_unknown: 0,
                disconnects: 0,
                undelivered: 0,
            }),
            specs: admission.tenants,
            tenant_idx,
            tenant_system,
            handles,
            fused: set.fusion_shared(),
            width: set.lane_width(),
            faults,
            default_deadline: admission.default_deadline,
            activations: config.activations,
        });
        let worker = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("dimsynth-traffic".to_string())
                .spawn(move || dispatch_loop(&inner, max_batch))?
        };
        Ok(TrafficEngine {
            inner,
            worker: Mutex::new(Some(worker)),
            started: Instant::now(),
        })
    }

    /// Submit one request on behalf of `tenant`. On success the request
    /// is queued and **will** be answered with exactly one
    /// [`TrafficReply`] on `reply`; the returned value is the tenant's
    /// admission sequence number (what [`FaultPlan`] keys on). On
    /// `Err`, nothing was queued and **no** reply will be sent — the
    /// caller owns surfacing the error (the net frontend encodes it
    /// straight onto the wire).
    pub fn submit(
        &self,
        tenant: &str,
        payload: RequestPayload,
        deadline: Option<Duration>,
        id: u64,
        reply: Sender<TrafficReply>,
    ) -> Result<u64, ServeError> {
        let inner = &self.inner;
        let Some(&t) = inner.tenant_idx.get(tenant) else {
            lock(&inner.metrics).tenant_unknown += 1;
            return Err(ServeError::TenantUnknown { tenant: tenant.to_string() });
        };
        if let Err(e) = validate(inner, t, &payload) {
            lock(&inner.metrics).tenants[t].0.protocol_errors += 1;
            return Err(e);
        }
        let budget = deadline.unwrap_or(inner.default_deadline);
        let admitted = inner.queues.try_admit_with(t, |seq| Item {
            tenant: t,
            seq,
            deadline: Deadline::after(budget),
            payload,
            id,
            reply,
            t0: Instant::now(),
        });
        match admitted {
            Ok(seq) => {
                lock(&inner.metrics).tenants[t].0.admitted += 1;
                Ok(seq)
            }
            Err(rejection) => {
                lock(&inner.metrics).tenants[t].0.shed += 1;
                Err(ServeError::Shed { retry_after_ms: rejection.retry_after_ms() })
            }
        }
    }

    /// Count a connection that dropped mid-request (net layer).
    pub fn note_disconnect(&self) {
        lock(&self.inner.metrics).disconnects += 1;
    }

    /// Count answers that could not be delivered (net layer).
    pub fn note_undelivered(&self, n: u64) {
        lock(&self.inner.metrics).undelivered += n;
    }

    /// Live pressure of one tenant's queue (depth, oldest age).
    pub fn pressure(&self, tenant: &str) -> Option<(usize, Option<Duration>)> {
        self.inner.tenant_idx.get(tenant).map(|&t| self.inner.queues.pressure(t))
    }

    /// Live snapshot of counters, latency, and queue pressure.
    pub fn report(&self) -> TrafficReport {
        self.snapshot(false)
    }

    fn snapshot(&self, engine_panicked: bool) -> TrafficReport {
        let inner = &self.inner;
        let m = lock(&inner.metrics);
        let tenants = inner
            .specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let (depth, oldest) = inner.queues.pressure(i);
                TenantTraffic {
                    tenant: spec.name.clone(),
                    counters: m.tenants[i].0.clone(),
                    latency: m.tenants[i].1.clone(),
                    queue_depth: depth,
                    queue_oldest_ms: oldest.map(|d| d.as_millis() as u64).unwrap_or(0),
                }
            })
            .collect();
        TrafficReport {
            tenants,
            tenant_unknown: m.tenant_unknown,
            disconnects: m.disconnects,
            undelivered: m.undelivered,
            wall: self.started.elapsed(),
            engine_panicked,
        }
    }

    /// The live report, rendered (wire `stats` requests).
    pub fn stats_text(&self) -> String {
        self.report().to_string()
    }

    /// The live report, machine-readable (wire `stats` requests with
    /// the JSON format flag).
    pub fn stats_json(&self) -> String {
        self.report().to_json()
    }

    /// One-line liveness summary (wire `health` requests).
    pub fn health_text(&self) -> String {
        format!(
            "ok: {} systems, {} tenants, {} queued, up {:.1} s",
            self.inner.handles.len(),
            self.inner.specs.len(),
            self.inner.queues.total_depth(),
            self.started.elapsed().as_secs_f64()
        )
    }

    /// Graceful drain: stop admitting, let the dispatcher answer
    /// everything still queued, join it, and return the final report.
    /// If the dispatcher itself died by panic, leftover queued requests
    /// are answered `WorkerPanicked` here (the no-silent-drop invariant
    /// holds even then) and the report says so loudly.
    pub fn shutdown(&self) -> TrafficReport {
        self.inner.queues.close();
        let engine_panicked =
            matches!(lock(&self.worker).take().map(JoinHandle::join), Some(Err(_)));
        if engine_panicked {
            // Janitor sweep: the dispatcher died mid-flight, so its
            // queues may still hold admitted-but-unanswered work.
            loop {
                let batch = match self.inner.queues.collect_fair(usize::MAX) {
                    FairBatch::Closing(b) | FairBatch::Batch(b) => b,
                };
                if batch.is_empty() {
                    break;
                }
                for item in batch {
                    finish(
                        &self.inner,
                        item,
                        Err(ServeError::WorkerPanicked {
                            reason: "dispatch engine panicked".to_string(),
                        }),
                    );
                }
            }
        }
        self.snapshot(engine_panicked)
    }
}

/// Reject malformed payloads before they are admitted: wrong port
/// count or a non-physical clock can never compute, so they are
/// answered `Protocol` at the door instead of poisoning a batch.
fn validate(inner: &Inner, tenant: usize, payload: &RequestPayload) -> Result<(), ServeError> {
    let handle = &inner.handles[inner.tenant_system[tenant]];
    match payload {
        RequestPayload::Pi { values_q } => {
            let want = handle.design().num_inputs();
            if values_q.len() != want {
                return Err(ServeError::Protocol {
                    detail: format!(
                        "system `{}` has {} ports, request carries {} values",
                        handle.system(),
                        want,
                        values_q.len()
                    ),
                });
            }
        }
        RequestPayload::Power(r) => {
            if !r.f_hz.is_finite() || r.f_hz <= 0.0 {
                return Err(ServeError::Protocol {
                    detail: format!("clock frequency {} Hz is not physical", r.f_hz),
                });
            }
        }
    }
    Ok(())
}

/// Record the outcome and deliver the reply (exactly once per admitted
/// item). A receiver that has gone away is counted, not an error.
fn finish(inner: &Inner, item: Item, result: Result<TrafficResponse, ServeError>) {
    {
        let mut m = lock(&inner.metrics);
        let (counters, latency) = &mut m.tenants[item.tenant];
        match &result {
            Ok(_) => {
                counters.served += 1;
                latency.record(item.t0.elapsed());
            }
            Err(ServeError::DeadlineExceeded) => counters.deadline_expired += 1,
            Err(ServeError::WorkerPanicked { .. }) => counters.panicked += 1,
            // Post-admission items only fail in the two ways above.
            Err(_) => {}
        }
    }
    if item.reply.send(TrafficReply { id: item.id, result }).is_err() {
        lock(&inner.metrics).undelivered += 1;
    }
}

fn dispatch_loop(inner: &Inner, max_batch: usize) {
    loop {
        let batch = match inner.queues.collect_fair(max_batch) {
            FairBatch::Batch(b) => b,
            // Draining: process leftovers until the empty batch that
            // signals full drain.
            FairBatch::Closing(b) => {
                if b.is_empty() {
                    return;
                }
                b
            }
        };
        process_batch(inner, batch);
    }
}

fn process_batch(inner: &Inner, batch: Vec<Item>) {
    // Partition at dequeue: expired work is answered, never computed;
    // fault-flagged work computes individually so an injected panic
    // takes down exactly one request; the rest batches per kind.
    let mut pi_by_system: HashMap<usize, Vec<Item>> = HashMap::new();
    let mut power_items: Vec<Item> = Vec::new();
    for item in batch {
        if item.deadline.expired() {
            finish(inner, item, Err(ServeError::DeadlineExceeded));
            continue;
        }
        let tenant_name = &inner.specs[item.tenant].name;
        if let Some(action) = inner.faults.action(tenant_name, item.seq) {
            compute_faulted(inner, item, action);
            continue;
        }
        match item.payload {
            RequestPayload::Pi { .. } => pi_by_system
                .entry(inner.tenant_system[item.tenant])
                .or_default()
                .push(item),
            RequestPayload::Power(_) => power_items.push(item),
        }
    }

    // Π inference: one cycle-accurate batch per target system.
    let mut systems: Vec<usize> = pi_by_system.keys().copied().collect();
    systems.sort_unstable(); // deterministic dispatch order
    for sys in systems {
        let items = pi_by_system.remove(&sys).unwrap();
        let design = inner.handles[sys].design();
        let samples: Vec<&[i64]> = items
            .iter()
            .map(|i| match &i.payload {
                RequestPayload::Pi { values_q } => values_q.as_slice(),
                RequestPayload::Power(_) => unreachable!("partitioned above"),
            })
            .collect();
        let outcome = catch_unwind(AssertUnwindSafe(|| rtl::run_batch(design, &samples)));
        match outcome {
            Ok(result) => {
                for (item, pis) in items.into_iter().zip(result.outputs) {
                    finish(
                        inner,
                        item,
                        Ok(TrafficResponse::Pi { pis, hw_cycles: result.cycles_per_sample }),
                    );
                }
            }
            Err(e) => {
                let reason = panic_reason(e);
                for item in items {
                    finish(
                        inner,
                        item,
                        Err(ServeError::WorkerPanicked { reason: reason.clone() }),
                    );
                }
            }
        }
    }

    // Power estimation: one cross-system dispatch for the whole batch —
    // the sharded fused evaluation when the serve set enabled fusion,
    // else per-netlist grouping (the lane-packing path the shared
    // frontend exists for). The two are bit-identical.
    if !power_items.is_empty() {
        let tagged: Vec<SystemPowerRequest> = power_items
            .iter()
            .map(|i| match &i.payload {
                RequestPayload::Power(r) => SystemPowerRequest {
                    system: inner.tenant_system[i.tenant],
                    request: *r,
                },
                RequestPayload::Pi { .. } => unreachable!("partitioned above"),
            })
            .collect();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            dispatch_flood(
                &inner.handles,
                inner.fused.as_deref(),
                &tagged,
                inner.activations,
                inner.width,
            )
        }));
        match outcome {
            Ok(estimates) => {
                for (item, est) in power_items.into_iter().zip(estimates) {
                    finish(inner, item, Ok(TrafficResponse::Power(est)));
                }
            }
            Err(e) => {
                let reason = panic_reason(e);
                for item in power_items {
                    finish(
                        inner,
                        item,
                        Err(ServeError::WorkerPanicked { reason: reason.clone() }),
                    );
                }
            }
        }
    }
}

/// Compute one fault-flagged request in isolation. A `Delay` sleeps
/// first (the slow-tenant injection) and re-checks the deadline after —
/// still "dropped before compute". A `Panic` fires inside the same
/// containment the real compute runs under.
fn compute_faulted(inner: &Inner, item: Item, action: FaultAction) {
    if let FaultAction::Delay(d) = action {
        std::thread::sleep(d);
        if item.deadline.expired() {
            finish(inner, item, Err(ServeError::DeadlineExceeded));
            return;
        }
    }
    let sys = inner.tenant_system[item.tenant];
    let handle = &inner.handles[sys];
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if action == FaultAction::Panic {
            panic!(
                "injected fault: tenant `{}` request {}",
                inner.specs[item.tenant].name, item.seq
            );
        }
        match &item.payload {
            RequestPayload::Pi { values_q } => {
                let result = rtl::run_batch(handle.design(), &[values_q.as_slice()]);
                TrafficResponse::Pi {
                    pis: result.outputs.into_iter().next().unwrap_or_default(),
                    hw_cycles: result.cycles_per_sample,
                }
            }
            RequestPayload::Power(r) => {
                let targets = [(handle.netlist(), handle.design())];
                let tagged = [SystemPowerRequest { system: 0, request: *r }];
                let est =
                    estimate_power_requests_grouped(&targets, &tagged, inner.activations, inner.width)
                        .into_iter()
                        .next()
                        .expect("one estimate per request");
                TrafficResponse::Power(est)
            }
        }
    }));
    match outcome {
        Ok(resp) => finish(inner, item, Ok(resp)),
        Err(e) => {
            finish(inner, item, Err(ServeError::WorkerPanicked { reason: panic_reason(e) }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::flow::FlowConfig;
    use std::sync::mpsc;

    fn boot_engine(
        tenants: Vec<TenantSpec>,
        faults: FaultPlan,
    ) -> (ServeSet, TrafficEngine) {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let engine = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants, default_deadline: Duration::from_secs(5) },
            EngineConfig::default(),
            faults,
        )
        .unwrap();
        (set, engine)
    }

    fn pi_payload(set: &ServeSet) -> RequestPayload {
        let n = set.handle_at(0).design().num_inputs();
        RequestPayload::Pi {
            values_q: (0..n).map(|i| Q16_15.from_f64(0.75 + 0.5 * i as f64)).collect(),
        }
    }

    #[test]
    fn start_rejects_bad_rosters() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let err = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants: vec![], default_deadline: Duration::from_secs(1) },
            EngineConfig::default(),
            FaultPlan::none(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("at least one tenant"), "{err}");
        let dup = AdmissionConfig {
            tenants: vec![TenantSpec::new("a", "pendulum"), TenantSpec::new("a", "pendulum")],
            default_deadline: Duration::from_secs(1),
        };
        let err = TrafficEngine::start(&set, dup, EngineConfig::default(), FaultPlan::none())
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate tenant"), "{err}");
        let missing = AdmissionConfig {
            tenants: vec![TenantSpec::new("a", "beam")],
            default_deadline: Duration::from_secs(1),
        };
        let err =
            TrafficEngine::start(&set, missing, EngineConfig::default(), FaultPlan::none())
                .unwrap_err()
                .to_string();
        assert!(err.contains("beam"), "{err}");
    }

    #[test]
    fn serves_pi_and_power_with_typed_refusals() {
        let (set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        let (tx, rx) = mpsc::channel();

        // Unknown tenant: typed, no reply promised.
        let err = engine
            .submit("ghost", pi_payload(&set), None, 1, tx.clone())
            .unwrap_err();
        assert!(matches!(err, ServeError::TenantUnknown { .. }));

        // Malformed Π request: wrong port count.
        let err = engine
            .submit("t", RequestPayload::Pi { values_q: vec![1] }, None, 2, tx.clone())
            .unwrap_err();
        assert!(matches!(err, ServeError::Protocol { .. }));

        // Malformed power request: non-physical clock.
        let err = engine
            .submit(
                "t",
                RequestPayload::Power(PowerRequest { seed: 1, f_hz: f64::NAN }),
                None,
                3,
                tx.clone(),
            )
            .unwrap_err();
        assert!(matches!(err, ServeError::Protocol { .. }));

        // A well-formed Π request is served with hardware cycles.
        engine.submit("t", pi_payload(&set), None, 10, tx.clone()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(reply.id, 10);
        match reply.result.unwrap() {
            TrafficResponse::Pi { pis, hw_cycles } => {
                assert_eq!(pis.len(), set.handle_at(0).design().num_outputs());
                assert!(hw_cycles > 0);
            }
            other => panic!("expected Pi, got {other:?}"),
        }

        // A power request runs through the grouped dispatch.
        engine
            .submit(
                "t",
                RequestPayload::Power(PowerRequest { seed: 7, f_hz: 6.0e6 }),
                None,
                11,
                tx,
            )
            .unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        match reply.result.unwrap() {
            TrafficResponse::Power(est) => {
                assert!(est.mw > 0.0);
                assert!(est.cycles > 0);
            }
            other => panic!("expected Power, got {other:?}"),
        }

        let report = engine.shutdown();
        assert!(!report.engine_panicked);
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.served, 2);
        assert_eq!(t.counters.admitted, 2);
        assert_eq!(t.counters.protocol_errors, 2);
        assert_eq!(t.counters.terminal(), t.counters.admitted);
        assert_eq!(report.tenant_unknown, 1);
    }

    #[test]
    fn injected_panic_is_contained_and_typed() {
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")],
            FaultPlan::none().panic_at("t", 1),
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..3u64 {
            engine.submit("t", pi_payload(&set), None, id, tx.clone()).unwrap();
        }
        let mut ok = 0;
        let mut panicked = 0;
        for _ in 0..3 {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            match reply.result {
                Ok(_) => ok += 1,
                Err(ServeError::WorkerPanicked { reason }) => {
                    assert!(reason.contains("injected fault"), "{reason}");
                    assert_eq!(reply.id, 1, "the fault keys on admission seq 1");
                    panicked += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!((ok, panicked), (2, 1));
        // The engine survived: it still serves after the panic.
        engine.submit("t", pi_payload(&set), None, 99, tx).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        let report = engine.shutdown();
        assert!(!report.engine_panicked);
        assert_eq!(report.tenant("t").unwrap().counters.panicked, 1);
    }

    #[test]
    fn expired_work_is_dropped_at_dequeue_not_computed() {
        // A 3 ms tenant-wide delay against a 1 ms budget: the first
        // request's sleep expires its own deadline, and everything
        // queued behind it ages out too.
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")],
            FaultPlan::none().delay_all("t", Duration::from_millis(3)),
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..4u64 {
            engine
                .submit("t", pi_payload(&set), Some(Duration::from_millis(1)), id, tx.clone())
                .unwrap();
        }
        for _ in 0..4 {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(reply.result.unwrap_err(), ServeError::DeadlineExceeded);
        }
        let report = engine.shutdown();
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.deadline_expired, 4);
        assert_eq!(t.counters.terminal(), t.counters.admitted);
    }

    #[test]
    fn queue_cap_sheds_and_drain_answers_everything() {
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")
                .with_queue_cap(2)
                .with_rate(f64::INFINITY, 1.0)],
            // Slow every request down so the queue actually fills.
            FaultPlan::none().delay_all("t", Duration::from_millis(20)),
        );
        let (tx, rx) = mpsc::channel();
        let mut admitted = 0u64;
        let mut shed = 0u64;
        for id in 0..40u64 {
            match engine.submit("t", pi_payload(&set), None, id, tx.clone()) {
                Ok(_) => admitted += 1,
                Err(ServeError::Shed { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1);
                    shed += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "40 instant submits against a cap-2 queue must shed");
        // Graceful drain: every admitted request still gets its answer.
        let report = engine.shutdown();
        let mut answered = 0;
        while rx.try_recv().is_ok() {
            answered += 1;
        }
        assert_eq!(answered, admitted);
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.admitted, admitted);
        assert_eq!(t.counters.shed, shed);
        assert_eq!(t.counters.terminal(), admitted);
        assert_eq!(t.queue_depth, 0, "drain leaves nothing queued");
    }

    #[test]
    fn draining_engine_sheds_new_work_with_zero_hint() {
        let (set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        engine.shutdown();
        let (tx, _rx) = mpsc::channel();
        match engine.submit("t", pi_payload(&set), None, 1, tx) {
            Err(ServeError::Shed { retry_after_ms }) => assert_eq!(retry_after_ms, 0),
            other => panic!("expected Shed, got {other:?}"),
        }
    }

    /// An engine started on a fusion-enabled set must answer power
    /// requests through the sharded fused evaluation with estimates
    /// bit-identical to the grouped path.
    #[test]
    fn fused_engine_power_matches_grouped_engine_power() {
        let mut answers = Vec::new();
        for fuse in [false, true] {
            let mut set =
                ServeSet::boot(&["pendulum", "spring_mass"], FlowConfig::default(), None)
                    .unwrap();
            if fuse {
                set.enable_fusion(2);
            }
            let engine = TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&set.systems()),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap();
            let (tx, rx) = mpsc::channel();
            for (id, tenant) in [(0u64, "pendulum"), (1, "spring_mass"), (2, "pendulum")] {
                engine
                    .submit(
                        tenant,
                        RequestPayload::Power(PowerRequest {
                            seed: 0xCAFE + id as u32,
                            f_hz: 6.0e6,
                        }),
                        None,
                        id,
                        tx.clone(),
                    )
                    .unwrap();
            }
            let mut got: Vec<(u64, f64, u64)> = (0..3)
                .map(|_| {
                    let reply = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                    match reply.result.unwrap() {
                        TrafficResponse::Power(est) => (reply.id, est.mw, est.cycles),
                        other => panic!("expected Power, got {other:?}"),
                    }
                })
                .collect();
            got.sort_by_key(|&(id, ..)| id);
            answers.push(got);
            engine.shutdown();
        }
        assert_eq!(answers[0], answers[1], "fused engine must match grouped engine");
    }

    #[test]
    fn health_and_stats_are_live() {
        let (_set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        assert!(engine.health_text().starts_with("ok:"));
        assert!(engine.stats_text().contains("admitted"));
        assert_eq!(engine.pressure("t").unwrap().0, 0);
        assert!(engine.pressure("ghost").is_none());
        engine.shutdown();
    }
}
