//! The traffic dispatch engine: admission-controlled, deadline-aware,
//! panic-containing request dispatch over one warm [`ServeSet`],
//! parallelized across `K` dispatch lanes.
//!
//! This is the layer between the network frontend
//! ([`super::net`]) and the compute substrate: requests from any
//! transport are [`TrafficEngine::submit`]ted with a tenant identity, a
//! payload, and a deadline; they pass per-tenant admission control
//! ([`super::admission`]) and land in bounded per-tenant queues. The
//! queues are sharded across `K` **dispatch lanes**
//! ([`EngineConfig::dispatchers`]); each lane runs its own dispatcher
//! thread with a private fair round-robin cursor over only its
//! tenants' queues, so Π compute for different lanes proceeds on
//! different cores. Each dispatcher drops expired work *at dequeue*
//! (answered `DeadlineExceeded`, never computed), executes Π inference
//! batches per system through the cycle-accurate RTL simulator, and
//! routes power requests through the cross-system flood dispatch —
//! power floods already fan out over every core, so concurrent lanes
//! arbitrate them through the serve set's shared
//! [`FloodGate`](super::serveset::FloodGate) instead of oversubscribing
//! the machine.
//!
//! Every admitted request is answered with exactly one
//! [`TrafficReply`] — including when the computation panics
//! (`catch_unwind` → [`ServeError::WorkerPanicked`], the engine keeps
//! serving other tenants), and including when a whole dispatcher
//! thread dies: each lane publishes its in-flight batch into a
//! holding cell ([`BatchGuard`]) before computing, so an uncaught
//! panic strands nothing silently — the per-lane janitor in
//! [`TrafficEngine::shutdown`] sweeps the dead lane's in-flight and
//! queued work (answering `WorkerPanicked`) without disturbing live
//! lanes.
//!
//! Fault injection ([`super::faults::FaultPlan`]) hooks in at compute
//! time — and, for lane kills, at batch-collect time — so the e2e
//! harness and soak bench exercise exactly these containment paths
//! deterministically.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{AdmissionConfig, Deadline, FairBatch, TenantQueues, TenantSpec};
use super::error::ServeError;
use super::faults::{FaultAction, FaultPlan};
use super::metrics::{
    AtomicLatencyHistogram, AtomicTrafficCounters, LaneTraffic, TenantTraffic, TrafficReport,
};
use super::pipeline::{
    estimate_power_requests_grouped, PowerEstimate, PowerRequest, SystemPowerRequest,
};
use super::serveset::{dispatch_flood, FloodGate, FusedPlan, ServeSet, SystemHandle};
use crate::rtl;
use crate::synth::LaneWidth;

/// What a traffic request asks the engine to compute.
#[derive(Clone, Debug)]
pub enum RequestPayload {
    /// Π inference on one quantized observation (port-order Q16.15 raw
    /// values), computed by the cycle-accurate RTL simulation of the
    /// tenant's synthesized hardware.
    Pi { values_q: Vec<i64> },
    /// Power estimation under one stimulus seed + clock frequency.
    Power(PowerRequest),
}

/// The engine's answer to one [`RequestPayload`].
#[derive(Clone, Debug)]
pub enum TrafficResponse {
    /// Π products plus the hardware cycles one activation costs.
    Pi { pis: Vec<i64>, hw_cycles: u64 },
    Power(PowerEstimate),
    /// Free-form text (stats/health introspection).
    Text(String),
}

/// Exactly one of these answers every submitted request.
#[derive(Clone, Debug)]
pub struct TrafficReply {
    /// Caller-chosen correlation id, echoed verbatim.
    pub id: u64,
    pub result: Result<TrafficResponse, ServeError>,
}

/// Engine tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Activations per power estimate (gate-sim stimulus length).
    pub activations: u32,
    /// Max requests per fair dispatch batch (per lane); 0 = `lanes ×
    /// systems`.
    pub max_batch: usize,
    /// Dispatch lanes (dispatcher threads); clamped to `[1, tenants]`.
    /// Tenants are hash-sharded across lanes unless pinned
    /// ([`TenantSpec::with_lane`]).
    pub dispatchers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { activations: 4, max_batch: 0, dispatchers: 1 }
    }
}

/// One admitted request waiting in its tenant's queue.
struct Item {
    tenant: usize,
    seq: u64,
    deadline: Deadline,
    payload: RequestPayload,
    id: u64,
    reply: Sender<TrafficReply>,
    /// Admission instant — served latency is queue-to-answer.
    t0: Instant,
}

/// One tenant's lock-free metrics shard. A tenant lives in exactly one
/// lane, so its shard is written by one dispatcher plus the submit
/// path; the scrape folds shards into a [`TrafficReport`] without ever
/// blocking the hot path.
struct TenantShard {
    counters: AtomicTrafficCounters,
    latency: AtomicLatencyHistogram,
}

/// One dispatch lane's runtime state shared between its dispatcher, the
/// submit path, and the shutdown janitor.
struct LaneState {
    /// In-flight items stranded by an uncaught dispatcher panic
    /// ([`BatchGuard`] moves them here on unwind). Swept by the
    /// per-lane janitor after the lane's thread is joined.
    orphans: Mutex<Vec<Item>>,
    /// Batches this lane's dispatcher has collected.
    batches: AtomicU64,
    /// Items dequeued into those batches.
    items: AtomicU64,
    /// The lane's dispatcher died by panic.
    panicked: AtomicBool,
}

/// Everything the submit path and the dispatchers share.
struct Inner {
    specs: Vec<TenantSpec>,
    /// tenant name → index into `specs` (= queue index).
    tenant_idx: HashMap<String, usize>,
    /// tenant index → serve-set system index.
    tenant_system: Vec<usize>,
    handles: Vec<SystemHandle>,
    /// The serve set's fused evaluation state at engine start: when
    /// present, power batches run as one sharded fused evaluation
    /// instead of per-netlist grouping (bit-identical results).
    fused: Option<Arc<FusedPlan>>,
    width: LaneWidth,
    queues: TenantQueues<Item>,
    tenant_shards: Vec<TenantShard>,
    lane_states: Vec<LaneState>,
    tenant_unknown: AtomicU64,
    disconnects: AtomicU64,
    undelivered: AtomicU64,
    /// Whole-machine power floods serialize across lanes through the
    /// serve set's shared gate (each flood already fans over all
    /// cores); Π batches run un-gated, which is where lane parallelism
    /// pays.
    flood_gate: Arc<FloodGate>,
    faults: FaultPlan,
    default_deadline: Duration,
    activations: u32,
}

/// The running engine: admission + sharded queues + K dispatcher
/// threads.
pub struct TrafficEngine {
    inner: Arc<Inner>,
    workers: Mutex<Vec<Option<JoinHandle<()>>>>,
    started: Instant,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn panic_reason(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Marks its lane panicked if the dispatcher thread unwinds — dropped
/// on every exit path, but only a panicking exit sets the flag.
struct LanePanicSentinel {
    inner: Arc<Inner>,
    lane: usize,
}

impl Drop for LanePanicSentinel {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.inner.lane_states[self.lane].panicked.store(true, SeqCst);
        }
    }
}

impl TrafficEngine {
    /// Validate the tenant roster against the serve set and start the
    /// dispatch lanes. Tenant names must be unique; every tenant's
    /// `system` must be served by `set`.
    pub fn start(
        set: &ServeSet,
        admission: AdmissionConfig,
        config: EngineConfig,
        faults: FaultPlan,
    ) -> anyhow::Result<TrafficEngine> {
        anyhow::ensure!(!admission.tenants.is_empty(), "traffic engine needs at least one tenant");
        let mut tenant_idx = HashMap::new();
        let mut tenant_system = Vec::with_capacity(admission.tenants.len());
        for (i, spec) in admission.tenants.iter().enumerate() {
            anyhow::ensure!(
                tenant_idx.insert(spec.name.clone(), i).is_none(),
                "duplicate tenant `{}`",
                spec.name
            );
            let sys = set.system_index(&spec.system).ok_or_else(|| {
                anyhow::anyhow!(
                    "tenant `{}` targets system `{}`, which this serve set does not serve",
                    spec.name,
                    spec.system
                )
            })?;
            tenant_system.push(sys);
        }
        let handles: Vec<SystemHandle> =
            (0..set.len()).map(|i| set.handle_at(i).clone()).collect();
        let max_batch = if config.max_batch == 0 {
            set.lane_width().lanes() * handles.len()
        } else {
            config.max_batch
        };
        // More lanes than tenants would leave dispatchers with nothing
        // to ever collect; fewer than one is meaningless.
        let k = config.dispatchers.clamp(1, admission.tenants.len());
        let inner = Arc::new(Inner {
            queues: TenantQueues::new(&admission.tenants, k),
            tenant_shards: admission
                .tenants
                .iter()
                .map(|_| TenantShard {
                    counters: AtomicTrafficCounters::new(),
                    latency: AtomicLatencyHistogram::new(),
                })
                .collect(),
            lane_states: (0..k)
                .map(|_| LaneState {
                    orphans: Mutex::new(Vec::new()),
                    batches: AtomicU64::new(0),
                    items: AtomicU64::new(0),
                    panicked: AtomicBool::new(false),
                })
                .collect(),
            tenant_unknown: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            undelivered: AtomicU64::new(0),
            specs: admission.tenants,
            tenant_idx,
            tenant_system,
            handles,
            fused: set.fusion_shared(),
            width: set.lane_width(),
            flood_gate: set.flood_gate(),
            faults,
            default_deadline: admission.default_deadline,
            activations: config.activations,
        });
        let mut workers = Vec::with_capacity(k);
        for lane in 0..k {
            let inner = inner.clone();
            workers.push(Some(
                std::thread::Builder::new()
                    .name(format!("dimsynth-dispatch-{lane}"))
                    .spawn(move || {
                        let _sentinel =
                            LanePanicSentinel { inner: inner.clone(), lane };
                        dispatch_loop(&inner, lane, max_batch);
                    })?,
            ));
        }
        Ok(TrafficEngine {
            inner,
            workers: Mutex::new(workers),
            started: Instant::now(),
        })
    }

    /// Number of dispatch lanes this engine runs.
    pub fn lane_count(&self) -> usize {
        self.inner.queues.lane_count()
    }

    /// Submit one request on behalf of `tenant`. On success the request
    /// is queued and **will** be answered with exactly one
    /// [`TrafficReply`] on `reply`; the returned value is the tenant's
    /// admission sequence number (what [`FaultPlan`] keys on). On
    /// `Err`, nothing was queued and **no** reply will be sent — the
    /// caller owns surfacing the error (the net frontend encodes it
    /// straight onto the wire).
    pub fn submit(
        &self,
        tenant: &str,
        payload: RequestPayload,
        deadline: Option<Duration>,
        id: u64,
        reply: Sender<TrafficReply>,
    ) -> Result<u64, ServeError> {
        let inner = &self.inner;
        let Some(&t) = inner.tenant_idx.get(tenant) else {
            inner.tenant_unknown.fetch_add(1, Relaxed);
            return Err(ServeError::TenantUnknown { tenant: tenant.to_string() });
        };
        if let Err(e) = validate(inner, t, &payload) {
            inner.tenant_shards[t].counters.protocol_errors.fetch_add(1, Relaxed);
            return Err(e);
        }
        let budget = deadline.unwrap_or(inner.default_deadline);
        let admitted = inner.queues.try_admit_with(t, |seq| Item {
            tenant: t,
            seq,
            deadline: Deadline::after(budget),
            payload,
            id,
            reply,
            t0: Instant::now(),
        });
        match admitted {
            Ok(seq) => {
                inner.tenant_shards[t].counters.admitted.fetch_add(1, Relaxed);
                Ok(seq)
            }
            Err(rejection) => {
                inner.tenant_shards[t].counters.shed.fetch_add(1, Relaxed);
                Err(ServeError::Shed { retry_after_ms: rejection.retry_after_ms() })
            }
        }
    }

    /// Count a connection that dropped mid-request (net layer).
    pub fn note_disconnect(&self) {
        self.inner.disconnects.fetch_add(1, Relaxed);
    }

    /// Count answers that could not be delivered (net layer).
    pub fn note_undelivered(&self, n: u64) {
        self.inner.undelivered.fetch_add(n, Relaxed);
    }

    /// Live pressure of one tenant's queue (depth, oldest age).
    pub fn pressure(&self, tenant: &str) -> Option<(usize, Option<Duration>)> {
        self.inner.tenant_idx.get(tenant).map(|&t| self.inner.queues.pressure(t))
    }

    /// Live snapshot of counters, latency, queue pressure, and lane
    /// activity — folds the lock-free shards, blocks no dispatcher.
    pub fn report(&self) -> TrafficReport {
        self.snapshot()
    }

    fn snapshot(&self) -> TrafficReport {
        let inner = &self.inner;
        let tenants = inner
            .specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let (depth, oldest) = inner.queues.pressure(i);
                TenantTraffic {
                    tenant: spec.name.clone(),
                    counters: inner.tenant_shards[i].counters.snapshot(),
                    latency: inner.tenant_shards[i].latency.snapshot(),
                    queue_depth: depth,
                    queue_oldest_ms: oldest.map(|d| d.as_millis() as u64).unwrap_or(0),
                }
            })
            .collect();
        let lanes: Vec<LaneTraffic> = inner
            .lane_states
            .iter()
            .enumerate()
            .map(|(l, s)| LaneTraffic {
                lane: l,
                tenants: inner
                    .queues
                    .lane_members(l)
                    .iter()
                    .map(|&t| inner.specs[t].name.clone())
                    .collect(),
                batches: s.batches.load(Relaxed),
                items: s.items.load(Relaxed),
                panicked: s.panicked.load(SeqCst),
            })
            .collect();
        let engine_panicked = lanes.iter().any(|l| l.panicked);
        TrafficReport {
            tenants,
            lanes,
            tenant_unknown: inner.tenant_unknown.load(Relaxed),
            disconnects: inner.disconnects.load(Relaxed),
            undelivered: inner.undelivered.load(Relaxed),
            wall: self.started.elapsed(),
            engine_panicked,
        }
    }

    /// The live report, rendered (wire `stats` requests).
    pub fn stats_text(&self) -> String {
        self.report().to_string()
    }

    /// The live report, machine-readable (wire `stats` requests with
    /// the JSON format flag).
    pub fn stats_json(&self) -> String {
        self.report().to_json()
    }

    /// One-line liveness summary (wire `health` requests).
    pub fn health_text(&self) -> String {
        format!(
            "ok: {} systems, {} tenants, {} lanes, {} queued, up {:.1} s",
            self.inner.handles.len(),
            self.inner.specs.len(),
            self.inner.queues.lane_count(),
            self.inner.queues.total_depth(),
            self.started.elapsed().as_secs_f64()
        )
    }

    /// Graceful drain: stop admitting, let every lane's dispatcher
    /// answer what is still queued, join them, and return the final
    /// report. Lanes have independent lifecycles: each is joined and
    /// then janitor-swept on its own — a lane whose dispatcher died by
    /// panic has its in-flight batch (stranded in the lane's holding
    /// cell) and queued leftovers answered `WorkerPanicked` here, while
    /// live lanes drain themselves undisturbed. The no-silent-drop
    /// invariant holds per lane, not just globally.
    pub fn shutdown(&self) -> TrafficReport {
        self.inner.queues.close();
        let handles: Vec<Option<JoinHandle<()>>> = {
            let mut w = lock(&self.workers);
            w.iter_mut().map(Option::take).collect()
        };
        for (lane, handle) in handles.into_iter().enumerate() {
            if matches!(handle.map(JoinHandle::join), Some(Err(_))) {
                // Redundant with the sentinel, but keeps the flag
                // truthful even if the unwind skipped it.
                self.inner.lane_states[lane].panicked.store(true, SeqCst);
            }
            // Per-lane janitor. Runs strictly after this lane's join,
            // so it can never race the dispatcher into a double answer;
            // for a cleanly drained lane both sweeps are no-ops.
            sweep_lane(&self.inner, lane);
        }
        self.snapshot()
    }
}

/// Answer everything a dead lane left behind: first the in-flight batch
/// its [`BatchGuard`] moved to the holding cell, then whatever was
/// still queued. Only this lane's queues are touched.
fn sweep_lane(inner: &Inner, lane: usize) {
    let reason = || ServeError::WorkerPanicked {
        reason: format!("dispatch lane {lane} panicked"),
    };
    let orphans: Vec<Item> = std::mem::take(&mut *lock(&inner.lane_states[lane].orphans));
    for item in orphans {
        finish(inner, item, Err(reason()));
    }
    loop {
        let batch = match inner.queues.collect_fair(lane, usize::MAX) {
            FairBatch::Closing(b) | FairBatch::Batch(b) => b,
        };
        if batch.is_empty() {
            break;
        }
        for item in batch {
            finish(inner, item, Err(reason()));
        }
    }
}

/// Reject malformed payloads before they are admitted: wrong port
/// count or a non-physical clock can never compute, so they are
/// answered `Protocol` at the door instead of poisoning a batch.
fn validate(inner: &Inner, tenant: usize, payload: &RequestPayload) -> Result<(), ServeError> {
    let handle = &inner.handles[inner.tenant_system[tenant]];
    match payload {
        RequestPayload::Pi { values_q } => {
            let want = handle.design().num_inputs();
            if values_q.len() != want {
                return Err(ServeError::Protocol {
                    detail: format!(
                        "system `{}` has {} ports, request carries {} values",
                        handle.system(),
                        want,
                        values_q.len()
                    ),
                });
            }
        }
        RequestPayload::Power(r) => {
            if !r.f_hz.is_finite() || r.f_hz <= 0.0 {
                return Err(ServeError::Protocol {
                    detail: format!("clock frequency {} Hz is not physical", r.f_hz),
                });
            }
        }
    }
    Ok(())
}

/// Record the outcome and deliver the reply (exactly once per admitted
/// item). A receiver that has gone away is counted, not an error.
fn finish(inner: &Inner, item: Item, result: Result<TrafficResponse, ServeError>) {
    let shard = &inner.tenant_shards[item.tenant];
    match &result {
        Ok(_) => {
            shard.counters.served.fetch_add(1, Relaxed);
            shard.latency.record(item.t0.elapsed());
        }
        Err(ServeError::DeadlineExceeded) => {
            shard.counters.deadline_expired.fetch_add(1, Relaxed);
        }
        Err(ServeError::WorkerPanicked { .. }) => {
            shard.counters.panicked.fetch_add(1, Relaxed);
        }
        // Post-admission items only fail in the two ways above.
        Err(_) => {}
    }
    if item.reply.send(TrafficReply { id: item.id, result }).is_err() {
        inner.undelivered.fetch_add(1, Relaxed);
    }
}

/// The collected batch, published for crash recovery while it is in
/// flight. Items leave through [`BatchGuard::finish`]/[`take`] exactly
/// once; anything still inside when the guard drops *during an unwind*
/// is moved to the lane's orphan cell for the shutdown janitor — an
/// uncaught dispatcher panic can strand work, never lose it. (On a
/// clean exit the guard is empty and the drop is a no-op.)
struct BatchGuard<'a> {
    inner: &'a Inner,
    lane: usize,
    items: Vec<Option<Item>>,
}

impl<'a> BatchGuard<'a> {
    fn new(inner: &'a Inner, lane: usize, batch: Vec<Item>) -> BatchGuard<'a> {
        BatchGuard { inner, lane, items: batch.into_iter().map(Some).collect() }
    }

    fn len(&self) -> usize {
        self.items.len()
    }

    /// Borrow item `i` (must not have been finished or taken yet).
    fn get(&self, i: usize) -> &Item {
        self.items[i].as_ref().expect("item already finished")
    }

    /// Remove item `i` for individually-contained processing.
    fn take(&mut self, i: usize) -> Item {
        self.items[i].take().expect("item already finished")
    }

    /// Answer item `i` and release it from the guard.
    fn finish(&mut self, i: usize, result: Result<TrafficResponse, ServeError>) {
        let item = self.take(i);
        finish(self.inner, item, result);
    }
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        let leftovers: Vec<Item> = self.items.drain(..).flatten().collect();
        if !leftovers.is_empty() {
            lock(&self.inner.lane_states[self.lane].orphans).extend(leftovers);
        }
    }
}

fn dispatch_loop(inner: &Inner, lane: usize, max_batch: usize) {
    let mut batch_no: u64 = 0;
    loop {
        let batch = match inner.queues.collect_fair(lane, max_batch) {
            FairBatch::Batch(b) => b,
            // Draining: process leftovers until the empty batch that
            // signals full drain.
            FairBatch::Closing(b) => {
                if b.is_empty() {
                    return;
                }
                b
            }
        };
        let state = &inner.lane_states[lane];
        state.batches.fetch_add(1, Relaxed);
        state.items.fetch_add(batch.len() as u64, Relaxed);
        // Publish before computing: from here on an uncaught panic
        // strands the items in the orphan cell instead of losing them.
        let guard = BatchGuard::new(inner, lane, batch);
        if inner.faults.lane_kill(lane, batch_no) {
            // Deliberately uncontained — this is the dispatcher-death
            // drill the per-lane janitor exists for.
            panic!("injected lane fault: lane {lane} killed on batch {batch_no}");
        }
        batch_no += 1;
        process_batch(inner, guard);
    }
}

fn process_batch(inner: &Inner, mut g: BatchGuard<'_>) {
    // Partition at dequeue: expired work is answered, never computed;
    // fault-flagged work computes individually so an injected panic
    // takes down exactly one request; the rest batches per kind.
    let mut pi_by_system: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut power_idx: Vec<usize> = Vec::new();
    for i in 0..g.len() {
        if g.get(i).deadline.expired() {
            g.finish(i, Err(ServeError::DeadlineExceeded));
            continue;
        }
        let item = g.get(i);
        let tenant_name = &inner.specs[item.tenant].name;
        if let Some(action) = inner.faults.action(tenant_name, item.seq) {
            let item = g.take(i);
            compute_faulted(inner, item, action);
            continue;
        }
        match g.get(i).payload {
            RequestPayload::Pi { .. } => pi_by_system
                .entry(inner.tenant_system[g.get(i).tenant])
                .or_default()
                .push(i),
            RequestPayload::Power(_) => power_idx.push(i),
        }
    }

    // Π inference: one cycle-accurate batch per target system. Runs
    // un-gated — each batch is single-threaded, so concurrent lanes
    // genuinely parallelize here.
    let mut systems: Vec<usize> = pi_by_system.keys().copied().collect();
    systems.sort_unstable(); // deterministic dispatch order
    for sys in systems {
        let idxs = pi_by_system.remove(&sys).unwrap();
        let outcome = {
            let design = inner.handles[sys].design();
            let samples: Vec<&[i64]> = idxs
                .iter()
                .map(|&i| match &g.get(i).payload {
                    RequestPayload::Pi { values_q } => values_q.as_slice(),
                    RequestPayload::Power(_) => unreachable!("partitioned above"),
                })
                .collect();
            catch_unwind(AssertUnwindSafe(|| rtl::run_batch(design, &samples)))
        };
        match outcome {
            Ok(result) => {
                if result.outputs.len() == idxs.len() {
                    for (&i, pis) in idxs.iter().zip(result.outputs) {
                        g.finish(
                            i,
                            Ok(TrafficResponse::Pi {
                                pis,
                                hw_cycles: result.cycles_per_sample,
                            }),
                        );
                    }
                } else {
                    // A short scatter must answer every request, not
                    // silently drop the tail.
                    let reason = format!(
                        "Π batch returned {} outputs for {} requests",
                        result.outputs.len(),
                        idxs.len()
                    );
                    for &i in &idxs {
                        g.finish(
                            i,
                            Err(ServeError::WorkerPanicked { reason: reason.clone() }),
                        );
                    }
                }
            }
            Err(e) => {
                let reason = panic_reason(e);
                for &i in &idxs {
                    g.finish(i, Err(ServeError::WorkerPanicked { reason: reason.clone() }));
                }
            }
        }
    }

    // Power estimation: one cross-system dispatch for the whole batch —
    // the sharded fused evaluation when the serve set enabled fusion,
    // else per-netlist grouping. Either way one flood fans out over
    // every core, so concurrent lanes take the serve set's flood gate
    // (held only around the flood — Π work never waits on it).
    if !power_idx.is_empty() {
        let outcome = {
            let tagged: Vec<SystemPowerRequest> = power_idx
                .iter()
                .map(|&i| match &g.get(i).payload {
                    RequestPayload::Power(r) => SystemPowerRequest {
                        system: inner.tenant_system[g.get(i).tenant],
                        request: *r,
                    },
                    RequestPayload::Pi { .. } => unreachable!("partitioned above"),
                })
                .collect();
            catch_unwind(AssertUnwindSafe(|| {
                inner.flood_gate.run(|| {
                    dispatch_flood(
                        &inner.handles,
                        inner.fused.as_deref(),
                        &tagged,
                        inner.activations,
                        inner.width,
                    )
                })
            }))
        };
        match outcome {
            Ok(estimates) => {
                if estimates.len() == power_idx.len() {
                    for (&i, est) in power_idx.iter().zip(estimates) {
                        g.finish(i, Ok(TrafficResponse::Power(est)));
                    }
                } else {
                    let reason = format!(
                        "power flood returned {} estimates for {} requests",
                        estimates.len(),
                        power_idx.len()
                    );
                    for &i in &power_idx {
                        g.finish(
                            i,
                            Err(ServeError::WorkerPanicked { reason: reason.clone() }),
                        );
                    }
                }
            }
            Err(e) => {
                let reason = panic_reason(e);
                for &i in &power_idx {
                    g.finish(i, Err(ServeError::WorkerPanicked { reason: reason.clone() }));
                }
            }
        }
    }
}

/// Compute one fault-flagged request in isolation. A `Delay` sleeps
/// first (the slow-tenant injection) and re-checks the deadline after —
/// still "dropped before compute". A `Panic` fires inside the same
/// containment the real compute runs under.
fn compute_faulted(inner: &Inner, item: Item, action: FaultAction) {
    if let FaultAction::Delay(d) = action {
        std::thread::sleep(d);
        if item.deadline.expired() {
            finish(inner, item, Err(ServeError::DeadlineExceeded));
            return;
        }
    }
    let sys = inner.tenant_system[item.tenant];
    let handle = &inner.handles[sys];
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if action == FaultAction::Panic {
            panic!(
                "injected fault: tenant `{}` request {}",
                inner.specs[item.tenant].name, item.seq
            );
        }
        match &item.payload {
            RequestPayload::Pi { values_q } => {
                let result = rtl::run_batch(handle.design(), &[values_q.as_slice()]);
                TrafficResponse::Pi {
                    pis: result.outputs.into_iter().next().unwrap_or_default(),
                    hw_cycles: result.cycles_per_sample,
                }
            }
            RequestPayload::Power(r) => {
                let targets = [(handle.netlist(), handle.design())];
                let tagged = [SystemPowerRequest { system: 0, request: *r }];
                let est = inner.flood_gate.run(|| {
                    estimate_power_requests_grouped(
                        &targets,
                        &tagged,
                        inner.activations,
                        inner.width,
                    )
                })
                .into_iter()
                .next()
                .expect("one estimate per request");
                TrafficResponse::Power(est)
            }
        }
    }));
    match outcome {
        Ok(resp) => finish(inner, item, Ok(resp)),
        Err(e) => {
            finish(inner, item, Err(ServeError::WorkerPanicked { reason: panic_reason(e) }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::flow::FlowConfig;
    use std::sync::mpsc;

    fn boot_engine(
        tenants: Vec<TenantSpec>,
        faults: FaultPlan,
    ) -> (ServeSet, TrafficEngine) {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let engine = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants, default_deadline: Duration::from_secs(5) },
            EngineConfig::default(),
            faults,
        )
        .unwrap();
        (set, engine)
    }

    fn pi_payload(set: &ServeSet) -> RequestPayload {
        let n = set.handle_at(0).design().num_inputs();
        RequestPayload::Pi {
            values_q: (0..n).map(|i| Q16_15.from_f64(0.75 + 0.5 * i as f64)).collect(),
        }
    }

    #[test]
    fn start_rejects_bad_rosters() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let err = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants: vec![], default_deadline: Duration::from_secs(1) },
            EngineConfig::default(),
            FaultPlan::none(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("at least one tenant"), "{err}");
        let dup = AdmissionConfig {
            tenants: vec![TenantSpec::new("a", "pendulum"), TenantSpec::new("a", "pendulum")],
            default_deadline: Duration::from_secs(1),
        };
        let err = TrafficEngine::start(&set, dup, EngineConfig::default(), FaultPlan::none())
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate tenant"), "{err}");
        let missing = AdmissionConfig {
            tenants: vec![TenantSpec::new("a", "beam")],
            default_deadline: Duration::from_secs(1),
        };
        let err =
            TrafficEngine::start(&set, missing, EngineConfig::default(), FaultPlan::none())
                .unwrap_err()
                .to_string();
        assert!(err.contains("beam"), "{err}");
    }

    #[test]
    fn serves_pi_and_power_with_typed_refusals() {
        let (set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        let (tx, rx) = mpsc::channel();

        // Unknown tenant: typed, no reply promised.
        let err = engine
            .submit("ghost", pi_payload(&set), None, 1, tx.clone())
            .unwrap_err();
        assert!(matches!(err, ServeError::TenantUnknown { .. }));

        // Malformed Π request: wrong port count.
        let err = engine
            .submit("t", RequestPayload::Pi { values_q: vec![1] }, None, 2, tx.clone())
            .unwrap_err();
        assert!(matches!(err, ServeError::Protocol { .. }));

        // Malformed power request: non-physical clock.
        let err = engine
            .submit(
                "t",
                RequestPayload::Power(PowerRequest { seed: 1, f_hz: f64::NAN }),
                None,
                3,
                tx.clone(),
            )
            .unwrap_err();
        assert!(matches!(err, ServeError::Protocol { .. }));

        // A well-formed Π request is served with hardware cycles.
        engine.submit("t", pi_payload(&set), None, 10, tx.clone()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(reply.id, 10);
        match reply.result.unwrap() {
            TrafficResponse::Pi { pis, hw_cycles } => {
                assert_eq!(pis.len(), set.handle_at(0).design().num_outputs());
                assert!(hw_cycles > 0);
            }
            other => panic!("expected Pi, got {other:?}"),
        }

        // A power request runs through the grouped dispatch.
        engine
            .submit(
                "t",
                RequestPayload::Power(PowerRequest { seed: 7, f_hz: 6.0e6 }),
                None,
                11,
                tx,
            )
            .unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        match reply.result.unwrap() {
            TrafficResponse::Power(est) => {
                assert!(est.mw > 0.0);
                assert!(est.cycles > 0);
            }
            other => panic!("expected Power, got {other:?}"),
        }

        let report = engine.shutdown();
        assert!(!report.engine_panicked);
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.served, 2);
        assert_eq!(t.counters.admitted, 2);
        assert_eq!(t.counters.protocol_errors, 2);
        assert_eq!(t.counters.terminal(), t.counters.admitted);
        assert_eq!(report.tenant_unknown, 1);
    }

    #[test]
    fn injected_panic_is_contained_and_typed() {
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")],
            FaultPlan::none().panic_at("t", 1),
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..3u64 {
            engine.submit("t", pi_payload(&set), None, id, tx.clone()).unwrap();
        }
        let mut ok = 0;
        let mut panicked = 0;
        for _ in 0..3 {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            match reply.result {
                Ok(_) => ok += 1,
                Err(ServeError::WorkerPanicked { reason }) => {
                    assert!(reason.contains("injected fault"), "{reason}");
                    assert_eq!(reply.id, 1, "the fault keys on admission seq 1");
                    panicked += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!((ok, panicked), (2, 1));
        // The engine survived: it still serves after the panic.
        engine.submit("t", pi_payload(&set), None, 99, tx).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        let report = engine.shutdown();
        assert!(!report.engine_panicked);
        assert_eq!(report.tenant("t").unwrap().counters.panicked, 1);
    }

    #[test]
    fn expired_work_is_dropped_at_dequeue_not_computed() {
        // A 3 ms tenant-wide delay against a 1 ms budget: the first
        // request's sleep expires its own deadline, and everything
        // queued behind it ages out too.
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")],
            FaultPlan::none().delay_all("t", Duration::from_millis(3)),
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..4u64 {
            engine
                .submit("t", pi_payload(&set), Some(Duration::from_millis(1)), id, tx.clone())
                .unwrap();
        }
        for _ in 0..4 {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(reply.result.unwrap_err(), ServeError::DeadlineExceeded);
        }
        let report = engine.shutdown();
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.deadline_expired, 4);
        assert_eq!(t.counters.terminal(), t.counters.admitted);
    }

    #[test]
    fn queue_cap_sheds_and_drain_answers_everything() {
        let (set, engine) = boot_engine(
            vec![TenantSpec::new("t", "pendulum")
                .with_queue_cap(2)
                .with_rate(f64::INFINITY, 1.0)],
            // Slow every request down so the queue actually fills.
            FaultPlan::none().delay_all("t", Duration::from_millis(20)),
        );
        let (tx, rx) = mpsc::channel();
        let mut admitted = 0u64;
        let mut shed = 0u64;
        for id in 0..40u64 {
            match engine.submit("t", pi_payload(&set), None, id, tx.clone()) {
                Ok(_) => admitted += 1,
                Err(ServeError::Shed { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1);
                    shed += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "40 instant submits against a cap-2 queue must shed");
        // Graceful drain: every admitted request still gets its answer.
        let report = engine.shutdown();
        let mut answered = 0;
        while rx.try_recv().is_ok() {
            answered += 1;
        }
        assert_eq!(answered, admitted);
        let t = report.tenant("t").unwrap();
        assert_eq!(t.counters.admitted, admitted);
        assert_eq!(t.counters.shed, shed);
        assert_eq!(t.counters.terminal(), admitted);
        assert_eq!(t.queue_depth, 0, "drain leaves nothing queued");
    }

    #[test]
    fn draining_engine_sheds_new_work_with_zero_hint() {
        let (set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        engine.shutdown();
        let (tx, _rx) = mpsc::channel();
        match engine.submit("t", pi_payload(&set), None, 1, tx) {
            Err(ServeError::Shed { retry_after_ms }) => assert_eq!(retry_after_ms, 0),
            other => panic!("expected Shed, got {other:?}"),
        }
    }

    /// An engine started on a fusion-enabled set must answer power
    /// requests through the sharded fused evaluation with estimates
    /// bit-identical to the grouped path.
    #[test]
    fn fused_engine_power_matches_grouped_engine_power() {
        let mut answers = Vec::new();
        for fuse in [false, true] {
            let mut set =
                ServeSet::boot(&["pendulum", "spring_mass"], FlowConfig::default(), None)
                    .unwrap();
            if fuse {
                set.enable_fusion(2).unwrap();
            }
            let engine = TrafficEngine::start(
                &set,
                AdmissionConfig::one_tenant_per_system(&set.systems()),
                EngineConfig::default(),
                FaultPlan::none(),
            )
            .unwrap();
            let (tx, rx) = mpsc::channel();
            for (id, tenant) in [(0u64, "pendulum"), (1, "spring_mass"), (2, "pendulum")] {
                engine
                    .submit(
                        tenant,
                        RequestPayload::Power(PowerRequest {
                            seed: 0xCAFE + id as u32,
                            f_hz: 6.0e6,
                        }),
                        None,
                        id,
                        tx.clone(),
                    )
                    .unwrap();
            }
            let mut got: Vec<(u64, f64, u64)> = (0..3)
                .map(|_| {
                    let reply = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                    match reply.result.unwrap() {
                        TrafficResponse::Power(est) => (reply.id, est.mw, est.cycles),
                        other => panic!("expected Power, got {other:?}"),
                    }
                })
                .collect();
            got.sort_by_key(|&(id, ..)| id);
            answers.push(got);
            engine.shutdown();
        }
        assert_eq!(answers[0], answers[1], "fused engine must match grouped engine");
    }

    #[test]
    fn health_and_stats_are_live() {
        let (_set, engine) =
            boot_engine(vec![TenantSpec::new("t", "pendulum")], FaultPlan::none());
        assert!(engine.health_text().starts_with("ok:"));
        assert!(engine.stats_text().contains("admitted"));
        assert_eq!(engine.pressure("t").unwrap().0, 0);
        assert!(engine.pressure("ghost").is_none());
        engine.shutdown();
    }

    /// Two lanes, both busy: requests for tenants pinned to different
    /// lanes are all served, and the report shows both lanes moving
    /// work with the right tenant residency.
    #[test]
    fn tenants_shard_across_lanes_and_all_serve() {
        let set =
            ServeSet::boot(&["pendulum", "spring_mass"], FlowConfig::default(), None).unwrap();
        let tenants = vec![
            TenantSpec::new("a0", "pendulum").with_lane(0),
            TenantSpec::new("a1", "spring_mass").with_lane(0),
            TenantSpec::new("b0", "pendulum").with_lane(1),
            TenantSpec::new("b1", "spring_mass").with_lane(1),
        ];
        let engine = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants, default_deadline: Duration::from_secs(30) },
            EngineConfig { dispatchers: 2, ..EngineConfig::default() },
            FaultPlan::none(),
        )
        .unwrap();
        assert_eq!(engine.lane_count(), 2);
        let (tx, rx) = mpsc::channel();
        let per_tenant = 8u64;
        let systems = ["pendulum", "spring_mass", "pendulum", "spring_mass"];
        for (t, name) in ["a0", "a1", "b0", "b1"].iter().enumerate() {
            let sys = set.system_index(systems[t]).unwrap();
            let n = set.handle_at(sys).design().num_inputs();
            for id in 0..per_tenant {
                engine
                    .submit(
                        name,
                        RequestPayload::Pi {
                            values_q: (0..n)
                                .map(|i| Q16_15.from_f64(0.8 + 0.25 * i as f64))
                                .collect(),
                        },
                        None,
                        (t as u64) << 32 | id,
                        tx.clone(),
                    )
                    .unwrap();
            }
        }
        for _ in 0..(4 * per_tenant) {
            let reply = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(reply.result.is_ok(), "{:?}", reply.result.err());
        }
        let report = engine.shutdown();
        assert!(!report.engine_panicked);
        assert_eq!(report.lanes.len(), 2);
        for lane in &report.lanes {
            assert!(!lane.panicked);
            assert!(lane.batches > 0, "lane {} collected nothing", lane.lane);
            assert!(lane.items > 0);
        }
        assert_eq!(report.lanes[0].tenants, vec!["a0", "a1"]);
        assert_eq!(report.lanes[1].tenants, vec!["b0", "b1"]);
        for name in ["a0", "a1", "b0", "b1"] {
            let t = report.tenant(name).unwrap();
            assert_eq!(t.counters.served, per_tenant);
            assert_eq!(t.counters.terminal(), t.counters.admitted);
        }
    }

    /// The satellite-3 regression: a dispatcher that dies *mid-batch*
    /// (uncontained panic after collecting work) must not lose the
    /// in-flight items or double-answer anything, and must not disturb
    /// the other lane. Before the holding-cell guard, the collected
    /// batch was simply dropped on unwind — admitted requests vanished
    /// without a reply.
    #[test]
    fn killed_lane_is_swept_without_disturbing_live_lanes() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let tenants = vec![
            TenantSpec::new("doomed", "pendulum").with_lane(0),
            TenantSpec::new("healthy", "pendulum").with_lane(1),
        ];
        let engine = TrafficEngine::start(
            &set,
            AdmissionConfig { tenants, default_deadline: Duration::from_secs(60) },
            EngineConfig { dispatchers: 2, ..EngineConfig::default() },
            // Lane 0 dies on its very first batch, with items in hand.
            FaultPlan::none().kill_lane_at(0, 0),
        )
        .unwrap();
        let (dtx, drx) = mpsc::channel();
        let (htx, hrx) = mpsc::channel();
        let n = set.handle_at(0).design().num_inputs();
        let payload = || RequestPayload::Pi {
            values_q: (0..n).map(|i| Q16_15.from_f64(0.9 + 0.1 * i as f64)).collect(),
        };
        let doomed_n = 6u64;
        for id in 0..doomed_n {
            engine.submit("doomed", payload(), None, id, dtx.clone()).unwrap();
        }
        // The healthy lane keeps serving while lane 0 is dead.
        for id in 0..4u64 {
            engine.submit("healthy", payload(), None, 100 + id, htx.clone()).unwrap();
            let reply = hrx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(reply.result.is_ok(), "healthy lane must serve: {:?}", reply.result.err());
        }
        let report = engine.shutdown();
        // Exactly one typed answer per admitted doomed request — the
        // in-flight batch came back from the orphan cell, the queued
        // remainder from the per-lane queue sweep, nothing twice.
        let mut doomed_replies = 0u64;
        while let Ok(reply) = drx.try_recv() {
            match reply.result {
                Err(ServeError::WorkerPanicked { reason }) => {
                    assert!(reason.contains("lane 0"), "{reason}");
                }
                other => panic!("doomed requests must be WorkerPanicked, got {other:?}"),
            }
            doomed_replies += 1;
        }
        assert_eq!(doomed_replies, doomed_n, "no lost or duplicated answers");
        assert!(report.engine_panicked, "a dead lane is loud");
        assert!(report.lanes[0].panicked);
        assert!(!report.lanes[1].panicked, "live lane undisturbed");
        let doomed = report.tenant("doomed").unwrap();
        assert_eq!(doomed.counters.panicked, doomed_n);
        assert_eq!(doomed.counters.terminal(), doomed.counters.admitted);
        assert_eq!(doomed.queue_depth, 0, "janitor leaves nothing queued");
        let healthy = report.tenant("healthy").unwrap();
        assert_eq!(healthy.counters.served, 4);
        assert_eq!(healthy.counters.panicked, 0);
    }
}
