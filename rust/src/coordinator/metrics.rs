//! Serving metrics: latency histogram + throughput counters.

use std::time::Duration;

/// Log-bucketed latency histogram (microseconds, power-of-two buckets).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket b counts latencies in [2^b, 2^(b+1)) µs; bucket 0 = <2µs.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as u64).min(31) as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper bucket bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let threshold = (self.count as f64 * p).ceil() as u64;
        let mut seen = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= threshold {
                // Upper bucket bound, clamped to the observed maximum.
                return (1u64 << (b + 1).min(63)).min(self.max_us.max(1));
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Aggregated serving report.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub latency: LatencyHistogram,
    pub batches: u64,
    pub samples: u64,
    pub wall: Duration,
    /// The serving worker died by panic: whatever it had counted is
    /// lost, so these stats must not be read as a clean zero-traffic
    /// run.
    pub worker_panicked: bool,
}

impl ServeStats {
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.samples as f64 / self.wall.as_secs_f64()
    }

    /// Mean samples per batch. Every sample is a member of exactly one
    /// batch, so the fill follows from the two counters — no separate
    /// fill accumulator to keep in sync.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.samples as f64 / self.batches as f64
    }
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.worker_panicked {
            writeln!(f, "worker:      PANICKED (stats below are lost/partial)")?;
        }
        writeln!(f, "samples:     {}", self.samples)?;
        writeln!(f, "batches:     {} (mean fill {:.1})", self.batches, self.mean_batch_fill())?;
        writeln!(f, "wall:        {:.3} s", self.wall.as_secs_f64())?;
        writeln!(f, "throughput:  {:.0} samples/s", self.throughput())?;
        writeln!(
            f,
            "latency µs:  mean {:.0}  p50 {}  p95 {}  p99 {}  max {}",
            self.latency.mean_us(),
            self.latency.percentile_us(0.50),
            self.latency.percentile_us(0.95),
            self.latency.percentile_us(0.99),
            self.latency.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_percentiles() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 2000.0);
        assert!(h.percentile_us(0.5) >= 64);
        assert!(h.percentile_us(0.99) >= 8192);
        assert_eq!(h.max_us(), 10_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 500);
    }

    #[test]
    fn stats_throughput() {
        let mut s = ServeStats::default();
        s.samples = 1000;
        s.wall = Duration::from_secs(2);
        s.batches = 20;
        assert_eq!(s.throughput(), 500.0);
        assert_eq!(s.mean_batch_fill(), 50.0);
        let txt = s.to_string();
        assert!(txt.contains("throughput"));
        assert!(!txt.contains("PANICKED"));
    }

    #[test]
    fn panicked_worker_is_loud_not_zero() {
        let s = ServeStats { worker_panicked: true, ..ServeStats::default() };
        assert!(s.to_string().contains("PANICKED"));
        assert!(!ServeStats::default().worker_panicked);
    }
}
