//! Serving metrics: latency histogram + throughput counters.
//!
//! Two representations live here. The plain [`LatencyHistogram`] /
//! [`TrafficCounters`] are owned snapshots used in reports and tests.
//! Their atomic twins ([`AtomicLatencyHistogram`],
//! [`AtomicTrafficCounters`]) are the hot-path shards the
//! multi-dispatcher engine writes through shared references — every
//! record is a handful of relaxed atomic ops, no lock — and are folded
//! into plain values only at scrape time via `snapshot()`. Each tenant
//! lives in exactly one dispatch lane, so a tenant's shard is written
//! by one dispatcher (plus the submit path for admission counters);
//! the atomics make the cross-thread scrape safe without ever making
//! the dispatchers wait on each other.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Log-bucketed latency histogram (microseconds, power-of-two buckets).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket b counts latencies in [2^b, 2^(b+1)) µs; bucket 0 = <2µs.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as u64).min(31) as usize;
        // Saturating: a histogram that has seen u64::MAX samples must
        // degrade (pin at the ceiling), not abort the serving path.
        self.buckets[b] = self.buckets[b].saturating_add(1);
        self.count = self.count.saturating_add(1);
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper bucket bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let threshold = (self.count as f64 * p).ceil() as u64;
        let mut seen = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= threshold {
                // Upper bucket bound, clamped to the observed maximum.
                return (1u64 << (b + 1).min(63)).min(self.max_us.max(1));
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Lock-free twin of [`LatencyHistogram`]: shared-reference recording
/// through relaxed atomics, folded into a plain histogram at scrape
/// time. The dispatch hot path must never block on a metrics lock.
#[derive(Debug, Default)]
pub struct AtomicLatencyHistogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl AtomicLatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample. Same bucketing as
    /// [`LatencyHistogram::record`]; wrapping `fetch_add` instead of
    /// saturating (a u64 of samples outlives any deployment, and a
    /// lock-free saturating add would cost a CAS loop per record).
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as u64).min(31) as usize;
        self.buckets[b].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
        self.max_us.fetch_max(us, Relaxed);
    }

    /// Fold into an owned histogram (scrape time). Relaxed loads: the
    /// scrape is a statistical snapshot, not a linearization point.
    pub fn snapshot(&self) -> LatencyHistogram {
        LatencyHistogram {
            buckets: self.buckets.iter().map(|b| b.load(Relaxed)).collect(),
            count: self.count.load(Relaxed),
            sum_us: self.sum_us.load(Relaxed),
            max_us: self.max_us.load(Relaxed),
        }
    }
}

/// Outcome counters of the traffic layer, per tenant. Every admitted
/// request lands in exactly one of `served`, `deadline_expired`, or
/// `panicked`; `shed`/`protocol_errors` count requests refused at the
/// door (answered but never admitted).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TrafficCounters {
    /// Admitted into the tenant's queue (will get a terminal answer).
    pub admitted: u64,
    /// Computed and answered `Ok`.
    pub served: u64,
    /// Refused at admission (rate-limited, queue full, or draining).
    pub shed: u64,
    /// Dropped at dequeue because the deadline had already passed.
    pub deadline_expired: u64,
    /// Worker panicked while computing; answered `WorkerPanicked`.
    pub panicked: u64,
    /// Malformed or invalid requests (answered `Protocol`).
    pub protocol_errors: u64,
}

impl TrafficCounters {
    /// Terminal answers owed to admitted requests. Equal to `admitted`
    /// once the server has drained — the no-silent-drop invariant.
    pub fn terminal(&self) -> u64 {
        self.served + self.deadline_expired + self.panicked
    }

    pub fn merge(&mut self, o: &TrafficCounters) {
        self.admitted += o.admitted;
        self.served += o.served;
        self.shed += o.shed;
        self.deadline_expired += o.deadline_expired;
        self.panicked += o.panicked;
        self.protocol_errors += o.protocol_errors;
    }
}

/// Lock-free twin of [`TrafficCounters`]: one atomic per outcome,
/// incremented from the submit path and the tenant's dispatch lane,
/// snapshotted at scrape time.
#[derive(Debug, Default)]
pub struct AtomicTrafficCounters {
    pub admitted: AtomicU64,
    pub served: AtomicU64,
    pub shed: AtomicU64,
    pub deadline_expired: AtomicU64,
    pub panicked: AtomicU64,
    pub protocol_errors: AtomicU64,
}

impl AtomicTrafficCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn snapshot(&self) -> TrafficCounters {
        TrafficCounters {
            admitted: self.admitted.load(Relaxed),
            served: self.served.load(Relaxed),
            shed: self.shed.load(Relaxed),
            deadline_expired: self.deadline_expired.load(Relaxed),
            panicked: self.panicked.load(Relaxed),
            protocol_errors: self.protocol_errors.load(Relaxed),
        }
    }
}

/// One tenant's slice of a [`TrafficReport`]: counters, served-request
/// latency, and the queue pressure observed at snapshot time.
#[derive(Clone, Debug)]
pub struct TenantTraffic {
    pub tenant: String,
    pub counters: TrafficCounters,
    /// Queue-to-answer latency of served requests.
    pub latency: LatencyHistogram,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    /// Age of the oldest queued entry at snapshot time (ms), 0 if empty.
    pub queue_oldest_ms: u64,
}

/// One dispatch lane's slice of a [`TrafficReport`]: which tenants it
/// hosts, how much work it moved, and whether its dispatcher died.
#[derive(Clone, Debug, Default)]
pub struct LaneTraffic {
    pub lane: usize,
    /// Tenant names resident in this lane (spec order).
    pub tenants: Vec<String>,
    /// Batches collected by this lane's dispatcher.
    pub batches: u64,
    /// Items dequeued into those batches.
    pub items: u64,
    /// This lane's dispatcher died by panic (its work was swept by the
    /// per-lane janitor; other lanes were undisturbed).
    pub panicked: bool,
}

/// Snapshot of the whole traffic layer: per-tenant slices plus the
/// global counters that have no tenant to charge.
#[derive(Clone, Debug, Default)]
pub struct TrafficReport {
    pub tenants: Vec<TenantTraffic>,
    /// Per-dispatch-lane activity (empty for pre-lane callers that
    /// assemble reports by hand).
    pub lanes: Vec<LaneTraffic>,
    /// Requests naming a tenant nobody registered.
    pub tenant_unknown: u64,
    /// Connections that dropped mid-request (their answers, if any,
    /// were undeliverable).
    pub disconnects: u64,
    /// Computed answers that could not be delivered (receiver gone).
    pub undelivered: u64,
    pub wall: Duration,
    /// The dispatch engine itself died by panic — per-tenant numbers
    /// below are partial, not a clean record.
    pub engine_panicked: bool,
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// tenant names are the only free-form strings on the export path.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn counters_json(c: &TrafficCounters) -> String {
    format!(
        "{{\"admitted\":{},\"served\":{},\"shed\":{},\"deadline_expired\":{},\"panicked\":{},\"protocol_errors\":{}}}",
        c.admitted, c.served, c.shed, c.deadline_expired, c.panicked, c.protocol_errors
    )
}

impl TrafficReport {
    /// Counters summed over all tenants.
    pub fn totals(&self) -> TrafficCounters {
        let mut t = TrafficCounters::default();
        for s in &self.tenants {
            t.merge(&s.counters);
        }
        t
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantTraffic> {
        self.tenants.iter().find(|t| t.tenant == name)
    }

    /// Machine-readable rendering of the snapshot — the JSON variant of
    /// the wire `stats` operation. Hand-rolled (no serde in-tree):
    /// `totals` precedes `tenants`, so flat key scans find the global
    /// counters first.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"engine_panicked\":{},", self.engine_panicked));
        out.push_str(&format!("\"wall_s\":{:.6},", self.wall.as_secs_f64()));
        out.push_str(&format!("\"totals\":{},", counters_json(&self.totals())));
        out.push_str(&format!(
            "\"tenant_unknown\":{},\"disconnects\":{},\"undelivered\":{},",
            self.tenant_unknown, self.disconnects, self.undelivered
        ));
        out.push_str("\"tenants\":[");
        for (i, t) in self.tenants.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"tenant\":\"{}\",\"counters\":{},\"latency_us\":{{\"count\":{},\"mean\":{:.1},\"p50\":{},\"p99\":{},\"p999\":{},\"max\":{}}},\"queue_depth\":{},\"queue_oldest_ms\":{}}}",
                json_escape(&t.tenant),
                counters_json(&t.counters),
                t.latency.count(),
                t.latency.mean_us(),
                t.latency.percentile_us(0.50),
                t.latency.percentile_us(0.99),
                t.latency.percentile_us(0.999),
                t.latency.max_us(),
                t.queue_depth,
                t.queue_oldest_ms
            ));
        }
        out.push_str("],\"lanes\":[");
        for (i, l) in self.lanes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let tenants = l
                .tenants
                .iter()
                .map(|t| format!("\"{}\"", json_escape(t)))
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "{{\"lane\":{},\"tenants\":[{}],\"batches\":{},\"items\":{},\"panicked\":{}}}",
                l.lane, tenants, l.batches, l.items, l.panicked
            ));
        }
        out.push_str("]}");
        out
    }
}

impl std::fmt::Display for TrafficReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.engine_panicked {
            writeln!(f, "engine:      PANICKED (counters below are partial)")?;
        }
        let tot = self.totals();
        writeln!(
            f,
            "traffic:     {} admitted, {} served, {} shed, {} deadline-expired, {} panicked",
            tot.admitted, tot.served, tot.shed, tot.deadline_expired, tot.panicked
        )?;
        writeln!(
            f,
            "errors:      {} protocol, {} unknown-tenant, {} disconnects, {} undelivered",
            tot.protocol_errors, self.tenant_unknown, self.disconnects, self.undelivered
        )?;
        if !self.wall.is_zero() {
            writeln!(f, "wall:        {:.3} s", self.wall.as_secs_f64())?;
        }
        for l in &self.lanes {
            writeln!(
                f,
                "lane {:<7} {} batches / {} items · tenants [{}]{}",
                l.lane,
                l.batches,
                l.items,
                l.tenants.join(", "),
                if l.panicked { " · PANICKED" } else { "" }
            )?;
        }
        for t in &self.tenants {
            writeln!(
                f,
                "{:<12} {} served / {} admitted, {} shed · latency µs p50 {} p99 {} p999 {} max {} · queue {} (oldest {} ms)",
                t.tenant,
                t.counters.served,
                t.counters.admitted,
                t.counters.shed,
                t.latency.percentile_us(0.50),
                t.latency.percentile_us(0.99),
                t.latency.percentile_us(0.999),
                t.latency.max_us(),
                t.queue_depth,
                t.queue_oldest_ms
            )?;
        }
        Ok(())
    }
}

/// Aggregated serving report.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub latency: LatencyHistogram,
    pub batches: u64,
    pub samples: u64,
    pub wall: Duration,
    /// The serving worker died by panic: whatever it had counted is
    /// lost, so these stats must not be read as a clean zero-traffic
    /// run.
    pub worker_panicked: bool,
}

impl ServeStats {
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.samples as f64 / self.wall.as_secs_f64()
    }

    /// Mean samples per batch. Every sample is a member of exactly one
    /// batch, so the fill follows from the two counters — no separate
    /// fill accumulator to keep in sync.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.samples as f64 / self.batches as f64
    }
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.worker_panicked {
            writeln!(f, "worker:      PANICKED (stats below are lost/partial)")?;
        }
        writeln!(f, "samples:     {}", self.samples)?;
        writeln!(f, "batches:     {} (mean fill {:.1})", self.batches, self.mean_batch_fill())?;
        writeln!(f, "wall:        {:.3} s", self.wall.as_secs_f64())?;
        writeln!(f, "throughput:  {:.0} samples/s", self.throughput())?;
        writeln!(
            f,
            "latency µs:  mean {:.0}  p50 {}  p95 {}  p99 {}  max {}",
            self.latency.mean_us(),
            self.latency.percentile_us(0.50),
            self.latency.percentile_us(0.95),
            self.latency.percentile_us(0.99),
            self.latency.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_percentiles() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 2000.0);
        assert!(h.percentile_us(0.5) >= 64);
        assert!(h.percentile_us(0.99) >= 8192);
        assert_eq!(h.max_us(), 10_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 500);
    }

    #[test]
    fn stats_throughput() {
        let mut s = ServeStats::default();
        s.samples = 1000;
        s.wall = Duration::from_secs(2);
        s.batches = 20;
        assert_eq!(s.throughput(), 500.0);
        assert_eq!(s.mean_batch_fill(), 50.0);
        let txt = s.to_string();
        assert!(txt.contains("throughput"));
        assert!(!txt.contains("PANICKED"));
    }

    #[test]
    fn panicked_worker_is_loud_not_zero() {
        let s = ServeStats { worker_panicked: true, ..ServeStats::default() };
        assert!(s.to_string().contains("PANICKED"));
        assert!(!ServeStats::default().worker_panicked);
    }

    #[test]
    fn empty_window_every_percentile_is_zero() {
        let h = LatencyHistogram::new();
        for p in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile_us(p), 0);
        }
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_us(), 0);
    }

    #[test]
    fn single_sample_pins_every_percentile() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(137));
        // With one sample every percentile is that sample (clamped to
        // the observed max, not the bucket's upper bound of 256).
        for p in [0.5, 0.99, 0.999] {
            assert_eq!(h.percentile_us(p), 137);
        }
        assert_eq!(h.mean_us(), 137.0);
        assert_eq!(h.max_us(), 137);
    }

    #[test]
    fn saturating_counts_never_wrap() {
        let mut h = LatencyHistogram::new();
        h.count = u64::MAX;
        h.sum_us = u64::MAX - 1;
        h.buckets[5] = u64::MAX;
        h.record(Duration::from_micros(40)); // bucket 5: [32, 64)
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.sum_us, u64::MAX);
        assert_eq!(h.buckets[5], u64::MAX);
        // Merge saturates the same way.
        let mut other = LatencyHistogram::new();
        other.record(Duration::from_micros(40));
        h.merge(&other);
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.buckets[5], u64::MAX);
    }

    #[test]
    fn percentiles_monotone_under_randomized_inserts() {
        // Repo-standard deterministic PRNG; no rand crate.
        let mut rng = crate::stim::Lfsr32::new(0x51_AB_2026);
        for trial in 0..20 {
            let mut h = LatencyHistogram::new();
            let n = 1 + (rng.below(4000) as usize);
            for _ in 0..n {
                // Spread over ~6 decades of microseconds.
                let us = 1u64 << rng.below(21);
                h.record(Duration::from_micros(us + rng.below(us.min(1 << 20) as u32) as u64));
            }
            let p50 = h.percentile_us(0.50);
            let p99 = h.percentile_us(0.99);
            let p999 = h.percentile_us(0.999);
            assert!(
                p50 <= p99 && p99 <= p999,
                "trial {trial}: p50 {p50} p99 {p99} p999 {p999} not monotone"
            );
            assert!(p999 <= h.max_us().max(1), "p999 exceeds observed max");
        }
    }

    #[test]
    fn report_json_is_balanced_and_escaped() {
        let mut lat = LatencyHistogram::new();
        lat.record(Duration::from_micros(42));
        let report = TrafficReport {
            tenants: vec![TenantTraffic {
                tenant: "we\"ird\\name".into(),
                counters: TrafficCounters { admitted: 3, served: 2, shed: 1, ..Default::default() },
                latency: lat,
                queue_depth: 1,
                queue_oldest_ms: 7,
            }],
            lanes: vec![LaneTraffic {
                lane: 0,
                tenants: vec!["we\"ird\\name".into()],
                batches: 4,
                items: 9,
                panicked: true,
            }],
            tenant_unknown: 2,
            disconnects: 1,
            undelivered: 0,
            wall: Duration::from_millis(1500),
            engine_panicked: false,
        };
        let json = report.to_json();
        // Structurally balanced and free of raw control characters.
        let opens = json.matches(['{', '[']).count();
        let closes = json.matches(['}', ']']).count();
        assert_eq!(opens, closes, "{json}");
        assert!(!json.chars().any(|c| (c as u32) < 0x20), "{json}");
        // Totals precede tenants so flat key scans hit globals first.
        assert!(json.find("\"totals\"").unwrap() < json.find("\"tenants\"").unwrap());
        assert!(json.contains("\"admitted\":3"), "{json}");
        assert!(json.contains("\"tenant_unknown\":2"), "{json}");
        assert!(json.contains("we\\\"ird\\\\name"), "{json}");
        assert!(json.contains("\"p99\":"), "{json}");
        assert!(json.contains("\"lanes\":[{\"lane\":0,"), "{json}");
        assert!(json.contains("\"batches\":4,\"items\":9,\"panicked\":true"), "{json}");
        assert!(json.starts_with('{') && json.ends_with('}'));
    }

    #[test]
    fn atomic_histogram_snapshot_matches_plain_twin() {
        // Same samples through both representations must agree exactly:
        // counts, buckets (via percentiles), mean, and max.
        let atomic = AtomicLatencyHistogram::new();
        let mut plain = LatencyHistogram::new();
        let mut rng = crate::stim::Lfsr32::new(0xD15A_7C42);
        for _ in 0..2000 {
            let us = 1u64 + rng.below(1 << 20) as u64;
            atomic.record(Duration::from_micros(us));
            plain.record(Duration::from_micros(us));
        }
        let snap = atomic.snapshot();
        assert_eq!(snap.count(), plain.count());
        assert_eq!(snap.max_us(), plain.max_us());
        assert_eq!(snap.mean_us(), plain.mean_us());
        for p in [0.5, 0.95, 0.99, 0.999] {
            assert_eq!(snap.percentile_us(p), plain.percentile_us(p));
        }
    }

    #[test]
    fn atomic_counters_snapshot_roundtrip() {
        let c = AtomicTrafficCounters::new();
        c.admitted.fetch_add(10, Relaxed);
        c.served.fetch_add(7, Relaxed);
        c.deadline_expired.fetch_add(2, Relaxed);
        c.panicked.fetch_add(1, Relaxed);
        c.shed.fetch_add(4, Relaxed);
        let snap = c.snapshot();
        assert_eq!(snap.terminal(), snap.admitted);
        assert_eq!(snap.shed, 4);
        assert_eq!(snap.protocol_errors, 0);
    }

    #[test]
    fn traffic_counters_terminal_invariant_and_merge() {
        let a = TrafficCounters {
            admitted: 10,
            served: 7,
            deadline_expired: 2,
            panicked: 1,
            shed: 4,
            protocol_errors: 3,
        };
        assert_eq!(a.terminal(), a.admitted);
        let mut b = a.clone();
        b.merge(&a);
        assert_eq!(b.admitted, 20);
        assert_eq!(b.terminal(), 20);
        assert_eq!(b.shed, 8);
    }

    #[test]
    fn traffic_report_totals_and_display() {
        let mut lat = LatencyHistogram::new();
        lat.record(Duration::from_micros(300));
        let report = TrafficReport {
            tenants: vec![
                TenantTraffic {
                    tenant: "good".into(),
                    counters: TrafficCounters { admitted: 5, served: 5, ..Default::default() },
                    latency: lat,
                    queue_depth: 0,
                    queue_oldest_ms: 0,
                },
                TenantTraffic {
                    tenant: "flood".into(),
                    counters: TrafficCounters {
                        admitted: 3,
                        served: 3,
                        shed: 9,
                        ..Default::default()
                    },
                    latency: LatencyHistogram::new(),
                    queue_depth: 2,
                    queue_oldest_ms: 12,
                },
            ],
            tenant_unknown: 1,
            ..Default::default()
        };
        let tot = report.totals();
        assert_eq!(tot.admitted, 8);
        assert_eq!(tot.shed, 9);
        assert_eq!(report.tenant("flood").unwrap().queue_depth, 2);
        assert!(report.tenant("nope").is_none());
        let txt = report.to_string();
        assert!(txt.contains("8 admitted"));
        assert!(txt.contains("p999"));
        assert!(!txt.contains("PANICKED"));
        let loud = TrafficReport { engine_panicked: true, ..Default::default() };
        assert!(loud.to_string().contains("PANICKED"));
    }
}
