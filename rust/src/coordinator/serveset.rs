//! [`ServeSet`]: one warm compiled artifact graph behind every serving
//! endpoint, plus the global cross-system power batcher.
//!
//! The single-system coordinator compiles one isolated flow per
//! [`super::Pipeline`], so an N-system deployment pays N cold compiles
//! and can never batch work across endpoints. A `ServeSet` inverts that
//! shape, the way Clipper-style serving frontends share model state
//! across endpoints:
//!
//! * it owns **one [`FlowSet`]** (one [`Flow`] session per served
//!   system) optionally backed by **one shared [`ArtifactStore`]**, so
//!   a restarted serve process boots warm — `recomputes() == 0` on
//!   [`ServeSet::total_counts`] for every previously compiled system;
//! * each per-system worker gets a [`SystemHandle`] — a cheap `Arc`
//!   view of its flow's memoized design + mapped netlist — instead of
//!   compiling a private copy ([`super::InferenceServer::start_shared`]);
//! * [`PowerRequest`] floods from *all* systems funnel through one
//!   width-aware [`PowerBatcher`]: requests are grouped by netlist and
//!   the resulting 64/256-lane chunks from every system share one
//!   worker fan-out ([`super::pipeline::estimate_power_requests_grouped`]),
//!   so a mixed flood saturates all cores regardless of how it is
//!   skewed across systems. Results are bit-identical to per-system
//!   dispatch — each lane's stimulus depends only on its own seed.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::batcher::{self, BatchOutcome, QueueGauge};
use super::error::ServeError;
use super::pipeline::{
    estimate_power_requests_fused, estimate_power_requests_grouped, PowerEstimate, PowerRequest,
    SystemPowerRequest,
};
use crate::analyze::{preflight_plan, Severity};
use crate::flow::{ensure_fused, ArtifactStore, Flow, FlowConfig, FlowSet, StageCounts};
use crate::rtl::PiModuleDesign;
use crate::shard::ShardPlan;
use crate::synth::techmap::MappedDesign;
use crate::synth::{LaneWidth, Netlist};

/// A cheap, cloneable view of one system's warm compiled state: the RTL
/// design and its mapped netlist from one consistent cache generation
/// of the owning [`Flow`], shared by reference with every consumer
/// (serving workers, the power batcher, benches).
#[derive(Clone)]
pub struct SystemHandle {
    system: String,
    design: Arc<PiModuleDesign>,
    mapped: Arc<MappedDesign>,
    lane_width: LaneWidth,
    /// The owning flow's netlist-stage fingerprint — the member key of
    /// the cross-system fused stage ([`crate::flow::ensure_fused`]).
    netlist_fp: u64,
}

impl SystemHandle {
    /// Snapshot a flow's design + netlist (compiling or cache-loading
    /// them on demand) into a shareable handle. The handle holds the
    /// *same* `Arc` allocations the flow's stage LRUs do — one resident
    /// copy per artifact no matter how many endpoints serve it, not a
    /// deep clone per handle (single residency, tested below).
    pub fn from_flow(flow: &mut Flow) -> anyhow::Result<SystemHandle> {
        let system = flow.id().to_string();
        let lane_width = flow.config().lane_width;
        let netlist_fp = flow.netlist_fingerprint();
        let design = flow.rtl_shared()?;
        let mapped = flow.netlist_shared()?;
        Ok(SystemHandle { system, design, mapped, lane_width, netlist_fp })
    }

    /// The corpus system this handle serves.
    pub fn system(&self) -> &str {
        &self.system
    }

    /// The generated RTL design.
    pub fn design(&self) -> &PiModuleDesign {
        &self.design
    }

    /// The LUT4-mapped netlist (simulation/power substrate).
    pub fn netlist(&self) -> &Netlist {
        &self.mapped.netlist
    }

    /// The mapped design with resource accounting.
    pub fn mapped(&self) -> &MappedDesign {
        &self.mapped
    }

    /// SIMD lane width of the owning flow's word-parallel passes.
    pub fn lane_width(&self) -> LaneWidth {
        self.lane_width
    }

    /// The owning flow's netlist-stage fingerprint (fused-stage member
    /// key).
    pub fn netlist_fp(&self) -> u64 {
        self.netlist_fp
    }
}

/// Arbitrates whole-machine power floods between concurrent consumers
/// (the traffic engine's dispatch lanes, the power batcher, synchronous
/// callers): one flood already fans out over every core through the
/// worker pool, so running two at once oversubscribes the machine
/// without adding throughput — they queue here instead. Π inference
/// batches are single-threaded per batch and never take this gate;
/// that is where lane parallelism pays.
#[derive(Debug, Default)]
pub struct FloodGate {
    gate: Mutex<()>,
}

impl FloodGate {
    pub fn new() -> FloodGate {
        FloodGate::default()
    }

    /// Run `f` while holding the gate. Poison-tolerant: a panic inside
    /// one flood (contained by its caller) must not wedge every
    /// subsequent flood behind a poisoned lock.
    pub fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        let _held = self.gate.lock().unwrap_or_else(|e| e.into_inner());
        f()
    }
}

/// The serve set's fused evaluation state: the fused netlist of every
/// served system (in boot order) plus its K-way shard plan. Built once
/// by [`ServeSet::enable_fusion`], shared (`Arc`) with the power
/// batcher's worker thread.
pub struct FusedPlan {
    /// The cached fused artifact (netlist + member metadata + keys).
    pub artifact: crate::flow::FusedArtifact,
    /// The K-way partition the sharded simulator runs.
    pub plan: ShardPlan,
}

/// The shared serving substrate: one warm [`FlowSet`] fronting every
/// per-system endpoint (see module docs).
pub struct ServeSet {
    set: FlowSet,
    handles: Vec<SystemHandle>,
    lane_width: LaneWidth,
    /// Shared persistent store (also attached to `set`) — consulted by
    /// the fused stage.
    store: Option<Arc<ArtifactStore>>,
    /// Fused evaluation state when [`ServeSet::enable_fusion`] ran.
    fused: Option<Arc<FusedPlan>>,
    /// Shared flood arbiter (see [`FloodGate`]): every consumer of this
    /// set's power path holds the same gate.
    flood_gate: Arc<FloodGate>,
}

impl ServeSet {
    /// Compile (or warm-load, when `store` carries a previous run's
    /// artifacts) every named system and snapshot a [`SystemHandle`]
    /// per system. Systems compile in parallel across all cores; the
    /// store is shared by every session, so a restarted serve process
    /// boots with zero recomputes ([`ServeSet::total_counts`]).
    ///
    /// Boot is gated by the static verifier: every system's memoized
    /// [`Flow::analysis`] report must be free of error-level findings,
    /// or boot refuses that system with a typed
    /// [`ServeError::AnalysisRejected`] — a netlist with a combinational
    /// loop or a non-dimensionless Π unit would serve garbage answers.
    pub fn boot(
        systems: &[&str],
        config: FlowConfig,
        store: Option<Arc<ArtifactStore>>,
    ) -> anyhow::Result<ServeSet> {
        anyhow::ensure!(!systems.is_empty(), "serve set needs at least one system");
        for (i, id) in systems.iter().enumerate() {
            anyhow::ensure!(
                !systems[..i].contains(id),
                "duplicate system `{id}` in serve set"
            );
        }
        let lane_width = config.lane_width;
        let mut set = FlowSet::for_systems(systems, config)?;
        if let Some(store) = &store {
            set = set.with_store(Arc::clone(store));
        }
        let handles = set
            .run_parallel(|flow| {
                let report = flow.analysis()?;
                if report.has_errors() {
                    return Err(ServeError::AnalysisRejected {
                        system: flow.id().to_string(),
                        errors: report.errors(),
                    }
                    .into());
                }
                SystemHandle::from_flow(flow)
            })
            .into_iter()
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ServeSet {
            set,
            handles,
            lane_width,
            store,
            fused: None,
            flood_gate: Arc::new(FloodGate::new()),
        })
    }

    /// Number of served systems.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Served system ids, in boot order (the `system` index space of
    /// [`SystemPowerRequest`]).
    pub fn systems(&self) -> Vec<&str> {
        self.handles.iter().map(SystemHandle::system).collect()
    }

    /// Index of a system in boot order.
    pub fn system_index(&self, system: &str) -> Option<usize> {
        self.handles.iter().position(|h| h.system() == system)
    }

    /// The shared handle for one served system.
    pub fn handle(&self, system: &str) -> Option<SystemHandle> {
        self.system_index(system).map(|i| self.handles[i].clone())
    }

    /// The shared handle at a boot-order index.
    pub fn handle_at(&self, index: usize) -> &SystemHandle {
        &self.handles[index]
    }

    /// SIMD lane width every batched power pass runs at.
    pub fn lane_width(&self) -> LaneWidth {
        self.lane_width
    }

    /// Fuse every served system's netlist into one module and partition
    /// it into `shards` shards: cross-system power floods then run as
    /// **one sharded evaluation** per lane-width round instead of one
    /// simulation pass per system per chunk
    /// ([`estimate_power_requests_fused`]), with results bit-identical
    /// to the grouped dispatch. The fused netlist is cached in the
    /// attached store under the member netlist fingerprints + K, so a
    /// warm restart skips re-fusing.
    ///
    /// The fused artifact is pre-flighted by the static verifier
    /// ([`preflight_plan`]) before it is installed: an incomplete cut
    /// map, a corrupted scatter index, or a plan whose refine report
    /// disagrees with its real cut cost refuses with a typed
    /// [`ServeError::AnalysisRejected`] instead of arming the sharded
    /// simulator with a plan that would trip its pack-time backstop.
    pub fn enable_fusion(&mut self, shards: usize) -> anyhow::Result<()> {
        let members: Vec<(u64, &Netlist)> = self
            .handles
            .iter()
            .map(|h| (h.netlist_fp(), h.netlist()))
            .collect();
        // The artifact carries the refined shard plan (computed fresh or
        // warm-loaded with the fused netlist; the store key includes the
        // partitioner version, so a stale-algorithm plan cannot serve).
        let artifact = ensure_fused(self.store.as_deref(), &members, shards);
        let findings = preflight_plan(
            &artifact.fused.netlist,
            &artifact.fused.members,
            &artifact.plan,
        );
        let errors = findings.iter().filter(|d| d.severity == Severity::Error).count();
        if errors > 0 {
            for d in &findings {
                eprintln!("{d}");
            }
            return Err(ServeError::AnalysisRejected {
                system: format!("fused({} members, {} shards)", members.len(), shards),
                errors,
            }
            .into());
        }
        let plan = artifact.plan.clone();
        self.fused = Some(Arc::new(FusedPlan { artifact, plan }));
        Ok(())
    }

    /// The fused evaluation state, when fusion is enabled.
    pub fn fusion(&self) -> Option<&FusedPlan> {
        self.fused.as_deref()
    }

    /// The shared flood arbiter. Every consumer that dispatches power
    /// floods against this set (engine lanes, batcher, sync callers)
    /// must run them through this gate.
    pub(crate) fn flood_gate(&self) -> Arc<FloodGate> {
        self.flood_gate.clone()
    }

    /// Shared handle to the fused plan, for consumers that outlive this
    /// borrow (the traffic engine snapshots it at start, like the
    /// batcher does at spawn).
    pub(crate) fn fusion_shared(&self) -> Option<Arc<FusedPlan>> {
        self.fused.clone()
    }

    /// Aggregated stage-cache telemetry across all sessions — after a
    /// warm boot from a populated `--cache-dir`, `recomputes()` is 0.
    pub fn total_counts(&self) -> StageCounts {
        self.set.total_counts()
    }

    /// The underlying sessions, for deeper queries (timing, Verilog …).
    pub fn flows_mut(&mut self) -> &mut [Flow] {
        self.set.flows_mut()
    }

    /// Answer a mixed-system flood of power requests synchronously:
    /// requests are grouped by netlist, packed into lane-width chunks,
    /// and every chunk — across all systems — shares one worker
    /// fan-out. Results come back in request order, bit-identical to
    /// per-system dispatch at either lane width. A request with an
    /// out-of-range system index is an error (like
    /// [`PowerBatcher::submit`]), not a panic.
    pub fn estimate_power_flood(
        &self,
        requests: &[SystemPowerRequest],
        activations: u32,
    ) -> anyhow::Result<Vec<PowerEstimate>> {
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(
                r.system < self.handles.len(),
                "request {i} targets system index {} but this serve set has {} systems",
                r.system,
                self.handles.len()
            );
        }
        Ok(self.flood_gate.run(|| {
            dispatch_flood(
                &self.handles,
                self.fused.as_deref(),
                requests,
                activations,
                self.lane_width,
            )
        }))
    }

    /// Start the global power batcher: a worker thread that collects
    /// [`PowerRequest`]s from every system behind one channel and
    /// answers each batch through the cross-system grouped dispatch.
    /// The batch cap is width-aware — `lanes × systems`, one full
    /// word-parallel pass per system per batch; `linger` bounds waiting
    /// only (zero linger still drains ready floods whole).
    pub fn power_batcher(&self, linger: Duration, activations: u32) -> PowerBatcher {
        let handles = self.handles.clone();
        let fused = self.fused.clone();
        let gate = self.flood_gate.clone();
        let width = self.lane_width;
        let max_batch = width.lanes() * handles.len();
        let (tx, rx) = mpsc::channel::<PowerJob>();
        let gauge = Arc::new(QueueGauge::new());
        let worker = {
            let gauge = gauge.clone();
            std::thread::Builder::new()
                .name("dimsynth-power-batcher".to_string())
                .spawn(move || {
                    batcher_loop(
                        &handles,
                        fused.as_deref(),
                        &gate,
                        width,
                        max_batch,
                        linger,
                        activations,
                        rx,
                        &gauge,
                    )
                })
                .expect("spawn power batcher")
        };
        PowerBatcher { tx: Some(tx), worker: Some(worker), gauge }
    }
}

/// One in-flight power request: target system index + stimulus request
/// + reply channel.
struct PowerJob {
    system: usize,
    request: PowerRequest,
    resp: Sender<anyhow::Result<PowerEstimate>>,
}

/// Counters of one [`PowerBatcher`] run.
#[derive(Clone, Copy, Debug, Default)]
pub struct FloodStats {
    /// Requests answered successfully.
    pub requests: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Batches that mixed more than one system — the cross-system
    /// packing the shared frontend exists for.
    pub mixed_batches: u64,
    /// The batcher worker died by panic; counters are partial.
    pub worker_panicked: bool,
}

impl FloodStats {
    /// Mean requests per dispatched batch.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }
}

/// Handle to the running cross-system power batcher
/// ([`ServeSet::power_batcher`]).
pub struct PowerBatcher {
    tx: Option<Sender<PowerJob>>,
    worker: Option<JoinHandle<FloodStats>>,
    /// Real queue pressure of the submit channel — admission control
    /// and metrics read this instead of guessing.
    gauge: Arc<QueueGauge>,
}

impl PowerBatcher {
    /// Submit one request against the serve set's `system` index (boot
    /// order); returns the response channel. An out-of-range index is
    /// answered with an error, not a crash.
    pub fn submit(
        &self,
        system: usize,
        request: PowerRequest,
    ) -> Receiver<anyhow::Result<PowerEstimate>> {
        let (tx, rx) = mpsc::channel();
        if let Some(q) = &self.tx {
            self.gauge.on_enqueue();
            let _ = q.send(PowerJob { system, request, resp: tx });
        }
        rx
    }

    /// Requests submitted but not yet collected into a batch.
    pub fn queue_depth(&self) -> usize {
        self.gauge.depth()
    }

    /// Age of the oldest uncollected request (`None` when the queue is
    /// empty) — the live drain-time estimate behind retry-after hints.
    pub fn queue_oldest_age(&self) -> Option<Duration> {
        self.gauge.oldest_age()
    }

    /// Close the queue and collect final statistics; a panicked worker
    /// is surfaced via [`FloodStats::worker_panicked`].
    pub fn shutdown(mut self) -> FloodStats {
        self.tx.take();
        match self.worker.take().map(JoinHandle::join) {
            Some(Ok(stats)) => stats,
            Some(Err(_)) => FloodStats { worker_panicked: true, ..FloodStats::default() },
            None => FloodStats::default(),
        }
    }
}

/// Route one validated flood through the fused sharded evaluation when
/// enabled, else the grouped per-system dispatch — the two produce
/// bit-identical estimates ([`estimate_power_requests_fused`]).
pub(crate) fn dispatch_flood(
    handles: &[SystemHandle],
    fused: Option<&FusedPlan>,
    requests: &[SystemPowerRequest],
    activations: u32,
    width: LaneWidth,
) -> Vec<PowerEstimate> {
    match fused {
        Some(f) => {
            let designs: Vec<&PiModuleDesign> = handles.iter().map(|h| h.design()).collect();
            estimate_power_requests_fused(
                &f.artifact.fused,
                &f.plan,
                &designs,
                requests,
                activations,
                width,
            )
        }
        None => {
            let targets: Vec<(&Netlist, &PiModuleDesign)> =
                handles.iter().map(|h| (h.netlist(), h.design())).collect();
            estimate_power_requests_grouped(&targets, requests, activations, width)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    handles: &[SystemHandle],
    fused: Option<&FusedPlan>,
    gate: &FloodGate,
    width: LaneWidth,
    max_batch: usize,
    linger: Duration,
    activations: u32,
    rx: Receiver<PowerJob>,
    gauge: &QueueGauge,
) -> FloodStats {
    let n_systems = handles.len();
    let mut stats = FloodStats::default();
    loop {
        let (batch, closing) = match batcher::collect(&rx, max_batch, linger) {
            BatchOutcome::Batch(b) => (b, false),
            BatchOutcome::Closed(b) => (b, true),
        };
        gauge.on_dequeue(batch.len());
        let mut jobs = Vec::with_capacity(batch.len());
        for job in batch {
            if job.system >= n_systems {
                let _ = job.resp.send(Err(anyhow::anyhow!(
                    "no system index {} in this serve set ({} systems)",
                    job.system,
                    n_systems
                )));
            } else {
                jobs.push(job);
            }
        }
        if !jobs.is_empty() {
            stats.batches += 1;
            stats.requests += jobs.len() as u64;
            if jobs.iter().any(|j| j.system != jobs[0].system) {
                stats.mixed_batches += 1;
            }
            let tagged: Vec<SystemPowerRequest> = jobs
                .iter()
                .map(|j| SystemPowerRequest { system: j.system, request: j.request })
                .collect();
            let estimates =
                gate.run(|| dispatch_flood(handles, fused, &tagged, activations, width));
            for (job, estimate) in jobs.into_iter().zip(estimates) {
                let _ = job.resp.send(Ok(estimate));
            }
        }
        if closing {
            break;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_rejects_empty_duplicate_and_unknown_sets() {
        let err = ServeSet::boot(&[], FlowConfig::default(), None).unwrap_err().to_string();
        assert!(err.contains("at least one"), "{err}");
        let err = ServeSet::boot(&["pendulum", "pendulum"], FlowConfig::default(), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        let err = ServeSet::boot(&["warp_core"], FlowConfig::default(), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("warp_core"), "{err}");
    }

    #[test]
    fn boot_hands_out_per_system_handles() {
        let set = ServeSet::boot(&["spring_mass", "pendulum"], FlowConfig::default(), None)
            .unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.systems(), ["spring_mass", "pendulum"]);
        assert_eq!(set.system_index("pendulum"), Some(1));
        assert!(set.handle("beam").is_none());
        let h = set.handle("pendulum").unwrap();
        assert_eq!(h.system(), "pendulum");
        assert_eq!(h.design().system, "pendulum");
        assert!(h.mapped().lut4_cells > 0);
        assert_eq!(h.lane_width(), LaneWidth::W256);
        // Handles are views of the same warm state, not copies per
        // caller.
        let again = set.handle("pendulum").unwrap();
        assert!(Arc::ptr_eq(&h.mapped, &again.mapped));
    }

    #[test]
    fn handles_share_single_resident_artifacts_with_the_flow() {
        // Regression for the double-resident memory bug: `from_flow`
        // used to deep-clone the design and netlist out of the stage
        // LRUs, so every serve set kept a second copy of each artifact
        // resident. The handle must hold the *same* allocation the
        // flow's cache does.
        let mut set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let h = set.handle("pendulum").unwrap();
        let h2 = set.handle("pendulum").unwrap();
        assert!(Arc::ptr_eq(&h.design, &h2.design));
        assert!(Arc::ptr_eq(&h.mapped, &h2.mapped));
        let flow = &mut set.flows_mut()[0];
        let counts_before = flow.counts();
        let design = flow.rtl_shared().unwrap();
        let mapped = flow.netlist_shared().unwrap();
        assert!(
            Arc::ptr_eq(&h.design, &design),
            "handle design must be the flow's cached allocation, not a clone"
        );
        assert!(
            Arc::ptr_eq(&h.mapped, &mapped),
            "handle netlist must be the flow's cached allocation, not a clone"
        );
        assert_eq!(
            flow.counts().recomputes(),
            counts_before.recomputes(),
            "shared lookups must not recompute"
        );
    }

    #[test]
    fn batcher_gauge_reports_real_queue_pressure() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        let batcher = set.power_batcher(Duration::ZERO, 1);
        assert_eq!(batcher.queue_depth(), 0);
        assert!(batcher.queue_oldest_age().is_none());
        let pending: Vec<_> = (0..8)
            .map(|i| batcher.submit(0, PowerRequest { seed: i + 1, f_hz: 6.0e6 }))
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        // Everything answered ⇒ everything collected ⇒ gauge drained.
        assert_eq!(batcher.queue_depth(), 0);
        assert!(batcher.queue_oldest_age().is_none());
        let stats = batcher.shutdown();
        assert_eq!(stats.requests, 8);
    }

    /// Enabling fusion must leave every flood answer bit-identical to
    /// the grouped dispatch — same requests, same estimates — while the
    /// batcher keeps working through the fused path.
    #[test]
    fn fused_flood_matches_grouped_flood() {
        let mut set =
            ServeSet::boot(&["pendulum", "spring_mass"], FlowConfig::default(), None).unwrap();
        let requests: Vec<SystemPowerRequest> = (0..9u32)
            .map(|i| SystemPowerRequest {
                system: (i % 2) as usize,
                request: PowerRequest { seed: 0x100 + i, f_hz: 6.0e6 },
            })
            .collect();
        let grouped = set.estimate_power_flood(&requests, 1).unwrap();
        assert!(set.fusion().is_none());
        set.enable_fusion(2).unwrap();
        let fp = set.fusion().expect("fusion enabled");
        assert_eq!(fp.artifact.fused.member_count(), 2);
        assert_eq!(fp.plan.shards, 2);
        let fused = set.estimate_power_flood(&requests, 1).unwrap();
        for (i, (g, f)) in grouped.iter().zip(&fused).enumerate() {
            assert_eq!(g.mw, f.mw, "request {i}");
            assert_eq!(g.toggles_per_cycle, f.toggles_per_cycle, "request {i}");
            assert_eq!(g.cycles, f.cycles, "request {i}");
        }
        // The batcher inherits the fused path at spawn.
        let batcher = set.power_batcher(Duration::ZERO, 1);
        let rx = batcher.submit(1, requests[1].request);
        let est = rx.recv().unwrap().unwrap();
        assert_eq!(est.mw, grouped[1].mw);
        let stats = batcher.shutdown();
        assert_eq!(stats.requests, 1);
    }

    /// The serve-boot analysis gate, end to end through the store: a
    /// stored analysis report carrying an error-level finding must make
    /// [`ServeSet::boot`] refuse that system with the typed
    /// `AnalysisRejected` message instead of serving it.
    #[test]
    fn boot_refuses_a_system_with_error_level_findings() {
        use crate::analyze::{AnalysisReport, DiagCode, Diagnostic, Locus};
        let dir = std::env::temp_dir()
            .join(format!("dimsynth-serve-gate-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        // The gate reads the memoized analyze artifact, so poisoning the
        // store entry under the real stage fingerprint exercises the
        // exact load path a warm production boot takes.
        let fp = Flow::for_system("pendulum", FlowConfig::default())
            .unwrap()
            .analysis_fingerprint();
        let poisoned = AnalysisReport {
            system: "pendulum".into(),
            diagnostics: vec![Diagnostic::new(
                DiagCode::CombLoop,
                Locus::Net(3),
                "cycle 3 -> 3 (injected)",
            )],
        };
        store.save(fp, &poisoned).unwrap();
        let err = ServeSet::boot(&["pendulum"], FlowConfig::default(), Some(store))
            .unwrap_err()
            .to_string();
        assert!(err.contains("rejected by static analysis"), "{err}");
        assert!(err.contains("pendulum"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
        // The pristine corpus system boots clean without the poison.
        assert!(ServeSet::boot(&["pendulum"], FlowConfig::default(), None).is_ok());
    }

    #[test]
    fn flood_gate_is_reusable_after_a_contained_panic() {
        let gate = FloodGate::new();
        assert_eq!(gate.run(|| 41 + 1), 42);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            gate.run(|| panic!("boom"))
        }));
        assert!(outcome.is_err());
        // Poison tolerance: a panicked flood must not wedge the next.
        assert_eq!(gate.run(|| 7), 7);
    }

    #[test]
    fn batcher_rejects_out_of_range_system_index() {
        let set = ServeSet::boot(&["pendulum"], FlowConfig::default(), None).unwrap();
        // The synchronous flood errors (not panics) on a bad index too.
        let bad_flood = [SystemPowerRequest {
            system: 5,
            request: PowerRequest { seed: 1, f_hz: 6.0e6 },
        }];
        let err = set.estimate_power_flood(&bad_flood, 1).unwrap_err().to_string();
        assert!(err.contains("system index 5"), "{err}");
        let batcher = set.power_batcher(Duration::ZERO, 1);
        let bad = batcher.submit(5, PowerRequest { seed: 1, f_hz: 6.0e6 });
        let ok = batcher.submit(0, PowerRequest { seed: 1, f_hz: 6.0e6 });
        assert!(bad.recv().unwrap().is_err());
        assert!(ok.recv().unwrap().is_ok());
        let stats = batcher.shutdown();
        assert_eq!(stats.requests, 1, "{stats:?}");
        assert!(!stats.worker_panicked);
    }
}
