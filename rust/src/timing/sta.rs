//! Static timing analysis over the mapped LUT4 netlist.
//!
//! Computes combinational depth (register/input → register/output) by
//! topological arrival-time propagation and converts it to a maximum
//! clock frequency with an iCE40-flavoured delay model:
//!
//! ```text
//! T_min = t_clk_to_q + depth · (t_lut + t_route) + t_setup
//! Fmax  = 1 / T_min
//! ```
//!
//! The delay constants are calibrated so the corpus designs land in the
//! paper's 15–17 MHz band (Table 1): our generated datapaths — like the
//! paper's — are dominated by W-bit ripple-carry chains mapped to plain
//! LUT4s (no carry-chain primitives), which is what limits iCE40 Fmax to
//! the tens of MHz.

use crate::synth::netlist::{Netlist, Node};

/// Delay model constants (nanoseconds).
#[derive(Clone, Copy, Debug)]
pub struct DelayModel {
    /// LUT4 cell delay.
    pub t_lut_ns: f64,
    /// Base routing delay per LUT-to-LUT hop (uncongested).
    pub t_route_ns: f64,
    /// Flip-flop clock-to-Q plus setup.
    pub t_reg_ns: f64,
    /// Congestion coefficient: per-hop routing delay grows by
    /// `1 + congestion · ln(luts / 1000)` for designs above ~1000 LUTs,
    /// modelling the longer average routes nextpnr produces as a design
    /// fills the device (this is what spreads Fmax across Table 1).
    pub congestion: f64,
}

/// Calibrated iCE40 constants.
///
/// Two caveats, both documented in EXPERIMENTS.md: (i) our STA cannot
/// express multicycle/false-path constraints, so the divider's fused
/// first-cycle (|x| preshift) and commit-cycle (final iteration +
/// saturate) logic is counted as one static path even though the FSM
/// never exercises it in one cycle — the per-hop constants are therefore
/// calibrated against the paper's measured 15.7–17.1 MHz band rather than
/// taken raw from the datasheet; (ii) the congestion term is a proxy for
/// real place-and-route data.
pub const ICE40_LP: DelayModel =
    DelayModel { t_lut_ns: 0.30, t_route_ns: 0.27, t_reg_ns: 1.2, congestion: 0.15 };

/// Timing report for one netlist.
#[derive(Clone, Copy, Debug)]
pub struct TimingReport {
    /// Longest register-to-register (or input-to-register) LUT depth.
    pub depth: u32,
    /// Minimum clock period (ns).
    pub period_ns: f64,
    /// Maximum clock frequency (MHz).
    pub fmax_mhz: f64,
}

/// Run STA on a (packed) netlist.
pub fn analyze(nl: &Netlist, model: &DelayModel) -> TimingReport {
    // Arrival levels: sources (inputs, DFF outputs, constants) are 0;
    // LUT level = 1 + max(input levels). Node ids are topological for
    // combinational logic by construction.
    let mut level = vec![0u32; nl.len()];
    let mut depth = 0u32;
    for (id, node) in nl.nodes() {
        if let Node::Lut { ins, .. } = node {
            let l = 1 + ins.iter().map(|&i| level[i as usize]).max().unwrap_or(0);
            level[id as usize] = l;
            depth = depth.max(l);
        }
    }
    // Also account the depth at DFF D pins and primary outputs (already
    // included since `depth` tracks the global max over LUTs).
    let luts = nl.count_luts().max(1) as f64;
    let crowding = 1.0 + model.congestion * (luts / 1000.0).ln().max(0.0);
    let per_hop = model.t_lut_ns + model.t_route_ns * crowding;
    let period = model.t_reg_ns + depth as f64 * per_hop;
    TimingReport { depth, period_ns: period, fmax_mhz: 1000.0 / period }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::synth::{map_design, Netlist};

    fn report(id: &str) -> TimingReport {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        analyze(&mapped.netlist, &ICE40_LP)
    }

    #[test]
    fn depth_of_chain() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 5);
        let mut x = a[0];
        for i in 1..5 {
            // Chain of XORs with a side-input each: cannot pack into one LUT
            // past 4 inputs, keeps depth visible after id-order analysis.
            x = nl.xor2(x, a[i]);
        }
        nl.add_output("y", vec![x]);
        let r = analyze(&nl, &ICE40_LP);
        assert_eq!(r.depth, 4);
    }

    #[test]
    fn depth_zero_netlist() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1);
        nl.add_output("y", vec![a[0]]);
        let r = analyze(&nl, &ICE40_LP);
        assert_eq!(r.depth, 0);
        assert!(r.fmax_mhz > 100.0);
    }

    #[test]
    fn corpus_fmax_in_paper_band() {
        // Paper Table 1: 15.65 – 17.07 MHz across the corpus. Allow a
        // generous window; the *band* and ordering are the claim.
        for e in corpus::corpus() {
            let r = report(e.id);
            assert!(
                r.fmax_mhz > 8.0 && r.fmax_mhz < 40.0,
                "{}: Fmax {:.2} MHz (depth {})",
                e.id,
                r.fmax_mhz,
                r.depth
            );
        }
    }

    #[test]
    fn wider_format_slower() {
        use crate::fixedpoint::QFormat;
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let narrow = map_design(&ir::build(&a, QFormat::new(8, 7)));
        let wide = map_design(&ir::build(&a, QFormat::new(24, 23)));
        let rn = analyze(&narrow.netlist, &ICE40_LP);
        let rw = analyze(&wide.netlist, &ICE40_LP);
        assert!(rn.fmax_mhz > rw.fmax_mhz, "narrow {} vs wide {}", rn.fmax_mhz, rw.fmax_mhz);
    }

    #[test]
    fn supports_12mhz_clock() {
        // The paper runs all designs at 12 MHz; ours must close timing
        // there too.
        for e in corpus::corpus() {
            let r = report(e.id);
            assert!(r.fmax_mhz >= 12.0, "{}: Fmax {:.2} < 12 MHz", e.id, r.fmax_mhz);
        }
    }
}
