//! Static timing analysis → maximum clock frequency (Table-1 "Maximum
//! Frequency" column).

pub mod sta;

pub use sta::{analyze, DelayModel, TimingReport, ICE40_LP};
