//! Fused multi-system netlists and register-boundary sharding.
//!
//! The paper's circuits are tiny (1.2–1.7k gates), so one system rarely
//! has enough width to keep even a single core busy, let alone many.
//! This subsystem goes the other way: instead of splitting one small
//! netlist across threads, it *fuses* many systems into one wide module
//! and partitions that across persistent workers.
//!
//! Three layers:
//!
//! 1. **Fusion** ([`fusion::FusedNetlist`]) — merge N member netlists
//!    into one module. Member net ids are offset into disjoint,
//!    contiguous ranges; input/output bus names are namespaced
//!    (`s{m}/…`); a per-member index records each member's net range so
//!    results scatter back exactly.
//! 2. **Partitioning** ([`partition::ShardPlan`]) — cut the fused
//!    netlist into K shards along register/level boundaries, balancing
//!    LUT count per shard (LPT over whole members, splitting the
//!    largest member at a level boundary when shards would otherwise
//!    sit empty). The cross-shard dependencies are reified as an
//!    explicit cut-signal interface ([`partition::CutMap`]).
//! 3. **Sharded evaluation** ([`shardsim::ShardSim`]) — one persistent
//!    worker per shard, driving the same packed-LUT word-parallel
//!    engine as [`crate::synth::WordSim`], with results (values,
//!    per-net toggles, per-member per-lane toggle totals, cycle counts)
//!    bit-identical to running every member solo.
//!
//! # Cut-signal exchange protocol
//!
//! A cut is a net owned by one shard and read by another. The simulator
//! exchanges cut values through the shared value array itself — the
//! "mailbox" is the value word of the cut net — under the same
//! monotonic spin-phase protocol as [`crate::synth::ParSession`]:
//!
//! * **Register cuts** (`CutMap::reg_cuts`): the cut net is level-0
//!   (primary input, constant, or DFF q). Its value only changes
//!   *between* evaluation phases — inputs are bound by the driving
//!   thread outside any phase, and DFF commits happen in the driving
//!   thread's clock-edge phase after all workers joined. Readers can
//!   never observe a half-updated cycle, so these cuts need no extra
//!   synchronization beyond the per-cycle barrier.
//! * **DFF cuts** (`CutMap::dff_cuts`): a combinational net feeding a
//!   DFF d-input owned by another shard. The driving thread samples
//!   every d after the last evaluation phase of the cycle joined, so
//!   the per-cycle barrier again suffices.
//! * **Combinational cuts** (`CutMap::comb_cuts`): a LUT output read by
//!   a cross-shard LUT in the *same* cycle. These force per-level
//!   phasing: every level becomes one phase, all shards evaluate their
//!   slice of the level, and the Release/Acquire pair on the phase and
//!   done counters publishes level-L cut values before any shard starts
//!   level L+1. A plan with no combinational cuts (the whole-member
//!   common case) collapses to one phase per cycle.
//!
//! Toggle accounting follows [`crate::synth::WordSim`] exactly, but the
//! per-lane carry-save accumulator is kept *per member*, so each
//! member's per-lane toggle totals (and hence its power figures) can be
//! read back individually and match its solo run bit for bit.

pub mod fusion;
pub mod partition;
pub mod power;
pub mod shardsim;

pub use fusion::{FusedMember, FusedNetlist};
pub use partition::{Cut, CutMap, ShardPlan};
pub use power::{measure_fused_activity, MemberStim};
pub use shardsim::{ShardDrive, ShardSim};
