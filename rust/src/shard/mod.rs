//! Fused multi-system netlists and register-boundary sharding.
//!
//! The paper's circuits are tiny (1.2–1.7k gates), so one system rarely
//! has enough width to keep even a single core busy, let alone many.
//! This subsystem goes the other way: instead of splitting one small
//! netlist across threads, it *fuses* many systems into one wide module
//! and partitions that across persistent workers.
//!
//! Three layers:
//!
//! 1. **Fusion** ([`fusion::FusedNetlist`]) — merge N member netlists
//!    into one module. Member net ids are offset into disjoint,
//!    contiguous ranges; input/output bus names are namespaced
//!    (`s{m}/…`); a per-member index records each member's net range so
//!    results scatter back exactly.
//! 2. **Partitioning** ([`partition::ShardPlan`]) — cut the fused
//!    netlist into K shards. A level-boundary LPT pass seeds the plan
//!    (whole members largest-first, splitting the largest member at a
//!    level boundary when shards would otherwise sit empty); a
//!    KL/FM-style refinement pass then greedily moves gate clusters —
//!    (member, level) tiles and level-0 nets — between shards, applying
//!    only moves that strictly shrink the cut under a gate-balance
//!    tolerance. Refinement is deterministic and monotone (the refined
//!    cut cost never exceeds the seed's); [`partition::RefineReport`]
//!    records the before/after cost and move counts, and
//!    [`partition::PARTITIONER_VERSION`] enters the fused-artifact
//!    fingerprint so cached plans are invalidated when the algorithm
//!    changes. The cross-shard dependencies are reified as an explicit
//!    cut-signal interface ([`partition::CutMap`]); its size
//!    ([`partition::ShardPlan::cut_cost`]) is the communication cost
//!    refinement minimizes.
//! 3. **Sharded evaluation** ([`shardsim::ShardSim`]) — one persistent
//!    worker per shard, driving the same packed-LUT word-parallel
//!    engine as [`crate::synth::WordSim`], with results (values,
//!    per-net toggles, per-member per-lane toggle totals, cycle counts)
//!    bit-identical to running every member solo.
//!
//! # Dirty-word cut exchange protocol
//!
//! A cut is a net owned by one shard and read by another. Each distinct
//! cut net gets a **mirror word** appended to the shared value array;
//! cross-shard readers are remapped to mirrors at pack time, so the
//! only writer of a cut net's home word is its owner and the only
//! writer of a mirror is the exchange. Publication into the mirrors is
//! **incremental**: a cut word is copied only when its value changed
//! since the last publication, so a quiescent region of the module
//! costs no exchange traffic. Because every change is published, a
//! clean dirty bit implies mirror == source — skipping clean words can
//! never be observed by a reader. Synchronization rides the same
//! monotonic spin-phase protocol as [`crate::synth::ParSession`]:
//!
//! * **Register cuts** (`CutMap::reg_cuts`): the cut net is level-0
//!   (primary input, constant, or DFF q). The driving thread marks a
//!   per-64-cut-word dirty-summary bitmask when it binds an input or
//!   commits a DFF, and pumps only the flagged words into their mirrors
//!   at the start of the next cycle, outside any phase — one summary
//!   test skips 64 clean words at once. Mirrors are frozen while
//!   workers run, so a mid-phase reader can never observe a
//!   half-updated cycle.
//! * **DFF cuts** (`CutMap::dff_cuts`): a combinational net feeding a
//!   DFF d-input owned by another shard. The driving thread samples
//!   every d after the last evaluation phase of the cycle joined, so
//!   the per-cycle barrier suffices (no mirror needed).
//! * **Combinational cuts** (`CutMap::comb_cuts`): a LUT output read by
//!   a cross-shard LUT in the *same* cycle. These force per-level
//!   phasing: every level becomes one phase, and the owning shard
//!   publishes its dirty level-L cut words into the mirrors before
//!   signalling the phase done — the Release/Acquire pair on the done
//!   and phase counters makes them visible before any shard starts
//!   level L+1. The dirty bit is free: the engine's per-net toggle word
//!   is nonzero exactly when the value word changed this cycle. A plan
//!   with no combinational cuts (the whole-member common case)
//!   collapses to one phase per cycle.
//!
//! [`shardsim::ExchangeStats`] counts, per shard, the words actually
//! published versus the publication opportunities skipped (each owned
//! cut word has exactly one opportunity per cycle), plus the sync
//! phases run — the shard bench gates on the dirty filter publishing
//! strictly fewer words than full republication.
//!
//! Toggle accounting follows [`crate::synth::WordSim`] exactly, but the
//! per-lane carry-save accumulator is kept *per member*, so each
//! member's per-lane toggle totals (and hence its power figures) can be
//! read back individually and match its solo run bit for bit.

pub mod fusion;
pub mod partition;
pub mod power;
pub mod shardsim;

pub use fusion::{Cluster, ClusterIndex, FusedMember, FusedNetlist};
pub use partition::{Cut, CutMap, RefineReport, ShardPlan, PARTITIONER_VERSION};
pub use power::{measure_fused_activity, MemberStim};
pub use shardsim::{ExchangeStats, ShardDrive, ShardSim};
