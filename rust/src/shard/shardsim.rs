//! Sharded word-parallel evaluation of a fused netlist.
//!
//! One persistent worker per shard, driven by the same monotonic
//! spin-phase protocol as [`crate::synth::ParSession`] — but where a
//! parallel session fans each *level* of one netlist across threads,
//! a shard session fans the *shards* of a fused netlist: worker `w`
//! owns shard `w`'s packed LUTs for the whole session (the driving
//! thread doubles as shard 0's worker). Cut-signal values travel
//! through the shared value array under the phase barrier (see the
//! exchange protocol in [`crate::shard`]).
//!
//! Phase granularity follows the plan: with no combinational cuts
//! (whole-member partitions) every worker sweeps all its levels in one
//! phase per cycle; with combinational cuts every level is a phase, so
//! cross-shard same-cycle signals are published before their readers
//! run. Either way, results are bit-identical to evaluating every
//! member solo with [`crate::synth::WordSim`]: identical output words,
//! per-net toggles, and per-member per-lane toggle totals.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::fusion::FusedNetlist;
use super::partition::ShardPlan;
use crate::synth::wordsim::{
    compile_tt, eval_chunk, flush_planes_into, plane_accumulate, wait_phase, PackedWordLut,
    ParCtrl, RawSlice, PHASE_STOP, PLANES,
};
use crate::synth::{Drive, LaneWord, NetId, Netlist, Node};

/// Flush every member's bit-plane accumulator into its per-lane totals.
fn flush_members<W: LaneWord>(
    member_planes: &mut [[W; PLANES]],
    member_flushed: &mut [Vec<u64>],
    plane_adds: &mut u64,
) {
    for (planes, flushed) in member_planes.iter_mut().zip(member_flushed.iter_mut()) {
        flush_planes_into(planes, flushed, plane_adds);
    }
    *plane_adds = 0;
}

/// Word-parallel simulation state for a fused netlist partitioned by a
/// [`ShardPlan`]. Construction packs the combinational plan level-major
/// and shard-grouped within each level; [`ShardSim::session`] spawns
/// the shard workers and hands out a [`ShardDrive`].
pub struct ShardSim<'n, W: LaneWord = u64> {
    fused: &'n FusedNetlist,
    /// Current value word of every net.
    vals: Vec<W>,
    /// Per-net toggle counters, summed across lanes.
    toggles: Vec<u64>,
    /// Per-member bit-plane accumulators of per-lane toggle totals.
    member_planes: Vec<[W; PLANES]>,
    /// Per-member flushed per-lane toggle totals.
    member_flushed: Vec<Vec<u64>>,
    /// Accumulator adds since the last flush (overflow guard, shared by
    /// all members — conservative, since each member sees at most this
    /// many adds).
    plane_adds: u64,
    flush_threshold: u64,
    cycles: u64,
    bus: HashMap<String, Vec<NetId>>,
    /// Packed plan: level-major, grouped by owning shard within each
    /// level.
    luts: Vec<PackedWordLut>,
    /// Per level, per shard: half-open range into `luts`.
    level_shard_bounds: Vec<Vec<(u32, u32)>>,
    /// Per level: the whole level's range (all shards).
    level_bounds: Vec<(u32, u32)>,
    /// Per shard: its non-empty per-level ranges, level-ascending (the
    /// single-phase sweep order).
    shard_levels: Vec<Vec<(u32, u32)>>,
    dffs: Vec<(u32, u32)>,
    scratch: Vec<W>,
    per_level: bool,
    workers: usize,
}

impl<'n, W: LaneWord> ShardSim<'n, W> {
    pub fn new(fused: &'n FusedNetlist, plan: &ShardPlan) -> ShardSim<'n, W> {
        let nl: &Netlist = &fused.netlist;
        assert_eq!(plan.owner.len(), nl.len(), "plan does not match netlist");
        let k = plan.shards.max(1);
        let lv = nl.levelize();
        let mut vals = vec![W::zero(); nl.len()];
        let mut dffs = Vec::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Const(true) => vals[id as usize] = W::ones(),
                Node::Dff { d, init } => {
                    if *init {
                        vals[id as usize] = W::ones();
                    }
                    dffs.push((id, *d));
                }
                _ => {}
            }
        }
        let mut luts = Vec::with_capacity(lv.order.len());
        let mut level_shard_bounds = Vec::with_capacity(lv.depth() as usize);
        let mut level_bounds = Vec::with_capacity(lv.depth() as usize);
        let mut shard_levels = vec![Vec::new(); k];
        for level in 1..=lv.depth() {
            let ls = luts.len() as u32;
            let mut per_shard = Vec::with_capacity(k);
            for shard in 0..k as u16 {
                let s = luts.len() as u32;
                for &id in lv.level_luts(level) {
                    if plan.owner[id as usize] != shard {
                        continue;
                    }
                    let Node::Lut { ins, tt } = nl.node(id) else {
                        unreachable!("levelization order contains only LUTs")
                    };
                    let mut packed = [ins[0]; 4];
                    for (j, &i) in ins.iter().enumerate() {
                        packed[j] = i;
                    }
                    let (sel, inv) = compile_tt(*tt, ins.len());
                    luts.push(PackedWordLut { out: id, ins: packed, sel, inv });
                }
                let e = luts.len() as u32;
                per_shard.push((s, e));
                if e > s {
                    shard_levels[shard as usize].push((s, e));
                }
            }
            level_shard_bounds.push(per_shard);
            level_bounds.push((ls, luts.len() as u32));
        }
        let n_members = fused.member_count();
        let scratch = vec![W::zero(); dffs.len()];
        ShardSim {
            fused,
            vals,
            toggles: vec![0; nl.len()],
            member_planes: vec![[W::zero(); PLANES]; n_members],
            member_flushed: vec![vec![0u64; W::LANES]; n_members],
            plane_adds: 0,
            flush_threshold: u64::from(u32::MAX),
            cycles: 0,
            bus: nl.input_buses.iter().map(|(n, b)| (n.clone(), b.clone())).collect(),
            luts,
            level_shard_bounds,
            level_bounds,
            shard_levels,
            dffs,
            scratch,
            per_level: plan.per_level_sync(),
            workers: k,
        }
    }

    /// Lower the bit-plane flush threshold (test hook; see
    /// [`crate::synth::WordSim::with_plane_flush_threshold`]).
    pub fn with_plane_flush_threshold(mut self, adds: u64) -> ShardSim<'n, W> {
        self.flush_threshold = adds.min(u64::from(u32::MAX));
        self
    }

    /// The fused netlist this simulator evaluates.
    pub fn fused(&self) -> &'n FusedNetlist {
        self.fused
    }

    /// Shard workers that a session would spawn in addition to the
    /// driving thread.
    pub fn extra_workers(&self) -> usize {
        self.workers - 1
    }

    /// Whether sessions synchronize per level (combinational cuts) or
    /// once per cycle.
    pub fn per_level_sync(&self) -> bool {
        self.per_level
    }

    /// Run `f` against a [`ShardDrive`] over this simulator: one
    /// persistent worker per shard beyond shard 0 (the driving
    /// thread's), spawned once for the whole session. All counters
    /// survive the session; results are bit-identical to solo
    /// evaluation of every member.
    pub fn session<R>(&mut self, f: impl FnOnce(&mut ShardDrive<'_, W>) -> R) -> R {
        let fused = self.fused;
        let nets = fused.netlist.len();
        let per_level = self.per_level;
        let workers = self.workers;
        let depth = self.level_bounds.len();
        let ShardSim {
            vals,
            toggles,
            member_planes,
            member_flushed,
            plane_adds,
            flush_threshold,
            cycles,
            bus,
            luts,
            level_shard_bounds,
            level_bounds,
            shard_levels,
            dffs,
            scratch,
            ..
        } = self;
        let mut tword = vec![W::zero(); luts.len()];
        // Shared raw views under the phase protocol, as in
        // `WordSim::parallel_session`.
        let vals_raw = RawSlice::new(vals.as_mut_slice());
        let toggles_raw = RawSlice::new(toggles.as_mut_slice());
        let tword_raw = RawSlice::new(tword.as_mut_slice());
        let ctrl = ParCtrl { phase: AtomicUsize::new(0), done: AtomicUsize::new(0) };
        let luts: &[PackedWordLut] = luts;
        let lsb: &[Vec<(u32, u32)>] = level_shard_bounds;
        let slv: &[Vec<(u32, u32)>] = shard_levels;
        let ctrl_ref = &ctrl;
        std::thread::scope(|s| {
            for w in 1..workers {
                s.spawn(move || {
                    let mut last = 0usize;
                    loop {
                        let p = wait_phase(ctrl_ref, last);
                        if p == PHASE_STOP {
                            break;
                        }
                        last = p;
                        // Safety: this shard owns its LUTs' out nets and
                        // tword slots exclusively (the owner map is a
                        // partition); reads are either same-shard
                        // earlier levels, cut nets published by the
                        // previous phase (comb cuts, per-level mode), or
                        // level-0 nets that only move between phases.
                        if per_level {
                            let (cs, ce) = lsb[(p - 1) % depth][w];
                            unsafe {
                                eval_chunk(
                                    luts, vals_raw, toggles_raw, tword_raw,
                                    cs as usize, ce as usize,
                                );
                            }
                        } else {
                            for &(cs, ce) in &slv[w] {
                                unsafe {
                                    eval_chunk(
                                        luts, vals_raw, toggles_raw, tword_raw,
                                        cs as usize, ce as usize,
                                    );
                                }
                            }
                        }
                        ctrl_ref.done.fetch_add(1, Ordering::Release);
                    }
                });
            }
            // Release the workers on return and unwind alike.
            struct StopGuard<'c>(&'c ParCtrl);
            impl Drop for StopGuard<'_> {
                fn drop(&mut self) {
                    self.0.phase.store(PHASE_STOP, Ordering::Release);
                }
            }
            let _stop = StopGuard(ctrl_ref);
            let mut drive = ShardDrive {
                fused,
                nets,
                vals: vals_raw,
                toggles: toggles_raw,
                tword: tword_raw,
                member_planes,
                member_flushed,
                plane_adds,
                flush_threshold: *flush_threshold,
                cycles,
                bus,
                luts,
                level_shard_bounds: lsb,
                level_bounds,
                shard0_levels: slv[0].as_slice(),
                dffs,
                scratch,
                per_level,
                workers,
                ctrl: ctrl_ref,
                next_phase: 1,
                expected_done: 0,
            };
            f(&mut drive)
        })
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-net toggle counts of the whole fused module.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Per-net toggle counts of one member (its slice of the fused
    /// module, indexed by the member's own net ids).
    pub fn member_net_toggles(&self, member: usize) -> &[u64] {
        let (s, e) = self.fused.members[member].net_range;
        &self.toggles[s as usize..e as usize]
    }

    /// Per-lane toggle totals of one member (flushes accumulators).
    pub fn member_lane_toggles(&mut self, member: usize) -> Vec<u64> {
        flush_members(&mut self.member_planes, &mut self.member_flushed, &mut self.plane_adds);
        self.member_flushed[member].clone()
    }
}

/// The driving handle of a shard session: the full [`Drive`] surface
/// (namespaced bus names, e.g. `s0/in_x`, `s0/start`, `s0/done`) plus
/// per-member toggle readback, so fused activity measurement can
/// snapshot a member the moment its activation schedule completes.
pub struct ShardDrive<'a, W: LaneWord> {
    fused: &'a FusedNetlist,
    nets: usize,
    vals: RawSlice<W>,
    toggles: RawSlice<u64>,
    tword: RawSlice<W>,
    member_planes: &'a mut Vec<[W; PLANES]>,
    member_flushed: &'a mut Vec<Vec<u64>>,
    plane_adds: &'a mut u64,
    flush_threshold: u64,
    cycles: &'a mut u64,
    bus: &'a HashMap<String, Vec<NetId>>,
    luts: &'a [PackedWordLut],
    level_shard_bounds: &'a [Vec<(u32, u32)>],
    level_bounds: &'a [(u32, u32)],
    shard0_levels: &'a [(u32, u32)],
    dffs: &'a [(u32, u32)],
    scratch: &'a mut Vec<W>,
    per_level: bool,
    workers: usize,
    ctrl: &'a ParCtrl,
    next_phase: usize,
    expected_done: usize,
}

impl<'a, W: LaneWord> ShardDrive<'a, W> {
    /// Compare-bump-store one input word (driving thread, outside any
    /// phase).
    #[inline]
    fn write_input_word(&mut self, idx: usize, w: W) {
        // Safety: outside a phase the driving thread has exclusive
        // access to every shared buffer.
        unsafe {
            let t = self.vals.get(idx) ^ w;
            if !t.is_zero() {
                self.bump(idx, t);
                self.vals.set(idx, w);
            }
        }
    }

    /// Full toggle accounting for one net.
    #[inline]
    unsafe fn bump(&mut self, idx: usize, t: W) {
        self.toggles.set(idx, self.toggles.get(idx) + u64::from(t.count_ones()));
        self.bump_planes(idx, t);
    }

    /// Per-member plane half of toggle accounting.
    #[inline]
    fn bump_planes(&mut self, idx: usize, t: W) {
        *self.plane_adds += 1;
        let m = self.fused.member_of(idx as NetId) as usize;
        let carry = plane_accumulate(&mut self.member_planes[m], t);
        debug_assert!(carry.is_zero(), "lane-toggle accumulator overflow");
    }

    /// Walk the toggle words of packed slots `[s, e)` (workers joined).
    fn account_planes(&mut self, s: usize, e: usize) {
        for i in s..e {
            // Safety: workers are joined (or never ran); exclusive.
            let t = unsafe { self.tword.get(i) };
            if !t.is_zero() {
                let idx = self.luts[i].out as usize;
                self.bump_planes(idx, t);
            }
        }
    }

    fn flush_all(&mut self) {
        flush_members(self.member_planes, self.member_flushed, self.plane_adds);
    }

    fn join(&self) {
        let mut spins = 0u32;
        while self.ctrl.done.load(Ordering::Acquire) < self.expected_done {
            spins = spins.wrapping_add(1);
            if spins % 4096 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    fn input_bits(&self, name: &str) -> &'a [NetId] {
        self.bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"))
    }

    pub fn cycles(&self) -> u64 {
        *self.cycles
    }

    /// Per-lane toggle totals of one member so far (flushes
    /// accumulators; callable mid-session, outside a phase).
    pub fn member_lane_toggles(&mut self, member: usize) -> Vec<u64> {
        self.flush_all();
        self.member_flushed[member].clone()
    }

    /// Per-net toggle counts of one member so far.
    pub fn member_net_toggles(&self, member: usize) -> Vec<u64> {
        let (s, e) = self.fused.members[member].net_range;
        // Safety: outside a phase; driving thread exclusive.
        (s..e).map(|i| unsafe { self.toggles.get(i as usize) }).collect()
    }
}

impl<W: LaneWord> Drive<W> for ShardDrive<'_, W> {
    fn set_bus_lanes(&mut self, name: &str, values: &[i64]) {
        assert_eq!(values.len(), W::LANES, "expected one value per lane");
        let bits = self.input_bits(name);
        for i in 0..bits.len() {
            let bit = bits[i];
            let mut w = W::zero();
            for (lane, v) in values.iter().enumerate() {
                w.set_lane(lane, (*v >> i) & 1 == 1);
            }
            self.write_input_word(bit as usize, w);
        }
    }

    fn set_bus(&mut self, name: &str, value: i64) {
        let bits = self.input_bits(name);
        for i in 0..bits.len() {
            let bit = bits[i];
            let w = W::splat((value >> i) & 1 == 1);
            self.write_input_word(bit as usize, w);
        }
    }

    fn set_bit_word(&mut self, name: &str, word: W) {
        let bits = self.input_bits(name);
        let bit = bits[0];
        self.write_input_word(bit as usize, word);
    }

    fn get_bit_word(&self, name: &str) -> W {
        let bits = self
            .fused
            .netlist
            .output_bits(name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"));
        // Safety: read outside any phase; driving thread exclusive.
        unsafe { self.vals.get(bits[0] as usize) }
    }

    /// One clock cycle for all lanes across all shards.
    fn step(&mut self) {
        *self.cycles += 1;
        if *self.plane_adds + 2 * self.nets as u64 >= self.flush_threshold {
            self.flush_all();
        }
        let fan = self.workers > 1;
        if self.per_level {
            // Per-level phasing: every level is one barrier, publishing
            // combinational cut values before their readers run.
            for lvl in 0..self.level_bounds.len() {
                if fan {
                    self.ctrl.phase.store(self.next_phase, Ordering::Release);
                    self.next_phase += 1;
                }
                let (cs, ce) = self.level_shard_bounds[lvl][0];
                // Safety: shard 0's slice of the level; see the
                // worker-side comment.
                unsafe {
                    eval_chunk(
                        self.luts, self.vals, self.toggles, self.tword,
                        cs as usize, ce as usize,
                    );
                }
                if fan {
                    self.expected_done += self.workers - 1;
                    self.join();
                }
                let (ls, le) = self.level_bounds[lvl];
                self.account_planes(ls as usize, le as usize);
            }
        } else {
            // Whole-member partition: one phase per cycle; every worker
            // sweeps its levels in ascending order.
            if fan {
                self.ctrl.phase.store(self.next_phase, Ordering::Release);
                self.next_phase += 1;
            }
            for i in 0..self.shard0_levels.len() {
                let (cs, ce) = self.shard0_levels[i];
                // Safety: shard 0's chunks; cross-shard reads are
                // level-0 only (no comb cuts), frozen during the phase.
                unsafe {
                    eval_chunk(
                        self.luts, self.vals, self.toggles, self.tword,
                        cs as usize, ce as usize,
                    );
                }
            }
            if fan {
                self.expected_done += self.workers - 1;
                self.join();
            }
            self.account_planes(0, self.luts.len());
        }
        // Clock edge: sample every D first, then commit (driving
        // thread; all workers joined).
        for (i, &(_, d)) in self.dffs.iter().enumerate() {
            // Safety: exclusive outside phases.
            self.scratch[i] = unsafe { self.vals.get(d as usize) };
        }
        for i in 0..self.dffs.len() {
            let (q, _) = self.dffs[i];
            let idx = q as usize;
            let sampled = self.scratch[i];
            unsafe {
                let t = self.vals.get(idx) ^ sampled;
                if !t.is_zero() {
                    self.bump(idx, t);
                    self.vals.set(idx, sampled);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::partition::ShardPlan;
    use crate::synth::{WordSim, W256};

    fn counter(bits: usize) -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..bits).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    fn fused_matches_solo_impl<W: LaneWord>(k: usize, steps: u64) {
        let members = [counter(4), counter(6), counter(9)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let plan = ShardPlan::partition(&fused, k);
        let mut sharded = ShardSim::<W>::new(&fused, &plan);
        let mut solos: Vec<WordSim<W>> = members.iter().map(WordSim::new).collect();
        sharded.session(|d| {
            for _ in 0..steps {
                d.step();
                for solo in solos.iter_mut() {
                    solo.step();
                }
                for (m, solo) in solos.iter().enumerate() {
                    let name = fused.bus_name(m, "q");
                    assert_eq!(
                        d.get_bit_word(&name),
                        solo.get_bit_word("q"),
                        "member {m} q[0] diverged at K={k}"
                    );
                }
            }
            for (m, solo) in solos.iter_mut().enumerate() {
                assert_eq!(
                    d.member_net_toggles(m),
                    solo.toggles(),
                    "member {m} per-net toggles at K={k}"
                );
                assert_eq!(
                    d.member_lane_toggles(m),
                    solo.lane_total_toggles(),
                    "member {m} per-lane toggles at K={k}"
                );
            }
        });
        assert_eq!(sharded.cycles(), steps);
    }

    #[test]
    fn fused_matches_solo_counters_k1() {
        fused_matches_solo_impl::<u64>(1, 40);
    }

    #[test]
    fn fused_matches_solo_counters_k2() {
        fused_matches_solo_impl::<u64>(2, 40);
    }

    #[test]
    fn fused_matches_solo_counters_k4_oversubscribed() {
        // K exceeds the member count: the partitioner splits the
        // largest member, forcing per-level sync with live comb cuts.
        fused_matches_solo_impl::<u64>(4, 40);
    }

    #[test]
    fn fused_matches_solo_counters_wide() {
        fused_matches_solo_impl::<W256>(2, 40);
    }

    #[test]
    fn split_single_member_uses_per_level_sync() {
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 2);
        assert!(plan.per_level_sync());
        let mut sharded = ShardSim::<u64>::new(&fused, &plan);
        assert!(sharded.per_level_sync());
        let mut solo = WordSim::<u64>::new(&a);
        sharded.session(|d| {
            for _ in 0..50 {
                d.step();
                solo.step();
                assert_eq!(d.get_bit_word("s0/q"), solo.get_bit_word("q"));
            }
        });
        assert_eq!(sharded.member_net_toggles(0), solo.toggles());
        assert_eq!(sharded.member_lane_toggles(0), solo.lane_total_toggles());
    }

    #[test]
    fn overflow_flush_preserves_member_totals() {
        let members = [counter(4), counter(7)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let plan = ShardPlan::partition(&fused, 2);
        let mut eager = ShardSim::<u64>::new(&fused, &plan).with_plane_flush_threshold(1);
        let mut lazy = ShardSim::<u64>::new(&fused, &plan);
        eager.session(|d| {
            for _ in 0..30 {
                d.step();
            }
        });
        lazy.session(|d| {
            for _ in 0..30 {
                d.step();
            }
        });
        for m in 0..2 {
            assert_eq!(eager.member_lane_toggles(m), lazy.member_lane_toggles(m));
        }
    }
}
