//! Sharded word-parallel evaluation of a fused netlist.
//!
//! One persistent worker per shard, driven by the same monotonic
//! spin-phase protocol as [`crate::synth::ParSession`] — but where a
//! parallel session fans each *level* of one netlist across threads,
//! a shard session fans the *shards* of a fused netlist: worker `w`
//! owns shard `w`'s packed LUTs for the whole session (the driving
//! thread doubles as shard 0's worker).
//!
//! Cut-signal values travel through explicit *mirror words* appended to
//! the value array — one per distinct exchanged net — and publication
//! into a mirror is **incremental**: only cut words whose value changed
//! since the last publication are copied (the dirty-word protocol in
//! [`crate::shard`]). Register cuts are pumped by the driving thread at
//! the start of each cycle from per-64-word dirty-summary bitmasks;
//! combinational cuts are published by their owning shard inside the
//! producing level's phase, using the evaluation toggle word as a free
//! dirty bit. [`ExchangeStats`] counts words published and skipped per
//! shard.
//!
//! Phase granularity follows the plan: with no combinational cuts
//! (whole-member partitions) every worker sweeps all its levels in one
//! phase per cycle; with combinational cuts every level is a phase, so
//! cross-shard same-cycle signals are published before their readers
//! run. Either way, results are bit-identical to evaluating every
//! member solo with [`crate::synth::WordSim`]: identical output words,
//! per-net toggles, and per-member per-lane toggle totals.

// Every unsafe operation inside an `unsafe fn` must name its own proof
// obligation in an explicit `unsafe { .. }` block — the audit discipline
// shared with [`crate::synth::wordsim`].
#![deny(unsafe_op_in_unsafe_fn)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::fusion::FusedNetlist;
use super::partition::ShardPlan;
use crate::synth::wordsim::{
    compile_tt, eval_chunk, flush_planes_into, plane_accumulate, wait_phase, PackedWordLut,
    ParCtrl, RawSlice, PHASE_STOP, PLANES,
};
use crate::synth::{Drive, LaneWord, NetId, Netlist, Node};

/// Flush every member's bit-plane accumulator into its per-lane totals.
fn flush_members<W: LaneWord>(
    member_planes: &mut [[W; PLANES]],
    member_flushed: &mut [Vec<u64>],
    plane_adds: &mut u64,
) {
    for (planes, flushed) in member_planes.iter_mut().zip(member_flushed.iter_mut()) {
        flush_planes_into(planes, flushed, plane_adds);
    }
    *plane_adds = 0;
}

/// Exchange counters of a [`ShardSim`]: how many cut words each shard
/// actually copied into its mirror region versus how many publication
/// opportunities it skipped because the word was clean.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExchangeStats {
    /// Per shard: cut words copied into mirrors (dirty at publication).
    pub published: Vec<u64>,
    /// Per shard: publication opportunities skipped (word was clean).
    /// Every owned cut word has exactly one opportunity per cycle, so
    /// `published[s] + skipped[s] == owner_cut_words[s] × cycles`.
    pub skipped: Vec<u64>,
    /// Per shard: cut words (mirror slots) this shard owns.
    pub owner_cut_words: Vec<u64>,
    /// Total mirror slots — distinct exchanged nets across all shards.
    pub cut_words: usize,
    /// Synchronization phases run (per-level plans: depth per cycle;
    /// whole-member plans: one per cycle).
    pub phases: u64,
}

impl ExchangeStats {
    pub fn total_published(&self) -> u64 {
        self.published.iter().sum()
    }

    pub fn total_skipped(&self) -> u64 {
        self.skipped.iter().sum()
    }

    /// Fold another simulator's counters into this one. Both must come
    /// from the same [`ShardPlan`] (identical cut-word geometry) —
    /// dispatchers that build a fresh simulator per round use this to
    /// report exchange totals across a whole batch. Merging into a
    /// default (empty) accumulator adopts the other's geometry.
    pub fn merge(&mut self, other: &ExchangeStats) {
        if self.published.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(
            self.owner_cut_words, other.owner_cut_words,
            "merging exchange stats from different shard plans"
        );
        for (a, b) in self.published.iter_mut().zip(&other.published) {
            *a += b;
        }
        for (a, b) in self.skipped.iter_mut().zip(&other.skipped) {
            *a += b;
        }
        self.phases += other.phases;
    }
}

/// Word-parallel simulation state for a fused netlist partitioned by a
/// [`ShardPlan`]. Construction packs the combinational plan level-major
/// and shard-grouped within each level — remapping every cross-shard
/// LUT input to the cut net's mirror word — and panics on a stale plan
/// (a cross-shard read with no matching cut entry).
/// [`ShardSim::session`] spawns the shard workers and hands out a
/// [`ShardDrive`].
pub struct ShardSim<'n, W: LaneWord = u64> {
    fused: &'n FusedNetlist,
    /// Current value word of every net, followed by one mirror word per
    /// distinct cut net (register cuts first, then combinational cuts).
    /// Cross-shard readers are remapped to the mirrors at pack time;
    /// only the owner writes a mirror, and only when the word is dirty.
    vals: Vec<W>,
    /// Per-net toggle counters, summed across lanes.
    toggles: Vec<u64>,
    /// Per-member bit-plane accumulators of per-lane toggle totals.
    member_planes: Vec<[W; PLANES]>,
    /// Per-member flushed per-lane toggle totals.
    member_flushed: Vec<Vec<u64>>,
    /// Accumulator adds since the last flush (overflow guard, shared by
    /// all members — conservative, since each member sees at most this
    /// many adds).
    plane_adds: u64,
    flush_threshold: u64,
    cycles: u64,
    bus: HashMap<String, Vec<NetId>>,
    /// Packed plan: level-major, grouped by owning shard within each
    /// level.
    luts: Vec<PackedWordLut>,
    /// Per level, per shard: half-open range into `luts`.
    level_shard_bounds: Vec<Vec<(u32, u32)>>,
    /// Per level: the whole level's range (all shards).
    level_bounds: Vec<(u32, u32)>,
    /// Per shard: its non-empty per-level ranges, level-ascending (the
    /// single-phase sweep order).
    shard_levels: Vec<Vec<(u32, u32)>>,
    dffs: Vec<(u32, u32)>,
    scratch: Vec<W>,
    per_level: bool,
    workers: usize,
    /// Register-cut publication list: `(net, mirror, owner)`, in
    /// dirty-bit order (64 entries per summary word).
    reg_pub: Vec<(u32, u32, u16)>,
    /// Dirty-summary words over `reg_pub`: bit b of word w marks entry
    /// `w*64 + b` as changed since its last publication. A zero summary
    /// word lets the exchange pump skip 64 cut words with one test.
    reg_dirty: Vec<u64>,
    /// Net id → dirty-bit index into `reg_dirty` (`u32::MAX` = not a
    /// register cut).
    reg_bit: Vec<u32>,
    /// Combinational-cut publication list: `(packed LUT slot, mirror)`,
    /// grouped level-major then by owning shard (`comb_bounds`). The
    /// slot's toggle word is the dirty bit.
    comb_pub: Vec<(u32, u32)>,
    /// Per level, per shard: half-open range into `comb_pub`.
    comb_bounds: Vec<Vec<(u32, u32)>>,
    /// Per shard: cut words published (written by the owner: workers
    /// flush at session stop, the driving thread inline).
    published: Vec<AtomicU64>,
    /// Per shard: mirror slots it owns (skip counts derive from this).
    owner_words: Vec<u64>,
    /// Synchronization phases run across all sessions.
    phases: u64,
}

impl<'n, W: LaneWord> ShardSim<'n, W> {
    pub fn new(fused: &'n FusedNetlist, plan: &ShardPlan) -> ShardSim<'n, W> {
        let nl: &Netlist = &fused.netlist;
        assert_eq!(plan.owner.len(), nl.len(), "plan does not match netlist");
        let k = plan.shards.max(1);
        let lv = nl.levelize();
        let mut vals = vec![W::zero(); nl.len()];
        let mut dffs = Vec::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Const(true) => vals[id as usize] = W::ones(),
                Node::Dff { d, init } => {
                    if *init {
                        vals[id as usize] = W::ones();
                    }
                    dffs.push((id, *d));
                }
                _ => {}
            }
        }
        // Mirror layout: one slot per distinct cut net, appended after
        // the real nets — register cuts first (their dirty bits live in
        // the `reg_dirty` summary words), then combinational cuts
        // (their dirty bit is the producing slot's toggle word).
        let nets = nl.len();
        let mut reg_mirror = vec![u32::MAX; nets];
        let mut comb_mirror = vec![u32::MAX; nets];
        let mut reg_bit = vec![u32::MAX; nets];
        let mut reg_pub: Vec<(u32, u32, u16)> = Vec::new();
        let mut owner_words = vec![0u64; k];
        let mut reg_nets: Vec<NetId> = plan.cuts.reg_cuts.iter().map(|c| c.net).collect();
        reg_nets.sort_unstable();
        reg_nets.dedup();
        let mut comb_nets: Vec<NetId> = plan.cuts.comb_cuts.iter().map(|c| c.net).collect();
        comb_nets.sort_unstable();
        comb_nets.dedup();
        let mut mirror_next = nets as u32;
        for (b, &n) in reg_nets.iter().enumerate() {
            reg_mirror[n as usize] = mirror_next;
            reg_bit[n as usize] = b as u32;
            let owner = plan.owner[n as usize];
            reg_pub.push((n, mirror_next, owner));
            owner_words[owner as usize] += 1;
            mirror_next += 1;
        }
        for &n in &comb_nets {
            comb_mirror[n as usize] = mirror_next;
            owner_words[plan.owner[n as usize] as usize] += 1;
            mirror_next += 1;
        }
        // Mirrors start in sync with their nets: publication happens on
        // every change, so a clean dirty bit always means mirror == net.
        for &n in reg_nets.iter().chain(&comb_nets) {
            let v = vals[n as usize];
            vals.push(v);
        }
        debug_assert_eq!(
            vals.len() as u32,
            mirror_next,
            "mirror slots must extend the net array contiguously"
        );
        let reg_dirty = vec![0u64; (reg_nets.len() + 63) / 64];

        // Cross-shard reads go through the cut net's mirror; a read the
        // plan does not list as a cut has no mirror and cannot be
        // published, so it would silently see stale values. The static
        // verifier ([`crate::analyze::preflight_plan`], AN402) rejects
        // incomplete cut maps before a plan reaches any simulator; this
        // pack-time assert is the never-fires backstop behind that gate.
        let remap = |reader: u16, i: NetId| -> NetId {
            let from = plan.owner[i as usize];
            if from == reader {
                return i;
            }
            let m = match nl.node(i) {
                Node::Lut { .. } => comb_mirror[i as usize],
                _ => reg_mirror[i as usize],
            };
            assert_ne!(
                m,
                u32::MAX,
                "stale shard plan: net {i} (owner shard {from}) is read by \
                 shard {reader} with no matching cut entry"
            );
            m
        };

        let mut luts = Vec::with_capacity(lv.order.len());
        let mut level_shard_bounds = Vec::with_capacity(lv.depth() as usize);
        let mut level_bounds = Vec::with_capacity(lv.depth() as usize);
        let mut shard_levels = vec![Vec::new(); k];
        for level in 1..=lv.depth() {
            let ls = luts.len() as u32;
            let mut per_shard = Vec::with_capacity(k);
            for shard in 0..k as u16 {
                let s = luts.len() as u32;
                for &id in lv.level_luts(level) {
                    if plan.owner[id as usize] != shard {
                        continue;
                    }
                    let Node::Lut { ins, tt } = nl.node(id) else {
                        unreachable!("levelization order contains only LUTs")
                    };
                    let mut packed = [remap(shard, ins[0]); 4];
                    for (j, &i) in ins.iter().enumerate() {
                        packed[j] = remap(shard, i);
                    }
                    let (sel, inv) = compile_tt(*tt, ins.len());
                    luts.push(PackedWordLut { out: id, ins: packed, sel, inv });
                }
                let e = luts.len() as u32;
                per_shard.push((s, e));
                if e > s {
                    shard_levels[shard as usize].push((s, e));
                }
            }
            level_shard_bounds.push(per_shard);
            level_bounds.push((ls, luts.len() as u32));
        }

        // Combinational publication list, level-major then by shard —
        // the owner walks its slice right after evaluating the level.
        let mut comb_pub: Vec<(u32, u32)> = Vec::new();
        let mut comb_bounds = Vec::with_capacity(level_shard_bounds.len());
        for per_shard in &level_shard_bounds {
            let mut row = Vec::with_capacity(k);
            for &(cs, ce) in per_shard {
                let s = comb_pub.len() as u32;
                for slot in cs..ce {
                    let out = luts[slot as usize].out as usize;
                    if comb_mirror[out] != u32::MAX {
                        comb_pub.push((slot, comb_mirror[out]));
                    }
                }
                row.push((s, comb_pub.len() as u32));
            }
            comb_bounds.push(row);
        }
        debug_assert_eq!(comb_pub.len(), comb_nets.len());
        let n_members = fused.member_count();
        let scratch = vec![W::zero(); dffs.len()];
        ShardSim {
            fused,
            vals,
            toggles: vec![0; nl.len()],
            member_planes: vec![[W::zero(); PLANES]; n_members],
            member_flushed: vec![vec![0u64; W::LANES]; n_members],
            plane_adds: 0,
            flush_threshold: u64::from(u32::MAX),
            cycles: 0,
            bus: nl.input_buses.iter().map(|(n, b)| (n.clone(), b.clone())).collect(),
            luts,
            level_shard_bounds,
            level_bounds,
            shard_levels,
            dffs,
            scratch,
            per_level: plan.per_level_sync(),
            workers: k,
            reg_pub,
            reg_dirty,
            reg_bit,
            comb_pub,
            comb_bounds,
            published: (0..k).map(|_| AtomicU64::new(0)).collect(),
            owner_words,
            phases: 0,
        }
    }

    /// Exchange counters so far (readable between sessions). Skip
    /// counts are derived: every owned cut word has exactly one
    /// publication opportunity per cycle — register cuts at the cycle's
    /// start, combinational cuts at their producing level.
    pub fn exchange_stats(&self) -> ExchangeStats {
        let published: Vec<u64> =
            self.published.iter().map(|p| p.load(Ordering::Relaxed)).collect();
        let skipped: Vec<u64> = published
            .iter()
            .zip(&self.owner_words)
            .map(|(&p, &w)| {
                let opportunities = w * self.cycles;
                debug_assert!(p <= opportunities, "published beyond opportunities");
                opportunities - p
            })
            .collect();
        ExchangeStats {
            published,
            skipped,
            owner_cut_words: self.owner_words.clone(),
            cut_words: self.reg_pub.len() + self.comb_pub.len(),
            phases: self.phases,
        }
    }

    /// Lower the bit-plane flush threshold (test hook; see
    /// [`crate::synth::WordSim::with_plane_flush_threshold`]).
    pub fn with_plane_flush_threshold(mut self, adds: u64) -> ShardSim<'n, W> {
        self.flush_threshold = adds.min(u64::from(u32::MAX));
        self
    }

    /// The fused netlist this simulator evaluates.
    pub fn fused(&self) -> &'n FusedNetlist {
        self.fused
    }

    /// Shard workers that a session would spawn in addition to the
    /// driving thread.
    pub fn extra_workers(&self) -> usize {
        self.workers - 1
    }

    /// Whether sessions synchronize per level (combinational cuts) or
    /// once per cycle.
    pub fn per_level_sync(&self) -> bool {
        self.per_level
    }

    /// Run `f` against a [`ShardDrive`] over this simulator: one
    /// persistent worker per shard beyond shard 0 (the driving
    /// thread's), spawned once for the whole session. All counters
    /// survive the session; results are bit-identical to solo
    /// evaluation of every member.
    pub fn session<R>(&mut self, f: impl FnOnce(&mut ShardDrive<'_, W>) -> R) -> R {
        let fused = self.fused;
        let nets = fused.netlist.len();
        let per_level = self.per_level;
        let workers = self.workers;
        let depth = self.level_bounds.len();
        let ShardSim {
            vals,
            toggles,
            member_planes,
            member_flushed,
            plane_adds,
            flush_threshold,
            cycles,
            bus,
            luts,
            level_shard_bounds,
            level_bounds,
            shard_levels,
            dffs,
            scratch,
            reg_pub,
            reg_dirty,
            reg_bit,
            comb_pub,
            comb_bounds,
            published,
            phases,
            ..
        } = self;
        let mut tword = vec![W::zero(); luts.len()];
        // Shared raw views under the phase protocol, as in
        // `WordSim::parallel_session`.
        let vals_raw = RawSlice::new(vals.as_mut_slice());
        let toggles_raw = RawSlice::new(toggles.as_mut_slice());
        let tword_raw = RawSlice::new(tword.as_mut_slice());
        let ctrl = ParCtrl { phase: AtomicUsize::new(0), done: AtomicUsize::new(0) };
        let luts: &[PackedWordLut] = luts;
        let lsb: &[Vec<(u32, u32)>] = level_shard_bounds;
        let slv: &[Vec<(u32, u32)>] = shard_levels;
        let cpb: &[(u32, u32)] = comb_pub;
        let cbb: &[Vec<(u32, u32)>] = comb_bounds;
        let rpb: &[(u32, u32, u16)] = reg_pub;
        let rbit: &[u32] = reg_bit;
        let published: &[AtomicU64] = published;
        let ctrl_ref = &ctrl;
        std::thread::scope(|s| {
            for w in 1..workers {
                s.spawn(move || {
                    let mut last = 0usize;
                    let mut local_pub = 0u64;
                    loop {
                        let p = wait_phase(ctrl_ref, last);
                        if p == PHASE_STOP {
                            break;
                        }
                        last = p;
                        // SAFETY: this shard owns its LUTs' out nets,
                        // tword slots, and cut mirrors exclusively (the
                        // owner map is a partition); reads are either
                        // same-shard earlier levels, mirrors published
                        // by an earlier phase, or level-0 nets that only
                        // move between phases.
                        if per_level {
                            let lvl = (p - 1) % depth;
                            let (cs, ce) = lsb[lvl][w];
                            unsafe {
                                eval_chunk(
                                    luts, vals_raw, toggles_raw, tword_raw,
                                    cs as usize, ce as usize,
                                );
                            }
                            // Publish this shard's dirty comb cuts of
                            // the level before signalling done: the
                            // toggle word is the dirty bit, and a clean
                            // word means the mirror already matches.
                            let (ps, pe) = cbb[lvl][w];
                            for &(slot, mirror) in &cpb[ps as usize..pe as usize] {
                                unsafe {
                                    if !tword_raw.get(slot as usize).is_zero() {
                                        let out = luts[slot as usize].out as usize;
                                        vals_raw.set(mirror as usize, vals_raw.get(out));
                                        local_pub += 1;
                                    }
                                }
                            }
                        } else {
                            for &(cs, ce) in &slv[w] {
                                unsafe {
                                    eval_chunk(
                                        luts, vals_raw, toggles_raw, tword_raw,
                                        cs as usize, ce as usize,
                                    );
                                }
                            }
                        }
                        ctrl_ref.done.fetch_add(1, Ordering::Release);
                    }
                    published[w].fetch_add(local_pub, Ordering::Relaxed);
                });
            }
            // Release the workers on return and unwind alike.
            struct StopGuard<'c>(&'c ParCtrl);
            impl Drop for StopGuard<'_> {
                fn drop(&mut self) {
                    self.0.phase.store(PHASE_STOP, Ordering::Release);
                }
            }
            let _stop = StopGuard(ctrl_ref);
            let mut drive = ShardDrive {
                fused,
                nets,
                vals: vals_raw,
                toggles: toggles_raw,
                tword: tword_raw,
                member_planes,
                member_flushed,
                plane_adds,
                flush_threshold: *flush_threshold,
                cycles,
                bus,
                luts,
                level_shard_bounds: lsb,
                level_bounds,
                shard0_levels: slv[0].as_slice(),
                dffs,
                scratch,
                per_level,
                workers,
                reg_pub: rpb,
                reg_dirty,
                reg_bit: rbit,
                comb_pub: cpb,
                comb_bounds: cbb,
                published,
                phases,
                ctrl: ctrl_ref,
                next_phase: 1,
                expected_done: 0,
            };
            f(&mut drive)
        })
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-net toggle counts of the whole fused module.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Per-net toggle counts of one member (its slice of the fused
    /// module, indexed by the member's own net ids).
    pub fn member_net_toggles(&self, member: usize) -> &[u64] {
        let (s, e) = self.fused.members[member].net_range;
        &self.toggles[s as usize..e as usize]
    }

    /// Per-lane toggle totals of one member (flushes accumulators).
    pub fn member_lane_toggles(&mut self, member: usize) -> Vec<u64> {
        flush_members(&mut self.member_planes, &mut self.member_flushed, &mut self.plane_adds);
        self.member_flushed[member].clone()
    }
}

/// The driving handle of a shard session: the full [`Drive`] surface
/// (namespaced bus names, e.g. `s0/in_x`, `s0/start`, `s0/done`) plus
/// per-member toggle readback, so fused activity measurement can
/// snapshot a member the moment its activation schedule completes.
pub struct ShardDrive<'a, W: LaneWord> {
    fused: &'a FusedNetlist,
    nets: usize,
    vals: RawSlice<W>,
    toggles: RawSlice<u64>,
    tword: RawSlice<W>,
    member_planes: &'a mut Vec<[W; PLANES]>,
    member_flushed: &'a mut Vec<Vec<u64>>,
    plane_adds: &'a mut u64,
    flush_threshold: u64,
    cycles: &'a mut u64,
    bus: &'a HashMap<String, Vec<NetId>>,
    luts: &'a [PackedWordLut],
    level_shard_bounds: &'a [Vec<(u32, u32)>],
    level_bounds: &'a [(u32, u32)],
    shard0_levels: &'a [(u32, u32)],
    dffs: &'a [(u32, u32)],
    scratch: &'a mut Vec<W>,
    per_level: bool,
    workers: usize,
    reg_pub: &'a [(u32, u32, u16)],
    reg_dirty: &'a mut Vec<u64>,
    reg_bit: &'a [u32],
    comb_pub: &'a [(u32, u32)],
    comb_bounds: &'a [Vec<(u32, u32)>],
    published: &'a [AtomicU64],
    phases: &'a mut u64,
    ctrl: &'a ParCtrl,
    next_phase: usize,
    expected_done: usize,
}

impl<'a, W: LaneWord> ShardDrive<'a, W> {
    /// Compare-bump-store one input word (driving thread, outside any
    /// phase).
    #[inline]
    fn write_input_word(&mut self, idx: usize, w: W) {
        // SAFETY: outside a phase the driving thread has exclusive
        // access to every shared buffer.
        unsafe {
            let t = self.vals.get(idx) ^ w;
            if !t.is_zero() {
                self.bump(idx, t);
                self.vals.set(idx, w);
                self.mark_reg_dirty(idx);
            }
        }
    }

    /// Flag a changed level-0 net for the next register-cut exchange
    /// (no-op for nets no other shard reads).
    #[inline]
    fn mark_reg_dirty(&mut self, idx: usize) {
        let b = self.reg_bit[idx];
        if b != u32::MAX {
            self.reg_dirty[b as usize / 64] |= 1u64 << (b % 64);
        }
    }

    /// Register-cut exchange pump (driving thread, outside any phase):
    /// copy every dirty level-0 cut word into its mirror. Whole
    /// 64-entry regions are skipped with one summary-word test.
    fn publish_reg_cuts(&mut self) {
        for w in 0..self.reg_dirty.len() {
            let mut summary = self.reg_dirty[w];
            if summary == 0 {
                continue;
            }
            self.reg_dirty[w] = 0;
            while summary != 0 {
                let bit = summary.trailing_zeros() as usize;
                summary &= summary - 1;
                debug_assert!(
                    w * 64 + bit < self.reg_pub.len(),
                    "dirty bit beyond the publication list"
                );
                let (net, mirror, owner) = self.reg_pub[w * 64 + bit];
                // SAFETY: outside a phase; driving thread exclusive.
                unsafe {
                    let v = self.vals.get(net as usize);
                    self.vals.set(mirror as usize, v);
                }
                self.published[owner as usize].fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Publish shard 0's dirty comb cuts of `lvl` (driving thread,
    /// between its chunk evaluation and the next phase store). Workers
    /// run the same loop for their own shard inside their phase.
    fn publish_comb_cuts(&mut self, lvl: usize) {
        let (ps, pe) = self.comb_bounds[lvl][0];
        let mut n = 0u64;
        for &(slot, mirror) in &self.comb_pub[ps as usize..pe as usize] {
            // SAFETY: shard 0 owns these slots and mirrors.
            unsafe {
                if !self.tword.get(slot as usize).is_zero() {
                    let out = self.luts[slot as usize].out as usize;
                    self.vals.set(mirror as usize, self.vals.get(out));
                    n += 1;
                }
            }
        }
        if n > 0 {
            self.published[0].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Full toggle accounting for one net.
    #[inline]
    unsafe fn bump(&mut self, idx: usize, t: W) {
        // SAFETY: the caller guarantees exclusive access to the shared
        // buffers (driving thread, outside any phase).
        unsafe {
            self.toggles.set(idx, self.toggles.get(idx) + u64::from(t.count_ones()));
        }
        self.bump_planes(idx, t);
    }

    /// Per-member plane half of toggle accounting.
    #[inline]
    fn bump_planes(&mut self, idx: usize, t: W) {
        *self.plane_adds += 1;
        let m = self.fused.member_of(idx as NetId) as usize;
        let carry = plane_accumulate(&mut self.member_planes[m], t);
        debug_assert!(carry.is_zero(), "lane-toggle accumulator overflow");
    }

    /// Walk the toggle words of packed slots `[s, e)` (workers joined).
    fn account_planes(&mut self, s: usize, e: usize) {
        for i in s..e {
            // SAFETY: workers are joined (or never ran); exclusive.
            let t = unsafe { self.tword.get(i) };
            if !t.is_zero() {
                let idx = self.luts[i].out as usize;
                self.bump_planes(idx, t);
            }
        }
    }

    fn flush_all(&mut self) {
        flush_members(self.member_planes, self.member_flushed, self.plane_adds);
    }

    fn join(&self) {
        let mut spins = 0u32;
        while self.ctrl.done.load(Ordering::Acquire) < self.expected_done {
            spins = spins.wrapping_add(1);
            if spins % 4096 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    fn input_bits(&self, name: &str) -> &'a [NetId] {
        self.bus.get(name).unwrap_or_else(|| panic!("no input bus `{name}`"))
    }

    pub fn cycles(&self) -> u64 {
        *self.cycles
    }

    /// Per-lane toggle totals of one member so far (flushes
    /// accumulators; callable mid-session, outside a phase).
    pub fn member_lane_toggles(&mut self, member: usize) -> Vec<u64> {
        self.flush_all();
        self.member_flushed[member].clone()
    }

    /// Per-net toggle counts of one member so far.
    pub fn member_net_toggles(&self, member: usize) -> Vec<u64> {
        let (s, e) = self.fused.members[member].net_range;
        // SAFETY: outside a phase; driving thread exclusive.
        (s..e).map(|i| unsafe { self.toggles.get(i as usize) }).collect()
    }
}

impl<W: LaneWord> Drive<W> for ShardDrive<'_, W> {
    fn set_bus_lanes(&mut self, name: &str, values: &[i64]) {
        assert_eq!(values.len(), W::LANES, "expected one value per lane");
        let bits = self.input_bits(name);
        for i in 0..bits.len() {
            let bit = bits[i];
            let mut w = W::zero();
            for (lane, v) in values.iter().enumerate() {
                w.set_lane(lane, (*v >> i) & 1 == 1);
            }
            self.write_input_word(bit as usize, w);
        }
    }

    fn set_bus(&mut self, name: &str, value: i64) {
        let bits = self.input_bits(name);
        for i in 0..bits.len() {
            let bit = bits[i];
            let w = W::splat((value >> i) & 1 == 1);
            self.write_input_word(bit as usize, w);
        }
    }

    fn set_bit_word(&mut self, name: &str, word: W) {
        let bits = self.input_bits(name);
        let bit = bits[0];
        self.write_input_word(bit as usize, word);
    }

    fn get_bit_word(&self, name: &str) -> W {
        let bits = self
            .fused
            .netlist
            .output_bits(name)
            .unwrap_or_else(|| panic!("no output bus `{name}`"));
        // SAFETY: read outside any phase; driving thread exclusive.
        unsafe { self.vals.get(bits[0] as usize) }
    }

    /// One clock cycle for all lanes across all shards.
    fn step(&mut self) {
        *self.cycles += 1;
        if *self.plane_adds + 2 * self.nets as u64 >= self.flush_threshold {
            self.flush_all();
        }
        // Exchange dirty level-0 cut words (inputs bound since the last
        // step, DFF commits from the previous clock edge) before any
        // phase runs; the first phase store publishes the mirrors to
        // every worker. Mid-phase the mirrors are frozen by
        // construction: only the driving thread writes them, and only
        // here.
        self.publish_reg_cuts();
        let fan = self.workers > 1;
        if self.per_level {
            // Per-level phasing: every level is one barrier; each shard
            // publishes its dirty comb cut words before signalling
            // done, so readers at later levels see them after the
            // barrier.
            for lvl in 0..self.level_bounds.len() {
                if fan {
                    self.ctrl.phase.store(self.next_phase, Ordering::Release);
                    self.next_phase += 1;
                }
                let (cs, ce) = self.level_shard_bounds[lvl][0];
                // SAFETY: shard 0's slice of the level; see the
                // worker-side comment.
                unsafe {
                    eval_chunk(
                        self.luts, self.vals, self.toggles, self.tword,
                        cs as usize, ce as usize,
                    );
                }
                self.publish_comb_cuts(lvl);
                if fan {
                    self.expected_done += self.workers - 1;
                    self.join();
                }
                let (ls, le) = self.level_bounds[lvl];
                self.account_planes(ls as usize, le as usize);
            }
            *self.phases += self.level_bounds.len() as u64;
        } else {
            // Whole-member partition: one phase per cycle; every worker
            // sweeps its levels in ascending order.
            if fan {
                self.ctrl.phase.store(self.next_phase, Ordering::Release);
                self.next_phase += 1;
            }
            for i in 0..self.shard0_levels.len() {
                let (cs, ce) = self.shard0_levels[i];
                // SAFETY: shard 0's chunks; cross-shard reads go
                // through register-cut mirrors, which only the driving
                // thread writes, outside phases — frozen mid-phase.
                unsafe {
                    eval_chunk(
                        self.luts, self.vals, self.toggles, self.tword,
                        cs as usize, ce as usize,
                    );
                }
            }
            if fan {
                self.expected_done += self.workers - 1;
                self.join();
            }
            self.account_planes(0, self.luts.len());
            *self.phases += 1;
        }
        // Clock edge: sample every D first, then commit (driving
        // thread; all workers joined). A committed q that another shard
        // reads is flagged for the next cycle's register-cut exchange.
        for (i, &(_, d)) in self.dffs.iter().enumerate() {
            // SAFETY: exclusive outside phases.
            self.scratch[i] = unsafe { self.vals.get(d as usize) };
        }
        for i in 0..self.dffs.len() {
            let (q, _) = self.dffs[i];
            let idx = q as usize;
            let sampled = self.scratch[i];
            unsafe {
                let t = self.vals.get(idx) ^ sampled;
                if !t.is_zero() {
                    self.bump(idx, t);
                    self.vals.set(idx, sampled);
                    self.mark_reg_dirty(idx);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::partition::ShardPlan;
    use crate::synth::{WordSim, W256};

    fn counter(bits: usize) -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..bits).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    fn fused_matches_solo_impl<W: LaneWord>(k: usize, steps: u64) {
        let members = [counter(4), counter(6), counter(9)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let plan = ShardPlan::partition(&fused, k);
        let mut sharded = ShardSim::<W>::new(&fused, &plan);
        let mut solos: Vec<WordSim<W>> = members.iter().map(WordSim::new).collect();
        sharded.session(|d| {
            for _ in 0..steps {
                d.step();
                for solo in solos.iter_mut() {
                    solo.step();
                }
                for (m, solo) in solos.iter().enumerate() {
                    let name = fused.bus_name(m, "q");
                    assert_eq!(
                        d.get_bit_word(&name),
                        solo.get_bit_word("q"),
                        "member {m} q[0] diverged at K={k}"
                    );
                }
            }
            for (m, solo) in solos.iter_mut().enumerate() {
                assert_eq!(
                    d.member_net_toggles(m),
                    solo.toggles(),
                    "member {m} per-net toggles at K={k}"
                );
                assert_eq!(
                    d.member_lane_toggles(m),
                    solo.lane_total_toggles(),
                    "member {m} per-lane toggles at K={k}"
                );
            }
        });
        assert_eq!(sharded.cycles(), steps);
    }

    #[test]
    fn fused_matches_solo_counters_k1() {
        fused_matches_solo_impl::<u64>(1, 40);
    }

    #[test]
    fn fused_matches_solo_counters_k2() {
        fused_matches_solo_impl::<u64>(2, 40);
    }

    #[test]
    fn fused_matches_solo_counters_k4_oversubscribed() {
        // K exceeds the member count: the partitioner splits the
        // largest member, forcing per-level sync with live comb cuts.
        fused_matches_solo_impl::<u64>(4, 40);
    }

    #[test]
    fn fused_matches_solo_counters_wide() {
        fused_matches_solo_impl::<W256>(2, 40);
    }

    #[test]
    fn split_single_member_uses_per_level_sync() {
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 2);
        assert!(plan.per_level_sync());
        let mut sharded = ShardSim::<u64>::new(&fused, &plan);
        assert!(sharded.per_level_sync());
        let mut solo = WordSim::<u64>::new(&a);
        sharded.session(|d| {
            for _ in 0..50 {
                d.step();
                solo.step();
                assert_eq!(d.get_bit_word("s0/q"), solo.get_bit_word("q"));
            }
        });
        assert_eq!(sharded.member_net_toggles(0), solo.toggles());
        assert_eq!(sharded.member_lane_toggles(0), solo.lane_total_toggles());
    }

    /// A feed-forward chain `not(x)`, `nand(prev, x)` × (levels − 1):
    /// one LUT per level, so an alternating owner map makes every
    /// level boundary a comb cut.
    fn chain(levels: usize) -> Netlist {
        let mut nl = Netlist::new();
        let x = nl.input_bus("x", 1)[0];
        let mut prev = nl.not(x);
        for _ in 1..levels {
            prev = nl.nand2(prev, x);
        }
        nl.add_output("y", vec![prev]);
        nl
    }

    /// Alternate shard ownership level by level: net ids in `chain` are
    /// construction-ordered (x = 0, LUT at level L has id L).
    fn alternating_plan(fused: &FusedNetlist) -> ShardPlan {
        let owner: Vec<u16> = (0..fused.netlist.len())
            .map(|id| match fused.netlist.node(id as NetId) {
                Node::Lut { .. } => (id % 2) as u16,
                _ => 0,
            })
            .collect();
        ShardPlan::from_owner(fused, 2, owner)
    }

    #[test]
    fn exchange_counters_are_sane() {
        let members = [counter(4), counter(6), counter(9)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let plan = ShardPlan::partition(&fused, 4);
        assert!(plan.per_level_sync(), "K=4 over 3 members must split");
        let mut sharded = ShardSim::<u64>::new(&fused, &plan);
        sharded.session(|d| {
            for _ in 0..40 {
                d.step();
            }
        });
        let stats = sharded.exchange_stats();
        assert!(stats.cut_words > 0);
        assert_eq!(
            stats.owner_cut_words.iter().sum::<u64>(),
            stats.cut_words as u64
        );
        assert!(stats.total_published() > 0, "a live counter exchanges words");
        for s in 0..4 {
            assert_eq!(
                stats.published[s] + stats.skipped[s],
                stats.owner_cut_words[s] * 40,
                "shard {s}: one publication opportunity per owned word per cycle"
            );
        }
        assert!(stats.total_published() <= stats.cut_words as u64 * stats.phases);
    }

    #[test]
    fn adversarial_alternating_plan_matches_wordsim() {
        // Regression for the phase-barrier audit: comb cuts at *every*
        // level — including the deepest — must be republished before
        // their same-cycle consumers run, never read stale.
        let nl = chain(9);
        let fused = FusedNetlist::fuse_refs(&[&nl]);
        let plan = alternating_plan(&fused);
        assert!(plan.per_level_sync());
        assert!(plan.cuts.comb_cuts.len() >= 8);
        let mut sharded = ShardSim::<u64>::new(&fused, &plan);
        let mut solo = WordSim::<u64>::new(&nl);
        sharded.session(|d| {
            let mut pat = 0x9e3779b97f4a7c15u64;
            for _ in 0..40 {
                d.set_bit_word("s0/x", pat);
                solo.set_bit_word("x", pat);
                d.step();
                solo.step();
                assert_eq!(d.get_bit_word("s0/y"), solo.get_bit_word("y"));
                pat = pat.rotate_left(7) ^ 0xD1B5_4A32_D192_ED03;
            }
        });
        assert_eq!(sharded.member_net_toggles(0), solo.toggles());
        assert!(sharded.exchange_stats().total_published() > 0);
    }

    #[test]
    fn quiescent_cut_words_are_skipped() {
        // Inputs bound once: after the first cycle every cut word is
        // clean, so the dirty exchange publishes at most one cycle's
        // worth — strictly fewer than full republication.
        let nl = chain(8);
        let fused = FusedNetlist::fuse_refs(&[&nl]);
        let plan = alternating_plan(&fused);
        let mut sharded = ShardSim::<u64>::new(&fused, &plan);
        let mut solo = WordSim::<u64>::new(&nl);
        sharded.session(|d| {
            d.set_bit_word("s0/x", 0xFF00_FF00_FF00_FF00);
            solo.set_bit_word("x", 0xFF00_FF00_FF00_FF00);
            for _ in 0..10 {
                d.step();
                solo.step();
                assert_eq!(d.get_bit_word("s0/y"), solo.get_bit_word("y"));
            }
        });
        let stats = sharded.exchange_stats();
        let full = stats.cut_words as u64 * 10;
        assert!(stats.total_published() > 0);
        assert_eq!(stats.total_published() + stats.total_skipped(), full);
        assert!(
            stats.total_published() <= stats.cut_words as u64,
            "published {} > one cycle's worth {}",
            stats.total_published(),
            stats.cut_words
        );
        // Per-level plan: every level is a phase, every cycle.
        assert_eq!(stats.phases, 8 * 10);
    }

    #[test]
    #[should_panic(expected = "stale shard plan")]
    fn stale_plan_without_cut_entries_panics() {
        // A plan whose cut lists were emptied (the frozen-mid-phase
        // hazard: a cross-shard read with no cut entry would silently
        // read stale values in whole-member mode).
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let mut plan = ShardPlan::partition(&fused, 2);
        assert!(plan.per_level_sync());
        plan.cuts.comb_cuts.clear();
        plan.cuts.reg_cuts.clear();
        let _ = ShardSim::<u64>::new(&fused, &plan);
    }

    #[test]
    fn overflow_flush_preserves_member_totals() {
        let members = [counter(4), counter(7)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let plan = ShardPlan::partition(&fused, 2);
        let mut eager = ShardSim::<u64>::new(&fused, &plan).with_plane_flush_threshold(1);
        let mut lazy = ShardSim::<u64>::new(&fused, &plan);
        eager.session(|d| {
            for _ in 0..30 {
                d.step();
            }
        });
        lazy.session(|d| {
            for _ in 0..30 {
                d.step();
            }
        });
        for m in 0..2 {
            assert_eq!(eager.member_lane_toggles(m), lazy.member_lane_toggles(m));
        }
    }
}
