//! Cut-minimizing partitioning of a fused netlist into K shards.
//!
//! Partitioning runs in two passes. The **seed** pass works at the
//! granularity of *segments* — a run of consecutive combinational
//! levels of one member. Initially every member is one segment (all its
//! levels); segments are bin-packed onto shards largest-first (LPT).
//! When K exceeds the member count some shards would sit empty, so the
//! largest splittable segment is cut at the level boundary closest to
//! halving its gate count and the tail moves to an empty shard.
//!
//! The **refinement** pass ([`ShardPlan::partition`]) then minimizes
//! the cut interface Kernighan–Lin/Fiduccia–Mattheyses-style: it
//! greedily moves whole clusters ([`super::fusion::Cluster`] — the LUTs
//! of one member at one level) between shards whenever the move
//! strictly shrinks the [`CutMap`] and keeps the gate balance within a
//! 12.5% tolerance of perfect, then re-homes level-0 nets (inputs,
//! constants, DFF q) onto their reader shards. Every applied move
//! strictly decreases the cut cost, so a refined plan never has more
//! cuts than the seed plan — [`RefineReport`] records both sides. The
//! whole pipeline is deterministic in its inputs: the same fused
//! netlist and K always produce the same plan.

use std::collections::HashSet;

use super::fusion::{Cluster, FusedNetlist};
use crate::synth::{Levelization, NetId, Netlist, Node};

/// Version of the partitioning algorithm. Mixed into the fused-stage
/// store fingerprint ([`crate::flow::fused_fingerprint`]) so plans
/// cached by an older partitioner are a clean miss, never served stale.
///
/// v2: cut-minimizing cluster refinement + level-0 re-homing on top of
/// the v1 level-boundary LPT seed.
pub const PARTITIONER_VERSION: u32 = 2;

/// One cut signal: net `net` is owned (written) by shard `from` and
/// read by shard `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Cut {
    pub net: NetId,
    pub from: u16,
    pub to: u16,
}

/// The explicit cut-signal interface of a [`ShardPlan`], split by
/// synchronization class (full protocol in the [`crate::shard`] module
/// docs).
#[derive(Clone, Debug, Default)]
pub struct CutMap {
    /// LUT outputs read by a cross-shard LUT in the same cycle; these
    /// force per-level phasing.
    pub comb_cuts: Vec<Cut>,
    /// Level-0 nets (inputs, constants, DFF q) read cross-shard;
    /// satisfied by the per-cycle barrier.
    pub reg_cuts: Vec<Cut>,
    /// Combinational nets feeding cross-shard DFF d-inputs; satisfied
    /// by the clock-edge sample after the last evaluation phase.
    pub dff_cuts: Vec<Cut>,
}

impl CutMap {
    /// Total cut signals of all classes — the cut cost the refinement
    /// pass minimizes (one exchange word per entry per relevant period).
    pub fn len(&self) -> usize {
        self.comb_cuts.len() + self.reg_cuts.len() + self.dff_cuts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// What the cut-minimizing refinement pass did to a plan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RefineReport {
    /// Cut cost of the seed (level-boundary LPT) plan.
    pub initial_cut_cost: usize,
    /// Cut cost after refinement (= [`ShardPlan::cut_cost`]). Never
    /// exceeds `initial_cut_cost`: only strictly improving moves apply.
    pub refined_cut_cost: usize,
    /// Cluster moves applied (whole member-level cells between shards).
    pub cluster_moves: usize,
    /// Level-0 nets re-homed onto a reader shard.
    pub level0_moves: usize,
    /// Greedy sweeps run before convergence (or the sweep cap).
    pub sweeps: usize,
}

impl RefineReport {
    /// Cut words removed by refinement.
    pub fn removed(&self) -> usize {
        self.initial_cut_cost - self.refined_cut_cost
    }
}

/// A K-way partition of a fused netlist: per-net shard ownership, the
/// per-shard gate loads, and the cut-signal interface between shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Shard count K (≥ 1).
    pub shards: usize,
    /// Owning shard per fused net.
    pub owner: Vec<u16>,
    /// LUTs per shard (the balance the partitioner optimized).
    pub shard_gates: Vec<usize>,
    /// Cross-shard signal interface.
    pub cuts: CutMap,
    /// What refinement did (seed vs refined cut cost, moves, sweeps).
    pub refinement: RefineReport,
}

/// A run of consecutive levels `[lo, hi]` (1-based, inclusive) of one
/// member, with its LUT count.
#[derive(Clone, Debug)]
struct Segment {
    member: usize,
    lo: u32,
    hi: u32,
    gates: usize,
}

/// Greedy sweep caps: refinement is monotone (every applied move
/// strictly reduces the cut cost), so these only bound the tail of
/// convergence, not correctness.
const MAX_CLUSTER_SWEEPS: usize = 8;
const MAX_LEVEL0_SWEEPS: usize = 4;
const MAX_REFINE_ROUNDS: usize = 2;

impl ShardPlan {
    /// Partition `fused` into `shards` shards (clamped to ≥ 1): seed
    /// LPT plan, then the cut-minimizing refinement pass. Deterministic
    /// in its inputs: the same fused netlist and K always produce the
    /// same plan.
    pub fn partition(fused: &FusedNetlist, shards: usize) -> ShardPlan {
        ShardPlan::partition_opts(fused, shards, true)
    }

    /// The seed plan only (no refinement) — the PR 7 baseline, kept for
    /// A/B comparison in benches and the refinement CI gate.
    pub fn partition_unrefined(fused: &FusedNetlist, shards: usize) -> ShardPlan {
        ShardPlan::partition_opts(fused, shards, false)
    }

    fn partition_opts(fused: &FusedNetlist, shards: usize, refine: bool) -> ShardPlan {
        let k = shards.max(1);
        let nl = &fused.netlist;
        let lv = nl.levelize();
        let (mut owner, mut load) = initial_partition(fused, &lv, k);
        let initial_cut_cost = extract_cuts(nl, &owner).len();
        let (cluster_moves, level0_moves, sweeps) = if refine {
            refine_owner(fused, &lv, k, &mut owner, &mut load)
        } else {
            (0, 0, 0)
        };
        let cuts = extract_cuts(nl, &owner);
        let refined_cut_cost = cuts.len();
        debug_assert!(
            refined_cut_cost <= initial_cut_cost,
            "refinement increased the cut cost ({initial_cut_cost} -> {refined_cut_cost})"
        );
        ShardPlan {
            shards: k,
            owner,
            shard_gates: load,
            cuts,
            refinement: RefineReport {
                initial_cut_cost,
                refined_cut_cost,
                cluster_moves,
                level0_moves,
                sweeps,
            },
        }
    }

    /// Build a plan from an explicit owner map (shard per net): computes
    /// the per-shard loads and extracts the cut interface. For tests
    /// and external partitioners; no refinement runs.
    pub fn from_owner(fused: &FusedNetlist, shards: usize, owner: Vec<u16>) -> ShardPlan {
        let k = shards.max(1);
        let nl = &fused.netlist;
        assert_eq!(owner.len(), nl.len(), "owner map does not match netlist");
        assert!(
            owner.iter().all(|&o| (o as usize) < k),
            "owner map references a shard >= {k}"
        );
        let mut load = vec![0usize; k];
        for (id, node) in nl.nodes() {
            if matches!(node, Node::Lut { .. }) {
                load[owner[id as usize] as usize] += 1;
            }
        }
        let cuts = extract_cuts(nl, &owner);
        let cost = cuts.len();
        ShardPlan {
            shards: k,
            owner,
            shard_gates: load,
            cuts,
            refinement: RefineReport {
                initial_cut_cost: cost,
                refined_cut_cost: cost,
                cluster_moves: 0,
                level0_moves: 0,
                sweeps: 0,
            },
        }
    }

    /// Total cut signals — the communication cost of the plan (one
    /// exchange word per cut per relevant period).
    pub fn cut_cost(&self) -> usize {
        self.cuts.len()
    }

    /// Whether evaluation must synchronize every level (true iff the
    /// plan has same-cycle combinational cuts; whole-member plans run
    /// one phase per cycle).
    pub fn per_level_sync(&self) -> bool {
        !self.cuts.comb_cuts.is_empty()
    }
}

/// The seed plan: whole-member LPT, splitting the largest segment at a
/// level boundary while shards would otherwise sit empty. Returns the
/// per-net owner map and per-shard gate loads.
fn initial_partition(
    fused: &FusedNetlist,
    lv: &Levelization,
    k: usize,
) -> (Vec<u16>, Vec<usize>) {
    let nl = &fused.netlist;
    let depth = lv.depth();
    // Per-member per-level LUT counts (level 1..=depth).
    let n_members = fused.member_count();
    let mut mlg = vec![vec![0usize; depth as usize + 1]; n_members];
    for level in 1..=depth {
        for &id in lv.level_luts(level) {
            mlg[fused.member_of(id) as usize][level as usize] += 1;
        }
    }

    // Seed: one whole-member segment each; LPT largest-first onto
    // the least-loaded shard. Ties break on lower shard index (and
    // on member order among equal-sized members), keeping the plan
    // deterministic.
    let mut segments: Vec<Segment> = (0..n_members)
        .map(|m| Segment {
            member: m,
            lo: 1,
            hi: depth,
            gates: fused.members[m].gates,
        })
        .collect();
    segments.sort_by(|a, b| b.gates.cmp(&a.gates).then(a.member.cmp(&b.member)));
    let mut bins: Vec<Vec<Segment>> = vec![Vec::new(); k];
    let mut load = vec![0usize; k];
    for seg in segments {
        let bin = (0..k).min_by_key(|&b| (load[b], b)).unwrap();
        load[bin] += seg.gates;
        bins[bin].push(seg);
    }

    // Fill empty shards by splitting the largest splittable segment
    // at the level boundary nearest its gate-count midpoint.
    while let Some(empty) = load.iter().position(|&l| l == 0) {
        let mut best: Option<(usize, usize, usize)> = None; // (bin, idx, gates)
        for (b, bin) in bins.iter().enumerate() {
            for (i, seg) in bin.iter().enumerate() {
                let spans = (seg.lo..=seg.hi)
                    .filter(|&l| mlg[seg.member][l as usize] > 0)
                    .count();
                if spans >= 2 && best.map_or(true, |(_, _, g)| seg.gates > g) {
                    best = Some((b, i, seg.gates));
                }
            }
        }
        let Some((b, i, _)) = best else { break };
        let seg = bins[b].remove(i);
        let half = seg.gates / 2;
        let (mut split, mut run, mut best_diff) = (seg.lo, 0usize, usize::MAX);
        // Split after level `l` ∈ [lo, hi): head = [lo, l].
        for l in seg.lo..seg.hi {
            run += mlg[seg.member][l as usize];
            let diff = run.abs_diff(half);
            if run > 0 && run < seg.gates && diff < best_diff {
                best_diff = diff;
                split = l;
            }
        }
        let head_gates: usize =
            (seg.lo..=split).map(|l| mlg[seg.member][l as usize]).sum();
        let tail = Segment {
            member: seg.member,
            lo: split + 1,
            hi: seg.hi,
            gates: seg.gates - head_gates,
        };
        let head = Segment { lo: seg.lo, hi: split, gates: head_gates, ..seg };
        load[b] -= tail.gates;
        load[empty] += tail.gates;
        bins[b].push(head);
        bins[empty].push(tail);
    }

    // Ownership: LUTs by their segment; level-0 nets (inputs,
    // constants, DFF q) by the member's head segment — their values
    // only move at cycle boundaries, so placement only affects cut
    // classification, not correctness (refinement re-homes them).
    let mut owner = vec![0u16; nl.len()];
    let mut head_shard = vec![0u16; n_members];
    let mut head_lo = vec![u32::MAX; n_members];
    for (b, bin) in bins.iter().enumerate() {
        for seg in bin {
            if seg.lo < head_lo[seg.member] {
                head_lo[seg.member] = seg.lo;
                head_shard[seg.member] = b as u16;
            }
        }
    }
    for (m, fm) in fused.members.iter().enumerate() {
        for id in fm.net_range.0..fm.net_range.1 {
            owner[id as usize] = head_shard[m];
        }
    }
    for (b, bin) in bins.iter().enumerate() {
        for seg in bin {
            for level in seg.lo..=seg.hi {
                for &id in lv.level_luts(level) {
                    if fused.member_of(id) as usize == seg.member {
                        owner[id as usize] = b as u16;
                    }
                }
            }
        }
    }
    (owner, load)
}

/// Cut extraction: every cross-shard read, classified by the kind of
/// the net being read. The total entry count is the cut cost — one
/// entry per distinct `(net, from, to)` triple, shared across classes.
fn extract_cuts(nl: &Netlist, owner: &[u16]) -> CutMap {
    let mut cuts = CutMap::default();
    let mut seen: HashSet<Cut> = HashSet::new();
    for (id, node) in nl.nodes() {
        match node {
            Node::Lut { ins, .. } => {
                let to = owner[id as usize];
                for &i in ins {
                    let from = owner[i as usize];
                    if from == to {
                        continue;
                    }
                    let cut = Cut { net: i, from, to };
                    if !seen.insert(cut) {
                        continue;
                    }
                    match nl.node(i) {
                        Node::Lut { .. } => cuts.comb_cuts.push(cut),
                        _ => cuts.reg_cuts.push(cut),
                    }
                }
            }
            Node::Dff { d, .. } => {
                let to = owner[id as usize];
                let from = owner[*d as usize];
                if from == to {
                    continue;
                }
                let cut = Cut { net: *d, from, to };
                if !seen.insert(cut) {
                    continue;
                }
                match nl.node(*d) {
                    Node::Lut { .. } => cuts.dff_cuts.push(cut),
                    _ => cuts.reg_cuts.push(cut),
                }
            }
            _ => {}
        }
    }
    cuts
}

/// Cut cost contributed by one net under candidate owner `ownr`: the
/// number of distinct shards that read it from elsewhere.
#[inline]
fn cost_with(row: &[u32], ownr: usize) -> i64 {
    let mut c = 0i64;
    for (t, &r) in row.iter().enumerate() {
        if r > 0 && t != ownr {
            c += 1;
        }
    }
    c
}

/// Exact cut-cost delta of moving cluster `cl` from shard `a` to `b`.
/// Independent per move: a cluster never reads its own outputs
/// (same-level reads are impossible), so output-owner flips and read
/// transfers decompose per net.
fn move_delta(
    readers: &[u32],
    owner: &[u16],
    k: usize,
    cl: &Cluster,
    a: usize,
    b: usize,
) -> i64 {
    let mut delta = 0i64;
    for &o in &cl.luts {
        let row = &readers[o as usize * k..o as usize * k + k];
        delta += cost_with(row, b) - cost_with(row, a);
    }
    for &(i, m) in &cl.ins {
        let n = i as usize;
        let ow = owner[n] as usize;
        let ra = readers[n * k + a];
        let rb = readers[n * k + b];
        debug_assert!(ra >= m, "reader accounting underflow");
        let old = i64::from(ra > 0 && a != ow) + i64::from(rb > 0 && b != ow);
        let new = i64::from(ra - m > 0 && a != ow) + i64::from(rb + m > 0 && b != ow);
        delta += new - old;
    }
    delta
}

fn apply_move(
    readers: &mut [u32],
    owner: &mut [u16],
    k: usize,
    cl: &Cluster,
    a: usize,
    b: usize,
) {
    for &o in &cl.luts {
        owner[o as usize] = b as u16;
    }
    for &(i, m) in &cl.ins {
        let n = i as usize;
        debug_assert!(readers[n * k + a] >= m);
        readers[n * k + a] -= m;
        readers[n * k + b] += m;
    }
}

/// The FM-style refinement pass: greedy cluster moves (strictly
/// cut-reducing, balance-bounded) alternated with level-0 re-homing,
/// to convergence or the sweep caps. Returns
/// `(cluster_moves, level0_moves, sweeps)`.
fn refine_owner(
    fused: &FusedNetlist,
    lv: &Levelization,
    k: usize,
    owner: &mut [u16],
    load: &mut [usize],
) -> (usize, usize, usize) {
    if k <= 1 {
        return (0, 0, 0);
    }
    let nl = &fused.netlist;
    let nets = nl.len();
    let ci = fused.cluster_index(lv);

    // Per-net per-shard read counts: LUT pins (by reading cluster's
    // shard) plus DFF clock-edge samples (by the DFF q net's shard).
    let mut readers = vec![0u32; nets * k];
    let mut cluster_owner: Vec<u16> = Vec::with_capacity(ci.clusters.len());
    for cl in &ci.clusters {
        let sh = owner[cl.luts[0] as usize];
        debug_assert!(
            cl.luts.iter().all(|&g| owner[g as usize] == sh),
            "seed plan split a (member, level) cell across shards"
        );
        cluster_owner.push(sh);
        for &(i, m) in &cl.ins {
            readers[i as usize * k + sh as usize] += m;
        }
    }
    for (id, node) in nl.nodes() {
        if let Node::Dff { d, .. } = node {
            readers[*d as usize * k + owner[id as usize] as usize] += 1;
        }
    }

    // Balance tolerance: 12.5% over perfect balance, rounded up. Moves
    // may also land above the cap when they strictly improve balance
    // (an oversized member can already sit above it).
    let total: usize = load.iter().sum();
    let cap = (total * 9 + 8 * k - 1) / (8 * k);

    let mut cluster_moves = 0usize;
    let mut level0_moves = 0usize;
    let mut sweeps = 0usize;
    for _round in 0..MAX_REFINE_ROUNDS {
        let mut round_moves = 0usize;

        // Cluster sweeps: deterministic cluster order, best strictly
        // negative delta wins (tie: lowest target shard).
        for _ in 0..MAX_CLUSTER_SWEEPS {
            sweeps += 1;
            let mut moved = false;
            for (c, cl) in ci.clusters.iter().enumerate() {
                let a = cluster_owner[c] as usize;
                if load[a] <= cl.gates {
                    continue; // the move would empty shard `a`
                }
                let mut best: Option<(i64, usize)> = None;
                for b in 0..k {
                    if b == a {
                        continue;
                    }
                    if load[b] + cl.gates > cap && load[b] + cl.gates >= load[a] {
                        continue; // breaks balance without improving it
                    }
                    let delta = move_delta(&readers, owner, k, cl, a, b);
                    if delta < 0 && best.map_or(true, |(d, _)| delta < d) {
                        best = Some((delta, b));
                    }
                }
                if let Some((_, b)) = best {
                    apply_move(&mut readers, owner, k, cl, a, b);
                    load[a] -= cl.gates;
                    load[b] += cl.gates;
                    cluster_owner[c] = b as u16;
                    cluster_moves += 1;
                    round_moves += 1;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }

        // Level-0 re-homing: place inputs/constants/DFF q nets on a
        // reader shard when that strictly shrinks the cut set. Gate
        // loads are untouched (level-0 nets carry no LUTs). Moving a
        // DFF q also moves the clock-edge sample of its d net, so that
        // delta is part of the decision.
        for _ in 0..MAX_LEVEL0_SWEEPS {
            let mut moved = false;
            for (id, node) in nl.nodes() {
                let dff_d = match node {
                    Node::Input(_) | Node::Const(_) => None,
                    Node::Dff { d, .. } => {
                        if *d == id {
                            continue; // degenerate self-loop: nothing to gain
                        }
                        Some(*d as usize)
                    }
                    _ => continue,
                };
                let n = id as usize;
                let ow = owner[n] as usize;
                let mut best: Option<(i64, usize)> = None;
                for s in 0..k {
                    if s == ow {
                        continue;
                    }
                    let reads_here = readers[n * k + s] > 0;
                    let d_home = dff_d.map_or(false, |d| owner[d] as usize == s);
                    if !reads_here && !d_home {
                        continue; // can only add cost elsewhere
                    }
                    let row = &readers[n * k..n * k + k];
                    let mut delta = cost_with(row, s) - cost_with(row, ow);
                    if let Some(d) = dff_d {
                        let od = owner[d] as usize;
                        let rdo = readers[d * k + ow];
                        let rds = readers[d * k + s];
                        debug_assert!(rdo >= 1, "dff sample not in reader accounting");
                        let old = i64::from(rdo > 0 && ow != od)
                            + i64::from(rds > 0 && s != od);
                        let new = i64::from(rdo - 1 > 0 && ow != od)
                            + i64::from(rds + 1 > 0 && s != od);
                        delta += new - old;
                    }
                    if delta < 0 && best.map_or(true, |(d, _)| delta < d) {
                        best = Some((delta, s));
                    }
                }
                if let Some((_, s)) = best {
                    if let Some(d) = dff_d {
                        readers[d * k + ow] -= 1;
                        readers[d * k + s] += 1;
                    }
                    owner[n] = s as u16;
                    level0_moves += 1;
                    round_moves += 1;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }

        if round_moves == 0 {
            break;
        }
    }
    (cluster_moves, level0_moves, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::Netlist;

    fn counter(bits: usize) -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..bits).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    fn assert_cut_consistency(plan: &ShardPlan) {
        for cut in plan
            .cuts
            .comb_cuts
            .iter()
            .chain(&plan.cuts.reg_cuts)
            .chain(&plan.cuts.dff_cuts)
        {
            assert_eq!(plan.owner[cut.net as usize], cut.from);
            assert_ne!(cut.from, cut.to);
        }
    }

    #[test]
    fn whole_member_partition_has_no_comb_cuts() {
        let a = counter(4);
        let b = counter(6);
        let c = counter(8);
        let fused = FusedNetlist::fuse_refs(&[&a, &b, &c]);
        let plan = ShardPlan::partition(&fused, 2);
        assert_eq!(plan.shards, 2);
        assert!(plan.cuts.comb_cuts.is_empty());
        assert!(plan.cuts.reg_cuts.is_empty());
        assert!(plan.cuts.dff_cuts.is_empty());
        assert!(!plan.per_level_sync());
        assert_eq!(plan.cut_cost(), 0);
        // A zero-cut seed leaves refinement nothing to do.
        assert_eq!(plan.refinement.initial_cut_cost, 0);
        assert_eq!(plan.refinement.cluster_moves, 0);
        // Every shard got work, and loads sum to the total gate count.
        assert!(plan.shard_gates.iter().all(|&g| g > 0));
        assert_eq!(
            plan.shard_gates.iter().sum::<usize>(),
            fused.netlist.count_luts()
        );
        // LPT: the biggest member sits alone on one shard.
        let owners: HashSet<u16> = (fused.members[2].net_range.0
            ..fused.members[2].net_range.1)
            .map(|id| plan.owner[id as usize])
            .collect();
        assert_eq!(owners.len(), 1);
    }

    #[test]
    fn oversubscribed_partition_splits_at_level_boundary() {
        // One member, two shards: the member must split, producing
        // cross-level cuts and per-level sync.
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 2);
        assert!(plan.shard_gates.iter().all(|&g| g > 0), "{:?}", plan.shard_gates);
        assert!(!plan.cuts.is_empty());
        assert!(plan.per_level_sync());
        // Split shards stay balanced within the widest level's worth.
        let diff = plan.shard_gates[0].abs_diff(plan.shard_gates[1]);
        assert!(diff < fused.netlist.count_luts(), "degenerate split");
        // Cut ownership is consistent: each cut's net really is owned
        // by `from` and ≠ `to`.
        assert_cut_consistency(&plan);
    }

    #[test]
    fn refinement_never_exceeds_seed_cut_cost() {
        // Oversubscribed fused modules at several K: the refined plan's
        // cut cost must never exceed the unrefined seed's, the report
        // must agree with both sides, and balance must hold.
        let members = [counter(4), counter(9), counter(16)];
        let refs: Vec<&Netlist> = members.iter().collect();
        let fused = FusedNetlist::fuse_refs(&refs);
        let total = fused.netlist.count_luts();
        for k in [2usize, 4, 6, 8] {
            let seed = ShardPlan::partition_unrefined(&fused, k);
            let plan = ShardPlan::partition(&fused, k);
            assert_eq!(
                seed.cut_cost(),
                plan.refinement.initial_cut_cost,
                "K={k}: report initial vs unrefined plan"
            );
            assert!(
                plan.cut_cost() <= seed.cut_cost(),
                "K={k}: refined {} > seed {}",
                plan.cut_cost(),
                seed.cut_cost()
            );
            assert_eq!(plan.cut_cost(), plan.refinement.refined_cut_cost);
            assert_eq!(plan.refinement.removed(), seed.cut_cost() - plan.cut_cost());
            assert_cut_consistency(&plan);
            // Loads: non-empty shards, exact total, tolerance respected
            // (or no worse than the seed's own worst shard).
            assert!(plan.shard_gates.iter().all(|&g| g > 0), "K={k} empty shard");
            assert_eq!(plan.shard_gates.iter().sum::<usize>(), total);
            let cap = (total * 9 + 8 * k - 1) / (8 * k);
            let seed_max = *seed.shard_gates.iter().max().unwrap();
            let max = *plan.shard_gates.iter().max().unwrap();
            assert!(
                max <= cap.max(seed_max),
                "K={k}: refined max load {max} above cap {cap} and seed max {seed_max}"
            );
        }
    }

    #[test]
    fn refinement_finds_the_narrow_boundary() {
        // A module with a deliberately narrow waist: wide fan-in cone ->
        // 1-bit bottleneck -> deep fan-out chain. The seed splits at the
        // gate-count midpoint, which lands one chain gate on the tree's
        // shard (2 comb cuts); moving that single-gate cluster across is
        // a strictly improving, balance-legal move, so refinement must
        // find a strictly smaller cut than the seed.
        let mut nl = Netlist::new();
        let ins: Vec<NetId> = (0..16).map(|i| nl.input(format!("x{i}"))).collect();
        // Reduction tree to one bit (15 LUTs over 4 levels).
        let mut layer = ins.clone();
        while layer.len() > 1 {
            let mut next = Vec::new();
            for pair in layer.chunks(2) {
                next.push(nl.xor2(pair[0], pair[1]));
            }
            layer = next;
        }
        let waist = layer[0];
        // Fan back out: an inverter then a nand chain re-reading the
        // waist each step (nand2 never folds here: distinct inputs, no
        // constants, both sensitive).
        let mut outs = Vec::new();
        let mut prev = nl.not(waist);
        outs.push(prev);
        for _ in 0..16 {
            prev = nl.nand2(prev, waist);
            outs.push(prev);
        }
        nl.add_output("y", outs);
        let fused = FusedNetlist::fuse_refs(&[&nl]);
        let seed = ShardPlan::partition_unrefined(&fused, 2);
        let plan = ShardPlan::partition(&fused, 2);
        assert!(
            plan.cut_cost() < seed.cut_cost(),
            "refined {} vs seed {}",
            plan.cut_cost(),
            seed.cut_cost()
        );
        assert!(plan.refinement.cluster_moves >= 1);
        assert_cut_consistency(&plan);
        assert!(plan.shard_gates.iter().all(|&g| g > 0));
    }

    #[test]
    fn partition_is_deterministic() {
        let a = counter(5);
        let b = counter(5);
        let fused = FusedNetlist::fuse_refs(&[&a, &b]);
        let p1 = ShardPlan::partition(&fused, 4);
        let p2 = ShardPlan::partition(&fused, 4);
        assert_eq!(p1.owner, p2.owner);
        assert_eq!(p1.shard_gates, p2.shard_gates);
        assert_eq!(p1.refinement, p2.refinement);
        assert_eq!(p1.cuts.comb_cuts, p2.cuts.comb_cuts);
        assert_eq!(p1.cuts.reg_cuts, p2.cuts.reg_cuts);
        assert_eq!(p1.cuts.dff_cuts, p2.cuts.dff_cuts);
    }

    #[test]
    fn k1_owns_everything() {
        let a = counter(4);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 1);
        assert!(plan.owner.iter().all(|&o| o == 0));
        assert!(plan.cuts.is_empty());
        assert_eq!(plan.refinement, RefineReport::default());
    }

    #[test]
    fn from_owner_matches_partition_extraction() {
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 2);
        let rebuilt = ShardPlan::from_owner(&fused, 2, plan.owner.clone());
        assert_eq!(rebuilt.shard_gates, plan.shard_gates);
        assert_eq!(rebuilt.cuts.comb_cuts, plan.cuts.comb_cuts);
        assert_eq!(rebuilt.cuts.reg_cuts, plan.cuts.reg_cuts);
        assert_eq!(rebuilt.cuts.dff_cuts, plan.cuts.dff_cuts);
        assert_eq!(rebuilt.cut_cost(), plan.cut_cost());
        assert_eq!(rebuilt.refinement.cluster_moves, 0);
    }

    #[test]
    #[should_panic(expected = "owner map does not match")]
    fn from_owner_rejects_wrong_length() {
        let a = counter(4);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        ShardPlan::from_owner(&fused, 2, vec![0u16; 3]);
    }
}
