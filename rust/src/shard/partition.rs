//! Levelization-aware partitioning of a fused netlist into K shards.
//!
//! The partitioner works at the granularity of *segments* — a run of
//! consecutive combinational levels of one member. Initially every
//! member is one segment (all its levels); segments are bin-packed onto
//! shards largest-first (LPT). When K exceeds the member count some
//! shards would sit empty, so the largest splittable segment is cut at
//! the level boundary closest to halving its gate count and the tail
//! moves to an empty shard. Cutting at level boundaries keeps the cut
//! interface small and classifiable (see [`CutMap`] and the exchange
//! protocol in [`crate::shard`]).

use std::collections::HashSet;

use super::fusion::FusedNetlist;
use crate::synth::{NetId, Node};

/// One cut signal: net `net` is owned (written) by shard `from` and
/// read by shard `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Cut {
    pub net: NetId,
    pub from: u16,
    pub to: u16,
}

/// The explicit cut-signal interface of a [`ShardPlan`], split by
/// synchronization class (full protocol in the [`crate::shard`] module
/// docs).
#[derive(Clone, Debug, Default)]
pub struct CutMap {
    /// LUT outputs read by a cross-shard LUT in the same cycle; these
    /// force per-level phasing.
    pub comb_cuts: Vec<Cut>,
    /// Level-0 nets (inputs, constants, DFF q) read cross-shard;
    /// satisfied by the per-cycle barrier.
    pub reg_cuts: Vec<Cut>,
    /// Combinational nets feeding cross-shard DFF d-inputs; satisfied
    /// by the clock-edge sample after the last evaluation phase.
    pub dff_cuts: Vec<Cut>,
}

impl CutMap {
    /// Total cut signals of all classes.
    pub fn len(&self) -> usize {
        self.comb_cuts.len() + self.reg_cuts.len() + self.dff_cuts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A K-way partition of a fused netlist: per-net shard ownership, the
/// per-shard gate loads, and the cut-signal interface between shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Shard count K (≥ 1).
    pub shards: usize,
    /// Owning shard per fused net.
    pub owner: Vec<u16>,
    /// LUTs per shard (the balance the partitioner optimized).
    pub shard_gates: Vec<usize>,
    /// Cross-shard signal interface.
    pub cuts: CutMap,
}

/// A run of consecutive levels `[lo, hi]` (1-based, inclusive) of one
/// member, with its LUT count.
#[derive(Clone, Debug)]
struct Segment {
    member: usize,
    lo: u32,
    hi: u32,
    gates: usize,
}

impl ShardPlan {
    /// Partition `fused` into `shards` shards (clamped to ≥ 1).
    /// Deterministic in its inputs: the same fused netlist and K always
    /// produce the same plan.
    pub fn partition(fused: &FusedNetlist, shards: usize) -> ShardPlan {
        let k = shards.max(1);
        let nl = &fused.netlist;
        let lv = nl.levelize();
        let depth = lv.depth();
        // Per-member per-level LUT counts (level 1..=depth).
        let n_members = fused.member_count();
        let mut mlg = vec![vec![0usize; depth as usize + 1]; n_members];
        for level in 1..=depth {
            for &id in lv.level_luts(level) {
                mlg[fused.member_of(id) as usize][level as usize] += 1;
            }
        }

        // Seed: one whole-member segment each; LPT largest-first onto
        // the least-loaded shard. Ties break on lower shard index (and
        // on member order among equal-sized members), keeping the plan
        // deterministic.
        let mut segments: Vec<Segment> = (0..n_members)
            .map(|m| Segment {
                member: m,
                lo: 1,
                hi: depth,
                gates: fused.members[m].gates,
            })
            .collect();
        segments.sort_by(|a, b| b.gates.cmp(&a.gates).then(a.member.cmp(&b.member)));
        let mut bins: Vec<Vec<Segment>> = vec![Vec::new(); k];
        let mut load = vec![0usize; k];
        for seg in segments {
            let bin = (0..k).min_by_key(|&b| (load[b], b)).unwrap();
            load[bin] += seg.gates;
            bins[bin].push(seg);
        }

        // Fill empty shards by splitting the largest splittable segment
        // at the level boundary nearest its gate-count midpoint.
        while let Some(empty) = load.iter().position(|&l| l == 0) {
            let mut best: Option<(usize, usize, usize)> = None; // (bin, idx, gates)
            for (b, bin) in bins.iter().enumerate() {
                for (i, seg) in bin.iter().enumerate() {
                    let spans = (seg.lo..=seg.hi)
                        .filter(|&l| mlg[seg.member][l as usize] > 0)
                        .count();
                    if spans >= 2 && best.map_or(true, |(_, _, g)| seg.gates > g) {
                        best = Some((b, i, seg.gates));
                    }
                }
            }
            let Some((b, i, _)) = best else { break };
            let seg = bins[b].remove(i);
            let half = seg.gates / 2;
            let (mut split, mut run, mut best_diff) = (seg.lo, 0usize, usize::MAX);
            // Split after level `l` ∈ [lo, hi): head = [lo, l].
            for l in seg.lo..seg.hi {
                run += mlg[seg.member][l as usize];
                let diff = run.abs_diff(half);
                if run > 0 && run < seg.gates && diff < best_diff {
                    best_diff = diff;
                    split = l;
                }
            }
            let head_gates: usize =
                (seg.lo..=split).map(|l| mlg[seg.member][l as usize]).sum();
            let tail = Segment {
                member: seg.member,
                lo: split + 1,
                hi: seg.hi,
                gates: seg.gates - head_gates,
            };
            let head = Segment { lo: seg.lo, hi: split, gates: head_gates, ..seg };
            load[b] -= tail.gates;
            load[empty] += tail.gates;
            bins[b].push(head);
            bins[empty].push(tail);
        }

        // Ownership: LUTs by their segment; level-0 nets (inputs,
        // constants, DFF q) by the member's head segment — their values
        // only move at cycle boundaries, so placement only affects cut
        // classification, not correctness.
        let mut owner = vec![0u16; nl.len()];
        let mut head_shard = vec![0u16; n_members];
        let mut head_lo = vec![u32::MAX; n_members];
        for (b, bin) in bins.iter().enumerate() {
            for seg in bin {
                if seg.lo < head_lo[seg.member] {
                    head_lo[seg.member] = seg.lo;
                    head_shard[seg.member] = b as u16;
                }
            }
        }
        for (m, fm) in fused.members.iter().enumerate() {
            for id in fm.net_range.0..fm.net_range.1 {
                owner[id as usize] = head_shard[m];
            }
        }
        for (b, bin) in bins.iter().enumerate() {
            for seg in bin {
                for level in seg.lo..=seg.hi {
                    for &id in lv.level_luts(level) {
                        if fused.member_of(id) as usize == seg.member {
                            owner[id as usize] = b as u16;
                        }
                    }
                }
            }
        }

        // Cut extraction: every cross-shard read, classified by the
        // kind of the net being read.
        let mut cuts = CutMap::default();
        let mut seen: HashSet<Cut> = HashSet::new();
        for (id, node) in nl.nodes() {
            match node {
                Node::Lut { ins, .. } => {
                    let to = owner[id as usize];
                    for &i in ins {
                        let from = owner[i as usize];
                        if from == to {
                            continue;
                        }
                        let cut = Cut { net: i, from, to };
                        if !seen.insert(cut) {
                            continue;
                        }
                        match nl.node(i) {
                            Node::Lut { .. } => cuts.comb_cuts.push(cut),
                            _ => cuts.reg_cuts.push(cut),
                        }
                    }
                }
                Node::Dff { d, .. } => {
                    let to = owner[id as usize];
                    let from = owner[*d as usize];
                    if from == to {
                        continue;
                    }
                    let cut = Cut { net: *d, from, to };
                    if !seen.insert(cut) {
                        continue;
                    }
                    match nl.node(*d) {
                        Node::Lut { .. } => cuts.dff_cuts.push(cut),
                        _ => cuts.reg_cuts.push(cut),
                    }
                }
                _ => {}
            }
        }

        ShardPlan { shards: k, owner, shard_gates: load, cuts }
    }

    /// Whether evaluation must synchronize every level (true iff the
    /// plan has same-cycle combinational cuts; whole-member plans run
    /// one phase per cycle).
    pub fn per_level_sync(&self) -> bool {
        !self.cuts.comb_cuts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::Netlist;

    fn counter(bits: usize) -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..bits).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    #[test]
    fn whole_member_partition_has_no_comb_cuts() {
        let a = counter(4);
        let b = counter(6);
        let c = counter(8);
        let fused = FusedNetlist::fuse_refs(&[&a, &b, &c]);
        let plan = ShardPlan::partition(&fused, 2);
        assert_eq!(plan.shards, 2);
        assert!(plan.cuts.comb_cuts.is_empty());
        assert!(plan.cuts.reg_cuts.is_empty());
        assert!(plan.cuts.dff_cuts.is_empty());
        assert!(!plan.per_level_sync());
        // Every shard got work, and loads sum to the total gate count.
        assert!(plan.shard_gates.iter().all(|&g| g > 0));
        assert_eq!(
            plan.shard_gates.iter().sum::<usize>(),
            fused.netlist.count_luts()
        );
        // LPT: the biggest member sits alone on one shard.
        let owners: HashSet<u16> = (fused.members[2].net_range.0
            ..fused.members[2].net_range.1)
            .map(|id| plan.owner[id as usize])
            .collect();
        assert_eq!(owners.len(), 1);
    }

    #[test]
    fn oversubscribed_partition_splits_at_level_boundary() {
        // One member, two shards: the member must split, producing
        // cross-level cuts and per-level sync.
        let a = counter(16);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 2);
        assert!(plan.shard_gates.iter().all(|&g| g > 0), "{:?}", plan.shard_gates);
        assert!(!plan.cuts.is_empty());
        assert!(plan.per_level_sync());
        // Split shards stay balanced within the widest level's worth.
        let diff = plan.shard_gates[0].abs_diff(plan.shard_gates[1]);
        assert!(diff < fused.netlist.count_luts(), "degenerate split");
        // Cut ownership is consistent: each cut's net really is owned
        // by `from` and ≠ `to`.
        for cut in plan
            .cuts
            .comb_cuts
            .iter()
            .chain(&plan.cuts.reg_cuts)
            .chain(&plan.cuts.dff_cuts)
        {
            assert_eq!(plan.owner[cut.net as usize], cut.from);
            assert_ne!(cut.from, cut.to);
        }
    }

    #[test]
    fn partition_is_deterministic() {
        let a = counter(5);
        let b = counter(5);
        let fused = FusedNetlist::fuse_refs(&[&a, &b]);
        let p1 = ShardPlan::partition(&fused, 4);
        let p2 = ShardPlan::partition(&fused, 4);
        assert_eq!(p1.owner, p2.owner);
        assert_eq!(p1.shard_gates, p2.shard_gates);
    }

    #[test]
    fn k1_owns_everything() {
        let a = counter(4);
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let plan = ShardPlan::partition(&fused, 1);
        assert!(plan.owner.iter().all(|&o| o == 0));
        assert!(plan.cuts.is_empty());
    }
}
