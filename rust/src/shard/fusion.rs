//! Netlist fusion: merge N member netlists into one wide module.
//!
//! Fusion is a pure renumbering — member m's nets are copied in order
//! at a fixed base offset, so member state in the fused module evolves
//! exactly as it does solo. Nothing is deduplicated across members
//! (two members' identical constant nodes stay distinct nets): the
//! per-member net ranges must remain disjoint and contiguous for the
//! scatter index and the per-member toggle accounting to be exact.

use std::collections::HashMap;
use std::sync::Arc;

use crate::synth::{Levelization, NetId, Netlist, Node};

/// One member system inside a [`FusedNetlist`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FusedMember {
    /// Bus-name namespace prefix (`s0`, `s1`, …); member bus `b` is
    /// fused bus `{prefix}/b`.
    pub prefix: String,
    /// Half-open net-id range `[start, end)` of this member's nodes in
    /// the fused netlist. `start` is also the id offset applied to the
    /// member's own net ids.
    pub net_range: (NetId, NetId),
    /// LUT count — the partitioner's balance weight.
    pub gates: usize,
}

/// N member netlists merged into one module with namespaced PI/PO maps
/// and a per-member index for exact result scatter.
#[derive(Clone)]
pub struct FusedNetlist {
    /// The merged netlist.
    pub netlist: Netlist,
    /// Per-member metadata, in fusion (= boot) order.
    pub members: Vec<FusedMember>,
    /// Owning member per net (dense inverse of the member ranges).
    net_member: Vec<u16>,
}

impl FusedNetlist {
    /// Fuse member netlists, in order. Member `m` keeps its internal
    /// structure verbatim; its net ids shift by the running base and
    /// its bus names gain the `s{m}/` prefix.
    pub fn fuse(members: &[Arc<Netlist>]) -> FusedNetlist {
        let refs: Vec<&Netlist> = members.iter().map(|m| m.as_ref()).collect();
        FusedNetlist::fuse_refs(&refs)
    }

    /// [`FusedNetlist::fuse`] over plain references.
    pub fn fuse_refs(members: &[&Netlist]) -> FusedNetlist {
        assert!(!members.is_empty(), "fuse needs at least one member netlist");
        assert!(
            members.len() <= usize::from(u16::MAX),
            "too many members for the u16 member index"
        );
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert!(total <= NetId::MAX as usize, "fused netlist exceeds NetId range");
        let mut nodes = Vec::with_capacity(total);
        let mut outputs = Vec::new();
        let mut input_buses = Vec::new();
        let mut meta = Vec::with_capacity(members.len());
        for (m, nl) in members.iter().enumerate() {
            let base = nodes.len() as NetId;
            let prefix = format!("s{m}");
            for (_, node) in nl.nodes() {
                nodes.push(match node {
                    Node::Const(b) => Node::Const(*b),
                    Node::Input(name) => Node::Input(format!("{prefix}/{name}")),
                    Node::Lut { ins, tt } => Node::Lut {
                        ins: ins.iter().map(|&i| i + base).collect(),
                        tt: *tt,
                    },
                    Node::Dff { d, init } => Node::Dff { d: *d + base, init: *init },
                });
            }
            for (name, bits) in nl.outputs() {
                outputs.push((
                    format!("{prefix}/{name}"),
                    bits.iter().map(|&b| b + base).collect(),
                ));
            }
            for (name, bits) in &nl.input_buses {
                input_buses.push((
                    format!("{prefix}/{name}"),
                    bits.iter().map(|&b| b + base).collect(),
                ));
            }
            meta.push(FusedMember {
                prefix,
                net_range: (base, nodes.len() as NetId),
                gates: nl.count_luts(),
            });
        }
        let netlist = Netlist::from_parts(nodes, outputs, input_buses);
        FusedNetlist::from_parts(netlist, meta)
    }

    /// Rebuild from a merged netlist plus member metadata (the store
    /// decode path). The member ranges must tile the netlist exactly.
    pub fn from_parts(netlist: Netlist, members: Vec<FusedMember>) -> FusedNetlist {
        assert!(!members.is_empty(), "fused netlist without members");
        let mut net_member = Vec::with_capacity(netlist.len());
        let mut cursor = 0 as NetId;
        for (m, fm) in members.iter().enumerate() {
            let (s, e) = fm.net_range;
            assert_eq!(s, cursor, "member {m} range does not tile the netlist");
            assert!(s <= e, "member {m} range inverted");
            net_member.extend(std::iter::repeat(m as u16).take((e - s) as usize));
            cursor = e;
        }
        assert_eq!(
            cursor as usize,
            netlist.len(),
            "member ranges do not cover the fused netlist"
        );
        FusedNetlist { netlist, members, net_member }
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// The member owning a fused net id.
    #[inline(always)]
    pub fn member_of(&self, net: NetId) -> u16 {
        self.net_member[net as usize]
    }

    /// Fused bus name for member `m`'s bus `name` (`s{m}/name`).
    pub fn bus_name(&self, member: usize, name: &str) -> String {
        format!("{}/{}", self.members[member].prefix, name)
    }

    /// Build the refinement [`ClusterIndex`] of this module: one cluster
    /// per non-empty (member, combinational level) cell, in level-major
    /// deterministic order, each with its LUTs and its read adjacency.
    /// `lv` must be this module's levelization.
    pub fn cluster_index(&self, lv: &Levelization) -> ClusterIndex {
        let depth = lv.depth() as usize;
        let n_members = self.member_count();
        // (member, level) -> cluster id, assigned in first-seen
        // (level-major) order so the index is deterministic.
        let mut cell = vec![u32::MAX; n_members * (depth + 1)];
        let mut clusters: Vec<Cluster> = Vec::new();
        for level in 1..=lv.depth() {
            for &id in lv.level_luts(level) {
                let m = self.member_of(id) as usize;
                let key = m * (depth + 1) + level as usize;
                let c = if cell[key] == u32::MAX {
                    let c = clusters.len() as u32;
                    cell[key] = c;
                    clusters.push(Cluster {
                        member: m,
                        level,
                        luts: Vec::new(),
                        ins: Vec::new(),
                        gates: 0,
                    });
                    c
                } else {
                    cell[key]
                };
                let cl = &mut clusters[c as usize];
                cl.luts.push(id);
                cl.gates += 1;
            }
        }
        // Read adjacency with multiplicities. Same-level reads cannot
        // exist (levelization), so a cluster never reads itself; the
        // map is sorted by net id so the adjacency is deterministic.
        for cl in &mut clusters {
            let mut reads: HashMap<NetId, u32> = HashMap::new();
            for &id in &cl.luts {
                let Node::Lut { ins, .. } = self.netlist.node(id) else {
                    unreachable!("level order contains only LUTs")
                };
                for &i in ins {
                    *reads.entry(i).or_insert(0) += 1;
                }
            }
            let mut ins: Vec<(NetId, u32)> = reads.into_iter().collect();
            ins.sort_unstable_by_key(|&(n, _)| n);
            cl.ins = ins;
        }
        ClusterIndex { clusters }
    }
}

/// One refinement cluster: the LUTs of one member at one combinational
/// level. The cut-minimizing partitioner
/// ([`super::partition::ShardPlan`]) moves whole clusters between
/// shards, so a cluster is the granularity at which the cut interface
/// can change.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// Owning member index.
    pub member: usize,
    /// Combinational level (1-based) of every LUT in the cluster.
    pub level: u32,
    /// The cluster's LUT output nets, in levelization order.
    pub luts: Vec<NetId>,
    /// Read adjacency: every net the cluster's LUTs read, with pin
    /// multiplicity, sorted by net id. Never contains the cluster's own
    /// outputs (same-level reads are impossible).
    pub ins: Vec<(NetId, u32)>,
    /// LUT count (= `luts.len()`, the balance weight).
    pub gates: usize,
}

/// The clusters of a fused module, in deterministic level-major order —
/// the move units of the cut-minimizing refinement pass.
#[derive(Clone, Debug)]
pub struct ClusterIndex {
    pub clusters: Vec<Cluster>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4-bit counter with q outputs (mirrors the wordsim test netlist).
    fn counter() -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..4).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    #[test]
    fn fusion_offsets_and_namespaces() {
        let a = counter();
        let b = counter();
        let fused = FusedNetlist::fuse_refs(&[&a, &b]);
        assert_eq!(fused.member_count(), 2);
        assert_eq!(fused.netlist.len(), a.len() + b.len());
        assert_eq!(fused.members[0].net_range, (0, a.len() as NetId));
        assert_eq!(
            fused.members[1].net_range,
            (a.len() as NetId, (a.len() + b.len()) as NetId)
        );
        assert_eq!(fused.members[0].gates, a.count_luts());
        // Namespaced outputs resolve; originals are gone.
        assert!(fused.netlist.output_bits("s0/q").is_some());
        assert!(fused.netlist.output_bits("s1/q").is_some());
        assert!(fused.netlist.output_bits("q").is_none());
        // Member index matches the ranges.
        assert_eq!(fused.member_of(0), 0);
        assert_eq!(fused.member_of(a.len() as NetId), 1);
        // Member 1's structure is member 0's, shifted.
        let base = a.len() as NetId;
        for (id, node) in a.nodes() {
            match (node, fused.netlist.node(id + base)) {
                (Node::Lut { ins, tt }, Node::Lut { ins: fins, tt: ftt }) => {
                    assert_eq!(tt, ftt);
                    let shifted: Vec<NetId> = ins.iter().map(|&i| i + base).collect();
                    assert_eq!(&shifted, fins);
                }
                (Node::Dff { d, init }, Node::Dff { d: fd, init: finit }) => {
                    assert_eq!((d + base, init), (*fd, finit));
                }
                (Node::Const(x), Node::Const(y)) => assert_eq!(x, y),
                (Node::Input(_), Node::Input(n)) => {
                    assert!(n.starts_with("s1/"), "{n}");
                }
                (a, b) => panic!("node kind changed: {a:?} vs {b:?}"),
            }
        }
        // The fused module levelizes (topological invariant preserved).
        let lv = fused.netlist.levelize();
        assert_eq!(lv.depth(), a.levelize().depth());
    }

    #[test]
    fn from_parts_validates_tiling() {
        let a = counter();
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let meta = fused.members.clone();
        // Round-trips.
        let rebuilt = FusedNetlist::from_parts(fused.netlist.clone(), meta);
        assert_eq!(rebuilt.member_count(), 1);
        assert_eq!(rebuilt.member_of(0), 0);
    }

    #[test]
    fn cluster_index_tiles_the_luts() {
        let a = counter();
        let b = counter();
        let fused = FusedNetlist::fuse_refs(&[&a, &b]);
        let lv = fused.netlist.levelize();
        let ci = fused.cluster_index(&lv);
        // Every LUT is in exactly one cluster, and the cluster's member
        // and level match the LUT's.
        let total: usize = ci.clusters.iter().map(|c| c.gates).sum();
        assert_eq!(total, fused.netlist.count_luts());
        let mut seen = std::collections::HashSet::new();
        for cl in &ci.clusters {
            assert_eq!(cl.gates, cl.luts.len());
            for &id in &cl.luts {
                assert!(seen.insert(id), "LUT {id} in two clusters");
                assert_eq!(fused.member_of(id) as usize, cl.member);
            }
            // Adjacency never contains the cluster's own outputs and is
            // sorted (deterministic).
            for w in cl.ins.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            for &(n, m) in &cl.ins {
                assert!(m >= 1);
                assert!(!cl.luts.contains(&n), "self-read in cluster adjacency");
            }
        }
        // Determinism: two builds agree exactly.
        let ci2 = fused.cluster_index(&lv);
        assert_eq!(ci.clusters.len(), ci2.clusters.len());
        for (x, y) in ci.clusters.iter().zip(&ci2.clusters) {
            assert_eq!(x.luts, y.luts);
            assert_eq!(x.ins, y.ins);
        }
    }

    #[test]
    #[should_panic(expected = "do not cover")]
    fn from_parts_rejects_short_ranges() {
        let a = counter();
        let fused = FusedNetlist::fuse_refs(&[&a]);
        let mut meta = fused.members.clone();
        meta[0].net_range.1 -= 1;
        FusedNetlist::from_parts(fused.netlist.clone(), meta);
    }
}
