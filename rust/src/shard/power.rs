//! Fused activity measurement: every member's activation schedule,
//! driven through one sharded simulation pass.
//!
//! The driver is an exact linearization of the solo activation loop
//! (`power::model::drive_activations`): each member advances its own
//! schedule — operand draws, start pulse, run to `done`, next
//! activation — against the shared global step. Because fusion keeps
//! member state disjoint and the operand protocol is the shared
//! `apply_activation_inputs`, member m's nets see exactly the cycle
//! sequence of its solo run, so outputs, toggle counts, cycle counts
//! and the power figures derived from them are bit-identical. A
//! member's per-lane toggles are snapshotted the moment its schedule
//! completes; whatever its FSM does while slower members finish is
//! discarded.

use crate::power::model::apply_activation_inputs;
use crate::power::LaneActivityReport;
use crate::rtl::ir::PiModuleDesign;
use crate::stim::Lfsr32;
use crate::synth::{Drive, LaneWord};

use super::shardsim::ShardSim;

/// One member's stimulus schedule for a fused measurement pass.
pub struct MemberStim<'a> {
    /// The member's RTL design (port list + fixed-point format).
    pub design: &'a PiModuleDesign,
    /// Activations to run (0 = member idles; it reports zero activity).
    pub activations: u32,
    /// Per-lane LFSR seeds, `W::LANES` entries.
    pub seeds: Vec<u32>,
}

struct MemberState {
    lfsrs: Vec<Lfsr32>,
    remaining: u32,
    guard: u32,
    started: bool,
    finished: bool,
}

/// Drive every member's activation schedule through `sim` (which must
/// be fresh) and return one [`LaneActivityReport`] per member, each
/// bit-identical to [`crate::power::measure_activity_batch_wide`] run
/// solo on that member with the same activations and seeds.
pub fn measure_fused_activity<W: LaneWord>(
    sim: &mut ShardSim<'_, W>,
    stims: &[MemberStim<'_>],
) -> Vec<LaneActivityReport> {
    let fused = sim.fused();
    assert_eq!(
        stims.len(),
        fused.member_count(),
        "one stimulus schedule per fused member"
    );
    assert_eq!(sim.cycles(), 0, "fused measurement needs a fresh simulator");
    for stim in stims {
        assert_eq!(stim.seeds.len(), W::LANES, "expected one seed per lane");
    }
    let start_bus: Vec<String> =
        (0..stims.len()).map(|m| fused.bus_name(m, "start")).collect();
    let done_bus: Vec<String> =
        (0..stims.len()).map(|m| fused.bus_name(m, "done")).collect();
    let in_prefix: Vec<String> =
        (0..stims.len()).map(|m| format!("{}/", fused.members[m].prefix)).collect();
    sim.session(|d| {
        let mut values = vec![0i64; W::LANES];
        let mut reports: Vec<Option<LaneActivityReport>> = (0..stims.len())
            .map(|_| None)
            .collect();
        let mut states: Vec<MemberState> = stims
            .iter()
            .map(|s| MemberState {
                lfsrs: s.seeds.iter().map(|&sd| Lfsr32::new(sd)).collect(),
                remaining: s.activations,
                guard: 0,
                started: false,
                finished: false,
            })
            .collect();
        let mut active = 0usize;
        for (m, stim) in stims.iter().enumerate() {
            if stim.activations == 0 {
                states[m].finished = true;
                reports[m] = Some(LaneActivityReport {
                    lanes: vec![0.0; W::LANES],
                    cycles: 0,
                    activations: 0,
                });
                continue;
            }
            apply_activation_inputs(
                d, stim.design, &in_prefix[m], &mut values, &mut states[m].lfsrs,
                stim.design.q,
            );
            d.set_bus(&start_bus[m], 1);
            states[m].started = true;
            active += 1;
        }
        while active > 0 {
            d.step();
            for m in 0..stims.len() {
                if states[m].finished {
                    continue;
                }
                if states[m].started {
                    d.set_bus(&start_bus[m], 0);
                    states[m].started = false;
                    states[m].guard = 0;
                }
                let done = d.get_bit_word(&done_bus[m]);
                if done == W::ones() {
                    states[m].remaining -= 1;
                    if states[m].remaining == 0 {
                        states[m].finished = true;
                        active -= 1;
                        // Snapshot at finish: the member consumed every
                        // global step so far, so the global cycle count
                        // is exactly its solo cycle count.
                        let cycles = d.cycles();
                        let lanes = d
                            .member_lane_toggles(m)
                            .iter()
                            .map(|&t| t as f64 / cycles.max(1) as f64)
                            .collect();
                        reports[m] = Some(LaneActivityReport {
                            lanes,
                            cycles,
                            activations: stims[m].activations,
                        });
                    } else {
                        apply_activation_inputs(
                            d, stims[m].design, &in_prefix[m], &mut values,
                            &mut states[m].lfsrs, stims[m].design.q,
                        );
                        d.set_bus(&start_bus[m], 1);
                        states[m].started = true;
                    }
                } else {
                    // Mirrors the solo loop's lockstep check: the FSMs
                    // have data-independent latency, so a member's lanes
                    // must finish together.
                    assert!(
                        done.is_zero(),
                        "lanes diverged on `done` (data-dependent latency?)"
                    );
                    states[m].guard += 1;
                    assert!(states[m].guard < 5_000, "activation did not finish");
                }
            }
        }
        reports.into_iter().map(|r| r.expect("member left unreported")).collect()
    })
}
