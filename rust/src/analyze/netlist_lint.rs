//! Pass 1: structural netlist lint.
//!
//! Four checks over a packed [`Netlist`], none of which trust the
//! producer's bookkeeping:
//!
//! * **AN102 dangling references** — every LUT input, DFF data input,
//!   and interface bus bit must name a net inside the netlist. Checked
//!   first; the later checks skip out-of-range edges so one defect does
//!   not cascade into panics.
//! * **AN101 multiple drivers** — in the sea-of-nodes representation a
//!   node *is* its net, so multi-drive can only enter through the
//!   interface maps: an input-bus bit bound to a node that is not a
//!   primary input (the binding would clobber a logic driver), or two
//!   bus bits bound to the same net.
//! * **AN103 combinational cycles** — an explicit iterative DFS cycle
//!   reporter over LUT→input edges. DFF data edges are excluded: the
//!   register boundary legally breaks cycles (a DFF's `d` may point
//!   forward). This intentionally does not call
//!   [`Netlist::levelize`], which `assert!`s topological order instead
//!   of reporting the offending cycle.
//! * **AN104 dead gates** (warning) — LUTs/DFFs unreachable from any
//!   output, mirroring the liveness rule of [`crate::synth::opt::dce`]
//!   (outputs are roots; reachability traces LUT inputs and DFF data;
//!   primary inputs and constants are interface, not gates). Pipeline
//!   netlists end in a DCE sweep, so any dead gate here means a
//!   producer bug.

use super::{DiagCode, Diagnostic, Locus};
use crate::synth::{NetId, Netlist, Node};
use std::collections::HashMap;

fn node_kind(node: &Node) -> &'static str {
    match node {
        Node::Const(_) => "a constant",
        Node::Input(_) => "a primary input",
        Node::Lut { .. } => "a LUT",
        Node::Dff { .. } => "a DFF",
    }
}

/// Run the structural lint. Returns every finding; empty on a clean
/// netlist.
pub fn lint_netlist(nl: &Netlist) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n = nl.len();
    let in_range = |id: NetId| (id as usize) < n;

    // AN102: dangling references, before anything dereferences an edge.
    for (id, node) in nl.nodes() {
        match node {
            Node::Lut { ins, .. } => {
                for &i in ins {
                    if !in_range(i) {
                        diags.push(Diagnostic::new(
                            DiagCode::DanglingRef,
                            Locus::Net(id),
                            format!("LUT {id} reads dangling net {i} (netlist has {n} nets)"),
                        ));
                    }
                }
            }
            Node::Dff { d, .. } => {
                if !in_range(*d) {
                    diags.push(Diagnostic::new(
                        DiagCode::DanglingRef,
                        Locus::Net(id),
                        format!("DFF {id} samples dangling net {d} (netlist has {n} nets)"),
                    ));
                }
            }
            _ => {}
        }
    }
    for (name, bits) in nl.outputs() {
        for (k, &b) in bits.iter().enumerate() {
            if !in_range(b) {
                diags.push(Diagnostic::new(
                    DiagCode::DanglingRef,
                    Locus::Net(b),
                    format!("output bus {name} bit {k} references dangling net {b}"),
                ));
            }
        }
    }
    for (name, bits) in &nl.input_buses {
        for (k, &b) in bits.iter().enumerate() {
            if !in_range(b) {
                diags.push(Diagnostic::new(
                    DiagCode::DanglingRef,
                    Locus::Net(b),
                    format!("input bus {name} bit {k} references dangling net {b}"),
                ));
            }
        }
    }

    // AN101: multiple drivers through the input-bus binding map.
    let mut bound: HashMap<NetId, (&str, usize)> = HashMap::new();
    for (name, bits) in &nl.input_buses {
        for (k, &b) in bits.iter().enumerate() {
            if !in_range(b) {
                continue;
            }
            if let Some(&(prev_name, prev_k)) = bound.get(&b) {
                diags.push(Diagnostic::new(
                    DiagCode::MultiDriver,
                    Locus::Net(b),
                    format!(
                        "net {b} is bound by input bus {name} bit {k} and \
                         by input bus {prev_name} bit {prev_k}"
                    ),
                ));
                continue;
            }
            bound.insert(b, (name.as_str(), k));
            if !matches!(nl.node(b), Node::Input(_)) {
                diags.push(Diagnostic::new(
                    DiagCode::MultiDriver,
                    Locus::Net(b),
                    format!(
                        "input bus {name} bit {k} binds net {b}, which is also driven by {}",
                        node_kind(nl.node(b))
                    ),
                ));
            }
        }
    }

    // AN103: combinational cycles. Iterative DFS with an explicit gray
    // path so the offending cycle is reported, not just detected.
    let mut color = vec![0u8; n]; // 0 = white, 1 = gray, 2 = black
    let mut path: Vec<NetId> = Vec::new();
    let mut stack: Vec<(NetId, usize)> = Vec::new();
    for (root, _) in nl.nodes() {
        if color[root as usize] != 0 {
            continue;
        }
        color[root as usize] = 1;
        path.push(root);
        stack.push((root, 0));
        while let Some(&(id, ci)) = stack.last() {
            let ins: &[NetId] = match nl.node(id) {
                Node::Lut { ins, .. } => ins,
                _ => &[],
            };
            if ci < ins.len() {
                stack.last_mut().expect("nonempty DFS stack").1 += 1;
                let child = ins[ci];
                if !in_range(child) {
                    continue; // dangling: already reported as AN102
                }
                match color[child as usize] {
                    0 => {
                        color[child as usize] = 1;
                        path.push(child);
                        stack.push((child, 0));
                    }
                    1 => {
                        // Back edge: the cycle is the gray path from the
                        // first occurrence of `child` down to `id`.
                        let pos = path
                            .iter()
                            .position(|&p| p == child)
                            .expect("gray net must be on the DFS path");
                        let mut cycle: Vec<String> =
                            path[pos..].iter().map(|p| p.to_string()).collect();
                        cycle.push(child.to_string());
                        diags.push(Diagnostic::new(
                            DiagCode::CombLoop,
                            Locus::Net(child),
                            format!("combinational cycle through nets {}", cycle.join(" -> ")),
                        ));
                    }
                    _ => {}
                }
            } else {
                stack.pop();
                color[id as usize] = 2;
                path.pop();
            }
        }
    }

    // AN104: dead gates — backward reachability from the outputs,
    // mirroring `opt::dce` liveness exactly.
    let mut live = vec![false; n];
    let mut work: Vec<NetId> = Vec::new();
    for (_, bits) in nl.outputs() {
        for &b in bits {
            if in_range(b) && !live[b as usize] {
                live[b as usize] = true;
                work.push(b);
            }
        }
    }
    while let Some(id) = work.pop() {
        match nl.node(id) {
            Node::Lut { ins, .. } => {
                for &i in ins {
                    if in_range(i) && !live[i as usize] {
                        live[i as usize] = true;
                        work.push(i);
                    }
                }
            }
            Node::Dff { d, .. } => {
                if in_range(*d) && !live[*d as usize] {
                    live[*d as usize] = true;
                    work.push(*d);
                }
            }
            _ => {}
        }
    }
    for (id, node) in nl.nodes() {
        let kind = match node {
            Node::Lut { .. } => "LUT",
            Node::Dff { .. } => "DFF",
            _ => continue,
        };
        if !live[id as usize] {
            diags.push(Diagnostic::new(
                DiagCode::DeadGate,
                Locus::Net(id),
                format!("{kind} {id} is unreachable from any output"),
            ));
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::opt::dce;

    fn codes(diags: &[Diagnostic]) -> Vec<DiagCode> {
        diags.iter().map(|d| d.code).collect()
    }

    fn clean_netlist() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 2);
        let b = nl.input_bus("b", 2);
        let x = nl.xor2(a[0], b[0]);
        let y = nl.and2(a[1], b[1]);
        let q = nl.dff(0, false);
        let z = nl.or2(y, q);
        nl.set_dff_input(q, x);
        nl.add_output("y", vec![x, z]);
        nl
    }

    #[test]
    fn clean_netlist_is_clean() {
        let (nl, _) = dce(&clean_netlist());
        assert!(lint_netlist(&nl).is_empty());
    }

    #[test]
    fn dff_feedback_is_not_a_comb_loop() {
        // q <= not q: legal cycle through the register boundary.
        let mut nl = Netlist::new();
        let q = nl.dff(0, false);
        let nq = nl.not(q);
        nl.set_dff_input(q, nq);
        nl.add_output("q", vec![q]);
        assert!(lint_netlist(&nl).is_empty());
    }

    #[test]
    fn comb_loop_reported_with_path() {
        // Two LUTs reading each other: built via from_parts, which does
        // no validation (the builder API cannot express this).
        let nodes = vec![
            Node::Input("a".into()),
            Node::Lut { ins: vec![0, 2], tt: 0b0110 },
            Node::Lut { ins: vec![1], tt: 0b01 },
        ];
        let nl = Netlist::from_parts(
            nodes,
            vec![("y".into(), vec![1])],
            vec![("a".into(), vec![0])],
        );
        let diags = lint_netlist(&nl);
        let loops: Vec<_> =
            diags.iter().filter(|d| d.code == DiagCode::CombLoop).collect();
        assert_eq!(loops.len(), 1, "{diags:?}");
        assert!(loops[0].message.contains("1 -> 2 -> 1"), "{}", loops[0].message);
    }

    #[test]
    fn double_driven_net_reported() {
        // Bus bit bound to a LUT output (a logic driver).
        let nodes = vec![
            Node::Input("a".into()),
            Node::Lut { ins: vec![0], tt: 0b01 },
        ];
        let nl = Netlist::from_parts(
            nodes,
            vec![("y".into(), vec![1])],
            vec![("a".into(), vec![0]), ("b".into(), vec![1])],
        );
        let diags = lint_netlist(&nl);
        assert_eq!(codes(&diags), vec![DiagCode::MultiDriver], "{diags:?}");
        assert!(diags[0].message.contains("driven by a LUT"), "{}", diags[0].message);
    }

    #[test]
    fn duplicate_bus_binding_reported() {
        let nodes = vec![Node::Input("a".into())];
        let nl = Netlist::from_parts(
            nodes,
            vec![],
            vec![("a".into(), vec![0]), ("b".into(), vec![0])],
        );
        let diags = lint_netlist(&nl);
        assert_eq!(codes(&diags), vec![DiagCode::MultiDriver], "{diags:?}");
    }

    #[test]
    fn dangling_refs_reported_without_panicking() {
        let nodes = vec![
            Node::Input("a".into()),
            Node::Lut { ins: vec![0, 99], tt: 0b0110 },
            Node::Dff { d: 77, init: false },
        ];
        let nl = Netlist::from_parts(
            nodes,
            vec![("y".into(), vec![1, 55])],
            vec![("a".into(), vec![0])],
        );
        let diags = lint_netlist(&nl);
        let dangling = diags.iter().filter(|d| d.code == DiagCode::DanglingRef).count();
        assert_eq!(dangling, 3, "{diags:?}");
    }

    #[test]
    fn dead_gate_warned() {
        let mut nl = clean_netlist(); // not DCE'd: or2/and2 feed z, but add a floater
        let a = nl.input_bus("c", 1);
        let _dead = nl.not(a[0]);
        let diags = lint_netlist(&nl);
        assert!(!diags.is_empty());
        assert!(diags.iter().all(|d| d.code == DiagCode::DeadGate), "{diags:?}");
        assert!(diags.iter().all(|d| d.severity == super::super::Severity::Warning));
    }
}
