//! Pass 3: dimensional re-check of Π units.
//!
//! The Π search promises that every emitted group is dimensionless —
//! that promise is the paper's core claim, and everything downstream
//! (the fixed-point envelope of pass 2 included) leans on it. This pass
//! closes the loop *independently*: for every unit it recomputes
//! `∏ dim(portᵖ)^eᵖ` from the system model's symbol dimensions and the
//! unit's exponent vector using the [`crate::units::Dimension`] algebra,
//! and asserts the product is dimensionless (`AN301` otherwise). It also
//! re-derives the canonical serial schedule
//! ([`crate::fixedpoint::monomial_ops`]) from the exponents and compares
//! it with the stored microprogram (`AN302` on mismatch) — the stored
//! ops, not the exponents, are what lowering turned into gates.

use super::{DiagCode, Diagnostic, Locus};
use crate::fixedpoint::monomial_ops;
use crate::newton::SystemModel;
use crate::rtl::PiModuleDesign;
use crate::units::Dimension;

/// Run the dimensional re-check. Returns every finding; empty when all
/// units are provably dimensionless with canonical schedules.
pub fn check_dimensions(system: &SystemModel, design: &PiModuleDesign) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (ui, unit) in design.units.iter().enumerate() {
        if unit.exponents.len() != design.ports.len() {
            diags.push(Diagnostic::new(
                DiagCode::OpsMismatch,
                Locus::Unit(ui),
                format!(
                    "unit {}: exponent vector has {} entries for {} ports",
                    unit.name,
                    unit.exponents.len(),
                    design.ports.len()
                ),
            ));
            continue;
        }

        let mut dim = Dimension::NONE;
        let mut resolved = true;
        for (p, port) in design.ports.iter().enumerate() {
            match system.symbols.get(port.symbol_index) {
                Some(sym) => dim = dim * sym.dimension.powi(unit.exponents[p]),
                None => {
                    diags.push(Diagnostic::new(
                        DiagCode::NotDimensionless,
                        Locus::Unit(ui),
                        format!(
                            "unit {}: port {} references symbol index {} \
                             outside the system model ({} symbols)",
                            unit.name,
                            port.name,
                            port.symbol_index,
                            system.symbols.len()
                        ),
                    ));
                    resolved = false;
                }
            }
        }
        if resolved && !dim.is_dimensionless() {
            diags.push(Diagnostic::new(
                DiagCode::NotDimensionless,
                Locus::Unit(ui),
                format!(
                    "unit {} ({}) has residual dimension {}",
                    unit.name,
                    unit.expr,
                    dim.formula()
                ),
            ));
        }

        if monomial_ops(&unit.exponents) != unit.ops {
            diags.push(Diagnostic::new(
                DiagCode::OpsMismatch,
                Locus::Unit(ui),
                format!(
                    "unit {}: stored microprogram ({} ops) does not match the \
                     canonical schedule of its exponent vector",
                    unit.name,
                    unit.ops.len()
                ),
            ));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::{MonOp, Q16_15};
    use crate::newton::{Symbol, SymbolKind};
    use crate::rtl::{PiUnit, Port};
    use crate::units::BaseDim;

    fn sym(name: &str, dimension: Dimension) -> Symbol {
        Symbol { name: name.into(), dimension, kind: SymbolKind::Signal, value: None }
    }

    /// Pendulum-like toy: t [T], l [L], g [L T^-2]; Π = g t² / l.
    fn toy(exps: Vec<i64>) -> (SystemModel, PiModuleDesign) {
        let system = SystemModel {
            name: "toy".into(),
            symbols: vec![
                sym("t", Dimension::base(BaseDim::Time)),
                sym("l", Dimension::base(BaseDim::Length)),
                sym(
                    "g",
                    Dimension::base(BaseDim::Length) / Dimension::base(BaseDim::Time).powi(2),
                ),
            ],
            relations: Vec::new(),
        };
        let ports: Vec<Port> = system
            .symbols
            .iter()
            .enumerate()
            .map(|(i, s)| Port { name: s.name.clone(), symbol_index: i })
            .collect();
        let design = PiModuleDesign {
            name: "toy".into(),
            system: "toy".into(),
            q: Q16_15,
            ports,
            units: vec![PiUnit {
                name: "pi_0".into(),
                ops: monomial_ops(&exps),
                expr: "g t^2 / l".into(),
                exponents: exps,
            }],
            target_unit: 0,
            dropped_symbols: Vec::new(),
        };
        (system, design)
    }

    #[test]
    fn dimensionless_group_is_clean() {
        let (sys, d) = toy(vec![2, -1, 1]);
        assert!(check_dimensions(&sys, &d).is_empty());
    }

    #[test]
    fn residual_dimension_reported() {
        // Drop the 1/l factor: residual dimension L.
        let (sys, d) = toy(vec![2, 0, 1]);
        let diags = check_dimensions(&sys, &d);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, DiagCode::NotDimensionless);
        assert!(diags[0].message.contains('L'), "{}", diags[0].message);
    }

    #[test]
    fn corrupted_microprogram_reported() {
        let (sys, mut d) = toy(vec![2, -1, 1]);
        // Flip a Mul to a Div: exponents still dimensionless, but the
        // schedule no longer computes the monomial.
        d.units[0].ops = vec![
            MonOp::Load(0),
            MonOp::Div(0),
            MonOp::Mul(2),
            MonOp::Div(1),
        ];
        let diags = check_dimensions(&sys, &d);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, DiagCode::OpsMismatch);
    }

    #[test]
    fn out_of_range_symbol_reported() {
        let (sys, mut d) = toy(vec![2, -1, 1]);
        d.ports[2].symbol_index = 99;
        let diags = check_dimensions(&sys, &d);
        assert!(
            diags.iter().any(|x| x.code == DiagCode::NotDimensionless),
            "{diags:?}"
        );
    }

    #[test]
    fn exponent_length_mismatch_reported() {
        let (sys, mut d) = toy(vec![2, -1, 1]);
        d.units[0].exponents.pop();
        let diags = check_dimensions(&sys, &d);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, DiagCode::OpsMismatch);
    }
}
