//! Pass 2: Q-format interval analysis of Π microprograms.
//!
//! Abstract interpretation of each unit's serial op schedule
//! ([`crate::fixedpoint::monomial_ops`]) over *magnitude intervals*
//! `[lo, hi]` of raw fixed-point values (`0 <= lo <= hi`). Port
//! intervals derive from the Newton system model: constants are point
//! intervals at `|value|`; sensor signals get the normalized envelope
//! `[`[`SIGNAL_LO`]`, `[`SIGNAL_HI`]`]` (the paper's premise is that
//! signals are scaled near unity before entering the datapath —
//! dimensionless Π products of near-unity inputs are themselves near
//! unity, which is what makes the narrow Q format viable at all).
//!
//! The transfer functions are the *actual* fixed-point ops: the
//! magnitude bound of a product/quotient is computed with the same
//! rounding as [`crate::fixedpoint::mul`] / [`crate::fixedpoint::div`],
//! via the pre-saturation wide forms [`crate::fixedpoint::mul_wide`] /
//! [`crate::fixedpoint::div_wide`] — an op is flagged (`AN201`) exactly
//! when its wide result exceeds `max_raw`, i.e. when the hardware would
//! saturate. Signed operands round toward `+inf`, which can shift a
//! mixed-sign magnitude by one LSB relative to these nonnegative
//! envelopes; the bounds are advisory (all pass-2 findings are
//! warnings), so that LSB does not affect gating.

use super::{DiagCode, Diagnostic, Locus};
use crate::fixedpoint::{div, div_wide, mul, mul_wide, MonOp};
use crate::newton::{SymbolKind, SystemModel};
use crate::rtl::PiModuleDesign;

/// Lower magnitude of the assumed sensor-signal envelope (in units of
/// the format's 1.0).
pub const SIGNAL_LO: f64 = 0.5;
/// Upper magnitude of the assumed sensor-signal envelope.
pub const SIGNAL_HI: f64 = 2.0;

/// A raw-magnitude interval: `0 <= lo <= hi`, in raw Q-format units.
#[derive(Clone, Copy, Debug)]
struct Interval {
    lo: i64,
    hi: i64,
}

fn port_name(design: &PiModuleDesign, p: usize) -> &str {
    design.ports.get(p).map_or("?", |port| port.name.as_str())
}

/// Run the interval analysis. Returns every finding; empty when no op
/// of any unit can saturate under the signal envelope.
pub fn check_qintervals(system: &SystemModel, design: &PiModuleDesign) -> Vec<Diagnostic> {
    let q = design.q;
    let mut diags = Vec::new();

    // Port intervals from the system model.
    let mut ivs: Vec<Interval> = Vec::with_capacity(design.ports.len());
    for port in &design.ports {
        let iv = match system.symbols.get(port.symbol_index) {
            Some(sym) if sym.kind == SymbolKind::Constant => {
                let v = sym.value.unwrap_or(1.0).abs();
                if v > q.max_value() {
                    diags.push(Diagnostic::new(
                        DiagCode::QConstUnrepresentable,
                        Locus::Module,
                        format!(
                            "constant {} = {v} exceeds the {q} range (max {:.6})",
                            sym.name,
                            q.max_value()
                        ),
                    ));
                    Interval { lo: q.max_raw(), hi: q.max_raw() }
                } else {
                    let raw = q.from_f64(v);
                    Interval { lo: raw, hi: raw }
                }
            }
            // Signals — and unresolvable symbol indices, which the
            // dimensional re-check reports as errors — get the envelope.
            _ => Interval { lo: q.from_f64(SIGNAL_LO), hi: q.from_f64(SIGNAL_HI) },
        };
        ivs.push(iv);
    }

    for (ui, unit) in design.units.iter().enumerate() {
        let mut acc: Option<Interval> = None;
        for (oi, op) in unit.ops.iter().enumerate() {
            match *op {
                MonOp::Load(p) => acc = ivs.get(p).copied(),
                MonOp::LoadOne => acc = Some(Interval { lo: q.one(), hi: q.one() }),
                MonOp::Mul(p) => {
                    let (Some(a), Some(&b)) = (acc, ivs.get(p)) else {
                        // Malformed schedule; pass 3 reports AN302.
                        acc = None;
                        continue;
                    };
                    let lo = mul(q, a.lo, b.lo);
                    let hi_wide = mul_wide(q, a.hi, b.hi);
                    let hi = if hi_wide > q.max_raw() as i128 {
                        diags.push(Diagnostic::new(
                            DiagCode::QSaturation,
                            Locus::Unit(ui),
                            format!(
                                "unit {}: op {oi} (mul by port {}) can saturate {q}: \
                                 |result| may reach {:.3}",
                                unit.name,
                                port_name(design, p),
                                hi_wide as f64 / q.scale() as f64
                            ),
                        ));
                        q.max_raw()
                    } else {
                        hi_wide as i64
                    };
                    acc = Some(Interval { lo, hi });
                }
                MonOp::Div(p) => {
                    let (Some(a), Some(&b)) = (acc, ivs.get(p)) else {
                        acc = None;
                        continue;
                    };
                    if b.lo == 0 {
                        diags.push(Diagnostic::new(
                            DiagCode::QDivByZero,
                            Locus::Unit(ui),
                            format!(
                                "unit {}: op {oi} divides by port {} whose magnitude \
                                 interval includes zero (divide-by-zero saturates)",
                                unit.name,
                                port_name(design, p)
                            ),
                        ));
                        acc = Some(Interval { lo: 0, hi: q.max_raw() });
                        continue;
                    }
                    let hi_wide = div_wide(q, a.hi, b.lo);
                    let hi = if hi_wide > q.max_raw() as i128 {
                        diags.push(Diagnostic::new(
                            DiagCode::QSaturation,
                            Locus::Unit(ui),
                            format!(
                                "unit {}: op {oi} (div by port {}) can saturate {q}: \
                                 |result| may reach {:.3}",
                                unit.name,
                                port_name(design, p),
                                hi_wide as f64 / q.scale() as f64
                            ),
                        ));
                        q.max_raw()
                    } else {
                        hi_wide as i64
                    };
                    let lo = div(q, a.lo, b.hi);
                    acc = Some(Interval { lo, hi });
                }
            }
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::{monomial_ops, QFormat, Q16_15};
    use crate::rtl::{PiUnit, Port};
    use crate::units::Dimension;

    fn sym(name: &str, kind: SymbolKind, value: Option<f64>) -> crate::newton::Symbol {
        crate::newton::Symbol {
            name: name.into(),
            dimension: Dimension::NONE,
            kind,
            value,
        }
    }

    fn toy(q: QFormat, symbols: Vec<crate::newton::Symbol>, exps: Vec<i64>) -> (SystemModel, PiModuleDesign) {
        let system = SystemModel {
            name: "toy".into(),
            symbols,
            relations: Vec::new(),
        };
        let ports: Vec<Port> = system
            .symbols
            .iter()
            .enumerate()
            .map(|(i, s)| Port { name: s.name.clone(), symbol_index: i })
            .collect();
        let design = PiModuleDesign {
            name: "toy".into(),
            system: "toy".into(),
            q,
            ports,
            units: vec![PiUnit {
                name: "pi_0".into(),
                ops: monomial_ops(&exps),
                expr: String::new(),
                exponents: exps,
            }],
            target_unit: 0,
            dropped_symbols: Vec::new(),
        };
        (system, design)
    }

    fn codes(diags: &[Diagnostic]) -> Vec<DiagCode> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn balanced_signals_are_clean() {
        let (sys, d) = toy(
            Q16_15,
            vec![
                sym("a", SymbolKind::Signal, None),
                sym("b", SymbolKind::Signal, None),
                sym("g", SymbolKind::Constant, Some(9.80665)),
            ],
            vec![2, -1, 1],
        );
        assert!(check_qintervals(&sys, &d).is_empty());
    }

    #[test]
    fn narrow_format_saturation_flagged() {
        // Q3.2: max value 7.75. a^3 with a up to 2.0 stays at 8 > 7.75.
        let (sys, d) = toy(
            QFormat::new(3, 2),
            vec![sym("a", SymbolKind::Signal, None)],
            vec![3],
        );
        let diags = check_qintervals(&sys, &d);
        assert_eq!(codes(&diags), vec![DiagCode::QSaturation], "{diags:?}");
    }

    #[test]
    fn unrepresentable_constant_flagged() {
        // g = 9.80665 does not fit Q3.2 (max 7.75).
        let (sys, d) = toy(
            QFormat::new(3, 2),
            vec![
                sym("a", SymbolKind::Signal, None),
                sym("g", SymbolKind::Constant, Some(9.80665)),
            ],
            vec![1, -1],
        );
        let diags = check_qintervals(&sys, &d);
        assert!(
            codes(&diags).contains(&DiagCode::QConstUnrepresentable),
            "{diags:?}"
        );
    }

    #[test]
    fn zero_constant_divisor_flagged() {
        let (sys, d) = toy(
            Q16_15,
            vec![
                sym("a", SymbolKind::Signal, None),
                sym("z", SymbolKind::Constant, Some(0.0)),
            ],
            vec![1, -1],
        );
        let diags = check_qintervals(&sys, &d);
        assert_eq!(codes(&diags), vec![DiagCode::QDivByZero], "{diags:?}");
    }

    #[test]
    fn division_blowup_flagged() {
        // 1 / a^9 with a down to 0.5 reaches 512 > 255.99 in Q8.7.
        let (sys, d) = toy(
            QFormat::new(8, 7),
            vec![sym("a", SymbolKind::Signal, None)],
            vec![-9],
        );
        let diags = check_qintervals(&sys, &d);
        assert!(codes(&diags).contains(&DiagCode::QSaturation), "{diags:?}");
    }
}
