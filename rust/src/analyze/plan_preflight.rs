//! Pass 4: shard-plan pre-flight.
//!
//! [`crate::shard::shardsim::ShardSim`] packs a fused netlist against a
//! [`ShardPlan`] and, until this pass existed, the only defense against
//! a stale or corrupted plan was a pack-time panic (any cross-shard
//! read with no matching cut entry). This pass proves the same
//! invariants *statically*, before anything packs or serves, demoting
//! that panic to a never-fires backstop:
//!
//! * **AN401** — the owner map must have one entry per fused net, each
//!   naming a shard `< K`. A malformed owner map stops the pass (cut
//!   re-derivation against it would be meaningless).
//! * **AN402 / AN403** — the plan's [`crate::shard::CutMap`] is compared
//!   against an *independent* re-derivation of the required cut set
//!   from the netlist structure and the owner map (mirroring the
//!   partitioner's extraction rule: first-seen classification of each
//!   distinct `(net, from, to)` crossing, LUT reads and DFF d-samples).
//!   A required cut missing from the plan is an error (`AN402`: the
//!   exchange would never publish a word a reader depends on); an entry
//!   no crossing needs, a duplicated entry, or an entry filed under the
//!   wrong synchronization class is a stale-plan warning (`AN403`,
//!   paired with `AN402` when the entry also belongs elsewhere).
//! * **AN404** — the fused scatter index must be a bijection: the
//!   member net ranges must tile `[0, len)` exactly. Checked over the
//!   raw `(netlist length, members)` data because
//!   [`crate::shard::FusedNetlist::from_parts`] `assert!`s the same
//!   property instead of reporting it.
//! * **AN405** — the plan's actual cut cost must equal its
//!   [`crate::shard::RefineReport::refined_cut_cost`]; a mismatch means
//!   the plan and its provenance report were separated (e.g. a corrupt
//!   or hand-edited artifact).

use super::{DiagCode, Diagnostic, Locus};
use crate::shard::{Cut, FusedMember, ShardPlan};
use crate::synth::{NetId, Netlist, Node};
use std::collections::HashSet;

/// Statically verify a shard plan against the fused netlist and member
/// index it was derived from. Returns every finding; empty for a plan
/// the sharded evaluator can pack and run safely.
pub fn preflight_plan(
    nl: &Netlist,
    members: &[FusedMember],
    plan: &ShardPlan,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n = nl.len();

    // AN404: scatter-index bijection over the raw member ranges.
    let mut cursor: NetId = 0;
    let mut tiled = true;
    for (m, member) in members.iter().enumerate() {
        let (lo, hi) = member.net_range;
        if hi < lo {
            diags.push(Diagnostic::new(
                DiagCode::ScatterCorrupt,
                Locus::Module,
                format!("member {m} ({}) has inverted net range [{lo}, {hi})", member.prefix),
            ));
            tiled = false;
        } else if lo != cursor {
            diags.push(Diagnostic::new(
                DiagCode::ScatterCorrupt,
                Locus::Module,
                format!(
                    "member {m} ({}) starts at net {lo}, expected {cursor}: \
                     member ranges do not tile the fused netlist",
                    member.prefix
                ),
            ));
            tiled = false;
        }
        cursor = cursor.max(hi);
    }
    if tiled && cursor as usize != n {
        diags.push(Diagnostic::new(
            DiagCode::ScatterCorrupt,
            Locus::Module,
            format!("member ranges cover {cursor} of {n} fused nets"),
        ));
    }

    // AN401: owner-map shape. Malformed ⇒ stop (nothing below is
    // derivable from a bad owner map).
    if plan.owner.len() != n {
        diags.push(Diagnostic::new(
            DiagCode::OwnerMapMalformed,
            Locus::Module,
            format!("owner map has {} entries for {n} fused nets", plan.owner.len()),
        ));
        return diags;
    }
    let mut owner_ok = true;
    for (i, &o) in plan.owner.iter().enumerate() {
        if (o as usize) >= plan.shards {
            diags.push(Diagnostic::new(
                DiagCode::OwnerMapMalformed,
                Locus::Net(i as NetId),
                format!("net {i} is owned by shard {o}, but the plan has {} shards", plan.shards),
            ));
            owner_ok = false;
        }
    }
    if !owner_ok {
        return diags;
    }

    // Independent cut re-derivation, mirroring the partitioner's
    // extraction rule: one shared first-seen set across classes.
    let owner = &plan.owner;
    let mut seen: HashSet<Cut> = HashSet::new();
    let mut want_comb: Vec<Cut> = Vec::new();
    let mut want_reg: Vec<Cut> = Vec::new();
    let mut want_dff: Vec<Cut> = Vec::new();
    for (id, node) in nl.nodes() {
        match node {
            Node::Lut { ins, .. } => {
                let to = owner[id as usize];
                for &i in ins {
                    let Some(&from) = owner.get(i as usize) else {
                        continue; // dangling ref: netlist lint territory
                    };
                    if from == to {
                        continue;
                    }
                    let cut = Cut { net: i, from, to };
                    if !seen.insert(cut) {
                        continue;
                    }
                    match nl.node(i) {
                        Node::Lut { .. } => want_comb.push(cut),
                        _ => want_reg.push(cut),
                    }
                }
            }
            Node::Dff { d, .. } => {
                let to = owner[id as usize];
                let Some(&from) = owner.get(*d as usize) else {
                    continue;
                };
                if from == to {
                    continue;
                }
                let cut = Cut { net: *d, from, to };
                if !seen.insert(cut) {
                    continue;
                }
                match nl.node(*d) {
                    Node::Lut { .. } => want_dff.push(cut),
                    _ => want_reg.push(cut),
                }
            }
            _ => {}
        }
    }

    // AN402 / AN403 per synchronization class.
    compare_class(&mut diags, "comb_cuts", &want_comb, &plan.cuts.comb_cuts);
    compare_class(&mut diags, "reg_cuts", &want_reg, &plan.cuts.reg_cuts);
    compare_class(&mut diags, "dff_cuts", &want_dff, &plan.cuts.dff_cuts);

    // AN405: refine-report consistency.
    let cost = plan.cut_cost();
    if cost != plan.refinement.refined_cut_cost {
        diags.push(Diagnostic::new(
            DiagCode::RefineMismatch,
            Locus::Module,
            format!(
                "plan carries {cost} cut entries but its refine report claims {}",
                plan.refinement.refined_cut_cost
            ),
        ));
    }

    diags
}

fn compare_class(diags: &mut Vec<Diagnostic>, class: &str, want: &[Cut], have: &[Cut]) {
    let want_set: HashSet<Cut> = want.iter().copied().collect();
    let have_set: HashSet<Cut> = have.iter().copied().collect();
    for cut in want {
        if !have_set.contains(cut) {
            diags.push(Diagnostic::new(
                DiagCode::MissingCut,
                Locus::Net(cut.net),
                format!(
                    "net {} (owner shard {}) is read by shard {} but has no \
                     {class} entry — the exchange would never publish it",
                    cut.net, cut.from, cut.to
                ),
            ));
        }
    }
    for cut in have {
        if !want_set.contains(cut) {
            diags.push(Diagnostic::new(
                DiagCode::StaleCut,
                Locus::Net(cut.net),
                format!(
                    "{class} entry (net {}, shard {} -> {}) matches no \
                     cross-shard read",
                    cut.net, cut.from, cut.to
                ),
            ));
        }
    }
    if have.len() != have_set.len() {
        diags.push(Diagnostic::new(
            DiagCode::StaleCut,
            Locus::Module,
            format!(
                "{class} carries {} duplicate entr{}",
                have.len() - have_set.len(),
                if have.len() - have_set.len() == 1 { "y" } else { "ies" }
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::FusedNetlist;

    fn codes(diags: &[Diagnostic]) -> Vec<DiagCode> {
        diags.iter().map(|d| d.code).collect()
    }

    /// Two small members with real cross-shard traffic once split.
    fn fused_pair() -> FusedNetlist {
        let mut a = Netlist::new();
        let ai = a.input_bus("x", 2);
        let a1 = a.xor2(ai[0], ai[1]);
        let a2 = a.and2(a1, ai[0]);
        a.add_output("y", vec![a2]);

        let mut b = Netlist::new();
        let bi = b.input_bus("x", 2);
        let q = b.dff(0, false);
        let b1 = b.or2(bi[0], q);
        let b2 = b.xor2(b1, bi[1]);
        b.set_dff_input(q, b2);
        b.add_output("y", vec![b2]);

        FusedNetlist::fuse_refs(&[&a, &b])
    }

    /// A 2-shard plan that owns each member's nets on its own shard —
    /// except member b's level-0 nets, moved to shard 0 to create
    /// cross-shard register reads and a cross-shard DFF d-sample.
    fn cross_plan(fused: &FusedNetlist) -> ShardPlan {
        let mut owner: Vec<u16> = (0..fused.netlist.len())
            .map(|i| fused.member_of(i as NetId))
            .collect();
        // Move every member-b level-0 net (inputs + DFF) to shard 0 so
        // member b's LUTs read cross-shard.
        let (blo, bhi) = fused.members[1].net_range;
        for i in blo..bhi {
            if matches!(
                fused.netlist.node(i),
                Node::Input(_) | Node::Dff { .. } | Node::Const(_)
            ) {
                owner[i as usize] = 0;
            }
        }
        ShardPlan::from_owner(fused, 2, owner)
    }

    #[test]
    fn pristine_plans_pass_at_all_k() {
        let fused = fused_pair();
        for k in [1usize, 2, 3] {
            let plan = ShardPlan::partition(&fused, k);
            let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
            assert!(diags.is_empty(), "K={k}: {diags:?}");
        }
        let plan = cross_plan(&fused);
        assert!(plan.cut_cost() > 0, "fixture should have cross-shard traffic");
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn dropped_cut_entry_is_an_error() {
        let fused = fused_pair();
        let mut plan = cross_plan(&fused);
        assert!(!plan.cuts.reg_cuts.is_empty());
        let dropped = plan.cuts.reg_cuts.pop().unwrap();
        // Keep the refine report consistent so only the drop is visible.
        plan.refinement.refined_cut_cost = plan.cut_cost();
        plan.refinement.initial_cut_cost = plan.cut_cost();
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert_eq!(codes(&diags), vec![DiagCode::MissingCut], "{diags:?}");
        assert!(diags[0].message.contains(&format!("net {}", dropped.net)));
    }

    #[test]
    fn stale_and_duplicate_entries_warn() {
        let fused = fused_pair();
        let mut plan = cross_plan(&fused);
        let extra = Cut { net: 0, from: 1, to: 0 };
        plan.cuts.reg_cuts.push(extra);
        let dup = plan.cuts.reg_cuts[0];
        plan.cuts.reg_cuts.push(dup);
        plan.refinement.refined_cut_cost = plan.cut_cost();
        plan.refinement.initial_cut_cost = plan.cut_cost();
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert!(!diags.is_empty());
        assert!(diags.iter().all(|d| d.code == DiagCode::StaleCut), "{diags:?}");
    }

    #[test]
    fn corrupt_scatter_index_is_an_error() {
        let fused = fused_pair();
        let plan = ShardPlan::partition(&fused, 2);
        let mut members = fused.members.clone();
        members[1].net_range.0 += 1; // gap between members
        let diags = preflight_plan(&fused.netlist, &members, &plan);
        assert!(
            diags.iter().any(|d| d.code == DiagCode::ScatterCorrupt),
            "{diags:?}"
        );
    }

    #[test]
    fn malformed_owner_map_is_an_error() {
        let fused = fused_pair();
        let mut plan = ShardPlan::partition(&fused, 2);
        plan.owner[3] = 9; // shard >= K
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert_eq!(codes(&diags), vec![DiagCode::OwnerMapMalformed], "{diags:?}");

        plan.owner.truncate(2);
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert_eq!(codes(&diags), vec![DiagCode::OwnerMapMalformed], "{diags:?}");
    }

    #[test]
    fn refine_report_mismatch_is_an_error() {
        let fused = fused_pair();
        let mut plan = cross_plan(&fused);
        plan.refinement.refined_cut_cost += 1;
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert_eq!(codes(&diags), vec![DiagCode::RefineMismatch], "{diags:?}");
    }
}
