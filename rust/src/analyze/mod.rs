//! Multi-pass static verification of compiled artifacts.
//!
//! Everything upstream of this module *generates* — Π-search emits
//! exponent vectors, the RTL builder emits microprograms, techmap emits
//! packed netlists, the partitioner emits shard plans — and until now
//! nothing independently *checked* those artifacts: correctness rested
//! on runtime panics and differential simulation. This module closes the
//! loop with four static passes, each re-deriving an invariant from
//! first principles rather than trusting the producer's bookkeeping:
//!
//! 1. **Structural netlist lint** ([`netlist_lint`]) — multiple drivers,
//!    dangling net references, combinational cycles (an explicit DFS
//!    cycle reporter; never calls [`crate::synth::Netlist::levelize`],
//!    which asserts on non-topological graphs), and dead gates
//!    unreachable from any output.
//! 2. **Q-format interval analysis** ([`qinterval`]) — abstract
//!    interpretation of each Π unit's microprogram over fixed-point
//!    magnitude intervals, flagging ops whose result can saturate the
//!    configured [`crate::fixedpoint::QFormat`].
//! 3. **Dimensional re-check** ([`dimcheck`]) — independently re-derives
//!    the [`crate::units::Dimension`] of every Π unit from its port
//!    dimensions and exponent vector and asserts it is dimensionless,
//!    and re-derives the canonical microprogram from the exponents.
//! 4. **Shard-plan pre-flight** ([`plan_preflight`]) — statically proves
//!    [`crate::shard::CutMap`] completeness against an independent cut
//!    re-derivation, scatter-index integrity, and refine-report
//!    consistency, demoting the pack-time stale-plan panic in
//!    [`crate::shard::shardsim`] to a never-fires backstop.
//!
//! # Diagnostics model
//!
//! Every finding is a [`Diagnostic`]: the [`Pass`] that produced it, a
//! [`Severity`], a stable [`DiagCode`] (`AN1xx` structural, `AN2xx`
//! numeric, `AN3xx` dimensional, `AN4xx` shard plan), a [`Locus`]
//! naming the net / unit / shard it anchors to, and a human-readable
//! message. Codes, severities, and the code→pass mapping are stable API:
//! tests and CI gates match on them, and the flow stage persists them in
//! the artifact store (`flow::store`, format v5). Error-level findings
//! are *gating*: the `lint` CLI exits non-zero and
//! [`crate::coordinator::ServeSet`] refuses to boot the system. Warnings
//! are advisory unless the caller opts into `--deny warnings`.
//!
//! # Pass contracts
//!
//! Each pass is a pure function of its inputs and returns all findings
//! it can prove (no early exit on the first defect, except where a
//! defect makes further derivation meaningless — a malformed owner map
//! stops cut re-derivation). On the pristine corpus every pass returns
//! no diagnostics at all; each defect class injected by
//! `rust/tests/analyze_verifier.rs` yields its expected code.

use crate::newton::SystemModel;
use crate::rtl::PiModuleDesign;
use crate::synth::MappedDesign;
use std::fmt;

pub mod dimcheck;
pub mod netlist_lint;
pub mod plan_preflight;
pub mod qinterval;

pub use dimcheck::check_dimensions;
pub use netlist_lint::lint_netlist;
pub use plan_preflight::preflight_plan;
pub use qinterval::check_qintervals;

/// How serious a finding is. `Error` findings gate serving and fail the
/// `lint` CLI; `Warning` findings are advisory (gating only under
/// `--deny warnings`).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// The verifier pass a diagnostic came from.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Pass {
    NetlistLint,
    QInterval,
    DimCheck,
    PlanPreflight,
}

impl Pass {
    pub fn name(&self) -> &'static str {
        match self {
            Pass::NetlistLint => "netlist-lint",
            Pass::QInterval => "q-interval",
            Pass::DimCheck => "dim-check",
            Pass::PlanPreflight => "plan-preflight",
        }
    }
}

impl fmt::Display for Pass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Stable diagnostic codes. The numeric value (`AN` + wire id) is
/// persisted by the artifact store and matched by tests and CI — codes
/// must never be renumbered, only appended.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DiagCode {
    /// AN101: a net has more than one driver (an input-bus binding
    /// clobbers a logic driver, or two bus bits bind the same net).
    MultiDriver,
    /// AN102: a LUT input, DFF data input, or interface bus bit
    /// references a net id outside the netlist.
    DanglingRef,
    /// AN103: a combinational cycle through LUT inputs.
    CombLoop,
    /// AN104: a LUT or DFF unreachable from any output (warning).
    DeadGate,
    /// AN201: an op's result interval can saturate the Q format
    /// (warning).
    QSaturation,
    /// AN202: a divisor's interval includes zero (warning).
    QDivByZero,
    /// AN203: a constant symbol exceeds the representable range of the
    /// Q format (warning).
    QConstUnrepresentable,
    /// AN301: a Π unit's re-derived dimension is not dimensionless.
    NotDimensionless,
    /// AN302: a Π unit's stored microprogram does not match the
    /// canonical schedule re-derived from its exponent vector.
    OpsMismatch,
    /// AN401: the shard plan's owner map is malformed (wrong length, or
    /// references a shard >= K).
    OwnerMapMalformed,
    /// AN402: a cross-shard read has no matching cut entry.
    MissingCut,
    /// AN403: the plan carries a cut entry no cross-shard read needs,
    /// or a duplicated entry (warning).
    StaleCut,
    /// AN404: the fused scatter index is corrupt (member net ranges do
    /// not tile the fused netlist bijectively).
    ScatterCorrupt,
    /// AN405: the plan's actual cut cost disagrees with its
    /// `RefineReport`.
    RefineMismatch,
}

impl DiagCode {
    /// Every code, in wire-id order.
    pub const ALL: [DiagCode; 14] = [
        DiagCode::MultiDriver,
        DiagCode::DanglingRef,
        DiagCode::CombLoop,
        DiagCode::DeadGate,
        DiagCode::QSaturation,
        DiagCode::QDivByZero,
        DiagCode::QConstUnrepresentable,
        DiagCode::NotDimensionless,
        DiagCode::OpsMismatch,
        DiagCode::OwnerMapMalformed,
        DiagCode::MissingCut,
        DiagCode::StaleCut,
        DiagCode::ScatterCorrupt,
        DiagCode::RefineMismatch,
    ];

    /// Stable numeric id, persisted by the artifact store.
    pub fn wire(&self) -> u16 {
        match self {
            DiagCode::MultiDriver => 101,
            DiagCode::DanglingRef => 102,
            DiagCode::CombLoop => 103,
            DiagCode::DeadGate => 104,
            DiagCode::QSaturation => 201,
            DiagCode::QDivByZero => 202,
            DiagCode::QConstUnrepresentable => 203,
            DiagCode::NotDimensionless => 301,
            DiagCode::OpsMismatch => 302,
            DiagCode::OwnerMapMalformed => 401,
            DiagCode::MissingCut => 402,
            DiagCode::StaleCut => 403,
            DiagCode::ScatterCorrupt => 404,
            DiagCode::RefineMismatch => 405,
        }
    }

    /// Decode a persisted wire id.
    pub fn from_wire(wire: u16) -> Option<DiagCode> {
        DiagCode::ALL.iter().copied().find(|c| c.wire() == wire)
    }

    /// Printable form, e.g. `AN103`.
    pub fn as_str(&self) -> &'static str {
        match self {
            DiagCode::MultiDriver => "AN101",
            DiagCode::DanglingRef => "AN102",
            DiagCode::CombLoop => "AN103",
            DiagCode::DeadGate => "AN104",
            DiagCode::QSaturation => "AN201",
            DiagCode::QDivByZero => "AN202",
            DiagCode::QConstUnrepresentable => "AN203",
            DiagCode::NotDimensionless => "AN301",
            DiagCode::OpsMismatch => "AN302",
            DiagCode::OwnerMapMalformed => "AN401",
            DiagCode::MissingCut => "AN402",
            DiagCode::StaleCut => "AN403",
            DiagCode::ScatterCorrupt => "AN404",
            DiagCode::RefineMismatch => "AN405",
        }
    }

    /// The fixed severity of this code.
    pub fn severity(&self) -> Severity {
        match self {
            DiagCode::DeadGate
            | DiagCode::QSaturation
            | DiagCode::QDivByZero
            | DiagCode::QConstUnrepresentable
            | DiagCode::StaleCut => Severity::Warning,
            _ => Severity::Error,
        }
    }

    /// The pass that emits this code.
    pub fn pass(&self) -> Pass {
        match self.wire() / 100 {
            1 => Pass::NetlistLint,
            2 => Pass::QInterval,
            3 => Pass::DimCheck,
            _ => Pass::PlanPreflight,
        }
    }
}

impl fmt::Display for DiagCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

/// What a diagnostic anchors to.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Locus {
    /// The whole module / system.
    Module,
    /// A net of the (fused or per-system) netlist.
    Net(u32),
    /// A Π unit, by index into `PiModuleDesign::units`.
    Unit(usize),
    /// A shard of a `ShardPlan`.
    Shard(u16),
}

impl fmt::Display for Locus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Locus::Module => write!(f, "module"),
            Locus::Net(n) => write!(f, "net {n}"),
            Locus::Unit(u) => write!(f, "unit {u}"),
            Locus::Shard(s) => write!(f, "shard {s}"),
        }
    }
}

/// One verifier finding.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Diagnostic {
    /// Pass that produced the finding (derived from `code`).
    pub pass: Pass,
    /// Severity (derived from `code`).
    pub severity: Severity,
    /// Stable code, e.g. [`DiagCode::CombLoop`].
    pub code: DiagCode,
    /// Net / unit / shard the finding anchors to.
    pub locus: Locus,
    /// Human-readable description.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic; pass and severity follow from the code.
    pub fn new(code: DiagCode, locus: Locus, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            pass: code.pass(),
            severity: code.severity(),
            code,
            locus,
            message: message.into(),
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] {}: {}: {}",
            self.severity,
            self.code.as_str(),
            self.pass,
            self.locus,
            self.message
        )
    }
}

/// The verifier's output for one system: every finding of passes 1–3
/// (the shard-plan pre-flight runs separately, per fused plan). Persisted
/// as the `analyze` stage artifact.
#[derive(Clone, PartialEq, Debug)]
pub struct AnalysisReport {
    /// System identifier the report describes.
    pub system: String,
    /// All findings, in pass order.
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    /// Number of error-level findings.
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// Number of warning-level findings.
    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// Whether any finding gates serving.
    pub fn has_errors(&self) -> bool {
        self.errors() > 0
    }

    /// Whether the report is entirely clean (no findings at any level).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Run passes 1–3 over one system's compiled artifacts.
pub fn analyze_design(
    system: &SystemModel,
    design: &PiModuleDesign,
    mapped: &MappedDesign,
) -> AnalysisReport {
    let mut diagnostics = lint_netlist(&mapped.netlist);
    diagnostics.extend(check_qintervals(system, design));
    diagnostics.extend(check_dimensions(system, design));
    AnalysisReport { system: design.system.clone(), diagnostics }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_ids_roundtrip_and_are_stable() {
        let expect: Vec<u16> =
            vec![101, 102, 103, 104, 201, 202, 203, 301, 302, 401, 402, 403, 404, 405];
        let got: Vec<u16> = DiagCode::ALL.iter().map(|c| c.wire()).collect();
        assert_eq!(got, expect);
        for c in DiagCode::ALL {
            assert_eq!(DiagCode::from_wire(c.wire()), Some(c));
            assert_eq!(c.as_str(), format!("AN{}", c.wire()));
        }
        assert_eq!(DiagCode::from_wire(0), None);
        assert_eq!(DiagCode::from_wire(999), None);
    }

    #[test]
    fn severities_and_passes_follow_codes() {
        assert_eq!(DiagCode::CombLoop.severity(), Severity::Error);
        assert_eq!(DiagCode::DeadGate.severity(), Severity::Warning);
        assert_eq!(DiagCode::QSaturation.severity(), Severity::Warning);
        assert_eq!(DiagCode::MissingCut.severity(), Severity::Error);
        assert_eq!(DiagCode::StaleCut.severity(), Severity::Warning);
        assert_eq!(DiagCode::CombLoop.pass(), Pass::NetlistLint);
        assert_eq!(DiagCode::QDivByZero.pass(), Pass::QInterval);
        assert_eq!(DiagCode::NotDimensionless.pass(), Pass::DimCheck);
        assert_eq!(DiagCode::RefineMismatch.pass(), Pass::PlanPreflight);
        assert!(Severity::Warning < Severity::Error);
    }

    #[test]
    fn diagnostic_display_reads_well() {
        let d = Diagnostic::new(DiagCode::CombLoop, Locus::Net(7), "cycle 5 -> 7 -> 5");
        assert_eq!(d.pass, Pass::NetlistLint);
        assert_eq!(d.severity, Severity::Error);
        assert_eq!(d.to_string(), "error[AN103] netlist-lint: net 7: cycle 5 -> 7 -> 5");
    }

    #[test]
    fn report_counts() {
        let r = AnalysisReport {
            system: "toy".into(),
            diagnostics: vec![
                Diagnostic::new(DiagCode::DeadGate, Locus::Net(1), "w"),
                Diagnostic::new(DiagCode::CombLoop, Locus::Net(2), "e"),
            ],
        };
        assert_eq!(r.warnings(), 1);
        assert_eq!(r.errors(), 1);
        assert!(r.has_errors());
        assert!(!r.is_clean());
    }
}
