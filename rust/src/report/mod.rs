//! Report generation: the Table-1 renderer, the compiler-interchange JSON
//! consumed by `python/compile/aot.py`, and small formatting helpers.

pub mod export;
pub mod table1;

pub use export::{export_json, SystemExport};
pub use table1::{generate_row, generate_table, render_markdown, Table1Row};
