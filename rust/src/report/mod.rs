//! Report generation: the Table-1 renderer, the compiler-interchange JSON
//! consumed by `python/compile/aot.py`, and small formatting helpers.

pub mod export;
pub mod table1;

pub use export::{export_from_flow, export_json, export_system, SystemExport};
pub use table1::{
    generate_row, generate_table, generate_table_opts, generate_table_sequential,
    render_markdown, row_from_flow, Table1Row,
};
