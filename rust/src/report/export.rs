//! Compiler-interchange export: the Π-search results the Python AOT
//! pipeline needs, serialized as JSON.
//!
//! This is the single source of truth for the exponent matrices: the Rust
//! Π-search computes them once; the generated RTL, the Pallas kernel and
//! the reference oracle all consume the same matrices, so a bug cannot
//! hide in a re-derivation. (No external serde dependency — the structure
//! is small and flat, emitted by hand.)

use crate::fixedpoint::QFormat;
use crate::flow::{Flow, FlowConfig};
use crate::newton::corpus;

/// Exported description of one compiled system.
#[derive(Clone, Debug)]
pub struct SystemExport {
    pub id: String,
    /// All symbol names, in Newton declaration order.
    pub symbols: Vec<String>,
    /// Indices of participating symbols (the hardware port order).
    pub ports: Vec<usize>,
    /// Port names (sanitized).
    pub port_names: Vec<String>,
    /// N×k' exponent matrix over *ports*.
    pub exponents: Vec<Vec<i64>>,
    /// Index of the target symbol (over `symbols`).
    pub target_index: usize,
    /// Which Π group isolates the target.
    pub target_group: usize,
    /// Module latency in cycles (paper scheduling policy).
    pub latency: u64,
}

impl SystemExport {
    /// Position of the target symbol in port order.
    pub fn target_port(&self) -> usize {
        self.ports
            .iter()
            .position(|&si| si == self.target_index)
            .expect("target participates, so it has a port")
    }

    /// Invert the target-isolating monomial: given a predicted Π₀ and the
    /// measured non-target port signals, solve for the target parameter.
    pub fn recover_target(&self, pi0: f64, values_q: &[i64], q: QFormat) -> f64 {
        let exps = &self.exponents[self.target_group];
        let tp = self.target_port();
        let e_t = exps[tp];
        debug_assert!(e_t != 0);
        let mut others = 1f64;
        for (i, &e) in exps.iter().enumerate() {
            if i != tp && e != 0 {
                others *= q.to_f64(values_q[i]).powi(e as i32);
            }
        }
        let ratio = pi0 / others;
        if ratio <= 0.0 {
            return f64::NAN;
        }
        ratio.powf(1.0 / e_t as f64)
    }
}

/// Build the export record for one corpus system.
pub fn export_system(id: &str, q: QFormat) -> anyhow::Result<SystemExport> {
    let mut flow =
        Flow::for_system(id, FlowConfig { qformat: q, ..FlowConfig::default() })?;
    export_from_flow(&mut flow)
}

/// Build the export record from an existing compilation session (stage
/// results are reused from the session's cache).
pub fn export_from_flow(flow: &mut Flow) -> anyhow::Result<SystemExport> {
    let id = flow.id().to_string();
    let (symbols, target_index) = {
        let analysis = flow.pis()?;
        (analysis.symbols.clone(), analysis.target)
    };
    let latency = flow.latency()?;
    let design = flow.rtl()?;
    Ok(SystemExport {
        id,
        symbols,
        ports: design.ports.iter().map(|p| p.symbol_index).collect(),
        port_names: design.ports.iter().map(|p| p.name.clone()).collect(),
        exponents: design.units.iter().map(|u| u.exponents.clone()).collect(),
        target_index,
        target_group: design.target_unit,
        latency,
    })
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_str_array(items: &[String]) -> String {
    let inner: Vec<String> = items.iter().map(|s| json_str(s)).collect();
    format!("[{}]", inner.join(","))
}

fn json_int_array<T: std::fmt::Display>(items: &[T]) -> String {
    let inner: Vec<String> = items.iter().map(|v| v.to_string()).collect();
    format!("[{}]", inner.join(","))
}

/// Serialize the full corpus export as JSON (plus the fixed-point format).
pub fn export_json(q: QFormat) -> anyhow::Result<String> {
    let mut systems = Vec::new();
    for e in corpus::corpus() {
        let ex = export_system(e.id, q)?;
        let exp_rows: Vec<String> = ex.exponents.iter().map(|r| json_int_array(r)).collect();
        systems.push(format!(
            "{{\"id\":{},\"symbols\":{},\"ports\":{},\"port_names\":{},\"exponents\":[{}],\"target_index\":{},\"target_group\":{},\"latency\":{}}}",
            json_str(&ex.id),
            json_str_array(&ex.symbols),
            json_int_array(&ex.ports),
            json_str_array(&ex.port_names),
            exp_rows.join(","),
            ex.target_index,
            ex.target_group,
            ex.latency,
        ));
    }
    Ok(format!(
        "{{\"format\":{{\"int_bits\":{},\"frac_bits\":{}}},\"systems\":[{}]}}\n",
        q.int_bits,
        q.frac_bits,
        systems.join(",")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;

    #[test]
    fn export_pendulum_shape() {
        let ex = export_system("pendulum", Q16_15).unwrap();
        assert_eq!(ex.symbols.len(), 4);
        assert_eq!(ex.ports.len(), 3); // bobmass dropped
        assert_eq!(ex.exponents.len(), 1);
        assert_eq!(ex.exponents[0].len(), 3);
        assert_eq!(ex.latency, 115);
    }

    #[test]
    fn json_is_parseable_shape() {
        // No JSON parser in the dependency set: check structural tokens.
        let j = export_json(Q16_15).unwrap();
        assert!(j.starts_with('{'));
        assert!(j.trim_end().ends_with('}'));
        assert_eq!(j.matches("\"id\":").count(), 7);
        assert!(j.contains("\"frac_bits\":15"));
        assert!(j.contains("\"pendulum\""));
        // Balanced braces/brackets.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn json_escapes_strings() {
        assert_eq!(super::json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
    }

    #[test]
    fn unknown_system_errors() {
        assert!(export_system("nope", Q16_15).is_err());
    }
}
