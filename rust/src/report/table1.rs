//! Table-1 regeneration: run the full flow (frontend → Π-search → RTL →
//! synthesis → timing → power) for every corpus system through the
//! [`crate::flow`] session API and render the same columns the paper
//! reports. The corpus sweep runs one [`Flow`] per system across all
//! cores via [`FlowSet`].

use std::sync::Arc;

use crate::fixedpoint::QFormat;
use crate::flow::{ArtifactStore, Flow, FlowConfig, FlowSet, StageCounts};
use crate::newton::CorpusEntry;

/// One row of the regenerated Table 1.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub id: String,
    pub display_name: String,
    pub description: String,
    pub target: String,
    pub lut4_cells: usize,
    pub gate_count: usize,
    pub fmax_mhz: f64,
    pub latency_cycles: u64,
    pub power_12mhz_mw: f64,
    pub power_6mhz_mw: f64,
    /// Number of Π groups (not in the paper's table; useful context).
    pub n_groups: usize,
}

/// Paper values for side-by-side comparison (Table 1 of the paper).
pub fn paper_row(id: &str) -> Option<(usize, usize, f64, u64, f64, f64)> {
    // (LUT4, gates, Fmax MHz, latency, P@12MHz mW, P@6MHz mW)
    match id {
        "beam" => Some((2958, 2590, 16.88, 115, 3.5, 1.8)),
        "pendulum" => Some((1402, 1239, 17.07, 115, 2.0, 1.1)),
        "fluid_pipe" => Some((4258, 3752, 15.65, 188, 5.8, 3.0)),
        "unpowered_flight" => Some((1930, 1865, 16.44, 81, 2.3, 1.2)),
        "vibrating_string" => Some((2183, 1787, 16.67, 183, 2.5, 1.3)),
        "warm_vibrating_string" => Some((3137, 2718, 16.77, 269, 1.9, 1.0)),
        "spring_mass" => Some((1419, 1240, 16.67, 115, 3.4, 1.8)),
        _ => None,
    }
}

/// The flow config a Table-1 run uses.
fn table_config(q: QFormat, power_samples: u32) -> FlowConfig {
    FlowConfig { qformat: q, power_samples, ..FlowConfig::default() }
}

/// Extract one table row from a (corpus) compilation session. All stage
/// results are served from the session's cache when already computed.
pub fn row_from_flow(flow: &mut Flow) -> anyhow::Result<Table1Row> {
    let entry = flow
        .corpus_entry()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("table rows require corpus flows"))?;
    let n_groups = flow.pis()?.n();
    let (lut4_cells, gate_count) = {
        let mapped = flow.netlist()?;
        (mapped.lut4_cells, mapped.gate_count)
    };
    let timing = flow.timing()?;
    let power = flow.power()?;
    let latency_cycles = flow.latency()?;
    Ok(Table1Row {
        id: entry.id.to_string(),
        display_name: entry.display_name.to_string(),
        description: entry.description.to_string(),
        target: entry.target_desc.to_string(),
        lut4_cells,
        gate_count,
        fmax_mhz: timing.fmax_mhz,
        latency_cycles,
        power_12mhz_mw: power.mw_12mhz,
        power_6mhz_mw: power.mw_6mhz,
        n_groups,
    })
}

/// Run the full flow for one system.
pub fn generate_row(entry: &CorpusEntry, q: QFormat, power_samples: u32) -> anyhow::Result<Table1Row> {
    let mut flow = Flow::for_entry(entry.clone(), table_config(q, power_samples));
    row_from_flow(&mut flow)
}

/// Full-control corpus sweep: optional shared persistent store and
/// sequential/parallel driver choice. Returns the rows plus the summed
/// per-stage cache telemetry (so callers can verify a warm `--cache-dir`
/// run recomputed nothing).
pub fn generate_table_opts(
    q: QFormat,
    power_samples: u32,
    store: Option<Arc<ArtifactStore>>,
    sequential: bool,
) -> anyhow::Result<(Vec<Table1Row>, StageCounts)> {
    let mut set = FlowSet::corpus(table_config(q, power_samples));
    if let Some(store) = store {
        set = set.with_store(store);
    }
    let rows = if sequential {
        set.run_sequential(row_from_flow)
    } else {
        set.run_parallel(row_from_flow)
    };
    let rows: anyhow::Result<Vec<Table1Row>> = rows.into_iter().collect();
    Ok((rows?, set.total_counts()))
}

/// Run the full flow for the whole corpus, one session per system across
/// all cores.
pub fn generate_table(q: QFormat, power_samples: u32) -> anyhow::Result<Vec<Table1Row>> {
    Ok(generate_table_opts(q, power_samples, None, false)?.0)
}

/// Sequential variant of [`generate_table`] (same rows, same order; used
/// for determinism checks and single-core baselines).
pub fn generate_table_sequential(q: QFormat, power_samples: u32) -> anyhow::Result<Vec<Table1Row>> {
    Ok(generate_table_opts(q, power_samples, None, true)?.0)
}

/// Render rows as a Markdown table with paper values side by side.
pub fn render_markdown(rows: &[Table1Row]) -> String {
    let mut s = String::new();
    s.push_str(
        "| Name | Target | LUT4 cells (paper) | Gates (paper) | Fmax MHz (paper) | Latency cyc (paper) | P@12MHz mW (paper) | P@6MHz mW (paper) |\n",
    );
    s.push_str("|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        let p = paper_row(&r.id);
        let fmt = |m: String, pv: String| format!("{m} ({pv})");
        let (pl, pg, pf, plat, p12, p6) = p
            .map(|(a, b, c, d, e, f)| {
                (a.to_string(), b.to_string(), format!("{c:.2}"), d.to_string(), format!("{e:.1}"), format!("{f:.1}"))
            })
            .unwrap_or(("–".into(), "–".into(), "–".into(), "–".into(), "–".into(), "–".into()));
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
            r.display_name,
            r.target,
            fmt(r.lut4_cells.to_string(), pl),
            fmt(r.gate_count.to_string(), pg),
            fmt(format!("{:.2}", r.fmax_mhz), pf),
            fmt(r.latency_cycles.to_string(), plat),
            fmt(format!("{:.1}", r.power_12mhz_mw), p12),
            fmt(format!("{:.1}", r.power_6mhz_mw), p6),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::{by_id, corpus};

    #[test]
    fn pendulum_row_matches_paper_latency() {
        let r = generate_row(&by_id("pendulum").unwrap(), Q16_15, 2).unwrap();
        assert_eq!(r.latency_cycles, 115);
        assert_eq!(r.n_groups, 1);
        assert!(r.lut4_cells > 500);
    }

    #[test]
    fn full_table_generates() {
        let rows = generate_table(Q16_15, 1).unwrap();
        assert_eq!(rows.len(), 7);
        let md = render_markdown(&rows);
        assert!(md.contains("Pendulum, static"));
        assert_eq!(md.lines().count(), 2 + 7);
    }

    #[test]
    fn paper_rows_present_for_all() {
        for e in corpus() {
            assert!(paper_row(e.id).is_some(), "{}", e.id);
        }
    }
}
