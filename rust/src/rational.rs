//! Exact rational arithmetic over `i64`.
//!
//! Dimensional analysis requires *exact* linear algebra: the dimensional
//! matrix of a physical system has small integer (occasionally fractional)
//! entries and its nullspace must be computed without floating-point error,
//! otherwise spurious "almost dimensionless" groups appear. This module
//! provides the minimal exact-arithmetic substrate used by
//! [`crate::pisearch`] and [`crate::units`].
//!
//! Values are kept in canonical form: `den > 0` and `gcd(num, den) == 1`.
//! All operations panic on overflow in debug builds and use checked
//! arithmetic with explicit reduction in release builds; the magnitudes in
//! dimensional analysis are tiny (exponents of units of measure), so `i64`
//! headroom is ample.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

fn gcd_u64(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Greatest common divisor (always non-negative).
///
/// Computed over `unsigned_abs`, so `i64::MIN` inputs are handled
/// exactly (`abs()` would overflow and panic in debug builds). The one
/// unrepresentable result — `gcd(i64::MIN, 0)` and
/// `gcd(i64::MIN, i64::MIN)` are 2⁶³ — saturates to `i64::MAX`,
/// consistent with [`lcm`]'s saturation.
pub fn gcd(a: i64, b: i64) -> i64 {
    i64::try_from(gcd_u64(a.unsigned_abs(), b.unsigned_abs())).unwrap_or(i64::MAX)
}

/// Least common multiple (non-negative; `lcm(0, x) == 0`; saturates at
/// `i64::MAX` when the true value exceeds the `i64` range).
///
/// The whole computation runs in `u64`: the old
/// `(a / gcd(a, b)).abs()` overflowed (panicking in debug builds) when
/// the quotient was `i64::MIN`, e.g. `lcm(i64::MIN, 1)`.
pub fn lcm(a: i64, b: i64) -> i64 {
    if a == 0 || b == 0 {
        return 0;
    }
    let (ua, ub) = (a.unsigned_abs(), b.unsigned_abs());
    let l = (ua / gcd_u64(ua, ub)).checked_mul(ub).unwrap_or(u64::MAX);
    i64::try_from(l).unwrap_or(i64::MAX)
}

/// An exact rational number `num/den` in canonical form.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rational {
    num: i64,
    den: i64,
}

impl Rational {
    pub const ZERO: Rational = Rational { num: 0, den: 1 };
    pub const ONE: Rational = Rational { num: 1, den: 1 };

    /// Construct `num/den`, reducing to canonical form.
    ///
    /// Panics if `den == 0`.
    pub fn new(num: i64, den: i64) -> Rational {
        assert!(den != 0, "Rational with zero denominator");
        let sign = if den < 0 { -1 } else { 1 };
        let g = gcd(num, den).max(1);
        Rational {
            num: sign * (num / g),
            den: (den / g).abs(),
        }
    }

    /// Construct from an integer.
    pub const fn from_int(n: i64) -> Rational {
        Rational { num: n, den: 1 }
    }

    pub fn num(&self) -> i64 {
        self.num
    }

    pub fn den(&self) -> i64 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// The integer value, if this rational is an integer.
    pub fn as_integer(&self) -> Option<i64> {
        if self.den == 1 {
            Some(self.num)
        } else {
            None
        }
    }

    pub fn abs(&self) -> Rational {
        Rational {
            num: self.num.abs(),
            den: self.den,
        }
    }

    pub fn signum(&self) -> i64 {
        self.num.signum()
    }

    pub fn recip(&self) -> Rational {
        assert!(self.num != 0, "reciprocal of zero");
        Rational::new(self.den, self.num)
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Checked addition (None on overflow).
    pub fn checked_add(&self, rhs: &Rational) -> Option<Rational> {
        let num = self
            .num
            .checked_mul(rhs.den)?
            .checked_add(rhs.num.checked_mul(self.den)?)?;
        let den = self.den.checked_mul(rhs.den)?;
        Some(Rational::new(num, den))
    }

    /// Checked multiplication (None on overflow). Cross-reduces first to
    /// keep intermediates small.
    pub fn checked_mul(&self, rhs: &Rational) -> Option<Rational> {
        let g1 = gcd(self.num, rhs.den).max(1);
        let g2 = gcd(rhs.num, self.den).max(1);
        let num = (self.num / g1).checked_mul(rhs.num / g2)?;
        let den = (self.den / g2).checked_mul(rhs.den / g1)?;
        Some(Rational::new(num, den))
    }
}

impl fmt::Debug for Rational {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)
    }
}

impl fmt::Display for Rational {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl From<i64> for Rational {
    fn from(n: i64) -> Rational {
        Rational::from_int(n)
    }
}

impl Add for Rational {
    type Output = Rational;
    fn add(self, rhs: Rational) -> Rational {
        self.checked_add(&rhs).expect("Rational add overflow")
    }
}

impl AddAssign for Rational {
    fn add_assign(&mut self, rhs: Rational) {
        *self = *self + rhs;
    }
}

impl Sub for Rational {
    type Output = Rational;
    fn sub(self, rhs: Rational) -> Rational {
        self + (-rhs)
    }
}

impl Neg for Rational {
    type Output = Rational;
    fn neg(self) -> Rational {
        Rational {
            num: -self.num,
            den: self.den,
        }
    }
}

impl Mul for Rational {
    type Output = Rational;
    fn mul(self, rhs: Rational) -> Rational {
        self.checked_mul(&rhs).expect("Rational mul overflow")
    }
}

impl Div for Rational {
    type Output = Rational;
    fn div(self, rhs: Rational) -> Rational {
        self * rhs.recip()
    }
}

impl PartialOrd for Rational {
    fn partial_cmp(&self, other: &Rational) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Rational {
    fn cmp(&self, other: &Rational) -> Ordering {
        // num1/den1 ? num2/den2  <=>  num1*den2 ? num2*den1 (dens positive)
        let lhs = (self.num as i128) * (other.den as i128);
        let rhs = (other.num as i128) * (self.den as i128);
        lhs.cmp(&rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(-12, 18), 6);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(0, 0), 0);
        assert_eq!(gcd(7, 13), 1);
    }

    #[test]
    fn lcm_basics() {
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 6), 0);
        assert_eq!(lcm(-4, 6), 12);
    }

    #[test]
    fn gcd_i64_min_regression() {
        // `i64::MIN.abs()` panics in debug builds; `unsigned_abs` must
        // give the exact answer wherever it is representable.
        assert_eq!(gcd(i64::MIN, 12), 4);
        assert_eq!(gcd(12, i64::MIN), 4);
        assert_eq!(gcd(i64::MIN, 3), 1);
        assert_eq!(gcd(i64::MIN, i64::MIN + 1), 1); // 2^63 and 2^63-1 are coprime
        assert_eq!(gcd(i64::MIN, 1 << 40), 1 << 40);
        // 2^63 itself does not fit i64: documented saturation.
        assert_eq!(gcd(i64::MIN, 0), i64::MAX);
        assert_eq!(gcd(i64::MIN, i64::MIN), i64::MAX);
    }

    #[test]
    fn lcm_i64_min_quotient_saturates() {
        // The old `(a / gcd).abs()` overflowed when the quotient was
        // `i64::MIN`; the u64 form saturates instead of panicking.
        assert_eq!(lcm(i64::MIN, 1), i64::MAX);
        assert_eq!(lcm(1, i64::MIN), i64::MAX);
        assert_eq!(lcm(i64::MIN, i64::MIN), i64::MAX);
        assert_eq!(lcm(i64::MIN, 0), 0);
        // Exact whenever the true value is representable.
        assert_eq!(lcm(1 << 62, 2), 1 << 62);
        assert_eq!(lcm(i64::MIN + 1, 1), i64::MAX); // |MIN+1| == MAX exactly
    }

    #[test]
    fn canonical_form() {
        let r = Rational::new(6, -4);
        assert_eq!(r.num(), -3);
        assert_eq!(r.den(), 2);
        assert_eq!(Rational::new(0, -7), Rational::ZERO);
    }

    #[test]
    #[should_panic]
    fn zero_denominator_panics() {
        let _ = Rational::new(1, 0);
    }

    #[test]
    fn arithmetic() {
        let a = Rational::new(1, 2);
        let b = Rational::new(1, 3);
        assert_eq!(a + b, Rational::new(5, 6));
        assert_eq!(a - b, Rational::new(1, 6));
        assert_eq!(a * b, Rational::new(1, 6));
        assert_eq!(a / b, Rational::new(3, 2));
        assert_eq!(-a, Rational::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Rational::new(1, 3) < Rational::new(1, 2));
        assert!(Rational::new(-1, 2) < Rational::ZERO);
        assert_eq!(Rational::new(2, 4).cmp(&Rational::new(1, 2)), Ordering::Equal);
    }

    #[test]
    fn display() {
        assert_eq!(Rational::new(3, 1).to_string(), "3");
        assert_eq!(Rational::new(-3, 6).to_string(), "-1/2");
    }

    #[test]
    fn integer_accessors() {
        assert_eq!(Rational::new(8, 4).as_integer(), Some(2));
        assert_eq!(Rational::new(1, 2).as_integer(), None);
        assert!(Rational::from_int(5).is_integer());
    }

    #[test]
    fn cross_reduction_avoids_overflow() {
        // (big/3) * (3/big) == 1 without overflowing i64 intermediates.
        let big = 1 << 40;
        let a = Rational::new(big, 3);
        let b = Rational::new(3, big);
        assert_eq!(a * b, Rational::ONE);
    }
}
