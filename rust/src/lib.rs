//! # dimsynth — Dimensional Circuit Synthesis
//!
//! A reproduction of *"Synthesizing Compact Hardware for Accelerating
//! Inference from Physical Signals in Sensors"* (Tsoutsouras, Vigdorchik,
//! Stanley-Marbell, 2020): a compiler backend that turns Newton-language
//! descriptions of physical systems into RTL hardware computing the
//! Buckingham-Π dimensionless products used as features for in-sensor
//! machine-learning inference — plus the full evaluation substrate
//! (synthesis to LUT4s, timing, power, cycle-accurate simulation) and an
//! in-sensor inference runtime (Π preprocessing + Φ model served via
//! AOT-compiled XLA executables).
//!
//! ## Front door: the [`flow`] compilation-session API
//!
//! The whole pipeline hangs off one session object: a [`flow::Flow`]
//! holds a [`flow::FlowConfig`] and a memoized artifact graph with typed
//! stage handles, and a [`flow::FlowSet`] drives the full corpus across
//! all cores. Stages compute on first demand and re-queries are free.
//! Every stage lookup goes **per-stage LRU → disk store → compute**:
//! stage artifacts are keyed on stable content fingerprints
//! ([`flow::config::StableHasher`], specified FNV-1a — identical in
//! every process and Rust release), so attaching a persistent
//! [`flow::ArtifactStore`] (CLI: `--cache-dir`) carries the whole
//! memoized graph across processes — a warm restart recomputes nothing
//! (versioned on-disk format, corrupt entries degrade to recomputes):
//!
//! ```
//! use dimsynth::flow::{Flow, FlowConfig};
//!
//! let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
//! println!("{}", flow.pis().unwrap());              // Π groups
//! let cells = flow.netlist().unwrap().lut4_cells;   // LUT4 resources
//! let fmax = flow.timing().unwrap().fmax_mhz;       // STA
//! assert!(cells > 500 && fmax > 5.0);
//! assert_eq!(flow.counts().netlist, 1);             // memoized: computed once
//! ```
//!
//! ## Layers
//!
//! * **Session** — [`flow`]: the unified compilation API; everything
//!   below is reachable through it. Includes the caching substrate:
//!   stable fingerprints ([`flow::config`]), per-stage LRUs and the
//!   persistent fingerprint-keyed artifact store ([`flow::store`]).
//! * **Frontend** — [`newton`]: lexer/parser/sema for the Newton subset,
//!   plus the 7-system Table-1 corpus.
//! * **Analysis** — [`pisearch`]: exact rational nullspace of the
//!   dimensional matrix, target isolation.
//! * **Backend** — [`rtl`]: Π datapaths in Q16.15 fixed point
//!   ([`fixedpoint`]), FSM scheduling, Verilog emission, cycle-accurate
//!   simulation.
//! * **Implementation flow** — [`synth`] (gate netlist, optimization,
//!   LUT4 technology mapping, scalar + bit-parallel gate-level
//!   simulation generic over the SIMD lane word: [`synth::LaneWord`]
//!   with `u64` = 64, [`synth::W256`] = 256 and [`synth::W512`] = 512
//!   stimulus streams per pass, plus opt-in intra-level parallel
//!   evaluation of wide combinational levels across worker threads),
//!   [`timing`] (STA → Fmax), [`power`] (switching-activity power
//!   model, one estimate per lane per simulation pass at the configured
//!   [`synth::LaneWidth`]), [`stim`] (LFSR stimulus, scalar and
//!   lane-bank [`stim::LfsrBank`] at any width).
//! * **Multi-system sharding** — [`shard`]: fuse → partition →
//!   [`shard::ShardSim`]. [`shard::FusedNetlist`] merges N systems'
//!   netlists into one wide module (namespaced nets, concatenated PI/PO
//!   maps, per-member scatter index); [`shard::ShardPlan`] seeds K
//!   gate-balanced shards at register/level boundaries, then a
//!   KL/FM-style refinement pass moves gate clusters between shards to
//!   minimize the explicit cut-signal interface ([`shard::CutMap`],
//!   reported per plan by [`shard::RefineReport`]); `ShardSim` runs one
//!   shard per persistent worker with a dirty-word incremental cut
//!   exchange (mirror words, per-cycle — per-level when combinational
//!   cuts exist — publication of changed words only, counted by
//!   [`shard::ExchangeStats`]), bit-identical to solo evaluation.
//!   Cached (plan included) as the `fused` flow stage and routed to by
//!   the coordinator's cross-system power batcher.
//! * **Static verification** — [`analyze`]: a multi-pass verifier over
//!   the compiled artifacts with a typed diagnostics model
//!   ([`analyze::Diagnostic`], stable `AN…` codes): structural netlist
//!   lint (multi-drivers, dangling refs, an explicit DFS combinational
//!   cycle reporter, dead gates), Q-format interval analysis of every Π
//!   microprogram, an independent dimensional re-check of every Π unit,
//!   and a shard-plan pre-flight that proves [`shard::CutMap`]
//!   completeness before anything packs. Memoized as the `analyze` flow
//!   stage (persisted in the artifact store), surfaced by the `lint`
//!   CLI subcommand, and gating: [`coordinator::ServeSet`] refuses to
//!   boot a system whose analysis has error-level findings.
//! * **Runtime** — [`runtime`] (PJRT executables compiled AOT from
//!   JAX/Pallas), [`coordinator`] (threaded in-sensor inference engine;
//!   multi-system deployments front the [`flow`] layer through one warm
//!   [`coordinator::ServeSet`] — a shared `FlowSet` + artifact store
//!   behind every endpoint, handing each serving worker an `Arc` view
//!   of its compiled state and batching power-request floods **across
//!   systems** at the configured SIMD lane width), [`train`]
//!   (offline/in-situ Φ calibration).
//! * **Serving front end** — the network-facing slice of
//!   [`coordinator`], layered **net → admission → K dispatch lanes →
//!   ServeSet → flow/shard**: [`coordinator::net`] speaks a
//!   length-prefixed binary wire protocol over TCP (blocking accept
//!   loop, one reader thread per connection, optional per-connection
//!   rate limit and an HTTP metrics scrape endpoint),
//!   [`coordinator::admission`] applies per-tenant token buckets,
//!   bounded queues, and end-to-end deadlines, and shards tenants
//!   across the parallel dispatch lanes of [`coordinator::engine`] —
//!   each lane an independent fair-dispatch thread over only its
//!   tenants' queues (CLI: `serve --dispatchers K`), all lanes sharing
//!   the warm `ServeSet` (Π batches run concurrently, power floods
//!   serialize on a flood gate since one flood already fans across all
//!   cores). Every refusal is a typed [`coordinator::ServeError`] on
//!   the wire (shed with a retry-after hint, deadline-exceeded,
//!   contained worker panics — never a hang or a silent drop); a
//!   panicked lane is swept at drain with typed answers while live
//!   lanes keep serving; and [`coordinator::metrics`] keeps lock-free
//!   per-tenant p50/p99/p999 latency histograms, outcome counters, and
//!   per-lane dispatch counters merged into one report;
//!   [`coordinator::faults`] injects deterministic panics/delays/lane
//!   kills for the e2e and soak harnesses (CLI: `serve --listen ADDR`).

pub mod analyze;
pub mod bench_util;
pub mod coordinator;
pub mod fixedpoint;
pub mod flow;
pub mod newton;
pub mod pisearch;
pub mod power;
pub mod rational;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod shard;
pub mod stim;
pub mod synth;
pub mod train;
pub mod timing;
pub mod units;
