//! Abstract syntax for the Newton physical-system description language.
//!
//! The subset implemented here covers what dimensional circuit synthesis
//! consumes (paper Fig. 2): *signal* definitions carrying units of measure,
//! *constant* definitions, and *invariant* blocks that list the physical
//! signals of a system and (optionally) proportionality relations between
//! them.
//!
//! ```text
//! distance : signal = { name = "meter" English; symbol = m; derivation = none; }
//! g        : constant = 9.80665 * m / (s ** 2);
//! glider   : invariant(h: distance, v: speed, t: time) = { h ~ v * t }
//! ```

use std::fmt;

/// Source position (1-based line and column) for diagnostics.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Pos {
    pub line: u32,
    pub col: u32,
}

impl fmt::Display for Pos {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// A unit/dimension expression: products, quotients and integer powers of
/// named signals, possibly with numeric scale factors.
#[derive(Clone, PartialEq, Debug)]
pub enum UnitExpr {
    /// Reference to a previously defined signal (or builtin).
    Ident(String, Pos),
    /// A numeric literal (scale factor; dimensionless).
    Number(f64, Pos),
    /// Product of two unit expressions.
    Mul(Box<UnitExpr>, Box<UnitExpr>),
    /// Quotient of two unit expressions.
    Div(Box<UnitExpr>, Box<UnitExpr>),
    /// Integer power (`expr ** n`).
    Pow(Box<UnitExpr>, i64),
    /// The literal `none` (a base signal with its own fresh dimension is
    /// not supported here; `none` marks a pre-seeded builtin base signal).
    None(Pos),
}

impl UnitExpr {
    pub fn pos(&self) -> Pos {
        match self {
            UnitExpr::Ident(_, p) | UnitExpr::Number(_, p) | UnitExpr::None(p) => *p,
            UnitExpr::Mul(a, _) | UnitExpr::Div(a, _) => a.pos(),
            UnitExpr::Pow(a, _) => a.pos(),
        }
    }
}

impl fmt::Display for UnitExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            UnitExpr::Ident(s, _) => write!(f, "{s}"),
            UnitExpr::Number(n, _) => write!(f, "{n}"),
            UnitExpr::Mul(a, b) => write!(f, "({a} * {b})"),
            UnitExpr::Div(a, b) => write!(f, "({a} / {b})"),
            UnitExpr::Pow(a, n) => write!(f, "({a} ** {n})"),
            UnitExpr::None(_) => write!(f, "none"),
        }
    }
}

/// `<name> : signal = { name = "..." <lang>; symbol = <sym>; derivation = <expr>; }`
#[derive(Clone, Debug)]
pub struct SignalDecl {
    pub ident: String,
    /// Human-readable unit name (`"meter"`), if given.
    pub unit_name: Option<String>,
    /// Language tag after the name (`English`), if given.
    pub language: Option<String>,
    /// Unit symbol (`m`), if given.
    pub symbol: Option<String>,
    /// Derivation expression; `UnitExpr::None` for base signals.
    pub derivation: UnitExpr,
    pub pos: Pos,
}

/// `<name> : constant = <number> * <unitexpr>;`
#[derive(Clone, Debug)]
pub struct ConstantDecl {
    pub ident: String,
    pub value: f64,
    /// Unit expression giving the constant's dimension (may be `None` for
    /// dimensionless constants).
    pub unit: Option<UnitExpr>,
    pub pos: Pos,
}

/// One parameter of an invariant: `h : distance`.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub signal: String,
    pub pos: Pos,
}

/// A proportionality/equality relation inside an invariant body, e.g.
/// `h ~ v * t`. Relations are parsed and dimension-checked but the Π-search
/// uses only the parameter list (the Buckingham theorem needs only the
/// dimensions).
#[derive(Clone, Debug)]
pub struct Relation {
    pub lhs: UnitExpr,
    pub op: RelOp,
    pub rhs: UnitExpr,
    pub pos: Pos,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RelOp {
    /// `~` — proportional to (dimensions must match).
    Proportional,
    /// `=` — equal (dimensions must match).
    Equal,
}

impl fmt::Display for RelOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RelOp::Proportional => write!(f, "~"),
            RelOp::Equal => write!(f, "="),
        }
    }
}

/// `<name> : invariant(p1: sig1, ...) = { relations }`
#[derive(Clone, Debug)]
pub struct InvariantDecl {
    pub ident: String,
    pub params: Vec<Param>,
    pub relations: Vec<Relation>,
    pub pos: Pos,
}

/// Top-level declaration.
#[derive(Clone, Debug)]
pub enum Decl {
    Signal(SignalDecl),
    Constant(ConstantDecl),
    Invariant(InvariantDecl),
}

impl Decl {
    pub fn ident(&self) -> &str {
        match self {
            Decl::Signal(s) => &s.ident,
            Decl::Constant(c) => &c.ident,
            Decl::Invariant(i) => &i.ident,
        }
    }

    pub fn pos(&self) -> Pos {
        match self {
            Decl::Signal(s) => s.pos,
            Decl::Constant(c) => c.pos,
            Decl::Invariant(i) => i.pos,
        }
    }
}

/// A parsed Newton source file.
#[derive(Clone, Debug, Default)]
pub struct File {
    pub decls: Vec<Decl>,
}

impl File {
    pub fn invariants(&self) -> impl Iterator<Item = &InvariantDecl> {
        self.decls.iter().filter_map(|d| match d {
            Decl::Invariant(i) => Some(i),
            _ => None,
        })
    }

    pub fn signals(&self) -> impl Iterator<Item = &SignalDecl> {
        self.decls.iter().filter_map(|d| match d {
            Decl::Signal(s) => Some(s),
            _ => None,
        })
    }

    pub fn constants(&self) -> impl Iterator<Item = &ConstantDecl> {
        self.decls.iter().filter_map(|d| match d {
            Decl::Constant(c) => Some(c),
            _ => None,
        })
    }
}
