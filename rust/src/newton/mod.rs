//! Newton-language frontend: lexer, parser, semantic analysis, and the
//! 7-system evaluation corpus from the paper's Table 1.

pub mod ast;
pub mod corpus;
pub mod lexer;
pub mod parser;
pub mod sema;

pub use corpus::{by_id, corpus, load_entry, CorpusEntry};
pub use parser::parse;
pub use sema::{analyze, load, Symbol, SymbolKind, SystemModel};
