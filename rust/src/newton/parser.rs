//! Recursive-descent parser for the Newton subset.
//!
//! Grammar (EBNF):
//!
//! ```text
//! file       := decl*
//! decl       := ident ":" "signal" "=" "{" sigfield* "}"
//!             | ident ":" "constant" "=" constexpr ";"
//!             | ident ":" "invariant" "(" params ")" "=" "{" relations "}"
//! sigfield   := "name" "=" STRING ident? ";"
//!             | "symbol" "=" ident ";"
//!             | "derivation" "=" unitexpr ";"
//!             | "derivation" "=" "none" ";"
//! constexpr  := NUMBER ("*" unitexpr)?
//! params     := param ("," param)*
//! param      := ident ":" ident
//! relations  := relation ("," relation)*
//! relation   := unitexpr ("~" | "=") unitexpr
//! unitexpr   := unitterm (("*" | "/") unitterm)*
//! unitterm   := unitfactor ("**" INT)?
//! unitfactor := ident | NUMBER | "(" unitexpr ")"
//! ```

use super::ast::*;
use super::lexer::{lex, LexError, Tok, Token};

/// Parse error with position and message.
#[derive(Debug)]
pub enum ParseError {
    Lex(LexError),
    Syntax { pos: Pos, msg: String },
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Lex(e) => write!(f, "{e}"),
            ParseError::Syntax { pos, msg } => write!(f, "parse error at {pos}: {msg}"),
        }
    }
}

impl std::error::Error for ParseError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // `Lex` is transparent: its Display *is* the inner error's, so
        // exposing the inner error as a source would duplicate the
        // message in flattened chains.
        match self {
            ParseError::Lex(e) => std::error::Error::source(e),
            ParseError::Syntax { .. } => None,
        }
    }
}

impl From<LexError> for ParseError {
    fn from(e: LexError) -> ParseError {
        ParseError::Lex(e)
    }
}

struct Parser {
    toks: Vec<Token>,
    i: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.toks[self.i]
    }

    fn next(&mut self) -> Token {
        let t = self.toks[self.i].clone();
        if self.i + 1 < self.toks.len() {
            self.i += 1;
        }
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError::Syntax { pos: self.peek().pos, msg: msg.into() })
    }

    fn expect(&mut self, tok: Tok) -> Result<Token, ParseError> {
        if self.peek().tok == tok {
            Ok(self.next())
        } else {
            self.err(format!("expected {}, found {}", tok, self.peek().tok))
        }
    }

    fn expect_ident(&mut self) -> Result<(String, Pos), ParseError> {
        match self.peek().tok.clone() {
            Tok::Ident(s) => {
                let p = self.peek().pos;
                self.next();
                Ok((s, p))
            }
            other => self.err(format!("expected identifier, found {other}")),
        }
    }

    fn expect_number(&mut self) -> Result<(f64, Pos), ParseError> {
        // Allow a leading unary minus on numbers.
        let neg = if self.peek().tok == Tok::Minus {
            self.next();
            true
        } else {
            false
        };
        match self.peek().tok.clone() {
            Tok::Number(n) => {
                let p = self.peek().pos;
                self.next();
                Ok((if neg { -n } else { n }, p))
            }
            other => self.err(format!("expected number, found {other}")),
        }
    }

    fn file(&mut self) -> Result<File, ParseError> {
        let mut decls = Vec::new();
        while self.peek().tok != Tok::Eof {
            decls.push(self.decl()?);
        }
        Ok(File { decls })
    }

    fn decl(&mut self) -> Result<Decl, ParseError> {
        let (ident, pos) = self.expect_ident()?;
        self.expect(Tok::Colon)?;
        let (kind, kpos) = self.expect_ident()?;
        match kind.as_str() {
            "signal" => self.signal_decl(ident, pos),
            "constant" => self.constant_decl(ident, pos),
            "invariant" => self.invariant_decl(ident, pos),
            other => Err(ParseError::Syntax {
                pos: kpos,
                msg: format!("expected `signal`, `constant` or `invariant`, found `{other}`"),
            }),
        }
    }

    fn signal_decl(&mut self, ident: String, pos: Pos) -> Result<Decl, ParseError> {
        self.expect(Tok::Equals)?;
        self.expect(Tok::LBrace)?;
        let mut unit_name = None;
        let mut language = None;
        let mut symbol = None;
        let mut derivation = None;
        while self.peek().tok != Tok::RBrace {
            let (field, fpos) = self.expect_ident()?;
            self.expect(Tok::Equals)?;
            match field.as_str() {
                "name" => {
                    match self.peek().tok.clone() {
                        Tok::Str(s) => {
                            self.next();
                            unit_name = Some(s);
                        }
                        other => return self.err(format!("expected string, found {other}")),
                    }
                    // Optional language tag, e.g. `English`.
                    if let Tok::Ident(lang) = self.peek().tok.clone() {
                        self.next();
                        language = Some(lang);
                    }
                }
                "symbol" => {
                    let (s, _) = self.expect_ident()?;
                    symbol = Some(s);
                }
                "derivation" => {
                    if let Tok::Ident(id) = self.peek().tok.clone() {
                        if id == "none" {
                            let p = self.peek().pos;
                            self.next();
                            derivation = Some(UnitExpr::None(p));
                            self.expect(Tok::Semicolon)?;
                            continue;
                        }
                    }
                    derivation = Some(self.unit_expr()?);
                }
                other => {
                    return Err(ParseError::Syntax {
                        pos: fpos,
                        msg: format!("unknown signal field `{other}`"),
                    })
                }
            }
            self.expect(Tok::Semicolon)?;
        }
        self.expect(Tok::RBrace)?;
        let derivation = derivation.ok_or(ParseError::Syntax {
            pos,
            msg: format!("signal `{ident}` missing `derivation` field"),
        })?;
        Ok(Decl::Signal(SignalDecl { ident, unit_name, language, symbol, derivation, pos }))
    }

    fn constant_decl(&mut self, ident: String, pos: Pos) -> Result<Decl, ParseError> {
        self.expect(Tok::Equals)?;
        // Optional parenthesized form: `= (9.8 * m / (s**2));`
        let parens = self.peek().tok == Tok::LParen;
        if parens {
            self.next();
        }
        let (value, _) = self.expect_number()?;
        let unit = if self.peek().tok == Tok::Star {
            self.next();
            Some(self.unit_expr()?)
        } else {
            None
        };
        if parens {
            self.expect(Tok::RParen)?;
        }
        self.expect(Tok::Semicolon)?;
        Ok(Decl::Constant(ConstantDecl { ident, value, unit, pos }))
    }

    fn invariant_decl(&mut self, ident: String, pos: Pos) -> Result<Decl, ParseError> {
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        loop {
            let (name, ppos) = self.expect_ident()?;
            self.expect(Tok::Colon)?;
            let (signal, _) = self.expect_ident()?;
            params.push(Param { name, signal, pos: ppos });
            if self.peek().tok == Tok::Comma {
                self.next();
            } else {
                break;
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::Equals)?;
        self.expect(Tok::LBrace)?;
        let mut relations = Vec::new();
        while self.peek().tok != Tok::RBrace {
            let lhs = self.unit_expr()?;
            let op = match self.peek().tok {
                Tok::Tilde => {
                    self.next();
                    RelOp::Proportional
                }
                Tok::Equals => {
                    self.next();
                    RelOp::Equal
                }
                _ => return self.err(format!("expected `~` or `=`, found {}", self.peek().tok)),
            };
            let rhs_pos = self.peek().pos;
            let rhs = self.unit_expr()?;
            relations.push(Relation { lhs, op, rhs, pos: rhs_pos });
            if self.peek().tok == Tok::Comma {
                self.next();
            } else {
                break;
            }
        }
        self.expect(Tok::RBrace)?;
        Ok(Decl::Invariant(InvariantDecl { ident, params, relations, pos }))
    }

    fn unit_expr(&mut self) -> Result<UnitExpr, ParseError> {
        let mut lhs = self.unit_term()?;
        loop {
            match self.peek().tok {
                Tok::Star => {
                    self.next();
                    let rhs = self.unit_term()?;
                    lhs = UnitExpr::Mul(Box::new(lhs), Box::new(rhs));
                }
                Tok::Slash => {
                    self.next();
                    let rhs = self.unit_term()?;
                    lhs = UnitExpr::Div(Box::new(lhs), Box::new(rhs));
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn unit_term(&mut self) -> Result<UnitExpr, ParseError> {
        let base = self.unit_factor()?;
        if self.peek().tok == Tok::StarStar {
            self.next();
            let neg = if self.peek().tok == Tok::Minus {
                self.next();
                true
            } else {
                false
            };
            match self.peek().tok.clone() {
                Tok::Number(n) => {
                    if n.fract() != 0.0 {
                        return self.err("unit exponent must be an integer");
                    }
                    self.next();
                    let e = n as i64;
                    return Ok(UnitExpr::Pow(Box::new(base), if neg { -e } else { e }));
                }
                other => return self.err(format!("expected integer exponent, found {other}")),
            }
        }
        Ok(base)
    }

    fn unit_factor(&mut self) -> Result<UnitExpr, ParseError> {
        let p = self.peek().pos;
        match self.peek().tok.clone() {
            Tok::Ident(s) => {
                self.next();
                Ok(UnitExpr::Ident(s, p))
            }
            Tok::Number(n) => {
                self.next();
                Ok(UnitExpr::Number(n, p))
            }
            Tok::LParen => {
                self.next();
                let e = self.unit_expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            other => self.err(format!("expected unit expression, found {other}")),
        }
    }
}

/// Parse Newton source text.
pub fn parse(src: &str) -> Result<File, ParseError> {
    let toks = lex(src)?;
    let mut p = Parser { toks, i: 0 };
    p.file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_signal_base() {
        let f = parse(
            r#"distance : signal = {
                name = "meter" English;
                symbol = m;
                derivation = none;
            }"#,
        )
        .unwrap();
        assert_eq!(f.decls.len(), 1);
        match &f.decls[0] {
            Decl::Signal(s) => {
                assert_eq!(s.ident, "distance");
                assert_eq!(s.unit_name.as_deref(), Some("meter"));
                assert_eq!(s.language.as_deref(), Some("English"));
                assert_eq!(s.symbol.as_deref(), Some("m"));
                assert!(matches!(s.derivation, UnitExpr::None(_)));
            }
            _ => panic!("expected signal"),
        }
    }

    #[test]
    fn parse_signal_derived() {
        let f = parse(
            r#"acceleration : signal = {
                derivation = distance / (time ** 2);
            }"#,
        )
        .unwrap();
        match &f.decls[0] {
            Decl::Signal(s) => {
                assert_eq!(s.derivation.to_string(), "(distance / (time ** 2))");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_constant() {
        let f = parse("g : constant = (9.80665 * distance / (time ** 2));").unwrap();
        match &f.decls[0] {
            Decl::Constant(c) => {
                assert_eq!(c.ident, "g");
                assert!((c.value - 9.80665).abs() < 1e-12);
                assert!(c.unit.is_some());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_dimensionless_constant() {
        let f = parse("two_pi : constant = 6.283185;").unwrap();
        match &f.decls[0] {
            Decl::Constant(c) => assert!(c.unit.is_none()),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_invariant() {
        let f = parse(
            r#"glider : invariant(h: distance, v: speed, t: time) = {
                h ~ v * t
            }"#,
        )
        .unwrap();
        match &f.decls[0] {
            Decl::Invariant(i) => {
                assert_eq!(i.ident, "glider");
                assert_eq!(i.params.len(), 3);
                assert_eq!(i.params[1].name, "v");
                assert_eq!(i.params[1].signal, "speed");
                assert_eq!(i.relations.len(), 1);
                assert_eq!(i.relations[0].op, RelOp::Proportional);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_multiple_relations() {
        let f = parse(
            r#"sys : invariant(a: distance, b: distance, t: time) = {
                a ~ b,
                a / b = 1
            }"#,
        )
        .unwrap();
        match &f.decls[0] {
            Decl::Invariant(i) => assert_eq!(i.relations.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn negative_exponent() {
        let f = parse("x : signal = { derivation = time ** -2; }").unwrap();
        match &f.decls[0] {
            Decl::Signal(s) => match &s.derivation {
                UnitExpr::Pow(_, e) => assert_eq!(*e, -2),
                other => panic!("expected pow, got {other:?}"),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn error_messages_have_positions() {
        let e = parse("x : bogus = {}").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("1:5"), "message was: {msg}");
    }

    #[test]
    fn rejects_fractional_exponent_literal() {
        assert!(parse("x : signal = { derivation = time ** 1.5; }").is_err());
    }

    #[test]
    fn rejects_missing_derivation() {
        assert!(parse("x : signal = { symbol = q; }").is_err());
    }
}
