//! Hand-written lexer for the Newton subset.
//!
//! Produces a flat token stream with positions. Comments are C-style
//! (`#` to end of line, or `/* ... */`).

use super::ast::Pos;
use std::fmt;

#[derive(Clone, PartialEq, Debug)]
pub enum Tok {
    Ident(String),
    Number(f64),
    Str(String),
    Colon,
    Semicolon,
    Comma,
    Equals,
    Tilde,
    Star,
    StarStar,
    Slash,
    Plus,
    Minus,
    LParen,
    RParen,
    LBrace,
    RBrace,
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "identifier `{s}`"),
            Tok::Number(n) => write!(f, "number `{n}`"),
            Tok::Str(s) => write!(f, "string \"{s}\""),
            Tok::Colon => write!(f, "`:`"),
            Tok::Semicolon => write!(f, "`;`"),
            Tok::Comma => write!(f, "`,`"),
            Tok::Equals => write!(f, "`=`"),
            Tok::Tilde => write!(f, "`~`"),
            Tok::Star => write!(f, "`*`"),
            Tok::StarStar => write!(f, "`**`"),
            Tok::Slash => write!(f, "`/`"),
            Tok::Plus => write!(f, "`+`"),
            Tok::Minus => write!(f, "`-`"),
            Tok::LParen => write!(f, "`(`"),
            Tok::RParen => write!(f, "`)`"),
            Tok::LBrace => write!(f, "`{{`"),
            Tok::RBrace => write!(f, "`}}`"),
            Tok::Eof => write!(f, "end of input"),
        }
    }
}

/// A token with its source position.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub pos: Pos,
}

/// Lexer error with position.
#[derive(Debug)]
pub struct LexError {
    pub pos: Pos,
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for LexError {}

pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let mut toks = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut col = 1u32;

    macro_rules! pos {
        () => {
            Pos { line, col }
        };
    }

    while i < bytes.len() {
        let c = bytes[i];
        let p = pos!();
        match c {
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            '#' => {
                while i < bytes.len() && bytes[i] != '\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                i += 2;
                col += 2;
                loop {
                    if i + 1 >= bytes.len() {
                        return Err(LexError { pos: p, msg: "unterminated block comment".into() });
                    }
                    if bytes[i] == '*' && bytes[i + 1] == '/' {
                        i += 2;
                        col += 2;
                        break;
                    }
                    if bytes[i] == '\n' {
                        line += 1;
                        col = 1;
                    } else {
                        col += 1;
                    }
                    i += 1;
                }
            }
            '"' => {
                i += 1;
                col += 1;
                let start = i;
                while i < bytes.len() && bytes[i] != '"' {
                    if bytes[i] == '\n' {
                        return Err(LexError { pos: p, msg: "newline in string literal".into() });
                    }
                    i += 1;
                    col += 1;
                }
                if i >= bytes.len() {
                    return Err(LexError { pos: p, msg: "unterminated string literal".into() });
                }
                let s: String = bytes[start..i].iter().collect();
                i += 1;
                col += 1;
                toks.push(Token { tok: Tok::Str(s), pos: p });
            }
            '*' => {
                if i + 1 < bytes.len() && bytes[i + 1] == '*' {
                    toks.push(Token { tok: Tok::StarStar, pos: p });
                    i += 2;
                    col += 2;
                } else {
                    toks.push(Token { tok: Tok::Star, pos: p });
                    i += 1;
                    col += 1;
                }
            }
            ':' | ';' | ',' | '=' | '~' | '/' | '+' | '-' | '(' | ')' | '{' | '}' => {
                let tok = match c {
                    ':' => Tok::Colon,
                    ';' => Tok::Semicolon,
                    ',' => Tok::Comma,
                    '=' => Tok::Equals,
                    '~' => Tok::Tilde,
                    '/' => Tok::Slash,
                    '+' => Tok::Plus,
                    '-' => Tok::Minus,
                    '(' => Tok::LParen,
                    ')' => Tok::RParen,
                    '{' => Tok::LBrace,
                    '}' => Tok::RBrace,
                    _ => unreachable!(),
                };
                toks.push(Token { tok, pos: p });
                i += 1;
                col += 1;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                let mut seen_dot = false;
                let mut seen_exp = false;
                while i < bytes.len() {
                    let d = bytes[i];
                    if d.is_ascii_digit() {
                        i += 1;
                        col += 1;
                    } else if d == '.' && !seen_dot && !seen_exp {
                        seen_dot = true;
                        i += 1;
                        col += 1;
                    } else if (d == 'e' || d == 'E') && !seen_exp {
                        seen_exp = true;
                        i += 1;
                        col += 1;
                        if i < bytes.len() && (bytes[i] == '+' || bytes[i] == '-') {
                            i += 1;
                            col += 1;
                        }
                    } else {
                        break;
                    }
                }
                let s: String = bytes[start..i].iter().collect();
                let n: f64 = s
                    .parse()
                    .map_err(|_| LexError { pos: p, msg: format!("bad number literal `{s}`") })?;
                toks.push(Token { tok: Tok::Number(n), pos: p });
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len() && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                    i += 1;
                    col += 1;
                }
                let s: String = bytes[start..i].iter().collect();
                toks.push(Token { tok: Tok::Ident(s), pos: p });
            }
            other => {
                return Err(LexError { pos: p, msg: format!("unexpected character `{other}`") });
            }
        }
    }
    toks.push(Token { tok: Tok::Eof, pos: pos!() });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn punctuation_and_idents() {
        let t = kinds("glider : invariant(h: distance) = { }");
        assert_eq!(
            t,
            vec![
                Tok::Ident("glider".into()),
                Tok::Colon,
                Tok::Ident("invariant".into()),
                Tok::LParen,
                Tok::Ident("h".into()),
                Tok::Colon,
                Tok::Ident("distance".into()),
                Tok::RParen,
                Tok::Equals,
                Tok::LBrace,
                Tok::RBrace,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(kinds("9.80665")[0], Tok::Number(9.80665));
        assert_eq!(kinds("1e-3")[0], Tok::Number(1e-3));
        assert_eq!(kinds("2.5E+2")[0], Tok::Number(250.0));
        assert_eq!(kinds("42")[0], Tok::Number(42.0));
    }

    #[test]
    fn star_star_vs_star() {
        assert_eq!(kinds("a ** 2"), vec![
            Tok::Ident("a".into()),
            Tok::StarStar,
            Tok::Number(2.0),
            Tok::Eof
        ]);
        assert_eq!(kinds("a * b")[1], Tok::Star);
    }

    #[test]
    fn comments_skipped() {
        let t = kinds("a # comment\n b /* block\n comment */ c");
        assert_eq!(
            t,
            vec![
                Tok::Ident("a".into()),
                Tok::Ident("b".into()),
                Tok::Ident("c".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn strings() {
        assert_eq!(kinds("\"meter\"")[0], Tok::Str("meter".into()));
    }

    #[test]
    fn positions_track_lines() {
        let toks = lex("a\nbb\n  c").unwrap();
        assert_eq!(toks[0].pos, Pos { line: 1, col: 1 });
        assert_eq!(toks[1].pos, Pos { line: 2, col: 1 });
        assert_eq!(toks[2].pos, Pos { line: 3, col: 3 });
    }

    #[test]
    fn errors() {
        assert!(lex("@").is_err());
        assert!(lex("\"unterminated").is_err());
        assert!(lex("/* unterminated").is_err());
    }
}
