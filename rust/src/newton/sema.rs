//! Semantic analysis: resolve a parsed Newton file to a [`SystemModel`] —
//! the typed, dimension-checked description that the Π-search consumes.
//!
//! Resolution proceeds in declaration order against an environment seeded
//! with the builtin signals and `kNewtonUnithave_*` constants
//! ([`crate::units::si`]). Every invariant is checked: its parameter
//! signals must resolve, and every relation in its body must be
//! dimensionally homogeneous.

use super::ast::{self, Decl, File, RelOp, UnitExpr};
use crate::rational::Rational;
use crate::units::{builtin_constants, builtin_signals, Dimension};
use std::collections::HashMap;

/// What kind of symbol a system variable is.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SymbolKind {
    /// A sensor signal: a runtime input to the synthesized circuit.
    Signal,
    /// A physical constant: folded into the circuit at configuration time
    /// (still an input port in the generated RTL so calibration can adjust
    /// it, but known at compile time for scheduling purposes).
    Constant,
}

/// A resolved variable of a physical system.
#[derive(Clone, Debug)]
pub struct Symbol {
    pub name: String,
    pub dimension: Dimension,
    pub kind: SymbolKind,
    /// Numeric value for constants (`None` for signals).
    pub value: Option<f64>,
}

/// A dimension-checked invariant: the input to dimensional circuit
/// synthesis for one physical system.
#[derive(Clone, Debug)]
pub struct SystemModel {
    /// Invariant identifier (e.g. `glider`).
    pub name: String,
    /// The k symbols of the system, in declaration order.
    pub symbols: Vec<Symbol>,
    /// Human-readable rendering of the body relations.
    pub relations: Vec<String>,
}

impl SystemModel {
    pub fn k(&self) -> usize {
        self.symbols.len()
    }

    pub fn symbol_index(&self, name: &str) -> Option<usize> {
        self.symbols.iter().position(|s| s.name == name)
    }

    pub fn dimensions(&self) -> Vec<Dimension> {
        self.symbols.iter().map(|s| s.dimension).collect()
    }
}

/// Semantic error.
#[derive(Debug)]
pub enum SemaError {
    Unknown { pos: ast::Pos, name: String },
    Duplicate { pos: ast::Pos, name: String },
    Inhomogeneous { pos: ast::Pos, lhs: String, op: RelOp, rhs: String },
    BadNone { pos: ast::Pos, name: String },
    BadPow { pos: ast::Pos },
}

impl std::fmt::Display for SemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SemaError::Unknown { pos, name } => {
                write!(f, "{pos}: unknown signal or constant `{name}`")
            }
            SemaError::Duplicate { pos, name } => {
                write!(f, "{pos}: duplicate definition of `{name}`")
            }
            SemaError::Inhomogeneous { pos, lhs, op, rhs } => write!(
                f,
                "{pos}: relation is not dimensionally homogeneous: [{lhs}] {op} [{rhs}]"
            ),
            SemaError::BadNone { pos, name } => write!(
                f,
                "{pos}: `none` derivation is only valid for builtin base signals; define `{name}` with a unit expression"
            ),
            SemaError::BadPow { pos } => write!(
                f,
                "{pos}: fractional power of a numeric scale factor is not supported"
            ),
        }
    }
}

impl std::error::Error for SemaError {}

/// Environment of resolved names → dimensions (+ values for constants).
struct Env {
    dims: HashMap<String, Dimension>,
    consts: HashMap<String, f64>,
}

impl Env {
    fn seeded() -> Env {
        let mut dims = HashMap::new();
        let mut consts = HashMap::new();
        for s in builtin_signals() {
            dims.insert(s.name.to_string(), s.dimension);
            // Symbols are also usable as unit names (`m`, `s`, `kg`, ...).
            dims.insert(s.symbol.to_string(), s.dimension);
        }
        for c in builtin_constants() {
            dims.insert(c.name.to_string(), c.dimension);
            consts.insert(c.name.to_string(), c.value);
        }
        Env { dims, consts }
    }

    fn eval(&self, e: &UnitExpr) -> Result<Dimension, SemaError> {
        match e {
            UnitExpr::Ident(name, pos) => self
                .dims
                .get(name)
                .copied()
                .ok_or_else(|| SemaError::Unknown { pos: *pos, name: name.clone() }),
            UnitExpr::Number(_, _) => Ok(Dimension::NONE),
            UnitExpr::Mul(a, b) => Ok(self.eval(a)? * self.eval(b)?),
            UnitExpr::Div(a, b) => Ok(self.eval(a)? / self.eval(b)?),
            UnitExpr::Pow(a, n) => Ok(self.eval(a)?.pow(Rational::from_int(*n))),
            UnitExpr::None(pos) => Err(SemaError::BadPow { pos: *pos }),
        }
    }
}

/// Resolve a parsed file into one [`SystemModel`] per invariant.
pub fn analyze(file: &File) -> Result<Vec<SystemModel>, SemaError> {
    let mut env = Env::seeded();
    let mut models = Vec::new();

    for decl in &file.decls {
        match decl {
            Decl::Signal(s) => {
                if env.dims.contains_key(&s.ident) && !matches!(s.derivation, UnitExpr::None(_)) {
                    // Redefinition of a builtin with a derivation is an error;
                    // re-declaring a builtin base signal with `derivation = none`
                    // (as real Newton preludes do) is accepted as a no-op.
                    return Err(SemaError::Duplicate { pos: s.pos, name: s.ident.clone() });
                }
                let dim = match &s.derivation {
                    UnitExpr::None(pos) => {
                        // Only builtins may use `none`.
                        env.dims.get(&s.ident).copied().ok_or(SemaError::BadNone {
                            pos: *pos,
                            name: s.ident.clone(),
                        })?
                    }
                    e => env.eval(e)?,
                };
                env.dims.insert(s.ident.clone(), dim);
                if let Some(sym) = &s.symbol {
                    env.dims.entry(sym.clone()).or_insert(dim);
                }
            }
            Decl::Constant(c) => {
                if env.dims.contains_key(&c.ident) {
                    return Err(SemaError::Duplicate { pos: c.pos, name: c.ident.clone() });
                }
                let dim = match &c.unit {
                    Some(u) => env.eval(u)?,
                    None => Dimension::NONE,
                };
                env.dims.insert(c.ident.clone(), dim);
                env.consts.insert(c.ident.clone(), c.value);
            }
            Decl::Invariant(inv) => {
                let mut symbols = Vec::new();
                let mut local = HashMap::new();
                for p in &inv.params {
                    let dim = env.dims.get(&p.signal).copied().ok_or_else(|| {
                        SemaError::Unknown { pos: p.pos, name: p.signal.clone() }
                    })?;
                    let kind = if env.consts.contains_key(&p.signal) {
                        SymbolKind::Constant
                    } else {
                        SymbolKind::Signal
                    };
                    if local.contains_key(&p.name) {
                        return Err(SemaError::Duplicate { pos: p.pos, name: p.name.clone() });
                    }
                    local.insert(p.name.clone(), dim);
                    symbols.push(Symbol {
                        name: p.name.clone(),
                        dimension: dim,
                        kind,
                        value: env.consts.get(&p.signal).copied(),
                    });
                }
                // Relation checking: parameters shadow globals inside the body.
                let mut body_env = Env {
                    dims: env.dims.clone(),
                    consts: env.consts.clone(),
                };
                for (name, dim) in &local {
                    body_env.dims.insert(name.clone(), *dim);
                }
                let mut relations = Vec::new();
                for r in &inv.relations {
                    let lhs = body_env.eval(&r.lhs)?;
                    let rhs = body_env.eval(&r.rhs)?;
                    if lhs != rhs {
                        return Err(SemaError::Inhomogeneous {
                            pos: r.pos,
                            lhs: lhs.formula(),
                            op: r.op,
                            rhs: rhs.formula(),
                        });
                    }
                    relations.push(format!("{} {} {}", r.lhs, r.op, r.rhs));
                }
                models.push(SystemModel { name: inv.ident.clone(), symbols, relations });
            }
        }
    }
    Ok(models)
}

/// Convenience: parse + analyze in one call.
pub fn load(src: &str) -> anyhow::Result<Vec<SystemModel>> {
    let file = super::parser::parse(src)?;
    Ok(analyze(&file)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::newton::parser::parse;
    use crate::units::BaseDim;

    const GLIDER: &str = r#"
        glider : invariant(h: distance,
                           v: speed,
                           t: time,
                           g: kNewtonUnithave_AccelerationDueToGravity) = {
            h ~ v * t
        }
    "#;

    #[test]
    fn glider_resolves() {
        let models = analyze(&parse(GLIDER).unwrap()).unwrap();
        assert_eq!(models.len(), 1);
        let m = &models[0];
        assert_eq!(m.name, "glider");
        assert_eq!(m.k(), 4);
        assert_eq!(m.symbols[0].dimension, Dimension::base(BaseDim::Length));
        assert_eq!(m.symbols[3].kind, SymbolKind::Constant);
        assert!((m.symbols[3].value.unwrap() - 9.80665).abs() < 1e-9);
        assert_eq!(m.relations.len(), 1);
    }

    #[test]
    fn custom_signal_and_constant() {
        let src = r#"
            linear_density : signal = { derivation = mass / distance; }
            k_spring : constant = (120.0 * force / distance);
            s : invariant(mu: linear_density, k: k_spring) = { }
        "#;
        let models = analyze(&parse(src).unwrap()).unwrap();
        let m = &models[0];
        assert_eq!(m.symbols[0].dimension.formula(), "M L^-1");
        assert_eq!(m.symbols[1].dimension.formula(), "M T^-2");
        assert_eq!(m.symbols[1].kind, SymbolKind::Constant);
    }

    #[test]
    fn unknown_signal_rejected() {
        let src = "s : invariant(x: warpdrive) = { }";
        assert!(matches!(
            analyze(&parse(src).unwrap()),
            Err(SemaError::Unknown { .. })
        ));
    }

    #[test]
    fn inhomogeneous_relation_rejected() {
        let src = "s : invariant(h: distance, t: time) = { h ~ t }";
        assert!(matches!(
            analyze(&parse(src).unwrap()),
            Err(SemaError::Inhomogeneous { .. })
        ));
    }

    #[test]
    fn homogeneous_relation_with_powers() {
        let src = r#"
            s : invariant(h: distance,
                          g: acceleration,
                          t: time) = { h ~ g * (t ** 2) }
        "#;
        assert!(analyze(&parse(src).unwrap()).is_ok());
    }

    #[test]
    fn duplicate_param_rejected() {
        let src = "s : invariant(x: distance, x: time) = { }";
        assert!(matches!(
            analyze(&parse(src).unwrap()),
            Err(SemaError::Duplicate { .. })
        ));
    }

    #[test]
    fn none_derivation_on_nonbuiltin_rejected() {
        let src = "weird : signal = { derivation = none; }";
        assert!(analyze(&parse(src).unwrap()).is_err());
    }

    #[test]
    fn builtin_redeclaration_with_none_ok() {
        let src = r#"
            time : signal = { name = "second" English; symbol = s; derivation = none; }
            s2 : invariant(t: time) = { }
        "#;
        assert!(analyze(&parse(src).unwrap()).is_ok());
    }
}
