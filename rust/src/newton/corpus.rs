//! The evaluation corpus: Newton descriptions of the 7 physical systems
//! from Table 1 of the paper, with the target parameter used in each
//! compiler invocation.
//!
//! | Name                  | Target parameter |
//! |-----------------------|------------------|
//! | Beam                  | beam deflection  |
//! | Pendulum, static      | oscillation period |
//! | Fluid in pipe         | fluid velocity   |
//! | Unpowered flight      | position (height) |
//! | Vibrating string      | oscillation frequency |
//! | Warm vibrating string | oscillation frequency |
//! | Spring-mass system    | spring constant  |

use super::sema::{self, SystemModel};

/// One corpus entry: name, description, Newton source, and the target
/// parameter the paper uses for that system.
#[derive(Clone, Debug)]
pub struct CorpusEntry {
    /// Short identifier (used for artifact and report names).
    pub id: &'static str,
    /// Table-1 display name.
    pub display_name: &'static str,
    /// Table-1 description.
    pub description: &'static str,
    /// Table-1 target parameter description.
    pub target_desc: &'static str,
    /// The invariant parameter that is the inference target.
    pub target: &'static str,
    /// Newton source text.
    pub source: &'static str,
}

/// Cantilevered beam model, excluding mass of beam. Deflection of the tip
/// under a point load: δ = F L³ / (3 E I). Because the beam's own mass is
/// excluded, Young's modulus E and the second moment of area I enter only
/// through the flexural rigidity E·I (dimension M L³ T⁻²), which is the
/// signal the sensor system observes.
pub const BEAM: &str = r#"
flexural_rigidity : signal = { derivation = force * (distance ** 2); }

beam : invariant(deflection : distance,
                 load       : force,
                 length     : distance,
                 rigidity   : flexural_rigidity) = {
    deflection * rigidity ~ load * (length ** 3)
}
"#;

/// Simple pendulum excluding dynamics and friction: t = 2π sqrt(l/g).
pub const PENDULUM: &str = r#"
pendulum : invariant(period  : time,
                     length  : distance,
                     bobmass : mass,
                     g       : kNewtonUnithave_AccelerationDueToGravity) = {
    (period ** 2) * g ~ length
}
"#;

/// Pressure drop of a fluid through a pipe (Darcy–Weisbach regime).
pub const FLUID_PIPE: &str = r#"
density   : signal = { derivation = mass / (distance ** 3); }
viscosity : signal = { derivation = pressure * time; }

fluid_pipe : invariant(pressure_drop : pressure,
                       rho           : density,
                       velocity      : speed,
                       diameter      : distance,
                       pipe_length   : distance,
                       mu            : viscosity) = {
    pressure_drop * diameter ~ rho * (velocity ** 2) * pipe_length
}
"#;

/// Unpowered flight (e.g., catapulted drone / glider). Fig. 2 of the paper.
pub const UNPOWERED_FLIGHT: &str = r#"
glider : invariant(height   : distance,
                   airspeed : speed,
                   flight_t : time,
                   payload  : mass,
                   g        : kNewtonUnithave_AccelerationDueToGravity) = {
    height * g ~ airspeed * airspeed
}
"#;

/// Vibrating string: f = (1/2l) sqrt(F/μ).
pub const VIBRATING_STRING: &str = r#"
linear_density : signal = { derivation = mass / distance; }

vibrating_string : invariant(freq    : frequency,
                             tension : force,
                             length  : distance,
                             mu      : linear_density) = {
    (freq ** 2) * (length ** 2) * mu ~ tension
}
"#;

/// Vibrating string with temperature dependence (thermal expansion changes
/// tension with temperature).
pub const WARM_VIBRATING_STRING: &str = r#"
linear_density : signal = { derivation = mass / distance; }
thermal_coeff  : signal = { derivation = temperature ** -1; }

warm_vibrating_string : invariant(freq     : frequency,
                                  tension  : force,
                                  length   : distance,
                                  mu       : linear_density,
                                  temp     : temperature,
                                  alpha    : thermal_coeff) = {
    (freq ** 2) * (length ** 2) * mu ~ tension,
    alpha * temp ~ 1
}
"#;

/// Vertical spring with attached mass: ω² = k/m. Gravity sets the static
/// operating point but cannot join any dimensionless product here (it is
/// the only length-bearing signal), which the Π-search detects and
/// reports — mirroring the pendulum's non-participating bob mass.
pub const SPRING_MASS: &str = r#"
stiffness : signal = { derivation = force / distance; }

spring_mass : invariant(springk   : stiffness,
                        bobmass   : mass,
                        period    : time,
                        g         : kNewtonUnithave_AccelerationDueToGravity) = {
    springk * (period ** 2) ~ bobmass
}
"#;

/// The full Table-1 corpus, in paper order.
pub fn corpus() -> Vec<CorpusEntry> {
    vec![
        CorpusEntry {
            id: "beam",
            display_name: "Beam",
            description: "Cantilevered beam model, excluding mass of beam",
            target_desc: "Beam deflection",
            target: "deflection",
            source: BEAM,
        },
        CorpusEntry {
            id: "pendulum",
            display_name: "Pendulum, static",
            description: "Simple pendulum excluding dynamics and friction",
            target_desc: "Osc. period",
            target: "period",
            source: PENDULUM,
        },
        CorpusEntry {
            id: "fluid_pipe",
            display_name: "Fluid in Pipe",
            description: "Pressure drop of a fluid through a pipe",
            target_desc: "Fluid velocity",
            target: "velocity",
            source: FLUID_PIPE,
        },
        CorpusEntry {
            id: "unpowered_flight",
            display_name: "Unpowered flight",
            description: "Unpowered flight (e.g., catapulted drone)",
            target_desc: "Position (height)",
            target: "height",
            source: UNPOWERED_FLIGHT,
        },
        CorpusEntry {
            id: "vibrating_string",
            display_name: "Vibrating string",
            description: "Vibrating string",
            target_desc: "Osc. frequency",
            target: "freq",
            source: VIBRATING_STRING,
        },
        CorpusEntry {
            id: "warm_vibrating_string",
            display_name: "Warm vibrating string",
            description: "Vibrating string with temperature dependence",
            target_desc: "Osc. frequency",
            target: "freq",
            source: WARM_VIBRATING_STRING,
        },
        CorpusEntry {
            id: "spring_mass",
            display_name: "Spring-mass system",
            description: "Vertical spring with attached mass",
            target_desc: "Spring constant",
            target: "springk",
            source: SPRING_MASS,
        },
    ]
}

/// Look up a corpus entry by id.
pub fn by_id(id: &str) -> Option<CorpusEntry> {
    corpus().into_iter().find(|e| e.id == id)
}

/// Parse + analyze a corpus entry, returning its system model.
pub fn load_entry(entry: &CorpusEntry) -> anyhow::Result<SystemModel> {
    let models = sema::load(entry.source)?;
    models
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("corpus entry `{}` has no invariant", entry.id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_seven_systems() {
        assert_eq!(corpus().len(), 7);
    }

    #[test]
    fn all_entries_parse_and_analyze() {
        for e in corpus() {
            let m = load_entry(&e).unwrap_or_else(|err| panic!("{}: {err}", e.id));
            assert!(m.k() >= 4, "{} has too few symbols", e.id);
            assert!(
                m.symbol_index(e.target).is_some(),
                "{}: target `{}` not among symbols",
                e.id,
                e.target
            );
        }
    }

    #[test]
    fn by_id_lookup() {
        assert!(by_id("pendulum").is_some());
        assert!(by_id("nonexistent").is_none());
    }

    #[test]
    fn pendulum_shape() {
        let m = load_entry(&by_id("pendulum").unwrap()).unwrap();
        assert_eq!(m.k(), 4);
        // g resolves as a constant with a value.
        let g = &m.symbols[3];
        assert_eq!(g.name, "g");
        assert!(g.value.is_some());
    }

    #[test]
    fn fluid_pipe_has_six_symbols() {
        let m = load_entry(&by_id("fluid_pipe").unwrap()).unwrap();
        assert_eq!(m.k(), 6);
    }
}
