//! Named SI units and physical constants known to the Newton frontend.
//!
//! Newton specifications refer to base signals (`time`, `distance`, …) and
//! derive the rest with unit expressions. This module provides the built-in
//! signal table the parser seeds its environment with, mirroring the
//! `NewtonBaseSignals.nt` prelude of the reference Newton implementation,
//! plus the built-in physical constants (`kNewtonUnithave_*`) that Newton
//! specifications may reference (Fig. 2 of the paper references
//! `kNewtonUnithave_AccelerationDueToGravity`).

use super::dimension::{BaseDim, Dimension};

/// A built-in signal: name, symbol, and dimension.
#[derive(Clone, Debug)]
pub struct BuiltinSignal {
    pub name: &'static str,
    pub symbol: &'static str,
    pub dimension: Dimension,
}

/// A built-in physical constant with value and dimension.
#[derive(Clone, Debug)]
pub struct BuiltinConstant {
    pub name: &'static str,
    pub value: f64,
    pub dimension: Dimension,
}

fn dim(t: i64, l: i64, m: i64, i: i64, th: i64, n: i64, j: i64) -> Dimension {
    Dimension::from_ints([t, l, m, i, th, n, j])
}

/// The base-signal prelude: the seven SI base quantities under their Newton
/// names plus common derived quantities used by the corpus specifications.
pub fn builtin_signals() -> Vec<BuiltinSignal> {
    vec![
        // SI base quantities (Newton names).
        BuiltinSignal { name: "time", symbol: "s", dimension: Dimension::base(BaseDim::Time) },
        BuiltinSignal { name: "distance", symbol: "m", dimension: Dimension::base(BaseDim::Length) },
        BuiltinSignal { name: "mass", symbol: "kg", dimension: Dimension::base(BaseDim::Mass) },
        BuiltinSignal { name: "current", symbol: "A", dimension: Dimension::base(BaseDim::Current) },
        BuiltinSignal { name: "temperature", symbol: "K", dimension: Dimension::base(BaseDim::Temperature) },
        BuiltinSignal { name: "substance", symbol: "mol", dimension: Dimension::base(BaseDim::Substance) },
        BuiltinSignal { name: "luminosity", symbol: "cd", dimension: Dimension::base(BaseDim::Luminosity) },
        // Common derived quantities.
        BuiltinSignal { name: "speed", symbol: "mps", dimension: dim(-1, 1, 0, 0, 0, 0, 0) },
        BuiltinSignal { name: "acceleration", symbol: "mps2", dimension: dim(-2, 1, 0, 0, 0, 0, 0) },
        BuiltinSignal { name: "force", symbol: "N", dimension: dim(-2, 1, 1, 0, 0, 0, 0) },
        BuiltinSignal { name: "pressure", symbol: "Pa", dimension: dim(-2, -1, 1, 0, 0, 0, 0) },
        BuiltinSignal { name: "energy", symbol: "J", dimension: dim(-2, 2, 1, 0, 0, 0, 0) },
        BuiltinSignal { name: "power", symbol: "W", dimension: dim(-3, 2, 1, 0, 0, 0, 0) },
        BuiltinSignal { name: "frequency", symbol: "Hz", dimension: dim(-1, 0, 0, 0, 0, 0, 0) },
        BuiltinSignal { name: "angle", symbol: "rad", dimension: Dimension::NONE },
    ]
}

/// Built-in physical constants available as `kNewtonUnithave_*` identifiers.
pub fn builtin_constants() -> Vec<BuiltinConstant> {
    vec![
        BuiltinConstant {
            name: "kNewtonUnithave_AccelerationDueToGravity",
            value: 9.80665,
            dimension: dim(-2, 1, 0, 0, 0, 0, 0),
        },
        BuiltinConstant {
            name: "kNewtonUnithave_SpeedOfLight",
            value: 299_792_458.0,
            dimension: dim(-1, 1, 0, 0, 0, 0, 0),
        },
        BuiltinConstant {
            name: "kNewtonUnithave_BoltzmannConstant",
            value: 1.380_649e-23,
            dimension: dim(-2, 2, 1, 0, -1, 0, 0),
        },
        BuiltinConstant {
            name: "kNewtonUnithave_PlanckConstant",
            value: 6.626_070_15e-34,
            dimension: dim(-1, 2, 1, 0, 0, 0, 0),
        },
        BuiltinConstant {
            name: "kNewtonUnithave_GravitationalConstant",
            value: 6.674_30e-11,
            dimension: dim(-2, 3, -1, 0, 0, 0, 0),
        },
        BuiltinConstant {
            name: "kNewtonUnithave_Pi",
            value: std::f64::consts::PI,
            dimension: Dimension::NONE,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_signals_present() {
        let sigs = builtin_signals();
        let names: Vec<_> = sigs.iter().map(|s| s.name).collect();
        for base in ["time", "distance", "mass", "temperature"] {
            assert!(names.contains(&base), "missing base signal {base}");
        }
    }

    #[test]
    fn derived_dimensions_consistent() {
        let sigs = builtin_signals();
        let get = |n: &str| sigs.iter().find(|s| s.name == n).unwrap().dimension;
        // force = mass * acceleration
        assert_eq!(get("force"), get("mass") * get("acceleration"));
        // pressure = force / distance^2
        assert_eq!(get("pressure"), get("force") / get("distance").powi(2));
        // energy = force * distance
        assert_eq!(get("energy"), get("force") * get("distance"));
        // power = energy / time
        assert_eq!(get("power"), get("energy") / get("time"));
        // speed = distance / time
        assert_eq!(get("speed"), get("distance") / get("time"));
    }

    #[test]
    fn gravity_constant_has_acceleration_dimension() {
        let consts = builtin_constants();
        let g = consts
            .iter()
            .find(|c| c.name == "kNewtonUnithave_AccelerationDueToGravity")
            .unwrap();
        let sigs = builtin_signals();
        let accel = sigs.iter().find(|s| s.name == "acceleration").unwrap();
        assert_eq!(g.dimension, accel.dimension);
        assert!((g.value - 9.80665).abs() < 1e-9);
    }

    #[test]
    fn no_duplicate_names() {
        let sigs = builtin_signals();
        let mut names: Vec<_> = sigs.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), sigs.len());
    }
}
