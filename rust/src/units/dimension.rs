//! SI dimension algebra.
//!
//! A [`Dimension`] is a vector of rational exponents over the seven SI base
//! dimensions. Units of measure in Newton specifications reduce to
//! dimensions; the dimensional matrix assembled in [`crate::pisearch`] has
//! one row per base dimension and one column per signal.

use crate::rational::Rational;
use std::fmt;
use std::ops::{Div, Mul};

/// The seven SI base dimensions, in canonical order.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BaseDim {
    /// T — time (second)
    Time = 0,
    /// L — length (metre)
    Length = 1,
    /// M — mass (kilogram)
    Mass = 2,
    /// I — electric current (ampere)
    Current = 3,
    /// Θ — thermodynamic temperature (kelvin)
    Temperature = 4,
    /// N — amount of substance (mole)
    Substance = 5,
    /// J — luminous intensity (candela)
    Luminosity = 6,
}

/// Number of SI base dimensions.
pub const NUM_BASE_DIMS: usize = 7;

impl BaseDim {
    pub const ALL: [BaseDim; NUM_BASE_DIMS] = [
        BaseDim::Time,
        BaseDim::Length,
        BaseDim::Mass,
        BaseDim::Current,
        BaseDim::Temperature,
        BaseDim::Substance,
        BaseDim::Luminosity,
    ];

    /// Conventional single-letter symbol used in dimensional formulas.
    pub fn symbol(&self) -> &'static str {
        match self {
            BaseDim::Time => "T",
            BaseDim::Length => "L",
            BaseDim::Mass => "M",
            BaseDim::Current => "I",
            BaseDim::Temperature => "Θ",
            BaseDim::Substance => "N",
            BaseDim::Luminosity => "J",
        }
    }

    /// SI base-unit symbol.
    pub fn unit_symbol(&self) -> &'static str {
        match self {
            BaseDim::Time => "s",
            BaseDim::Length => "m",
            BaseDim::Mass => "kg",
            BaseDim::Current => "A",
            BaseDim::Temperature => "K",
            BaseDim::Substance => "mol",
            BaseDim::Luminosity => "cd",
        }
    }
}

/// A dimension: rational exponents over the 7 SI base dimensions.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dimension {
    exps: [Rational; NUM_BASE_DIMS],
}

impl Dimension {
    /// The dimensionless dimension (all exponents zero).
    pub const NONE: Dimension = Dimension {
        exps: [Rational::ZERO; NUM_BASE_DIMS],
    };

    /// A single base dimension to the first power.
    pub fn base(d: BaseDim) -> Dimension {
        let mut exps = [Rational::ZERO; NUM_BASE_DIMS];
        exps[d as usize] = Rational::ONE;
        Dimension { exps }
    }

    /// Build from integer exponents in canonical order (T, L, M, I, Θ, N, J).
    pub fn from_ints(exps: [i64; NUM_BASE_DIMS]) -> Dimension {
        let mut r = [Rational::ZERO; NUM_BASE_DIMS];
        for (i, e) in exps.iter().enumerate() {
            r[i] = Rational::from_int(*e);
        }
        Dimension { exps: r }
    }

    /// Build from explicit rational exponents in canonical order — the
    /// decode path of the persistent artifact store
    /// ([`crate::flow::store`]).
    pub fn from_exps(exps: [Rational; NUM_BASE_DIMS]) -> Dimension {
        Dimension { exps }
    }

    /// Exponent of one base dimension.
    pub fn exp(&self, d: BaseDim) -> Rational {
        self.exps[d as usize]
    }

    /// All exponents in canonical order.
    pub fn exps(&self) -> &[Rational; NUM_BASE_DIMS] {
        &self.exps
    }

    pub fn is_dimensionless(&self) -> bool {
        self.exps.iter().all(|e| e.is_zero())
    }

    /// Raise to a rational power.
    pub fn pow(&self, p: Rational) -> Dimension {
        let mut exps = self.exps;
        for e in exps.iter_mut() {
            *e = *e * p;
        }
        Dimension { exps }
    }

    pub fn powi(&self, p: i64) -> Dimension {
        self.pow(Rational::from_int(p))
    }

    pub fn recip(&self) -> Dimension {
        self.powi(-1)
    }

    /// Dimensional formula, e.g. `L T^-2` for acceleration. Dimensionless
    /// dimensions render as `1`.
    pub fn formula(&self) -> String {
        let mut parts = Vec::new();
        // Render in the conventional M L T I Θ N J order.
        let order = [
            BaseDim::Mass,
            BaseDim::Length,
            BaseDim::Time,
            BaseDim::Current,
            BaseDim::Temperature,
            BaseDim::Substance,
            BaseDim::Luminosity,
        ];
        for d in order {
            let e = self.exp(d);
            if e.is_zero() {
                continue;
            }
            if e == Rational::ONE {
                parts.push(d.symbol().to_string());
            } else {
                parts.push(format!("{}^{}", d.symbol(), e));
            }
        }
        if parts.is_empty() {
            "1".to_string()
        } else {
            parts.join(" ")
        }
    }

    /// SI unit rendering, e.g. `m s^-2`. Dimensionless renders as `1`.
    pub fn si_unit(&self) -> String {
        let mut parts = Vec::new();
        let order = [
            BaseDim::Mass,
            BaseDim::Length,
            BaseDim::Time,
            BaseDim::Current,
            BaseDim::Temperature,
            BaseDim::Substance,
            BaseDim::Luminosity,
        ];
        for d in order {
            let e = self.exp(d);
            if e.is_zero() {
                continue;
            }
            if e == Rational::ONE {
                parts.push(d.unit_symbol().to_string());
            } else {
                parts.push(format!("{}^{}", d.unit_symbol(), e));
            }
        }
        if parts.is_empty() {
            "1".to_string()
        } else {
            parts.join(" ")
        }
    }
}

impl Mul for Dimension {
    type Output = Dimension;
    fn mul(self, rhs: Dimension) -> Dimension {
        let mut exps = self.exps;
        for (i, e) in exps.iter_mut().enumerate() {
            *e = *e + rhs.exps[i];
        }
        Dimension { exps }
    }
}

impl Div for Dimension {
    type Output = Dimension;
    fn div(self, rhs: Dimension) -> Dimension {
        self * rhs.recip()
    }
}

impl fmt::Debug for Dimension {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Dimension[{}]", self.formula())
    }
}

impl fmt::Display for Dimension {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.formula())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accel() -> Dimension {
        Dimension::base(BaseDim::Length) / Dimension::base(BaseDim::Time).powi(2)
    }

    #[test]
    fn base_dimension_exponents() {
        let t = Dimension::base(BaseDim::Time);
        assert_eq!(t.exp(BaseDim::Time), Rational::ONE);
        assert_eq!(t.exp(BaseDim::Length), Rational::ZERO);
        assert!(!t.is_dimensionless());
    }

    #[test]
    fn dimensionless() {
        assert!(Dimension::NONE.is_dimensionless());
        let v = Dimension::base(BaseDim::Length) / Dimension::base(BaseDim::Length);
        assert!(v.is_dimensionless());
    }

    #[test]
    fn algebra() {
        let a = accel();
        assert_eq!(a.exp(BaseDim::Length), Rational::ONE);
        assert_eq!(a.exp(BaseDim::Time), Rational::from_int(-2));
        // force = M * a
        let f = Dimension::base(BaseDim::Mass) * a;
        assert_eq!(f.formula(), "M L T^-2");
        // energy = F * L
        let e = f * Dimension::base(BaseDim::Length);
        assert_eq!(e.formula(), "M L^2 T^-2");
    }

    #[test]
    fn pow_rational() {
        // sqrt(L^2) = L
        let l2 = Dimension::base(BaseDim::Length).powi(2);
        let l = l2.pow(Rational::new(1, 2));
        assert_eq!(l, Dimension::base(BaseDim::Length));
    }

    #[test]
    fn si_unit_rendering() {
        assert_eq!(accel().si_unit(), "m s^-2");
        assert_eq!(Dimension::NONE.si_unit(), "1");
        let pressure = Dimension::from_ints([-2, -1, 1, 0, 0, 0, 0]);
        assert_eq!(pressure.formula(), "M L^-1 T^-2");
    }

    #[test]
    fn from_ints_roundtrip() {
        let d = Dimension::from_ints([1, 2, 3, 0, -1, 0, 0]);
        assert_eq!(d.exp(BaseDim::Time), Rational::from_int(1));
        assert_eq!(d.exp(BaseDim::Length), Rational::from_int(2));
        assert_eq!(d.exp(BaseDim::Temperature), Rational::from_int(-1));
    }
}
