//! Units of measure: SI dimension algebra and the built-in signal/constant
//! tables used by the Newton frontend.

pub mod dimension;
pub mod si;

pub use dimension::{BaseDim, Dimension, NUM_BASE_DIMS};
pub use si::{builtin_constants, builtin_signals, BuiltinConstant, BuiltinSignal};
