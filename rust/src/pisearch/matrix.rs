//! Exact rational matrices and Gaussian elimination.
//!
//! The dimensional matrix of a physical system is tiny (≤ 7 rows, k ≤ ~10
//! columns) but must be handled exactly — see [`crate::rational`]. This
//! module provides a dense rational matrix with reduced-row-echelon-form
//! (RREF) elimination, rank, and nullspace-basis extraction.

use crate::rational::{gcd, lcm, Rational};
use crate::units::{Dimension, NUM_BASE_DIMS};
use std::fmt;

/// Dense matrix of exact rationals (row-major).
#[derive(Clone, PartialEq, Eq)]
pub struct RMatrix {
    rows: usize,
    cols: usize,
    data: Vec<Rational>,
}

impl RMatrix {
    pub fn zeros(rows: usize, cols: usize) -> RMatrix {
        RMatrix { rows, cols, data: vec![Rational::ZERO; rows * cols] }
    }

    /// Build from integer rows (panics if rows are ragged).
    pub fn from_int_rows(rows: &[Vec<i64>]) -> RMatrix {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = RMatrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, v) in row.iter().enumerate() {
                m[(i, j)] = Rational::from_int(*v);
            }
        }
        m
    }

    /// The dimensional matrix of a list of symbol dimensions: one row per
    /// SI base dimension, one column per symbol.
    pub fn dimensional(dims: &[Dimension]) -> RMatrix {
        let mut m = RMatrix::zeros(NUM_BASE_DIMS, dims.len());
        for (j, d) in dims.iter().enumerate() {
            for (i, e) in d.exps().iter().enumerate() {
                m[(i, j)] = *e;
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// In-place reduction to RREF. Returns the pivot columns.
    pub fn rref(&mut self) -> Vec<usize> {
        let mut pivots = Vec::new();
        let mut r = 0usize;
        for c in 0..self.cols {
            if r >= self.rows {
                break;
            }
            // Find a pivot row at or below r with nonzero entry in column c.
            let Some(p) = (r..self.rows).find(|&i| !self[(i, c)].is_zero()) else {
                continue;
            };
            self.swap_rows(r, p);
            // Normalize pivot row.
            let inv = self[(r, c)].recip();
            for j in c..self.cols {
                self[(r, j)] = self[(r, j)] * inv;
            }
            // Eliminate column c from all other rows.
            for i in 0..self.rows {
                if i != r && !self[(i, c)].is_zero() {
                    let f = self[(i, c)];
                    for j in c..self.cols {
                        let v = self[(r, j)] * f;
                        self[(i, j)] = self[(i, j)] - v;
                    }
                }
            }
            pivots.push(c);
            r += 1;
        }
        pivots
    }

    /// Rank via RREF on a copy.
    pub fn rank(&self) -> usize {
        let mut m = self.clone();
        m.rref().len()
    }

    fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for j in 0..self.cols {
            let ia = a * self.cols + j;
            let ib = b * self.cols + j;
            self.data.swap(ia, ib);
        }
    }

    /// Basis of the (right) nullspace: vectors `x` with `A x = 0`.
    ///
    /// Returned in the standard RREF parameterization: one basis vector per
    /// free column, with a `1` in the free column's position. The basis
    /// vectors are rational; see [`integerize`] for integer scaling.
    pub fn nullspace(&self) -> Vec<Vec<Rational>> {
        let mut m = self.clone();
        let pivots = m.rref();
        let pivot_set: Vec<Option<usize>> = {
            // pivot_of_col[c] = row index of pivot in column c
            let mut v = vec![None; self.cols];
            for (row, &c) in pivots.iter().enumerate() {
                v[c] = Some(row);
            }
            v
        };
        let free: Vec<usize> =
            (0..self.cols).filter(|c| pivot_set[*c].is_none()).collect();
        let mut basis = Vec::with_capacity(free.len());
        for &fc in &free {
            let mut x = vec![Rational::ZERO; self.cols];
            x[fc] = Rational::ONE;
            for (c, p) in pivot_set.iter().enumerate() {
                if let Some(row) = p {
                    // pivot var = -sum(free coeffs)
                    x[c] = -m[(*row, fc)];
                }
            }
            basis.push(x);
        }
        basis
    }

    /// Multiply this matrix by a vector.
    pub fn mul_vec(&self, x: &[Rational]) -> Vec<Rational> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                let mut acc = Rational::ZERO;
                for j in 0..self.cols {
                    acc = acc + self[(i, j)] * x[j];
                }
                acc
            })
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for RMatrix {
    type Output = Rational;
    fn index(&self, (i, j): (usize, usize)) -> &Rational {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for RMatrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut Rational {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for RMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "RMatrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  ")?;
            for j in 0..self.cols {
                write!(f, "{:>6} ", self[(i, j)].to_string())?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

/// Scale a rational vector to the smallest integer vector with the same
/// direction (positive multiple). Returns the integer exponents.
pub fn integerize(x: &[Rational]) -> Vec<i64> {
    let mut l = 1i64;
    for r in x {
        l = lcm(l, r.den()).max(1);
    }
    let ints: Vec<i64> = x.iter().map(|r| r.num() * (l / r.den())).collect();
    let mut g = 0i64;
    for v in &ints {
        g = gcd(g, *v);
    }
    if g > 1 {
        ints.iter().map(|v| v / g).collect()
    } else {
        ints
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::BaseDim;

    #[test]
    fn rref_identity() {
        let mut m = RMatrix::from_int_rows(&[vec![2, 0], vec![0, 3]]);
        let p = m.rref();
        assert_eq!(p, vec![0, 1]);
        assert_eq!(m[(0, 0)], Rational::ONE);
        assert_eq!(m[(1, 1)], Rational::ONE);
    }

    #[test]
    fn rank_deficient() {
        let m = RMatrix::from_int_rows(&[vec![1, 2, 3], vec![2, 4, 6], vec![1, 1, 1]]);
        assert_eq!(m.rank(), 2);
    }

    #[test]
    fn nullspace_simple() {
        // x + y + z = 0 → nullity 2.
        let m = RMatrix::from_int_rows(&[vec![1, 1, 1]]);
        let ns = m.nullspace();
        assert_eq!(ns.len(), 2);
        for x in &ns {
            let out = m.mul_vec(x);
            assert!(out.iter().all(|r| r.is_zero()));
        }
    }

    #[test]
    fn nullspace_of_full_rank_is_empty() {
        let m = RMatrix::from_int_rows(&[vec![1, 0], vec![0, 1]]);
        assert!(m.nullspace().is_empty());
    }

    #[test]
    fn dimensional_matrix_pendulum() {
        // t(T), l(L), m(M), g(L T^-2)
        let dims = vec![
            Dimension::base(BaseDim::Time),
            Dimension::base(BaseDim::Length),
            Dimension::base(BaseDim::Mass),
            Dimension::base(BaseDim::Length) / Dimension::base(BaseDim::Time).powi(2),
        ];
        let m = RMatrix::dimensional(&dims);
        assert_eq!(m.rows(), NUM_BASE_DIMS);
        assert_eq!(m.cols(), 4);
        assert_eq!(m.rank(), 3);
        let ns = m.nullspace();
        assert_eq!(ns.len(), 1); // N = k - rank = 1
        // Verify the basis vector is (up to scale) [2, -1, 0, 1]: g t^2 / l.
        let ints = integerize(&ns[0]);
        let scaled: Vec<i64> = if ints[0] < 0 { ints.iter().map(|v| -v).collect() } else { ints };
        assert_eq!(scaled, vec![2, -1, 0, 1]);
    }

    #[test]
    fn integerize_scales_fractions() {
        let v = vec![Rational::new(1, 2), Rational::new(-1, 3), Rational::ONE];
        assert_eq!(integerize(&v), vec![3, -2, 6]);
    }

    #[test]
    fn integerize_reduces_common_factor() {
        let v = vec![Rational::from_int(4), Rational::from_int(-6)];
        assert_eq!(integerize(&v), vec![2, -3]);
    }

    #[test]
    fn mul_vec() {
        let m = RMatrix::from_int_rows(&[vec![1, 2], vec![3, 4]]);
        let x = vec![Rational::from_int(1), Rational::from_int(1)];
        let y = m.mul_vec(&x);
        assert_eq!(y, vec![Rational::from_int(3), Rational::from_int(7)]);
    }
}
