//! Cost-directed Π-basis optimization.
//!
//! Any integer unimodular combination of Π groups is an equally valid
//! basis for the nullspace of the dimensional matrix. The RTL datapath
//! cost of a group, however, depends on its exponents: each unit of
//! |exponent| is one sequential multiply or divide, and divides are
//! slower than multiplies (restoring division needs `width + frac` cycles
//! vs `width + 1` for shift-add multiplication). This pass therefore:
//!
//! 1. **Sign-selects** each group: a dimensionless product may be used
//!    inverted, so we pick the orientation with cheaper hardware (fewer
//!    divides / shorter serial chain).
//! 2. **Greedily reduces** the basis: repeatedly tries replacing a group
//!    `gᵢ` with `gᵢ ± gⱼ` when that lowers its cost, subject to the
//!    *target-isolation invariant*: the target symbol keeps a nonzero
//!    exponent in exactly one group (only non-target groups may be added
//!    into others, and the target group may not be added into anything).
//!
//! This mirrors the engineering freedom the paper exercises — e.g. its
//! unpowered-flight design concludes in fewer cycles than the static
//! pendulum despite more signals, which is only possible with short,
//! multiply-biased groups.

use super::groups::{PiAnalysis, PiGroup};
use crate::fixedpoint::{monomial_ops, MonOp};

/// Relative op costs used to steer the reduction. These mirror the RTL
/// latencies for the default Q16.15 format (load 1, mul 33, div 47) but
/// only the *ratios* matter for basis selection.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub load: u64,
    pub mul: u64,
    pub div: u64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel { load: 1, mul: 33, div: 47 }
    }
}

impl CostModel {
    /// Serial cost of one group's canonical op schedule.
    pub fn group_cost(&self, exponents: &[i64]) -> u64 {
        monomial_ops(exponents)
            .iter()
            .map(|op| match op {
                MonOp::Load(_) | MonOp::LoadOne => self.load,
                MonOp::Mul(_) => self.mul,
                MonOp::Div(_) => self.div,
            })
            .sum()
    }

    /// Cost of the cheaper orientation of a group.
    fn oriented(&self, exps: &[i64]) -> (Vec<i64>, u64) {
        let flipped: Vec<i64> = exps.iter().map(|e| -e).collect();
        let c0 = self.group_cost(exps);
        let c1 = self.group_cost(&flipped);
        if c1 < c0 {
            (flipped, c1)
        } else {
            (exps.to_vec(), c0)
        }
    }
}

/// Optimize the basis of `analysis` in place under `cost`.
///
/// Postconditions (checked by debug assertions and tests):
/// * every group is still dimensionless (a linear combination of the
///   original nullspace vectors),
/// * the target symbol has nonzero exponent in `target_group` and zero
///   exponent everywhere else,
/// * no group becomes trivial (all-zero).
pub fn optimize(analysis: &mut PiAnalysis, cost: &CostModel) {
    let n = analysis.groups.len();
    let tg = analysis.target_group;
    let target = analysis.target;

    // Greedy reduction to a local optimum. The basis is tiny (N ≤ 4 for
    // the corpus) so a simple fixpoint loop is plenty.
    let mut changed = true;
    let mut rounds = 0;
    while changed && rounds < 32 {
        changed = false;
        rounds += 1;
        for i in 0..n {
            for j in 0..n {
                if i == j || j == tg {
                    // Adding the target group into another would leak the
                    // target symbol; skip.
                    continue;
                }
                let base_cost = cost.oriented(&analysis.groups[i].exponents).1;
                for m in [-2i64, -1, 1, 2] {
                    let cand: Vec<i64> = analysis.groups[i]
                        .exponents
                        .iter()
                        .zip(&analysis.groups[j].exponents)
                        .map(|(a, b)| a + m * b)
                        .collect();
                    if cand.iter().all(|&e| e == 0) {
                        continue;
                    }
                    // Preserve isolation: group i's target exponent must
                    // stay nonzero iff i is the target group. Since
                    // j != tg, groups[j].exponents[target] == 0 and the
                    // target exponent of i is unchanged — still checked
                    // defensively.
                    let t_ok = if i == tg { cand[target] != 0 } else { cand[target] == 0 };
                    if !t_ok {
                        continue;
                    }
                    let cand_cost = cost.oriented(&cand).1;
                    if cand_cost < base_cost {
                        analysis.groups[i].exponents = cand;
                        changed = true;
                        break;
                    }
                }
            }
        }
    }

    // Final orientation pass.
    for (i, g) in analysis.groups.iter_mut().enumerate() {
        let (exps, _) = cost.oriented(&g.exponents);
        g.exponents = exps;
        debug_assert!(
            if i == tg { g.exponents[target] != 0 } else { g.exponents[target] == 0 },
            "target isolation violated in group {i}"
        );
    }
}

/// Convenience: run [`super::groups::analyze`] followed by [`optimize`]
/// with the default cost model. This is what the RTL backend consumes.
pub fn analyze_optimized(
    model: &crate::newton::SystemModel,
    target: &str,
) -> Result<PiAnalysis, super::groups::PiError> {
    let mut a = super::groups::analyze(model, target)?;
    optimize(&mut a, &CostModel::default());
    Ok(a)
}

/// Total serial cost of the most expensive group — the analytic latency
/// proxy used when comparing bases.
pub fn critical_cost(groups: &[PiGroup], cost: &CostModel) -> u64 {
    groups
        .iter()
        .map(|g| cost.group_cost(&g.exponents))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::newton::corpus;
    use crate::units::Dimension;

    fn optimized(id: &str) -> PiAnalysis {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        analyze_optimized(&m, e.target).unwrap()
    }

    fn check_invariants(id: &str, a: &PiAnalysis) {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        for (gi, g) in a.groups.iter().enumerate() {
            // Dimensionless.
            let mut d = Dimension::NONE;
            for (i, &exp) in g.exponents.iter().enumerate() {
                d = d * m.symbols[i].dimension.powi(exp);
            }
            assert!(d.is_dimensionless(), "{id}: group {gi} not dimensionless");
            // Isolation.
            if gi == a.target_group {
                assert_ne!(g.exponents[a.target], 0, "{id}: target missing from target group");
            } else {
                assert_eq!(g.exponents[a.target], 0, "{id}: target leaked into group {gi}");
            }
            assert!(!g.is_trivial(), "{id}: group {gi} trivial");
        }
    }

    #[test]
    fn all_corpus_systems_optimize() {
        for e in corpus::corpus() {
            let a = optimized(e.id);
            check_invariants(e.id, &a);
        }
    }

    #[test]
    fn optimization_never_increases_critical_cost() {
        let cost = CostModel::default();
        for e in corpus::corpus() {
            let m = corpus::load_entry(&e).unwrap();
            let before = super::super::groups::analyze(&m, e.target).unwrap();
            let mut after = before.clone();
            optimize(&mut after, &cost);
            assert!(
                critical_cost(&after.groups, &cost) <= critical_cost(&before.groups, &cost),
                "{}: cost increased",
                e.id
            );
        }
    }

    #[test]
    fn flight_prefers_multiply_biased_groups() {
        // The optimized glider basis should avoid double-divides: no group
        // should cost more than load + mul + div (one chain of 3 ops) —
        // this is what lets the flight design finish faster than the
        // pendulum, as the paper observes.
        let a = optimized("unpowered_flight");
        let cost = CostModel::default();
        for g in &a.groups {
            assert!(
                cost.group_cost(&g.exponents) <= 1 + 33 + 47,
                "group {:?} too expensive",
                g.exponents
            );
        }
    }

    #[test]
    fn sign_selection_prefers_fewer_divides() {
        let cost = CostModel::default();
        // 1/(a·b) should be flipped to a·b.
        let (exps, _) = cost.oriented(&[-1, -1]);
        assert_eq!(exps, vec![1, 1]);
        // a/b ties with b/a (1 load, 1 div each) — orientation kept.
        let (exps, _) = cost.oriented(&[1, -1]);
        assert_eq!(exps, vec![1, -1]);
    }

    #[test]
    fn cost_model_values() {
        let cost = CostModel::default();
        // g t^2 / l: load + mul + mul + div.
        assert_eq!(cost.group_cost(&[2, -1, 0, 1]), 1 + 33 + 33 + 47);
        // Pure reciprocal: load-one + div.
        assert_eq!(cost.group_cost(&[-1]), 1 + 47);
    }
}
