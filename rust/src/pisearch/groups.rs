//! Buckingham Π-group construction and target isolation.
//!
//! Given a [`SystemModel`] with k symbols, we form the dimensional matrix
//! D (7 × k) and compute a basis of its nullspace — each basis vector is a
//! vector of exponents `e` such that `∏ sᵢ^eᵢ` is dimensionless (paper
//! Eq. 1, Buckingham Π-theorem). The backend then performs a *basis
//! change* so that the user-selected target parameter appears in exactly
//! one Π (paper Section 2.A, Step 2), and canonicalizes each group:
//! smallest integer exponents, target's (or first) exponent positive.

use super::matrix::{integerize, RMatrix};
use crate::newton::{SystemModel};
use crate::rational::Rational;
use std::fmt;

/// One dimensionless product: integer exponents over the system symbols.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct PiGroup {
    /// Exponent of each system symbol (same order as `SystemModel::symbols`).
    pub exponents: Vec<i64>,
}

impl PiGroup {
    /// Total serial work: Σ|eᵢ| fixed-point operations (each unit power is
    /// one multiply or divide in the generated datapath).
    pub fn op_count(&self) -> usize {
        self.exponents.iter().map(|e| e.unsigned_abs() as usize).sum()
    }

    /// Number of multiplications (positive-exponent unit powers), counting
    /// the implicit chaining: the first factor is a load, not a multiply.
    pub fn is_trivial(&self) -> bool {
        self.exponents.iter().all(|&e| e == 0)
    }

    /// Render as a monomial over the given symbol names, e.g. `g·t^2/l`.
    pub fn render(&self, names: &[String]) -> String {
        let mut num = Vec::new();
        let mut den = Vec::new();
        for (i, &e) in self.exponents.iter().enumerate() {
            if e > 0 {
                num.push(if e == 1 { names[i].clone() } else { format!("{}^{}", names[i], e) });
            } else if e < 0 {
                den.push(if e == -1 { names[i].clone() } else { format!("{}^{}", names[i], -e) });
            }
        }
        let n = if num.is_empty() { "1".to_string() } else { num.join("·") };
        if den.is_empty() {
            n
        } else {
            format!("{}/({})", n, den.join("·"))
        }
    }
}

/// The result of Π-group construction for one system.
#[derive(Clone, Debug)]
pub struct PiAnalysis {
    /// System name.
    pub system: String,
    /// Symbol names in column order.
    pub symbols: Vec<String>,
    /// Index of the target symbol.
    pub target: usize,
    /// The Π groups; the target appears (with positive exponent) in
    /// `groups[target_group]` and nowhere else.
    pub groups: Vec<PiGroup>,
    /// Which group contains the target.
    pub target_group: usize,
    /// Rank of the dimensional matrix.
    pub rank: usize,
    /// Symbols that cannot participate in any dimensionless product (their
    /// exponent is zero in the whole nullspace), e.g. the bob mass of an
    /// ideal pendulum.
    pub nonparticipating: Vec<usize>,
}

impl PiAnalysis {
    pub fn n(&self) -> usize {
        self.groups.len()
    }

    /// Indices of the symbols that actually feed the datapath.
    pub fn participating(&self) -> Vec<usize> {
        (0..self.symbols.len())
            .filter(|i| self.groups.iter().any(|g| g.exponents[*i] != 0))
            .collect()
    }
}

impl fmt::Display for PiAnalysis {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "system `{}`: k={} rank={} N={}", self.system, self.symbols.len(), self.rank, self.n())?;
        for (i, g) in self.groups.iter().enumerate() {
            let marker = if i == self.target_group { " (target group)" } else { "" };
            writeln!(f, "  Π{} = {}{}", i + 1, g.render(&self.symbols), marker)?;
        }
        if !self.nonparticipating.is_empty() {
            let names: Vec<_> = self.nonparticipating.iter().map(|&i| self.symbols[i].as_str()).collect();
            writeln!(f, "  non-participating: {}", names.join(", "))?;
        }
        Ok(())
    }
}

/// Error cases of the Π search.
#[derive(Debug)]
pub enum PiError {
    NoGroups(String),
    TargetNotExpressible { system: String, target: String },
    UnknownTarget { system: String, target: String },
}

impl fmt::Display for PiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PiError::NoGroups(system) => write!(
                f,
                "system `{system}` has no dimensionless products (nullspace is trivial)"
            ),
            PiError::TargetNotExpressible { system, target } => write!(
                f,
                "target `{target}` of system `{system}` cannot appear in any dimensionless product"
            ),
            PiError::UnknownTarget { system, target } => {
                write!(f, "unknown target symbol `{target}` in system `{system}`")
            }
        }
    }
}

impl std::error::Error for PiError {}

/// Run the Π-search for `model` with the given target parameter.
pub fn analyze(model: &SystemModel, target: &str) -> Result<PiAnalysis, PiError> {
    let target_idx = model.symbol_index(target).ok_or_else(|| PiError::UnknownTarget {
        system: model.name.clone(),
        target: target.to_string(),
    })?;

    let dims = model.dimensions();
    let d = RMatrix::dimensional(&dims);
    let rank = d.rank();
    let basis = d.nullspace();
    if basis.is_empty() {
        return Err(PiError::NoGroups(model.name.clone()));
    }

    // Non-participating symbols: zero in every nullspace basis vector.
    let k = model.k();
    let nonparticipating: Vec<usize> = (0..k)
        .filter(|&i| basis.iter().all(|x| x[i].is_zero()))
        .collect();
    if nonparticipating.contains(&target_idx) {
        return Err(PiError::TargetNotExpressible {
            system: model.name.clone(),
            target: target.to_string(),
        });
    }

    // Basis change: make the target appear in exactly one basis vector.
    // Pick the vector with the "simplest" nonzero target coefficient as
    // pivot, then eliminate the target coordinate from all others.
    let mut basis: Vec<Vec<Rational>> = basis;
    let pivot = basis
        .iter()
        .enumerate()
        .filter(|(_, x)| !x[target_idx].is_zero())
        .min_by_key(|(_, x)| {
            // Prefer small exponent magnitudes overall.
            x.iter().map(|r| (r.abs().to_f64() * 6.0) as i64).sum::<i64>()
        })
        .map(|(i, _)| i)
        .expect("target participates, so some vector has nonzero coefficient");
    basis.swap(0, pivot);
    // Split-borrow: the pivot row is read while the rest are eliminated,
    // so no clone of the pivot vector is needed.
    let (pivot_vec, rest) = basis.split_first_mut().expect("basis is non-empty");
    let pc = pivot_vec[target_idx];
    for v in rest {
        if !v[target_idx].is_zero() {
            let f = v[target_idx] / pc;
            for (j, x) in v.iter_mut().enumerate() {
                *x = *x - f * pivot_vec[j];
            }
        }
    }

    // Canonicalize: integer scaling; target group gets positive target
    // exponent, others get positive first-nonzero exponent.
    let mut groups = Vec::with_capacity(basis.len());
    for (gi, v) in basis.iter().enumerate() {
        let mut ints = integerize(v);
        let sign_ref = if gi == 0 {
            ints[target_idx]
        } else {
            *ints.iter().find(|&&e| e != 0).unwrap_or(&1)
        };
        if sign_ref < 0 {
            for e in ints.iter_mut() {
                *e = -*e;
            }
        }
        groups.push(PiGroup { exponents: ints });
    }

    // Deterministic order: target group first, the rest sorted by
    // (op_count, exponents) for reproducible RTL generation.
    let target_g = groups.remove(0);
    groups.sort_by(|a, b| a.op_count().cmp(&b.op_count()).then(a.exponents.cmp(&b.exponents)));
    groups.insert(0, target_g);

    Ok(PiAnalysis {
        system: model.name.clone(),
        symbols: model.symbols.iter().map(|s| s.name.clone()).collect(),
        target: target_idx,
        groups,
        target_group: 0,
        rank,
        nonparticipating,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::newton::corpus;
    use crate::units::Dimension;

    fn analyze_entry(id: &str) -> PiAnalysis {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        analyze(&m, e.target).unwrap()
    }

    /// Every Π group must actually be dimensionless.
    fn assert_dimensionless(id: &str, a: &PiAnalysis) {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        for g in &a.groups {
            let mut d = Dimension::NONE;
            for (i, &exp) in g.exponents.iter().enumerate() {
                d = d * m.symbols[i].dimension.powi(exp);
            }
            assert!(d.is_dimensionless(), "{id}: Π {:?} has dimension {}", g.exponents, d);
        }
    }

    #[test]
    fn pendulum_single_group() {
        let a = analyze_entry("pendulum");
        assert_eq!(a.n(), 1);
        assert_dimensionless("pendulum", &a);
        // Mass cannot participate.
        assert_eq!(a.nonparticipating.len(), 1);
        assert_eq!(a.symbols[a.nonparticipating[0]], "bobmass");
        // Π = g t² / l (up to our canonical ordering): target exponent +2 or +1.
        let g = &a.groups[0];
        assert!(g.exponents[a.target] > 0);
    }

    #[test]
    fn beam_groups_target_isolated() {
        // Beam (δ, F, L, EI): M and T appear in fixed ratio across F and
        // EI, so the dimensional matrix has rank 2 and N = 4 - 2 = 2
        // groups (δ/L and F·L²/(EI) up to basis choice).
        let a = analyze_entry("beam");
        assert_eq!(a.n(), 2);
        assert_dimensionless("beam", &a);
        // deflection appears only in the target group.
        for (i, g) in a.groups.iter().enumerate() {
            if i != a.target_group {
                assert_eq!(g.exponents[a.target], 0, "target leaked into Π{}", i + 1);
            } else {
                assert!(g.exponents[a.target] > 0);
            }
        }
    }

    #[test]
    fn fluid_pipe_three_groups() {
        let a = analyze_entry("fluid_pipe");
        assert_eq!(a.n(), 3);
        assert_dimensionless("fluid_pipe", &a);
        // velocity isolated to one group.
        let v = a.target;
        let holders: Vec<_> = a.groups.iter().filter(|g| g.exponents[v] != 0).collect();
        assert_eq!(holders.len(), 1);
    }

    #[test]
    fn all_corpus_systems_analyze() {
        for e in corpus::corpus() {
            let m = corpus::load_entry(&e).unwrap();
            let a = analyze(&m, e.target).unwrap_or_else(|err| panic!("{}: {err}", e.id));
            assert!(a.n() >= 1);
            assert_dimensionless(e.id, &a);
            // Target isolation invariant.
            for (i, g) in a.groups.iter().enumerate() {
                if i == a.target_group {
                    assert!(g.exponents[a.target] > 0, "{}: target exponent not positive", e.id);
                } else {
                    assert_eq!(g.exponents[a.target], 0, "{}: target not isolated", e.id);
                }
            }
        }
    }

    #[test]
    fn unknown_target_errors() {
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        assert!(matches!(
            analyze(&m, "nonexistent"),
            Err(PiError::UnknownTarget { .. })
        ));
    }

    #[test]
    fn nonexpressible_target_errors() {
        // Pendulum's bob mass cannot form a dimensionless group.
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        assert!(matches!(
            analyze(&m, "bobmass"),
            Err(PiError::TargetNotExpressible { .. })
        ));
    }

    #[test]
    fn render_groups() {
        let a = analyze_entry("pendulum");
        let s = a.groups[0].render(&a.symbols);
        // Should mention period and length.
        assert!(s.contains("period"), "render: {s}");
        assert!(s.contains("length"), "render: {s}");
    }

    #[test]
    fn op_count() {
        let g = PiGroup { exponents: vec![2, -1, 0, 1] };
        assert_eq!(g.op_count(), 4);
        assert!(!g.is_trivial());
        assert!(PiGroup { exponents: vec![0, 0] }.is_trivial());
    }
}
