//! Buckingham Π-theorem engine: exact dimensional-matrix nullspace
//! computation and target-isolating basis change (paper Section 2.A).

pub mod groups;
pub mod matrix;
pub mod reduce;

pub use groups::{analyze, PiAnalysis, PiError, PiGroup};
pub use matrix::{integerize, RMatrix};
pub use reduce::{analyze_optimized, optimize, CostModel};
