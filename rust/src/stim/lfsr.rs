//! Pseudorandom stimulus generation.
//!
//! The paper drives the synthesized designs "with a pseudorandom signal
//! input stream" produced by an LFSR. [`Lfsr32`] is a 32-bit Fibonacci
//! LFSR with the maximal-length taps (32, 22, 2, 1); it is used for
//! power-analysis stimulus, simulation inputs, and as the repo-wide
//! deterministic PRNG (no external `rand` dependency).

/// 32-bit maximal-length Fibonacci LFSR (taps 32, 22, 2, 1).
#[derive(Clone, Debug)]
pub struct Lfsr32 {
    state: u32,
}

impl Lfsr32 {
    /// Create with a seed; a zero seed is remapped to a fixed nonzero
    /// value (the all-zero state is the LFSR's lock-up state).
    pub fn new(seed: u32) -> Lfsr32 {
        Lfsr32 { state: if seed == 0 { 0xACE1_u32 } else { seed } }
    }

    /// Advance one bit; returns the output bit.
    pub fn next_bit(&mut self) -> u32 {
        // taps: 32 22 2 1 (1-indexed from LSB side of the shift register)
        let s = self.state;
        let bit = (s ^ (s >> 10) ^ (s >> 30) ^ (s >> 31)) & 1;
        self.state = (s >> 1) | (bit << 31);
        bit
    }

    /// Advance 32 bits; returns the full register (fast path: one whole
    /// register refresh per call would be slow bit-by-bit, so we shift 32
    /// times — still cheap, and bit-compatible with the hardware LFSR).
    pub fn next_u32(&mut self) -> u32 {
        for _ in 0..32 {
            self.next_bit();
        }
        self.state
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Current register state.
    pub fn state(&self) -> u32 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn zero_seed_remapped() {
        let mut a = Lfsr32::new(0);
        assert_ne!(a.state(), 0);
        // Must not lock up.
        a.next_u32();
        assert_ne!(a.state(), 0);
    }

    #[test]
    fn deterministic() {
        let mut a = Lfsr32::new(42);
        let mut b = Lfsr32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Lfsr32::new(1);
        let mut b = Lfsr32::new(2);
        let same = (0..50).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn no_short_cycles() {
        // State must not repeat within a modest horizon.
        let mut l = Lfsr32::new(0xDEAD_BEEF);
        let mut seen = HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(l.next_u32()), "state repeated early");
        }
    }

    #[test]
    fn bits_roughly_balanced() {
        let mut l = Lfsr32::new(7);
        let ones: u32 = (0..10_000).map(|_| l.next_bit()).sum();
        assert!((4_000..6_000).contains(&ones), "ones = {ones}");
    }

    #[test]
    fn range_bounds() {
        let mut l = Lfsr32::new(3);
        for _ in 0..1_000 {
            let v = l.range(0.5, 8.0);
            assert!((0.5..8.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut l = Lfsr32::new(9);
        for _ in 0..1_000 {
            assert!(l.below(7) < 7);
        }
    }
}
