//! Pseudorandom stimulus generation.
//!
//! The paper drives the synthesized designs "with a pseudorandom signal
//! input stream" produced by an LFSR. [`Lfsr32`] is a 32-bit Fibonacci
//! LFSR with the maximal-length taps (32, 22, 2, 1); it is used for
//! power-analysis stimulus, simulation inputs, and as the repo-wide
//! deterministic PRNG (no external `rand` dependency).

use crate::synth::lane::{LaneWord, W256, W512};

/// 32-bit maximal-length Fibonacci LFSR (taps 32, 22, 2, 1).
#[derive(Clone, Debug)]
pub struct Lfsr32 {
    state: u32,
}

impl Lfsr32 {
    /// Create with a seed; a zero seed is remapped to a fixed nonzero
    /// value (the all-zero state is the LFSR's lock-up state).
    pub fn new(seed: u32) -> Lfsr32 {
        Lfsr32 { state: if seed == 0 { 0xACE1_u32 } else { seed } }
    }

    /// Advance one bit; returns the output bit.
    pub fn next_bit(&mut self) -> u32 {
        // taps: 32 22 2 1 (1-indexed from LSB side of the shift register)
        let s = self.state;
        let bit = (s ^ (s >> 10) ^ (s >> 30) ^ (s >> 31)) & 1;
        self.state = (s >> 1) | (bit << 31);
        bit
    }

    /// Advance 32 bits; returns the full register (fast path: one whole
    /// register refresh per call would be slow bit-by-bit, so we shift 32
    /// times — still cheap, and bit-compatible with the hardware LFSR).
    pub fn next_u32(&mut self) -> u32 {
        for _ in 0..32 {
            self.next_bit();
        }
        self.state
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Current register state.
    pub fn state(&self) -> u32 {
        self.state
    }
}

/// `W::LANES` independent [`Lfsr32`] streams advanced word-parallel, for
/// the bit-parallel gate-level simulator ([`crate::synth::WordSim`]).
/// [`LfsrBank64`] (64 lanes in a `u64`) and [`LfsrBank256`] (256 lanes
/// in a [`W256`]) are the two instantiations.
///
/// The lane registers are stored **bit-sliced**: `planes[k]` holds bit
/// *k* of every lane's shift register (bit *l* of the plane = lane *l*),
/// so one [`LfsrBank::next_bit_word`] computes the feedback of all lanes
/// with three XOR word ops and a plane rotation — the same transposition
/// the simulator uses for net values. Lane *l* of the bank is
/// bit-compatible with `Lfsr32::new(seeds[l])` for nonzero seeds
/// (tested); zero seeds are remapped to *distinct per-lane* states —
/// unlike `Lfsr32::new`'s single constant — so no two lanes can share a
/// stream.
#[derive(Clone, Debug)]
pub struct LfsrBank<W: LaneWord> {
    planes: [W; 32],
}

/// The original 64-lane bank (one `u64` per plane).
pub type LfsrBank64 = LfsrBank<u64>;

/// The 256-lane bank feeding the `WordSim<W256>` engine.
pub type LfsrBank256 = LfsrBank<W256>;

/// The 512-lane bank feeding the `WordSim<W512>` engine.
pub type LfsrBank512 = LfsrBank<W512>;

impl<W: LaneWord> LfsrBank<W> {
    /// The nonzero replacement state for a zero-seeded lane.
    ///
    /// Remapping every zero seed to one shared constant (as
    /// [`Lfsr32::new`] does for its single stream) would give two
    /// zero-seeded lanes *identical* streams, silently correlating the
    /// power samples they drive. Instead each lane gets a distinct
    /// value: bits 16..25 encode `lane + 1` (so the value is provably
    /// nonzero — the low bits keep the classic `0xACE1` pattern — and
    /// pairwise distinct across all lanes of the widest bank).
    fn zero_seed_replacement(lane: usize) -> u32 {
        0xACE1 ^ ((lane as u32 + 1) << 16)
    }

    /// Create from `W::LANES` explicit lane seeds. Zero seeds (the LFSR
    /// lock-up state) are remapped to distinct per-lane nonzero states,
    /// so no two lanes ever share a stream.
    pub fn from_seeds(seeds: &[u32]) -> LfsrBank<W> {
        assert_eq!(seeds.len(), W::LANES, "expected one seed per lane");
        let mut planes = [W::zero(); 32];
        for (lane, &seed) in seeds.iter().enumerate() {
            let s = if seed == 0 { Self::zero_seed_replacement(lane) } else { seed };
            for (k, plane) in planes.iter_mut().enumerate() {
                plane.set_lane(lane, s >> k & 1 == 1);
            }
        }
        LfsrBank { planes }
    }

    /// Create with `W::LANES` distinct lane seeds derived from one
    /// master seed.
    pub fn new(seed: u32) -> LfsrBank<W> {
        LfsrBank::from_seeds(&Self::lane_seeds(seed))
    }

    /// The per-lane seeds [`LfsrBank::new`] derives from a master seed
    /// (all nonzero: an LFSR state stream never visits zero). Useful for
    /// constructing bit-compatible scalar references. The first 64 seeds
    /// of a 256-lane bank equal a 64-lane bank's seeds for the same
    /// master, so narrow runs are a lane-prefix of wide ones.
    pub fn lane_seeds(seed: u32) -> Vec<u32> {
        let mut gen = Lfsr32::new(seed);
        (0..W::LANES).map(|_| gen.next_u32()).collect()
    }

    /// Advance every lane one bit; returns the output bits as a lane
    /// word (bit *l* = lane *l*).
    pub fn next_bit_word(&mut self) -> W {
        // Same taps as Lfsr32::next_bit, evaluated across all lanes at
        // once: bit = s0 ^ s10 ^ s30 ^ s31.
        let bits = self.planes[0] ^ self.planes[10] ^ self.planes[30] ^ self.planes[31];
        self.planes.copy_within(1.., 0);
        self.planes[31] = bits;
        bits
    }

    /// Current register state of one lane (for tests and checkpointing).
    pub fn lane_state(&self, lane: usize) -> u32 {
        assert!(lane < W::LANES, "lane out of range");
        let mut s = 0u32;
        for (k, plane) in self.planes.iter().enumerate() {
            s |= u32::from(plane.lane(lane)) << k;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn zero_seed_remapped() {
        let mut a = Lfsr32::new(0);
        assert_ne!(a.state(), 0);
        // Must not lock up.
        a.next_u32();
        assert_ne!(a.state(), 0);
    }

    #[test]
    fn deterministic() {
        let mut a = Lfsr32::new(42);
        let mut b = Lfsr32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Lfsr32::new(1);
        let mut b = Lfsr32::new(2);
        let same = (0..50).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn no_short_cycles() {
        // State must not repeat within a modest horizon.
        let mut l = Lfsr32::new(0xDEAD_BEEF);
        let mut seen = HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(l.next_u32()), "state repeated early");
        }
    }

    #[test]
    fn bits_roughly_balanced() {
        let mut l = Lfsr32::new(7);
        let ones: u32 = (0..10_000).map(|_| l.next_bit()).sum();
        assert!((4_000..6_000).contains(&ones), "ones = {ones}");
    }

    #[test]
    fn range_bounds() {
        let mut l = Lfsr32::new(3);
        for _ in 0..1_000 {
            let v = l.range(0.5, 8.0);
            assert!((0.5..8.0).contains(&v));
        }
    }

    #[test]
    fn bank_matches_scalar_lanes() {
        let seeds = LfsrBank64::lane_seeds(0xBEEF);
        let mut bank = LfsrBank64::from_seeds(&seeds);
        let mut scalars: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
        for step in 0..2_000 {
            let w = bank.next_bit_word();
            for (lane, s) in scalars.iter_mut().enumerate() {
                assert_eq!(w >> lane & 1, u64::from(s.next_bit()), "step {step} lane {lane}");
            }
        }
        for (lane, s) in scalars.iter().enumerate() {
            assert_eq!(bank.lane_state(lane), s.state(), "lane {lane}");
        }
    }

    #[test]
    fn bank_zero_seed_remapped() {
        let mut seeds = [7u32; 64];
        seeds[5] = 0;
        let mut bank = LfsrBank64::from_seeds(&seeds);
        assert_ne!(bank.lane_state(5), 0);
        // Must not lock up.
        for _ in 0..64 {
            bank.next_bit_word();
        }
        assert_ne!(bank.lane_state(5), 0);
    }

    #[test]
    fn bank_zero_seeds_get_distinct_lanes() {
        // Two zero-seeded lanes used to both remap to 0xACE1, silently
        // producing identical stimulus streams.
        let mut seeds = [7u32; 64];
        seeds[3] = 0;
        seeds[5] = 0;
        let mut bank = LfsrBank64::from_seeds(&seeds);
        assert_ne!(bank.lane_state(3), bank.lane_state(5), "zero lanes must not share a stream");
        // And the streams diverge, not just the initial states.
        let mut agree = 0u32;
        for _ in 0..512 {
            let w = bank.next_bit_word();
            if (w >> 3) & 1 == (w >> 5) & 1 {
                agree += 1;
            }
        }
        assert!(agree < 400, "lanes 3 and 5 correlated: {agree}/512 equal bits");
    }

    #[test]
    fn bank_all_zero_seeds_pairwise_distinct_and_nonzero() {
        let bank = LfsrBank64::from_seeds(&[0u32; 64]);
        let states: HashSet<u32> = (0..64).map(|l| bank.lane_state(l)).collect();
        assert_eq!(states.len(), 64, "zero-seed remapping collided lanes");
        assert!(!states.contains(&0), "a lane landed in the lock-up state");
    }

    #[test]
    fn bank_master_seed_lanes_pairwise_distinct() {
        // For any master seed, all 64 lane states must be pairwise
        // distinct and nonzero (an LFSR state stream never revisits a
        // state within its period and never visits zero).
        for seed in [0u32, 1, 42, 0xACE1, 0xDEAD_BEEF, u32::MAX] {
            let bank = LfsrBank64::new(seed);
            let states: HashSet<u32> = (0..64).map(|l| bank.lane_state(l)).collect();
            assert_eq!(states.len(), 64, "master seed {seed:#x} collided lanes");
            assert!(!states.contains(&0), "master seed {seed:#x} locked up a lane");
        }
    }

    #[test]
    fn bank_lane_seeds_distinct_and_nonzero() {
        let seeds = LfsrBank64::lane_seeds(42);
        let uniq: HashSet<u32> = seeds.iter().copied().collect();
        assert_eq!(uniq.len(), 64);
        assert!(seeds.iter().all(|&s| s != 0));
    }

    #[test]
    fn bank256_matches_scalar_lanes() {
        let seeds = LfsrBank256::lane_seeds(0xBEEF);
        let mut bank = LfsrBank256::from_seeds(&seeds);
        let mut scalars: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
        for step in 0..500 {
            let w = bank.next_bit_word();
            for (lane, s) in scalars.iter_mut().enumerate() {
                assert_eq!(w.lane(lane), s.next_bit() == 1, "step {step} lane {lane}");
            }
        }
        for (lane, s) in scalars.iter().enumerate() {
            assert_eq!(bank.lane_state(lane), s.state(), "lane {lane}");
        }
    }

    #[test]
    fn wide_bank_seeds_extend_narrow_bank() {
        // A 256-lane bank's first 64 seeds equal the 64-lane bank's for
        // the same master seed, so narrow runs are lane-prefixes of wide
        // ones (relied on by the cross-width differential tests).
        let narrow = LfsrBank64::lane_seeds(0x5EED);
        let wide = LfsrBank256::lane_seeds(0x5EED);
        assert_eq!(&wide[..64], &narrow[..]);
        let wider = LfsrBank512::lane_seeds(0x5EED);
        assert_eq!(&wider[..256], &wide[..]);
    }

    #[test]
    fn bank256_zero_seeds_pairwise_distinct_and_nonzero() {
        let bank = LfsrBank256::from_seeds(&[0u32; 256]);
        let states: HashSet<u32> = (0..256).map(|l| bank.lane_state(l)).collect();
        assert_eq!(states.len(), 256, "zero-seed remapping collided lanes");
        assert!(!states.contains(&0), "a lane landed in the lock-up state");
    }

    #[test]
    fn below_bounds() {
        let mut l = Lfsr32::new(9);
        for _ in 0..1_000 {
            assert!(l.below(7) < 7);
        }
    }
}
