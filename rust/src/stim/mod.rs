//! Stimulus generation: the paper's LFSR pseudorandom input stream plus
//! physics-based synthetic sensor traces (the substitute for the authors'
//! physical testbeds — DESIGN.md §2).

pub mod lfsr;
pub mod traces;

pub use lfsr::{Lfsr32, LfsrBank, LfsrBank256, LfsrBank512, LfsrBank64};
pub use traces::{sample, sample_noisy, samples, Sample, G};
