//! Physics-based synthetic sensor traces for the corpus systems.
//!
//! The paper's authors had physical testbeds; we substitute closed-form
//! physics plus measurement noise (DESIGN.md §2). Each generator draws the
//! free signals of a system uniformly from plausible physical ranges and
//! computes the dependent (target) signal from the governing equation, so
//! the traces exercise exactly the relationship the Φ model must learn.
//!
//! Signal order matches the corpus invariant parameter order
//! ([`mod@crate::newton::corpus`]), so a trace row can be fed directly to the
//! generated hardware / kernels after fixed-point quantization.

use super::lfsr::Lfsr32;

/// Standard gravity, matching the builtin Newton constant.
pub const G: f64 = 9.80665;

/// One sampled observation: signal values in corpus symbol order.
pub type Sample = Vec<f64>;

/// Generate one noiseless observation of system `id`. Returns `None` for
/// unknown ids.
pub fn sample(id: &str, rng: &mut Lfsr32) -> Option<Sample> {
    sample_noisy(id, rng, 0.0)
}

/// Generate one observation with multiplicative Gaussian-ish noise of
/// relative magnitude `noise` applied to the *measured* (target) signal —
/// modelling sensor error on the quantity the model must predict from the
/// others.
pub fn sample_noisy(id: &str, rng: &mut Lfsr32, noise: f64) -> Option<Sample> {
    // Approximate standard normal from 4 uniforms (Irwin–Hall, var=1/3 each).
    let mut normal = |rng: &mut Lfsr32| -> f64 {
        let s: f64 = (0..4).map(|_| rng.next_f64()).sum::<f64>();
        (s - 2.0) * (3.0f64).sqrt() / 2.0
    };
    let jitter = |v: f64, rng: &mut Lfsr32, normal: &mut dyn FnMut(&mut Lfsr32) -> f64| {
        v * (1.0 + noise * normal(rng))
    };

    let s = match id {
        // (period, length, bobmass, g); t = 2π √(l/g).
        "pendulum" => {
            let l = rng.range(0.1, 2.0);
            let m = rng.range(0.05, 1.0);
            let t = 2.0 * std::f64::consts::PI * (l / G).sqrt();
            vec![jitter(t, rng, &mut normal), l, m, G]
        }
        // (deflection, load, length, rigidity); δ = F L³ / (3 EI).
        "beam" => {
            // Ranges model one beam-monitoring design envelope: the
            // dimensionless load F·L²/EI spans ~2 decades. (Wider ranges
            // push both the Q16.15 resolution floor and tanh-feature
            // saturation — a real deployment of a fixed-point sensor
            // product would be specified for a bounded envelope too.)
            let f = rng.range(20.0, 100.0);
            let l = rng.range(0.8, 1.6);
            let ei = rng.range(20.0, 100.0);
            let d = f * l.powi(3) / (3.0 * ei);
            vec![jitter(d, rng, &mut normal), f, l, ei]
        }
        // (pressure_drop, rho, velocity, diameter, pipe_length, mu);
        // Darcy–Weisbach with a fixed friction factor f_D = 0.02:
        // Δp = f_D (L/D) ρ v² / 2.
        "fluid_pipe" => {
            let rho = rng.range(800.0, 1200.0);
            let v = rng.range(0.5, 5.0);
            let d = rng.range(0.05, 0.5);
            let l = rng.range(1.0, 10.0);
            let mu = rng.range(0.01, 0.5);
            let dp = 0.02 * (l / d) * rho * v * v / 2.0;
            vec![dp, rho, jitter(v, rng, &mut normal), d, l, mu]
        }
        // (height, airspeed, flight_t, payload, g); ballistic
        // h = v t − g t²/2, with t sampled inside the ascent arc.
        "unpowered_flight" => {
            let v = rng.range(5.0, 30.0);
            // Sample the ascent arc away from the apex: at the apex
            // h → 0 relative to v·t and the dimensionless ratio v·t/h
            // diverges, which no bounded-feature model can calibrate.
            let t = rng.range(0.1, 0.8) * (2.0 * v / G);
            let m = rng.range(0.1, 2.0);
            let h = (v * t - G * t * t / 2.0).max(0.01);
            vec![jitter(h, rng, &mut normal), v, t, m, G]
        }
        // (freq, tension, length, mu); f = (1/2l) √(F/μ).
        "vibrating_string" => {
            let ten = rng.range(10.0, 200.0);
            let l = rng.range(0.3, 1.5);
            let mu = rng.range(0.005, 0.05);
            let f = (ten / mu).sqrt() / (2.0 * l);
            vec![jitter(f, rng, &mut normal), ten, l, mu]
        }
        // (freq, tension, length, mu, temp, alpha); tension relaxes with
        // temperature: F_eff = F (1 − α ΔT), f = (1/2l) √(F_eff/μ).
        // α is exaggerated vs. steel so the α·ΔT product stays well above
        // the Q16.15 resolution (DESIGN.md §2 notes the substitution).
        "warm_vibrating_string" => {
            let ten = rng.range(10.0, 200.0);
            let l = rng.range(0.3, 1.5);
            let mu = rng.range(0.005, 0.05);
            let dt = rng.range(10.0, 100.0);
            let alpha = rng.range(0.001, 0.008);
            let f_eff = ten * (1.0 - alpha * dt).max(0.05);
            let f = (f_eff / mu).sqrt() / (2.0 * l);
            vec![jitter(f, rng, &mut normal), ten, l, mu, dt, alpha]
        }
        // (springk, bobmass, period, g); t = 2π √(m/k).
        "spring_mass" => {
            let k = rng.range(20.0, 500.0);
            let m = rng.range(0.1, 2.0);
            let t = 2.0 * std::f64::consts::PI * (m / k).sqrt();
            vec![jitter(k, rng, &mut normal), m, t, G]
        }
        _ => return None,
    };
    Some(s)
}

/// Generate `n` observations.
pub fn samples(id: &str, rng: &mut Lfsr32, n: usize, noise: f64) -> Option<Vec<Sample>> {
    (0..n).map(|_| sample_noisy(id, rng, noise)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::newton::corpus;

    #[test]
    fn arity_matches_corpus() {
        let mut rng = Lfsr32::new(1);
        for e in corpus::corpus() {
            let m = corpus::load_entry(&e).unwrap();
            let s = sample(e.id, &mut rng).unwrap();
            assert_eq!(s.len(), m.k(), "{}: arity mismatch", e.id);
        }
    }

    #[test]
    fn unknown_id_is_none() {
        let mut rng = Lfsr32::new(1);
        assert!(sample("no_such_system", &mut rng).is_none());
    }

    #[test]
    fn pendulum_pi_is_4pi2() {
        // Noiseless pendulum: g t² / l = 4π² exactly.
        let mut rng = Lfsr32::new(7);
        for _ in 0..50 {
            let s = sample("pendulum", &mut rng).unwrap();
            let (t, l, g) = (s[0], s[1], s[3]);
            let pi = g * t * t / l;
            assert!((pi - 4.0 * std::f64::consts::PI.powi(2)).abs() < 1e-9);
        }
    }

    #[test]
    fn beam_deflection_formula() {
        let mut rng = Lfsr32::new(9);
        for _ in 0..50 {
            let s = sample("beam", &mut rng).unwrap();
            let (d, f, l, ei) = (s[0], s[1], s[2], s[3]);
            assert!((d - f * l.powi(3) / (3.0 * ei)).abs() < 1e-9);
        }
    }

    #[test]
    fn values_fit_q16_15() {
        use crate::fixedpoint::Q16_15;
        let mut rng = Lfsr32::new(11);
        for e in corpus::corpus() {
            for _ in 0..100 {
                let s = sample(e.id, &mut rng).unwrap();
                for (i, v) in s.iter().enumerate() {
                    assert!(
                        *v < Q16_15.max_value() && *v > Q16_15.min_value(),
                        "{}: signal {i} = {v} out of Q16.15 range",
                        e.id
                    );
                    // Nonzero signals should be comfortably above resolution.
                    assert!(
                        v.abs() > 8.0 * Q16_15.epsilon(),
                        "{}: signal {i} = {v} below Q16.15 resolution",
                        e.id
                    );
                }
            }
        }
    }

    #[test]
    fn noise_perturbs_target_only_slightly() {
        let mut a = Lfsr32::new(21);
        let mut b = Lfsr32::new(21);
        let clean = sample_noisy("pendulum", &mut a, 0.0).unwrap();
        let noisy = sample_noisy("pendulum", &mut b, 0.01).unwrap();
        // Same free signals (same RNG stream consumed in same order for
        // l, m; the jitter consumes extra draws after the target compute).
        assert_eq!(clean[1], noisy[1]);
        let rel = (clean[0] - noisy[0]).abs() / clean[0];
        assert!(rel < 0.2, "noise too large: {rel}");
    }

    #[test]
    fn flight_height_nonnegative() {
        let mut rng = Lfsr32::new(31);
        for _ in 0..200 {
            let s = sample("unpowered_flight", &mut rng).unwrap();
            assert!(s[0] > 0.0);
        }
    }
}
