//! RTL backend: Π-module IR, latency scheduling, Verilog emission, and
//! cycle-accurate simulation (paper Sections 2.A and 3).

pub mod ir;
pub mod sched;
pub mod sim;
pub mod testbench;
pub mod verilog;

pub use ir::{build, PiModuleDesign, PiUnit, Port};
pub use sched::{max_sample_rate, module_latency, OpLatency, Policy};
pub use sim::{run_batch, run_cycle_accurate, run_once, run_stream, BatchResult, RtlSim, SimResult};
pub use testbench::{emit_testbench, golden_vectors, GoldenVector};
