//! Self-checking Verilog testbench generation.
//!
//! For each generated module we emit a testbench that drives LFSR-derived
//! stimulus and compares the DUT's Π outputs against golden vectors
//! computed by the bit-exact software model — so the emitted RTL can be
//! verified with any external simulator (iverilog/verilator) outside this
//! repo, closing the loop a real hardware release needs.

use super::ir::PiModuleDesign;
use super::sched::{module_latency, Policy};
use super::sim;
use crate::stim::Lfsr32;
use std::fmt::Write as _;

/// One stimulus/response pair.
#[derive(Clone, Debug)]
pub struct GoldenVector {
    pub inputs: Vec<i64>,
    pub outputs: Vec<i64>,
    pub cycles: u64,
}

/// Generate `n` golden vectors with LFSR stimulus over a safe operand
/// range (plus the all-ones identity vector first).
pub fn golden_vectors(design: &PiModuleDesign, n: usize, seed: u32) -> Vec<GoldenVector> {
    let q = design.q;
    let mut rng = Lfsr32::new(seed);
    let mut out = Vec::with_capacity(n + 1);
    let ones = vec![q.one(); design.num_inputs()];
    let r = sim::run_once(design, &ones);
    out.push(GoldenVector { inputs: ones, outputs: r.outputs, cycles: r.cycles });
    for _ in 0..n {
        let inputs: Vec<i64> =
            (0..design.num_inputs()).map(|_| q.from_f64(rng.range(0.25, 8.0))).collect();
        let r = sim::run_once(design, &inputs);
        out.push(GoldenVector { inputs, outputs: r.outputs, cycles: r.cycles });
    }
    out
}

/// Emit a self-checking Verilog testbench for the design.
pub fn emit_testbench(design: &PiModuleDesign, vectors: &[GoldenVector]) -> String {
    let w = design.q.width();
    let latency = module_latency(design, Policy::ParallelPerPi);
    let mut v = String::new();
    let _ = writeln!(
        v,
        "// Self-checking testbench for {} — golden vectors from the\n\
         // bit-exact dimsynth software model. Expected latency: {} cycles.\n\
         `timescale 1ns/1ps\nmodule {}_tb;",
        design.name, latency, design.name
    );
    let _ = writeln!(v, "    reg clk = 0, rst = 1, start = 0;");
    for p in &design.ports {
        let _ = writeln!(v, "    reg  signed [{}:0] in_{};", w - 1, p.name);
    }
    for u in 0..design.units.len() {
        let _ = writeln!(v, "    wire signed [{}:0] pi_{u};", w - 1);
    }
    let _ = writeln!(v, "    wire done;");
    let _ = writeln!(v, "    integer errors = 0;");
    let _ = writeln!(v, "    {} dut (", design.name);
    let _ = writeln!(v, "        .clk(clk), .rst(rst), .start(start),");
    for p in &design.ports {
        let _ = writeln!(v, "        .in_{n}(in_{n}),", n = p.name);
    }
    for u in 0..design.units.len() {
        let _ = writeln!(v, "        .pi_{u}(pi_{u}),");
    }
    let _ = writeln!(v, "        .done(done)\n    );");
    let _ = writeln!(v, "    always #5 clk = ~clk;");
    let _ = writeln!(v, "    task run_vector;");
    let _ = writeln!(v, "        begin");
    let _ = writeln!(v, "            @(negedge clk); start = 1;");
    let _ = writeln!(v, "            @(negedge clk); start = 0;");
    let _ = writeln!(v, "            wait (done); @(negedge clk);");
    let _ = writeln!(v, "        end");
    let _ = writeln!(v, "    endtask");
    let _ = writeln!(v, "    initial begin");
    let _ = writeln!(v, "        repeat (2) @(negedge clk); rst = 0;");
    for (vi, gv) in vectors.iter().enumerate() {
        for (p, val) in design.ports.iter().zip(&gv.inputs) {
            let _ = writeln!(
                v,
                "        in_{} = {}'sd{};",
                p.name,
                w,
                if *val < 0 { format!("0 - {w}'sd{}", -val) } else { val.to_string() }
            );
        }
        let _ = writeln!(v, "        run_vector;");
        for (u, out) in gv.outputs.iter().enumerate() {
            let expect = if *out < 0 {
                format!("-{w}'sd{}", -out)
            } else {
                format!("{w}'sd{out}")
            };
            let _ = writeln!(
                v,
                "        if (pi_{u} !== {expect}) begin errors = errors + 1; \
                 $display(\"FAIL v{vi} pi_{u}: got %0d want {out}\", pi_{u}); end"
            );
        }
    }
    let _ = writeln!(
        v,
        "        if (errors == 0) $display(\"PASS: {} vectors\");",
        vectors.len()
    );
    let _ = writeln!(v, "        $finish;");
    let _ = writeln!(v, "    end");
    let _ = writeln!(v, "endmodule");
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::{by_id, corpus, load_entry};
    use crate::pisearch::analyze_optimized;
    use crate::rtl;

    fn design(id: &str) -> PiModuleDesign {
        let e = by_id(id).unwrap();
        let m = load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        rtl::build(&a, Q16_15)
    }

    #[test]
    fn golden_vectors_match_sim() {
        let d = design("pendulum");
        let gv = golden_vectors(&d, 8, 0x60D);
        assert_eq!(gv.len(), 9);
        // First vector is the all-ones identity.
        assert!(gv[0].outputs.iter().all(|&o| o == Q16_15.one()));
        for g in &gv {
            let r = sim::run_once(&d, &g.inputs);
            assert_eq!(r.outputs, g.outputs);
            assert_eq!(r.cycles, g.cycles);
        }
    }

    #[test]
    fn testbench_structure() {
        let d = design("beam");
        let gv = golden_vectors(&d, 4, 1);
        let tb = emit_testbench(&d, &gv);
        assert!(tb.contains("module pi_compute_beam_tb;"));
        assert!(tb.contains("pi_compute_beam dut ("));
        assert!(tb.contains("run_vector;"));
        // One check per vector per unit.
        assert_eq!(tb.matches("!==").count(), gv.len() * d.units.len());
        assert!(tb.contains("$finish"));
        assert_eq!(tb.matches("endmodule").count(), 1);
    }

    #[test]
    fn testbenches_for_whole_corpus() {
        for e in corpus() {
            let d = design(e.id);
            let tb = emit_testbench(&d, &golden_vectors(&d, 2, 7));
            assert!(tb.contains(&format!("module {}_tb;", d.name)), "{}", e.id);
        }
    }
}
