//! RTL intermediate representation for dimensional circuit synthesis.
//!
//! A [`PiModuleDesign`] is the backend's description of one generated
//! hardware module (paper Fig. 3): `k'` signal input ports (participating
//! symbols only), one parallel datapath unit per Π product, each unit a
//! microprogrammed FSM driving one sequential multiplier and one
//! sequential divider, and a `done` handshake when all units finish.
//!
//! The same IR feeds four consumers: the Verilog emitter
//! ([`super::verilog`]), the cycle-accurate simulator ([`super::sim`]),
//! the analytic scheduler ([`super::sched`]), and the gate-level lowering
//! ([`mod@crate::synth::lower`]).

use crate::fixedpoint::{monomial_ops, MonOp, QFormat};
use crate::pisearch::PiAnalysis;

/// One input port of the generated module.
#[derive(Clone, Debug)]
pub struct Port {
    /// Port name (sanitized symbol name).
    pub name: String,
    /// Index of the symbol in the originating `SystemModel`.
    pub symbol_index: usize,
}

/// One Π datapath unit: a serial microprogram over the module's ports.
#[derive(Clone, Debug)]
pub struct PiUnit {
    /// Unit name (`pi_0`, `pi_1`, ...).
    pub name: String,
    /// Exponents over the module's *ports* (not the original symbols).
    pub exponents: Vec<i64>,
    /// Canonical serial schedule (op indices refer to ports).
    pub ops: Vec<MonOp>,
    /// Human-readable monomial, for reports and Verilog comments.
    pub expr: String,
}

/// A complete generated module.
#[derive(Clone, Debug)]
pub struct PiModuleDesign {
    /// Module name (`pi_compute_<system>`).
    pub name: String,
    /// System identifier it was generated from.
    pub system: String,
    /// Fixed-point format of all ports and datapaths.
    pub q: QFormat,
    /// Signal input ports, in order.
    pub ports: Vec<Port>,
    /// Parallel Π units, target group first.
    pub units: Vec<PiUnit>,
    /// Index of the unit computing the target group.
    pub target_unit: usize,
    /// Names of symbols that did not participate (reported, not ported).
    pub dropped_symbols: Vec<String>,
}

impl PiModuleDesign {
    /// Number of signal inputs.
    pub fn num_inputs(&self) -> usize {
        self.ports.len()
    }

    /// Number of Π outputs.
    pub fn num_outputs(&self) -> usize {
        self.units.len()
    }

    /// Map a full symbol-value vector (one entry per system symbol) to the
    /// module's port order.
    pub fn select_inputs(&self, symbol_values: &[i64]) -> Vec<i64> {
        self.ports.iter().map(|p| symbol_values[p.symbol_index]).collect()
    }
}

/// Sanitize a symbol name into a Verilog-safe identifier.
pub fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if s.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        s.insert(0, '_');
    }
    s
}

/// Build the RTL design for an analyzed system.
///
/// Non-participating symbols are dropped from the port list (they cannot
/// influence any dimensionless product); exponent vectors are re-indexed
/// to port positions.
pub fn build(analysis: &PiAnalysis, q: QFormat) -> PiModuleDesign {
    let participating = analysis.participating();
    let ports: Vec<Port> = participating
        .iter()
        .map(|&i| Port { name: sanitize(&analysis.symbols[i]), symbol_index: i })
        .collect();
    // symbol index -> port index
    let mut port_of = vec![usize::MAX; analysis.symbols.len()];
    for (pi, &si) in participating.iter().enumerate() {
        port_of[si] = pi;
    }

    let units: Vec<PiUnit> = analysis
        .groups
        .iter()
        .enumerate()
        .map(|(gi, g)| {
            let mut exps = vec![0i64; ports.len()];
            for (si, &e) in g.exponents.iter().enumerate() {
                if e != 0 {
                    exps[port_of[si]] = e;
                }
            }
            PiUnit {
                name: format!("pi_{gi}"),
                ops: monomial_ops(&exps),
                expr: g.render(&analysis.symbols),
                exponents: exps,
            }
        })
        .collect();

    PiModuleDesign {
        name: format!("pi_compute_{}", sanitize(&analysis.system)),
        system: analysis.system.clone(),
        q,
        ports,
        units,
        target_unit: analysis.target_group,
        dropped_symbols: analysis
            .nonparticipating
            .iter()
            .map(|&i| analysis.symbols[i].clone())
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;

    fn design(id: &str) -> PiModuleDesign {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        build(&a, Q16_15)
    }

    #[test]
    fn pendulum_design_shape() {
        let d = design("pendulum");
        // bobmass dropped: 3 ports, 1 unit.
        assert_eq!(d.num_inputs(), 3);
        assert_eq!(d.num_outputs(), 1);
        assert_eq!(d.dropped_symbols, vec!["bobmass".to_string()]);
        assert_eq!(d.name, "pi_compute_pendulum");
    }

    #[test]
    fn all_corpus_designs_build() {
        for e in corpus::corpus() {
            let d = design(e.id);
            assert!(d.num_inputs() >= 2, "{}", e.id);
            assert!(d.num_outputs() >= 1, "{}", e.id);
            for u in &d.units {
                assert!(!u.ops.is_empty());
                assert_eq!(u.exponents.len(), d.num_inputs());
            }
            assert!(d.target_unit < d.num_outputs());
        }
    }

    #[test]
    fn select_inputs_reorders() {
        let d = design("pendulum");
        // Symbol order: period, length, bobmass, g. Ports skip bobmass.
        let vals = vec![10, 20, 30, 40];
        let sel = d.select_inputs(&vals);
        assert_eq!(sel.len(), 3);
        assert!(!sel.contains(&30));
    }

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize("abc"), "abc");
        assert_eq!(sanitize("a-b c"), "a_b_c");
        assert_eq!(sanitize("2fast"), "_2fast");
        assert_eq!(sanitize(""), "_");
    }
}
