//! Cycle-accurate simulation of generated Π-compute modules.
//!
//! The simulator interprets the module's FSM at clock-cycle granularity:
//! every Π unit steps its microprogram, each micro-op occupying exactly
//! the number of cycles the sequential functional unit needs
//! ([`super::sched::OpLatency`]), with the datapath result computed by the
//! bit-exact software model ([`crate::fixedpoint`]). Two invariants are
//! enforced by tests:
//!
//! * **cycle fidelity** — the observed cycle count equals the analytic
//!   schedule of [`super::sched::module_latency`];
//! * **bit fidelity** — outputs equal `fixedpoint::eval_monomial` exactly.
//!
//! This simulator stands in for RTL simulation of the emitted Verilog
//! (the paper simulated its modules with LFSR stimulus to obtain the
//! Table-1 latency column).

use super::ir::PiModuleDesign;
use super::sched::OpLatency;
use crate::fixedpoint::{self, MonOp};

/// Result of simulating one activation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimResult {
    /// One Π value per unit, in unit order.
    pub outputs: Vec<i64>,
    /// Cycles from `start` assertion to `done` assertion.
    pub cycles: u64,
}

/// Per-unit FSM state.
#[derive(Clone, Debug)]
enum UnitState {
    /// Executing op `pc`; `remaining` cycles left for it.
    Busy { pc: usize, remaining: u64 },
    /// Microprogram complete; accumulator holds the Π value.
    Done,
}

/// A simulation instance bound to a design.
pub struct RtlSim<'d> {
    design: &'d PiModuleDesign,
    lat: OpLatency,
    /// Accumulator register per unit.
    acc: Vec<i64>,
    state: Vec<UnitState>,
    /// Input operand registers (captured at start).
    inputs: Vec<i64>,
    /// Epilogue countdown once all units are done.
    epilogue_left: u64,
    cycles: u64,
    done: bool,
}

impl<'d> RtlSim<'d> {
    /// Capture inputs (port order) and assert `start`.
    pub fn start(design: &'d PiModuleDesign, inputs: &[i64]) -> RtlSim<'d> {
        assert_eq!(
            inputs.len(),
            design.num_inputs(),
            "input vector must match port count"
        );
        let lat = OpLatency::for_format(design.q);
        let state = design
            .units
            .iter()
            .map(|u| UnitState::Busy { pc: 0, remaining: lat.of(&u.ops[0]) })
            .collect();
        RtlSim {
            design,
            lat,
            acc: vec![0; design.units.len()],
            state,
            inputs: inputs.to_vec(),
            epilogue_left: lat.epilogue,
            cycles: 0,
            done: false,
        }
    }

    /// Advance one clock cycle. Returns `true` when `done` asserts.
    pub fn tick(&mut self) -> bool {
        if self.done {
            return true;
        }
        self.cycles += 1;

        // Epilogue runs on the cycles *after* the last unit finishes
        // (result capture then done flip-flop).
        if self.state.iter().all(|s| matches!(s, UnitState::Done)) {
            self.epilogue_left -= 1;
            if self.epilogue_left == 0 {
                self.done = true;
            }
            return self.done;
        }

        let mut all_done = true;
        for (ui, unit) in self.design.units.iter().enumerate() {
            match &mut self.state[ui] {
                UnitState::Done => {}
                UnitState::Busy { pc, remaining } => {
                    *remaining -= 1;
                    if *remaining == 0 {
                        // Op completes this cycle: commit the datapath result.
                        let q = self.design.q;
                        let op = &unit.ops[*pc];
                        self.acc[ui] = match op {
                            MonOp::Load(i) => self.inputs[*i],
                            MonOp::LoadOne => q.one(),
                            MonOp::Mul(i) => fixedpoint::mul(q, self.acc[ui], self.inputs[*i]),
                            MonOp::Div(i) => fixedpoint::div(q, self.acc[ui], self.inputs[*i]),
                        };
                        let next = *pc + 1;
                        if next < unit.ops.len() {
                            self.state[ui] = UnitState::Busy {
                                pc: next,
                                remaining: self.lat.of(&unit.ops[next]),
                            };
                            all_done = false;
                        } else {
                            self.state[ui] = UnitState::Done;
                        }
                    } else {
                        all_done = false;
                    }
                }
            }
        }

        let _ = all_done;
        self.done
    }

    /// Run until `done`; panics after a safety bound (malformed design).
    pub fn run(mut self) -> SimResult {
        let bound = 10_000u64
            + self.design.units.iter().map(|u| u.ops.len() as u64 * 64).sum::<u64>();
        while !self.tick() {
            assert!(self.cycles < bound, "simulation did not converge");
        }
        SimResult { outputs: self.acc, cycles: self.cycles }
    }
}

/// Simulate one activation of `design` on `inputs` (port order).
///
/// §Perf: this is the serving hot path, so it *jumps* over the cycles an
/// op occupies instead of ticking them — the FSM schedule is
/// deterministic, so the outputs and cycle count are identical to the
/// tick-by-tick interpreter ([`run_cycle_accurate`]; equality is asserted
/// by tests for the whole corpus).
pub fn run_once(design: &PiModuleDesign, inputs: &[i64]) -> SimResult {
    assert_eq!(
        inputs.len(),
        design.num_inputs(),
        "input vector must match port count"
    );
    let lat = OpLatency::for_format(design.q);
    let q = design.q;
    let mut cycles = 0u64;
    let outputs = design
        .units
        .iter()
        .map(|u| {
            let mut acc = 0i64;
            let mut c = 0u64;
            for op in &u.ops {
                c += lat.of(op);
                acc = match op {
                    MonOp::Load(i) => inputs[*i],
                    MonOp::LoadOne => q.one(),
                    MonOp::Mul(i) => fixedpoint::mul(q, acc, inputs[*i]),
                    MonOp::Div(i) => fixedpoint::div(q, acc, inputs[*i]),
                };
            }
            cycles = cycles.max(c);
            acc
        })
        .collect();
    SimResult { outputs, cycles: cycles + lat.epilogue }
}

/// Tick-by-tick interpretation of the module FSM (one call to
/// [`RtlSim::tick`] per clock). Reference semantics for [`run_once`].
pub fn run_cycle_accurate(design: &PiModuleDesign, inputs: &[i64]) -> SimResult {
    RtlSim::start(design, inputs).run()
}

/// Result of simulating one batch of activations ([`run_batch`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchResult {
    /// Per-sample Π outputs, in submission order.
    pub outputs: Vec<Vec<i64>>,
    /// Cycles per activation (the corpus FSMs have data-independent
    /// latency, validated here).
    pub cycles_per_sample: u64,
    /// Total hardware cycles for the batch, back-to-back.
    pub total_cycles: u64,
}

/// Batched entry point: simulate up to a whole serving batch of samples
/// (each a port-order input vector) through the module, asserting the
/// schedule's data-independent latency so callers can account cycles
/// per-sample without per-sample bookkeeping. This is the RTL-sim
/// counterpart of the lane-wide power dispatch in
/// [`crate::coordinator::Pipeline`]; unlike the gate-level engine it
/// has no SIMD lane word — batching here is a plain loop, so it is
/// width-agnostic by construction.
pub fn run_batch(design: &PiModuleDesign, samples: &[impl AsRef<[i64]>]) -> BatchResult {
    let mut outputs = Vec::with_capacity(samples.len());
    let mut per_sample = 0u64;
    for s in samples {
        let r = run_once(design, s.as_ref());
        if per_sample == 0 {
            per_sample = r.cycles;
        } else {
            assert_eq!(
                per_sample, r.cycles,
                "data-dependent latency in a fixed-schedule module"
            );
        }
        outputs.push(r.outputs);
    }
    BatchResult {
        outputs,
        cycles_per_sample: per_sample,
        total_cycles: per_sample * samples.len() as u64,
    }
}

/// Simulate a stream of samples back-to-back (no pipelining: the next
/// sample starts the cycle after `done`). Returns per-sample outputs and
/// the total cycle count.
pub fn run_stream(design: &PiModuleDesign, samples: &[Vec<i64>]) -> (Vec<Vec<i64>>, u64) {
    let mut outputs = Vec::with_capacity(samples.len());
    let mut total = 0u64;
    for s in samples {
        let r = run_once(design, s);
        total += r.cycles;
        outputs.push(r.outputs);
    }
    (outputs, total)
}

/// Reference output for an activation: evaluate every unit's monomial with
/// the bit-exact software model.
pub fn reference_outputs(design: &PiModuleDesign, inputs: &[i64]) -> Vec<i64> {
    design
        .units
        .iter()
        .map(|u| fixedpoint::eval_monomial(design.q, inputs, &u.exponents))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::rtl::sched::{module_latency, Policy};
    use crate::stim::Lfsr32;

    fn design(id: &str) -> PiModuleDesign {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        ir::build(&a, Q16_15)
    }

    /// Draw a "safe" pseudorandom operand in [0.25, 8): avoids saturation
    /// so outputs stay informative.
    fn rand_operand(lfsr: &mut Lfsr32) -> i64 {
        let u = lfsr.next_u32();
        Q16_15.from_f64(0.25 + (u >> 20) as f64 / 4096.0 * 7.75)
    }

    #[test]
    fn fast_path_equals_tick_interpreter() {
        let mut lfsr = Lfsr32::new(0xFA57);
        for e in corpus::corpus() {
            let d = design(e.id);
            for _ in 0..20 {
                let inputs: Vec<i64> = (0..d.num_inputs())
                    .map(|_| {
                        if lfsr.below(10) == 0 {
                            0
                        } else {
                            Q16_15.from_f64(lfsr.range(-64.0, 64.0))
                        }
                    })
                    .collect();
                let fast = run_once(&d, &inputs);
                let slow = run_cycle_accurate(&d, &inputs);
                assert_eq!(fast, slow, "{}: fast/tick divergence on {inputs:?}", e.id);
            }
        }
    }

    #[test]
    fn sim_cycles_match_analytic_schedule() {
        for e in corpus::corpus() {
            let d = design(e.id);
            let inputs: Vec<i64> = vec![Q16_15.one(); d.num_inputs()];
            let r = run_once(&d, &inputs);
            assert_eq!(
                r.cycles,
                module_latency(&d, Policy::ParallelPerPi),
                "{}: sim vs schedule mismatch",
                e.id
            );
        }
    }

    #[test]
    fn sim_outputs_bit_exact_vs_software_model() {
        let mut lfsr = Lfsr32::new(0xACE1_u32 as u32);
        for e in corpus::corpus() {
            let d = design(e.id);
            for _ in 0..50 {
                let inputs: Vec<i64> =
                    (0..d.num_inputs()).map(|_| rand_operand(&mut lfsr)).collect();
                let r = run_once(&d, &inputs);
                assert_eq!(
                    r.outputs,
                    reference_outputs(&d, &inputs),
                    "{}: sim output mismatch for {:?}",
                    e.id,
                    inputs
                );
            }
        }
    }

    #[test]
    fn all_ones_inputs_give_unity_pis() {
        // Every Π of all-1.0 signals is exactly 1.0 (mul/div by one are
        // exact in the fixed-point model).
        for e in corpus::corpus() {
            let d = design(e.id);
            let inputs = vec![Q16_15.one(); d.num_inputs()];
            let r = run_once(&d, &inputs);
            for (ui, &o) in r.outputs.iter().enumerate() {
                assert_eq!(o, Q16_15.one(), "{}: unit {} not unity", e.id, ui);
            }
        }
    }

    #[test]
    fn batch_matches_run_once() {
        let d = design("pendulum");
        let mut lfsr = Lfsr32::new(0xBA7C);
        let samples: Vec<Vec<i64>> = (0..9)
            .map(|_| (0..d.num_inputs()).map(|_| rand_operand(&mut lfsr)).collect())
            .collect();
        let batch = run_batch(&d, &samples);
        assert_eq!(batch.outputs.len(), 9);
        assert_eq!(batch.cycles_per_sample, module_latency(&d, Policy::ParallelPerPi));
        assert_eq!(batch.total_cycles, 9 * batch.cycles_per_sample);
        for (s, out) in samples.iter().zip(&batch.outputs) {
            assert_eq!(out, &run_once(&d, s).outputs);
        }
    }

    #[test]
    fn empty_batch_is_zero_cycles() {
        let d = design("pendulum");
        let batch = run_batch(&d, &Vec::<Vec<i64>>::new());
        assert!(batch.outputs.is_empty());
        assert_eq!(batch.total_cycles, 0);
    }

    #[test]
    fn stream_totals_accumulate() {
        let d = design("pendulum");
        let samples: Vec<Vec<i64>> = (1..=4)
            .map(|i| vec![Q16_15.from_f64(i as f64); d.num_inputs()])
            .collect();
        let (outs, total) = run_stream(&d, &samples);
        assert_eq!(outs.len(), 4);
        assert_eq!(total, 4 * module_latency(&d, Policy::ParallelPerPi));
    }

    #[test]
    #[should_panic]
    fn wrong_input_arity_panics() {
        let d = design("pendulum");
        let _ = run_once(&d, &[0]);
    }

    #[test]
    fn division_by_zero_saturates_in_sim() {
        let d = design("pendulum");
        // Zero in every port: whichever port is divided by zero forces
        // saturation; acc ends at an extremum, never panics.
        let inputs = vec![0i64; d.num_inputs()];
        let r = run_once(&d, &inputs);
        assert_eq!(r.outputs.len(), 1);
    }
}
