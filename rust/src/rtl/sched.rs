//! Analytic latency model of the generated RTL (paper Table 1, "Execution
//! Latency" column).
//!
//! In each generated module "the calculation of different Π products is
//! parallelized but the required operations per Π product are executed
//! serially" (paper §3.A). The functional-unit latencies follow from the
//! sequential datapath structure:
//!
//! * load: 1 cycle (operand register capture),
//! * multiply: `width + 1` cycles (shift-add over `width` partial
//!   products, plus the rounding/saturation cycle),
//! * divide: `width + frac` cycles (restoring division producing the
//!   `width + frac`-bit pre-truncation quotient of `(|a| << frac) / |b|`),
//! * epilogue: 1 cycle (result capture / done assertion).
//!
//! For the paper's Q16.15 this gives mul = 33, div = 47 — e.g. the static
//! pendulum's single group `g·t²/l` costs 1 + 33 + 33 + 47 + 1 = 115
//! cycles, exactly the paper's figure.

use super::ir::{PiModuleDesign, PiUnit};
use crate::fixedpoint::{MonOp, QFormat};

/// Cycle costs of the sequential functional units for a given format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpLatency {
    pub load: u64,
    pub mul: u64,
    pub div: u64,
    /// Final result-capture / done cycle per module activation.
    pub epilogue: u64,
}

impl OpLatency {
    /// Latencies implied by the datapath structure for format `q`.
    pub fn for_format(q: QFormat) -> OpLatency {
        OpLatency {
            load: 1,
            mul: q.width() as u64 + 1,
            div: (q.width() + q.frac_bits) as u64,
            epilogue: 1,
        }
    }

    pub fn of(&self, op: &MonOp) -> u64 {
        match op {
            MonOp::Load(_) | MonOp::LoadOne => self.load,
            MonOp::Mul(_) => self.mul,
            MonOp::Div(_) => self.div,
        }
    }
}

/// Scheduling policy — the paper's design plus ablation alternatives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// One datapath per Π, all running in parallel; ops within a Π serial.
    /// This is the paper's design. Latency = max over units.
    ParallelPerPi,
    /// A single shared datapath executes every Π in sequence.
    /// Latency = sum over units. Smallest area, worst latency.
    FullySerial,
}

/// Latency of one Π unit's serial schedule (excluding module epilogue).
pub fn unit_latency(unit: &PiUnit, lat: &OpLatency) -> u64 {
    unit.ops.iter().map(|op| lat.of(op)).sum()
}

/// Total module latency in cycles under a policy.
pub fn module_latency(design: &PiModuleDesign, policy: Policy) -> u64 {
    let lat = OpLatency::for_format(design.q);
    let per_unit: Vec<u64> = design.units.iter().map(|u| unit_latency(u, &lat)).collect();
    let body = match policy {
        Policy::ParallelPerPi => per_unit.iter().copied().max().unwrap_or(0),
        Policy::FullySerial => per_unit.iter().sum(),
    };
    body + lat.epilogue
}

/// Maximum sustainable sample rate (samples/second) at clock `f_hz`:
/// the module is not pipelined, so one sample occupies `latency` cycles.
pub fn max_sample_rate(design: &PiModuleDesign, policy: Policy, f_hz: f64) -> f64 {
    let cycles = module_latency(design, policy).max(1);
    f_hz / cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::{Q16_15, QFormat};
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;

    fn design(id: &str) -> PiModuleDesign {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        ir::build(&a, Q16_15)
    }

    #[test]
    fn q16_15_unit_latencies() {
        let lat = OpLatency::for_format(Q16_15);
        assert_eq!(lat.mul, 33);
        assert_eq!(lat.div, 47);
        assert_eq!(lat.load, 1);
    }

    #[test]
    fn pendulum_latency_matches_paper() {
        // Paper Table 1: static pendulum = 115 cycles.
        let d = design("pendulum");
        assert_eq!(module_latency(&d, Policy::ParallelPerPi), 115);
    }

    #[test]
    fn beam_latency_matches_paper() {
        // Paper Table 1: beam = 115 cycles.
        let d = design("beam");
        assert_eq!(module_latency(&d, Policy::ParallelPerPi), 115);
    }

    #[test]
    fn spring_mass_latency_matches_paper() {
        // Paper Table 1: spring-mass = 115 cycles.
        let d = design("spring_mass");
        assert_eq!(module_latency(&d, Policy::ParallelPerPi), 115);
    }

    #[test]
    fn flight_faster_than_pendulum() {
        // Paper observation: the unpowered-flight module (more signals,
        // more parallel units) concludes *faster* than the pendulum.
        let flight = module_latency(&design("unpowered_flight"), Policy::ParallelPerPi);
        let pendulum = module_latency(&design("pendulum"), Policy::ParallelPerPi);
        assert!(flight < pendulum, "flight={flight} pendulum={pendulum}");
    }

    #[test]
    fn all_under_300_cycles() {
        // Paper: "All modules require less than 300 cycles."
        for e in corpus::corpus() {
            let cycles = module_latency(&design(e.id), Policy::ParallelPerPi);
            assert!(cycles < 300, "{}: {} cycles", e.id, cycles);
        }
    }

    #[test]
    fn sample_rate_over_10k() {
        // Paper: "for both 6 and 12 MHz clocks, the generated hardware can
        // handle sample rates of over 10k samples/second".
        for e in corpus::corpus() {
            let d = design(e.id);
            let rate6 = max_sample_rate(&d, Policy::ParallelPerPi, 6.0e6);
            assert!(rate6 > 10_000.0, "{}: {rate6} samples/s @6MHz", e.id);
        }
    }

    #[test]
    fn serial_policy_is_sum() {
        let d = design("unpowered_flight");
        let par = module_latency(&d, Policy::ParallelPerPi);
        let ser = module_latency(&d, Policy::FullySerial);
        assert!(ser >= par);
        if d.units.len() > 1 {
            assert!(ser > par);
        }
    }

    #[test]
    fn latency_scales_with_width() {
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let narrow = ir::build(&a, QFormat::new(8, 7));
        let wide = ir::build(&a, QFormat::new(24, 23));
        assert!(
            module_latency(&narrow, Policy::ParallelPerPi)
                < module_latency(&wide, Policy::ParallelPerPi)
        );
    }
}
