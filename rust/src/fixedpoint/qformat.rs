//! Signed fixed-point formats.
//!
//! The paper uses a Q16.15 representation: 32 bits — 1 sign bit, 16
//! integer bits, 15 fractional bits — and the compiler backend is "fully
//! parametric with respect to the length of the fixed point representation
//! as well as the precision of the fractional part". [`QFormat`] carries
//! that parameterization through the whole stack: software model, RTL
//! generation, gate-level lowering, and the JAX/Pallas kernels (which bake
//! the same constants into the AOT artifacts).

use std::fmt;

/// A signed fixed-point format: 1 sign bit + `int_bits` + `frac_bits`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct QFormat {
    pub int_bits: u32,
    pub frac_bits: u32,
}

/// The paper's default format: Q16.15 (32-bit words).
pub const Q16_15: QFormat = QFormat { int_bits: 16, frac_bits: 15 };

impl QFormat {
    pub const fn new(int_bits: u32, frac_bits: u32) -> QFormat {
        QFormat { int_bits, frac_bits }
    }

    /// Total word width in bits (sign + integer + fraction).
    pub const fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Scale factor: `2^frac_bits`.
    pub const fn scale(&self) -> i64 {
        1i64 << self.frac_bits
    }

    /// Largest representable raw value: `2^(width-1) - 1`.
    pub const fn max_raw(&self) -> i64 {
        (1i64 << (self.width() - 1)) - 1
    }

    /// Smallest representable raw value: `-2^(width-1)`.
    pub const fn min_raw(&self) -> i64 {
        -(1i64 << (self.width() - 1))
    }

    /// Largest representable real value.
    pub fn max_value(&self) -> f64 {
        self.max_raw() as f64 / self.scale() as f64
    }

    /// Smallest representable real value.
    pub fn min_value(&self) -> f64 {
        self.min_raw() as f64 / self.scale() as f64
    }

    /// Resolution (value of one LSB).
    pub fn epsilon(&self) -> f64 {
        1.0 / self.scale() as f64
    }

    /// Saturate a raw (already scaled) integer into range.
    pub fn saturate(&self, raw: i128) -> i64 {
        let max = self.max_raw() as i128;
        let min = self.min_raw() as i128;
        if raw > max {
            self.max_raw()
        } else if raw < min {
            self.min_raw()
        } else {
            raw as i64
        }
    }

    /// Quantize a real number to the nearest representable raw value
    /// (round half away from zero, saturating).
    pub fn from_f64(&self, v: f64) -> i64 {
        let scaled = v * self.scale() as f64;
        let rounded = if scaled >= 0.0 { (scaled + 0.5).floor() } else { (scaled - 0.5).ceil() };
        if rounded.is_nan() {
            return 0;
        }
        self.saturate(rounded as i128)
    }

    /// Real value of a raw integer.
    pub fn to_f64(&self, raw: i64) -> f64 {
        raw as f64 / self.scale() as f64
    }

    /// Raw representation of 1.0.
    pub const fn one(&self) -> i64 {
        self.scale()
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Q{}.{}", self.int_bits, self.frac_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q16_15_constants() {
        assert_eq!(Q16_15.width(), 32);
        assert_eq!(Q16_15.scale(), 32768);
        assert_eq!(Q16_15.max_raw(), i32::MAX as i64);
        assert_eq!(Q16_15.min_raw(), i32::MIN as i64);
        assert_eq!(Q16_15.one(), 32768);
    }

    #[test]
    fn roundtrip() {
        for v in [0.0, 1.0, -1.0, 3.14159, -2.5, 1000.125] {
            let raw = Q16_15.from_f64(v);
            let back = Q16_15.to_f64(raw);
            assert!((back - v).abs() <= Q16_15.epsilon(), "{v} -> {back}");
        }
    }

    #[test]
    fn rounding_half_away_from_zero() {
        // 0.5 LSB rounds up in magnitude.
        let half_lsb = Q16_15.epsilon() / 2.0;
        assert_eq!(Q16_15.from_f64(half_lsb), 1);
        assert_eq!(Q16_15.from_f64(-half_lsb), -1);
    }

    #[test]
    fn saturation() {
        assert_eq!(Q16_15.from_f64(1e9), Q16_15.max_raw());
        assert_eq!(Q16_15.from_f64(-1e9), Q16_15.min_raw());
        assert_eq!(Q16_15.saturate(i128::MAX), Q16_15.max_raw());
        assert_eq!(Q16_15.saturate(i128::MIN), Q16_15.min_raw());
    }

    #[test]
    fn parametric_formats() {
        let q8_7 = QFormat::new(8, 7);
        assert_eq!(q8_7.width(), 16);
        assert_eq!(q8_7.scale(), 128);
        let q24_23 = QFormat::new(24, 23);
        assert_eq!(q24_23.width(), 48);
        // Max value grows with int bits.
        assert!(q24_23.max_value() > Q16_15.max_value());
        assert!(q8_7.epsilon() > Q16_15.epsilon());
    }

    #[test]
    fn display() {
        assert_eq!(Q16_15.to_string(), "Q16.15");
    }
}
