//! Bit-exact software model of the RTL fixed-point functional units.
//!
//! These functions define the *reference semantics* shared by four
//! implementations which must agree bit-for-bit:
//!
//! 1. this software model (used by tests and the fast native Π path),
//! 2. the cycle-accurate RTL simulator ([`crate::rtl::sim`]),
//! 3. the gate-level netlist produced by [`crate::synth::lower()`],
//! 4. the JAX/Pallas kernel (`python/compile/kernels/pi_kernel.py`),
//!    whose AOT-compiled artifact the runtime executes.
//!
//! Semantics:
//! * **Multiply** — full-width product, round-half-up at the fraction
//!   point (`+2^(f-1)` then arithmetic shift right by `f`), saturate to
//!   the word width. This matches a hardware multiplier with a rounding
//!   adder on the product.
//! * **Divide** — sign-magnitude restoring division of `(|a| << f) / |b|`
//!   (truncating), sign applied afterwards, saturate. Division by zero
//!   saturates to the signed extremum of the dividend's sign (an explicit
//!   hardware flag in the RTL).

use super::qformat::QFormat;

/// The pre-saturation wide product: `round((a*b) / 2^f)` as an `i128`.
///
/// This is [`mul`] without the final saturation — the exact value the
/// hardware's rounding adder produces before the width clamp. The static
/// range analysis ([`crate::analyze::qinterval`]) uses it to detect
/// saturation (`mul_wide(..) > q.max_raw()`) instead of observing the
/// already-clamped result.
pub fn mul_wide(q: QFormat, a: i64, b: i64) -> i128 {
    let prod = (a as i128) * (b as i128);
    let round = 1i128 << (q.frac_bits - 1);
    // Arithmetic shift right after adding the rounding constant: this is
    // round-half-up (toward +inf at .5), identical to the RTL rounding adder.
    (prod + round) >> q.frac_bits
}

/// Fixed-point multiply: `round((a*b) / 2^f)`, saturating.
pub fn mul(q: QFormat, a: i64, b: i64) -> i64 {
    q.saturate(mul_wide(q, a, b))
}

/// Fixed-point divide: `trunc((a << f) / b)` in sign-magnitude, saturating.
///
/// Division by zero returns the saturated extremum matching the sign of
/// the dividend (`max` for `a >= 0`, `min` for `a < 0`), mirroring the
/// RTL's divide-by-zero flag behaviour.
pub fn div(q: QFormat, a: i64, b: i64) -> i64 {
    if b == 0 {
        return if a >= 0 { q.max_raw() } else { q.min_raw() };
    }
    q.saturate(div_wide(q, a, b))
}

/// The pre-saturation wide quotient: `trunc((a << f) / b)` as an `i128`.
///
/// [`div`] without the zero-divisor special case and the final
/// saturation; the caller must guarantee `b != 0`. Used by the static
/// range analysis to detect quotient saturation exactly.
pub fn div_wide(q: QFormat, a: i64, b: i64) -> i128 {
    debug_assert!(b != 0, "div_wide requires a nonzero divisor");
    let na = (a as i128).unsigned_abs() << q.frac_bits;
    let nb = (b as i128).unsigned_abs();
    let quot = (na / nb) as i128;
    if (a < 0) != (b < 0) {
        -quot
    } else {
        quot
    }
}

/// One step of a monomial evaluation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MonOp {
    /// Load symbol `i` into the accumulator (first numerator factor).
    Load(usize),
    /// Load the constant 1.0 (monomials with no numerator).
    LoadOne,
    /// `acc <- acc * symbol[i]`.
    Mul(usize),
    /// `acc <- acc / symbol[i]`.
    Div(usize),
}

/// The canonical serial op schedule for a monomial `∏ sᵢ^eᵢ`:
/// numerator factors first (repeated |e| times), then denominator factors.
/// All implementations (software, RTL, gates, JAX) follow this order, so
/// rounding composes identically everywhere.
pub fn monomial_ops(exponents: &[i64]) -> Vec<MonOp> {
    let mut ops = Vec::new();
    let mut loaded = false;
    for (i, &e) in exponents.iter().enumerate() {
        for _ in 0..e.max(0) {
            if !loaded {
                ops.push(MonOp::Load(i));
                loaded = true;
            } else {
                ops.push(MonOp::Mul(i));
            }
        }
    }
    if !loaded {
        ops.push(MonOp::LoadOne);
    }
    for (i, &e) in exponents.iter().enumerate() {
        for _ in 0..(-e).max(0) {
            ops.push(MonOp::Div(i));
        }
    }
    ops
}

/// Evaluate a monomial over raw fixed-point symbol values using the
/// canonical schedule.
pub fn eval_monomial(q: QFormat, values: &[i64], exponents: &[i64]) -> i64 {
    assert_eq!(values.len(), exponents.len());
    let mut acc = 0i64;
    for op in monomial_ops(exponents) {
        acc = match op {
            MonOp::Load(i) => values[i],
            MonOp::LoadOne => q.one(),
            MonOp::Mul(i) => mul(q, acc, values[i]),
            MonOp::Div(i) => div(q, acc, values[i]),
        };
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::qformat::Q16_15;

    fn q(v: f64) -> i64 {
        Q16_15.from_f64(v)
    }

    fn f(raw: i64) -> f64 {
        Q16_15.to_f64(raw)
    }

    #[test]
    fn mul_basics() {
        assert_eq!(mul(Q16_15, q(2.0), q(3.0)), q(6.0));
        assert_eq!(mul(Q16_15, q(-2.0), q(3.0)), q(-6.0));
        assert_eq!(mul(Q16_15, q(0.5), q(0.5)), q(0.25));
        assert_eq!(mul(Q16_15, 0, q(123.0)), 0);
        // Identity: x * 1 == x exactly.
        for v in [0.125, -7.75, 1000.0] {
            assert_eq!(mul(Q16_15, q(v), Q16_15.one()), q(v));
        }
    }

    #[test]
    fn mul_rounding() {
        // Smallest positive values: lsb * lsb rounds to lsb/32768 ≈ 0,
        // but 0.5 (raw 16384) * lsb (raw 1): product raw = 16384,
        // (16384 + 16384) >> 15 = 1 — rounds up at exactly half.
        assert_eq!(mul(Q16_15, 16384, 1), 1);
        // Just below half rounds down.
        assert_eq!(mul(Q16_15, 16383, 1), 0);
    }

    #[test]
    fn mul_saturates() {
        let big = q(30000.0);
        assert_eq!(mul(Q16_15, big, big), Q16_15.max_raw());
        assert_eq!(mul(Q16_15, big, -big), Q16_15.min_raw());
    }

    #[test]
    fn div_basics() {
        assert_eq!(div(Q16_15, q(6.0), q(3.0)), q(2.0));
        assert_eq!(div(Q16_15, q(-6.0), q(3.0)), q(-2.0));
        assert_eq!(div(Q16_15, q(6.0), q(-3.0)), q(-2.0));
        assert_eq!(div(Q16_15, q(1.0), q(2.0)), q(0.5));
        // Identity: x / 1 == x exactly.
        for v in [0.125, -7.75, 1000.0] {
            assert_eq!(div(Q16_15, q(v), Q16_15.one()), q(v));
        }
    }

    #[test]
    fn div_truncates_toward_zero() {
        // 1/3 in Q16.15: floor(32768*32768 / 32768 / 3)... raw:
        // (32768 << 15) / 98304 = 10922.67 -> 10922 (truncation).
        assert_eq!(div(Q16_15, q(1.0), q(3.0)), 10922);
        // Negative result truncates toward zero (sign-magnitude).
        assert_eq!(div(Q16_15, q(-1.0), q(3.0)), -10922);
    }

    #[test]
    fn div_by_zero_saturates() {
        assert_eq!(div(Q16_15, q(5.0), 0), Q16_15.max_raw());
        assert_eq!(div(Q16_15, q(-5.0), 0), Q16_15.min_raw());
        assert_eq!(div(Q16_15, 0, 0), Q16_15.max_raw());
    }

    #[test]
    fn div_saturates_on_overflow() {
        assert_eq!(div(Q16_15, q(30000.0), 1), Q16_15.max_raw());
    }

    #[test]
    fn wide_forms_agree_with_saturating_forms() {
        // In range, wide == saturating; out of range, wide carries the
        // true magnitude while the narrow form clamps.
        let big = q(30000.0);
        assert_eq!(mul_wide(Q16_15, q(2.0), q(3.0)), q(6.0) as i128);
        assert!(mul_wide(Q16_15, big, big) > Q16_15.max_raw() as i128);
        assert_eq!(mul(Q16_15, big, big), Q16_15.max_raw());
        assert_eq!(div_wide(Q16_15, q(6.0), q(3.0)), q(2.0) as i128);
        assert_eq!(div_wide(Q16_15, q(-6.0), q(3.0)), q(-2.0) as i128);
        assert!(div_wide(Q16_15, big, 1) > Q16_15.max_raw() as i128);
        for (a, b) in [(2.5, 3.0), (-7.0, 0.125), (100.0, -0.5)] {
            let (ra, rb) = (q(a), q(b));
            assert_eq!(mul(Q16_15, ra, rb), Q16_15.saturate(mul_wide(Q16_15, ra, rb)));
            assert_eq!(div(Q16_15, ra, rb), Q16_15.saturate(div_wide(Q16_15, ra, rb)));
        }
    }

    #[test]
    fn monomial_schedule_order() {
        // exponents [2, -1, 0, 1]: load s0, mul s0, mul s3, div s1.
        let ops = monomial_ops(&[2, -1, 0, 1]);
        assert_eq!(
            ops,
            vec![MonOp::Load(0), MonOp::Mul(0), MonOp::Mul(3), MonOp::Div(1)]
        );
    }

    #[test]
    fn monomial_all_negative_uses_one() {
        let ops = monomial_ops(&[-1, -1]);
        assert_eq!(ops, vec![MonOp::LoadOne, MonOp::Div(0), MonOp::Div(1)]);
    }

    #[test]
    fn eval_pendulum_pi() {
        // Π = g t² / l with g=9.81, t=2.0, l=1.5 → 9.81*4/1.5 = 26.16.
        let vals = vec![q(2.0), q(1.5), q(0.3), q(9.81)];
        let exps = vec![2, -1, 0, 1];
        let pi = eval_monomial(Q16_15, &vals, &exps);
        let expected = 9.81 * 4.0 / 1.5;
        assert!((f(pi) - expected).abs() < 1e-2, "got {}", f(pi));
    }

    #[test]
    fn eval_matches_f64_within_tolerance() {
        // Pseudorandom-ish sweep with values in a safe range.
        let exps = vec![1, -2, 1];
        let mut state = 0x1234_5678u32;
        for _ in 0..200 {
            let mut vals = Vec::new();
            let mut expect = 1.0f64;
            let mut es = exps.iter();
            for _ in 0..3 {
                state = state.wrapping_mul(1664525).wrapping_add(1013904223);
                let v = 0.5 + (state >> 16) as f64 / 65536.0 * 7.5; // [0.5, 8)
                let raw = Q16_15.from_f64(v);
                vals.push(raw);
                let e = *es.next().unwrap();
                expect *= Q16_15.to_f64(raw).powi(e as i32);
            }
            let got = f(eval_monomial(Q16_15, &vals, &exps));
            assert!(
                (got - expect).abs() < 0.01 * expect.abs().max(1.0),
                "got {got}, expected {expect}"
            );
        }
    }

    #[test]
    fn parametric_width_q8_7() {
        let q8 = QFormat::new(8, 7);
        let a = q8.from_f64(2.0);
        let b = q8.from_f64(3.0);
        assert_eq!(mul(q8, a, b), q8.from_f64(6.0));
        assert_eq!(div(q8, b, a), q8.from_f64(1.5));
    }
}
