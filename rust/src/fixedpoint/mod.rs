//! Signed fixed-point arithmetic: the paper's Q16.15 representation
//! (parametric in width), with bit-exact multiply/divide semantics shared
//! by the software model, the RTL simulator, the gate-level netlist, and
//! the JAX/Pallas kernels.

pub mod ops;
pub mod qformat;

pub use ops::{div, div_wide, eval_monomial, monomial_ops, mul, mul_wide, MonOp};
pub use qformat::{QFormat, Q16_15};
